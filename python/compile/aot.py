"""AOT export: lower the L2 model (with L1 Pallas kernels inside) to HLO
*text* artifacts the rust runtime loads via PJRT.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts (per PE type ∈ {fp32, int16, lightpe1, lightpe2}):

* ``train_<pe>.hlo.txt`` — one SGD+momentum step:
  ``(conv1, conv2, fc, m1, m2, m3, images, labels)``
  → ``(conv1', conv2', fc', m1', m2', m3', loss)``
* ``eval_<pe>.hlo.txt``  — ``(conv1, conv2, fc, images, labels)``
  → ``(accuracy, loss)``

Plus ``init.hlo.txt`` (zero-arg → initial params), ``batch.hlo.txt``
(``(seed) → (images, labels)`` synthetic batch generator, so the rust
driver needs no RNG of its own), ``kernel_smoke.hlo.txt`` (a small
quantized matmul for runtime unit tests), and ``manifest.json`` describing
every artifact's signature.

Run once via ``make artifacts``; python never executes on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import quant_matmul as qm
from .kernels import ref

PE_TYPES = ref.PE_TYPES


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs():
    return [spec(model.PARAM_SHAPES[k]) for k in model.param_order()]


def batch_specs():
    images = spec((model.BATCH, model.IMG_HW, model.IMG_HW, model.IMG_C))
    labels = spec((model.BATCH,), jnp.int32)
    return images, labels


def train_flat(pe_type):
    """Flat-signature train step (rust passes positional literals)."""

    def fn(conv1, conv2, fc, m1, m2, m3, images, labels):
        params = {"conv1": conv1, "conv2": conv2, "fc": fc}
        momentum = {"conv1": m1, "conv2": m2, "fc": m3}
        loss, grads = jax.value_and_grad(model.loss_fn)(
            params, images, labels, pe_type
        )
        new_m = {k: model.MOMENTUM * momentum[k] + grads[k] for k in params}
        new_p = {k: params[k] - model.LEARNING_RATE * new_m[k] for k in params}
        return (
            new_p["conv1"], new_p["conv2"], new_p["fc"],
            new_m["conv1"], new_m["conv2"], new_m["fc"],
            loss,
        )

    return fn


def eval_flat(pe_type):
    def fn(conv1, conv2, fc, images, labels):
        params = {"conv1": conv1, "conv2": conv2, "fc": fc}
        return model.evaluate(params, images, labels, pe_type)

    return fn


def init_flat():
    params = model.init_params(seed=0)
    return tuple(params[k] for k in model.param_order())


def batch_flat(seed):
    key = jax.random.PRNGKey(seed[0])
    images, labels = model.synthetic_batch(key)
    return images, labels


def kernel_smoke(x, w):
    """A small INT16 quantized matmul — the runtime smoke artifact."""
    scale = ref.act_scale_for(x, "int16")
    w_q = ref.quantize_weights(w, "int16")
    return (qm.quant_matmul_fwd_impl(x, w_q, scale, "int16"),)


def describe(name, in_specs, n_outputs):
    return {
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in in_specs
        ],
        "n_outputs": n_outputs,
    }


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--pe", default="all", help="comma-separated PE types or 'all'"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    pe_types = PE_TYPES if args.pe == "all" else tuple(args.pe.split(","))

    manifest = {
        "batch": model.BATCH,
        "img_hw": model.IMG_HW,
        "img_c": model.IMG_C,
        "num_classes": model.NUM_CLASSES,
        "param_order": model.param_order(),
        "param_shapes": {
            k: list(v) for k, v in model.PARAM_SHAPES.items()
        },
        "artifacts": {},
    }

    def emit(name, fn, in_specs, n_outputs):
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = describe(name, in_specs, n_outputs)
        print(f"wrote {path} ({len(text)} chars)")

    images, labels = batch_specs()

    for pe in pe_types:
        emit(
            f"train_{pe}",
            train_flat(pe),
            param_specs() + param_specs() + [images, labels],
            7,
        )
        emit(f"eval_{pe}", eval_flat(pe), param_specs() + [images, labels], 2)

    emit("init", init_flat, [], len(model.param_order()))
    emit("batch", batch_flat, [spec((1,), jnp.int32)], 2)
    emit(
        "kernel_smoke",
        kernel_smoke,
        [spec((32, 27)), spec((27, 8))],
        1,
    )

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
