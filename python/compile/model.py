"""Layer-2 JAX model: quantization-aware CNN + SGD-momentum train step.

The Fig. 5/6 accuracy axis requires QAT per PE type. Full 200-epoch
CIFAR/ImageNet runs are out of scope on this box (DESIGN.md §1), so the
end-to-end driver trains this compact CNN on synthetic CIFAR-like data —
enough to prove the three-layer stack composes (loss ↓, quantized eval runs
through the PJRT runtime) and to measure the relative accuracy ordering of
the PE types.

Architecture (NHWC, ``IMG_HW``×``IMG_HW``×3 inputs, ``NUM_CLASSES`` way):

    conv3×3(3→C1) → ReLU → avgpool2
  → conv3×3(C1→C2) → ReLU → avgpool2
  → flatten → dense(→NUM_CLASSES)

Every conv/dense runs through the Pallas quantized matmul with the PE
type's quantizer (FP32 is the identity path). The train step is a single
jitted function (SGD + Nesterov-free momentum, the paper's recipe scaled
down) that `aot.py` lowers to HLO text; python never runs at serve time.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import quant_matmul as qm
from .kernels import ref

IMG_HW = 8
IMG_C = 3
C1 = 8
C2 = 16
NUM_CLASSES = 10
BATCH = 32
#: Training recipe (paper §IV-B, scaled to the synthetic task).
LEARNING_RATE = 0.05
MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4

PARAM_SHAPES = {
    "conv1": (3, 3, IMG_C, C1),
    "conv2": (3, 3, C1, C2),
    "fc": ((IMG_HW // 4) * (IMG_HW // 4) * C2, NUM_CLASSES),
}


def init_params(seed=0):
    """He-normal initialization, deterministic from the seed."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in PARAM_SHAPES.items():
        key, sub = jax.random.split(key)
        fan_in = 1
        for d in shape[:-1]:
            fan_in *= d
        params[name] = (
            jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        )
    return params


def init_momentum():
    """Zero momentum buffers matching the parameter tree."""
    return {k: jnp.zeros(v, jnp.float32) for k, v in PARAM_SHAPES.items()}


def avgpool2(x):
    """2×2 average pooling, NHWC."""
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def forward(params, images, pe_type):
    """Logits for a batch of NHWC images under a PE type's quantizers."""
    x = qm.conv2d(images, params["conv1"], pe_type, stride=1, padding=1)
    x = jax.nn.relu(x)
    x = avgpool2(x)
    x = qm.conv2d(x, params["conv2"], pe_type, stride=1, padding=1)
    x = jax.nn.relu(x)
    x = avgpool2(x)
    x = x.reshape(x.shape[0], -1)
    return qm.dense(x, params["fc"], pe_type)


def loss_fn(params, images, labels, pe_type):
    """Softmax cross-entropy with L2 weight decay."""
    logits = forward(params, images, pe_type)
    log_probs = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(log_probs, labels[:, None], axis=1))
    l2 = sum(jnp.sum(w * w) for w in params.values())
    return nll + WEIGHT_DECAY * l2


@partial(jax.jit, static_argnames=("pe_type",), donate_argnums=(0, 1))
def train_step(params, momentum, images, labels, pe_type):
    """One SGD+momentum step; params/momentum buffers are donated so the
    AOT executable updates state in place (no copies on the rust side)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, images, labels, pe_type)
    new_momentum = {
        k: MOMENTUM * momentum[k] + grads[k] for k in params
    }
    new_params = {
        k: params[k] - LEARNING_RATE * new_momentum[k] for k in params
    }
    return new_params, new_momentum, loss


@partial(jax.jit, static_argnames=("pe_type",))
def evaluate(params, images, labels, pe_type):
    """(mean accuracy, mean loss) over one batch."""
    logits = forward(params, images, pe_type)
    accuracy = jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    log_probs = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(log_probs, labels[:, None], axis=1))
    return accuracy, nll


def synthetic_batch(key):
    """A synthetic CIFAR-like batch with learnable class structure: each
    class has a fixed random template; samples are noisy templates. A model
    that learns must beat 1/NUM_CLASSES accuracy quickly."""
    template_key = jax.random.PRNGKey(0xC1FA)  # fixed across batches
    templates = jax.random.normal(
        template_key, (NUM_CLASSES, IMG_HW, IMG_HW, IMG_C), jnp.float32
    )
    label_key, noise_key = jax.random.split(key)
    labels = jax.random.randint(label_key, (BATCH,), 0, NUM_CLASSES)
    noise = 0.6 * jax.random.normal(
        noise_key, (BATCH, IMG_HW, IMG_HW, IMG_C), jnp.float32
    )
    return templates[labels] + noise, labels


def param_order():
    """Canonical parameter ordering used by the AOT interface (the rust
    runtime passes flat argument lists)."""
    return ["conv1", "conv2", "fc"]


def flatten_state(params, momentum):
    """Flat argument list in the AOT calling convention."""
    return [params[k] for k in param_order()] + [momentum[k] for k in param_order()]


def unflatten_state(flat):
    """Inverse of :func:`flatten_state`."""
    names = param_order()
    params = dict(zip(names, flat[: len(names)]))
    momentum = dict(zip(names, flat[len(names) :]))
    return params, momentum
