"""Layer-1 Pallas kernel: quantization-aware tiled matmul.

The paper's compute hot-spot is the PE array's quantized MAC loop. On TPU
the row-stationary PE grid becomes a VMEM-tiled MXU matmul (DESIGN.md
§Hardware-Adaptation): `BlockSpec` expresses the GLB→scratchpad schedule the
paper's dataflow expresses with strips, the per-PE activation quantizer is
fused into the tile prologue (so quantize-dequantize never round-trips to
HBM), and accumulation is f32 in the output tile, matching the wide psum
scratchpad.

Weights arrive **pre-quantized** (`ref.quantize_weights`) exactly as the
hardware receives them — weight quantization is an offline step.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; on a real TPU the same kernel lowers to MXU ops. Block shapes
are chosen MXU-aligned (multiples of 8×128 where the problem allows) so the
TPU estimate in EXPERIMENTS.md §Perf is meaningful.

The kernel is differentiable via a custom VJP (straight-through estimator
through the activation quantizer), with both backward matmuls also running
through the Pallas kernel — QAT training lowers to Pallas end to end.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile shapes: MXU-friendly (8×128 lanes); clipped to the problem.
BLOCK_M = 128
BLOCK_N = 128


def _ceil_to(x, m):
    return -(-x // m) * m


def _kernel(x_ref, w_ref, scale_ref, o_ref, *, pe_type):
    """One (bm, bn) output tile: fake-quant the x tile, full-K matmul."""
    x_tile = x_ref[...]
    if pe_type != "fp32":
        bits = ref.ACT_BITS[pe_type]
        qmax = float(2 ** (bits - 1) - 1)
        scale = scale_ref[0, 0]
        x_tile = jnp.clip(jnp.round(x_tile / scale), -qmax, qmax) * scale
    o_ref[...] = jnp.dot(x_tile, w_ref[...], preferred_element_type=jnp.float32)


def _pad_to(x, rows, cols):
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@partial(jax.jit, static_argnames=("pe_type", "block_m", "block_n"))
def quant_matmul_fwd_impl(x, w_q, act_scale, pe_type, block_m=BLOCK_M, block_n=BLOCK_N):
    """Forward quantized matmul via `pallas_call` (non-differentiable core).

    ``x: (M, K) f32``, ``w_q: (K, N) f32`` (pre-quantized values),
    ``act_scale: () f32`` → ``(M, N) f32``.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    m_pad = _ceil_to(m, bm)
    n_pad = _ceil_to(n, bn)
    x_p = _pad_to(x, m_pad, k)
    w_p = _pad_to(w_q, k, n_pad)
    scale_arr = jnp.reshape(act_scale.astype(jnp.float32), (1, 1))
    grid = (m_pad // bm, n_pad // bn)
    out = pl.pallas_call(
        partial(_kernel, pe_type=pe_type),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x_p, w_p, scale_arr)
    return out[:m, :n]


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def quant_matmul(x, w_q, act_scale, pe_type):
    """Differentiable quantized matmul (straight-through estimator).

    Forward: fake-quant(x) @ w_q with f32 accumulation, on the Pallas
    kernel. Backward: STE passes gradients through the quantizer; both
    gradient matmuls reuse the Pallas kernel in fp32 mode.
    """
    return quant_matmul_fwd_impl(x, w_q, act_scale, pe_type)


def _fwd(x, w_q, act_scale, pe_type):
    out = quant_matmul_fwd_impl(x, w_q, act_scale, pe_type)
    return out, (x, w_q, act_scale)


def _bwd(pe_type, residuals, g):
    x, w_q, act_scale = residuals
    one = jnp.float32(1.0)
    # dL/dx = g @ w_qᵀ (STE: quantizer treated as identity inside the
    # clipped range; the clip mask is second-order and omitted, standard QAT).
    dx = quant_matmul_fwd_impl(g, w_q.T, one, "fp32")
    # dL/dw_q = fake_quant(x)ᵀ @ g — gradient w.r.t. the *quantized* weight,
    # which the weight-STE then carries to the latent fp32 weight.
    x_q = ref.fake_quant_act(x, act_scale, pe_type)
    dw = quant_matmul_fwd_impl(x_q.T, g, one, "fp32")
    return dx, dw, jnp.zeros_like(act_scale)


quant_matmul.defvjp(_fwd, _bwd)


def conv2d(x, w, pe_type, stride=1, padding=1):
    """Quantized conv: im2col + Pallas quant matmul (the L2 building block).

    ``x: (N, H, W, C)``, ``w: (k, k, C, M)`` → ``(N, out, out, M)``.
    Weight quantization applies the straight-through estimator so the layer
    is trainable.
    """
    k, _, c, m = w.shape
    w_q = ref.quantize_weights_ste(w, pe_type).reshape(k * k * c, m)
    patches, out_hw = ref.im2col(x, k, stride, padding)
    scale = jax.lax.stop_gradient(ref.act_scale_for(patches, pe_type))
    out = quant_matmul(patches, w_q, scale, pe_type)
    return out.reshape(x.shape[0], out_hw, out_hw, m)


def dense(x, w, pe_type):
    """Quantized fully-connected layer: ``x: (N, K)``, ``w: (K, M)``."""
    w_q = ref.quantize_weights_ste(w, pe_type)
    scale = jax.lax.stop_gradient(ref.act_scale_for(x, pe_type))
    return quant_matmul(x, w_q, scale, pe_type)


def vmem_footprint_bytes(m, k, n, block_m=BLOCK_M, block_n=BLOCK_N):
    """Estimated VMEM working set of one grid step (f32): x tile + w tile +
    out tile. Used by the §Perf TPU estimate (interpret mode has no VMEM)."""
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    return 4 * (bm * k + k * bn + bm * bn)


def mxu_utilization_estimate(m, k, n, block_m=BLOCK_M, block_n=BLOCK_N):
    """Fraction of MXU lanes a (bm, K)×(K, bn) tile keeps busy (128×128
    systolic array, 8-row granularity): edge-tile waste only."""
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    m_pad = _ceil_to(m, bm)
    n_pad = _ceil_to(n, bn)
    useful = m * k * n
    issued = m_pad * k * n_pad
    return useful / issued
