"""Pure-jnp oracle for the quantization-aware kernels (Layer-1 reference).

Semantics mirror the rust golden model (`rust/src/quant/`) exactly:

* round-to-nearest-ties-even (``jnp.round``) symmetric affine quantization,
* power-of-two weight codebooks for the LightPE types built by exhaustive
  nearest-value search over singles (LightPE-1) or singles + two-term sums
  (LightPE-2) of seven exponents anchored at the tensor's max-abs,
* f32 accumulation (the psum scratchpad is wide enough to be exact).

Everything here is build-time only; nothing imports from the runtime path.
"""

from functools import partial

import jax
import jax.numpy as jnp

PE_TYPES = ("fp32", "int16", "lightpe1", "lightpe2")

#: Activation bit width per PE type (paper §III-B).
ACT_BITS = {"fp32": 32, "int16": 16, "lightpe1": 8, "lightpe2": 8}
#: Number of distinct exponents in the LightPE codebooks (rust `levels`).
PO2_LEVELS = 7


def act_scale_for(x, pe_type):
    """Per-tensor symmetric activation scale (max-abs calibration)."""
    if pe_type == "fp32":
        return jnp.float32(1.0)
    bits = ACT_BITS[pe_type]
    qmax = float(2 ** (bits - 1) - 1)
    max_abs = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    return (max_abs / qmax).astype(jnp.float32)


def fake_quant_act(x, scale, pe_type):
    """Fake-quantize activations: round-ties-even, clip, rescale."""
    if pe_type == "fp32":
        return x
    bits = ACT_BITS[pe_type]
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q * scale


def po2_codebook(max_abs, pe_type):
    """Representable weight magnitudes for a LightPE type.

    Exponents span ``[e_max - 6, e_max]`` with ``e_max = ceil(log2(max_abs))``
    (rust `Po2Quantizer::calibrate`). LightPE-1: singles; LightPE-2: singles
    plus all two-term sums ``2^e1 + 2^e2`` with ``e2 < e1``. Zero included.
    """
    e_max = jnp.ceil(jnp.log2(jnp.maximum(max_abs, 1e-12)))
    exps = e_max - jnp.arange(PO2_LEVELS, dtype=jnp.float32)  # e_max .. e_max-6
    singles = 2.0 ** exps
    if pe_type == "lightpe1":
        mags = singles
    elif pe_type == "lightpe2":
        pair_sums = singles[:, None] + singles[None, :]
        upper = jnp.triu(pair_sums, k=1)  # e2 < e1 strictly
        mags = jnp.concatenate([singles, upper[jnp.triu_indices(PO2_LEVELS, k=1)]])
    else:
        raise ValueError(f"not a LightPE type: {pe_type}")
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), mags.astype(jnp.float32)])


def quantize_weights(w, pe_type):
    """Quantize a weight tensor with the PE type's hardware semantics.

    Returns the value-domain quantized weights (what the shift-add or
    integer datapath effectively multiplies by).
    """
    if pe_type == "fp32":
        return w
    if pe_type == "int16":
        qmax = float(2**15 - 1)
        max_abs = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)
        scale = max_abs / qmax
        return jnp.clip(jnp.round(w / scale), -qmax, qmax) * scale
    # LightPE: nearest codebook value, sign restored. Exact zero below the
    # half-step of the smallest magnitude (rust `zero_threshold`).
    max_abs = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)
    codebook = po2_codebook(max_abs, pe_type)  # (V,)
    mag = jnp.abs(w)
    distance = jnp.abs(mag[..., None] - codebook)  # (..., V)
    nearest = codebook[jnp.argmin(distance, axis=-1)]
    return jnp.sign(w) * nearest


def quantize_weights_ste(w, pe_type):
    """Weight fake-quant with a straight-through gradient estimator."""
    return w + jax.lax.stop_gradient(quantize_weights(w, pe_type) - w)


@partial(jax.jit, static_argnames=("pe_type",))
def quant_matmul_ref(x, w_q, act_scale, pe_type):
    """Reference quantized matmul: fake-quant activations × pre-quantized
    weights, f32 accumulation. ``x: (M, K)``, ``w_q: (K, N)``."""
    x_q = fake_quant_act(x, act_scale, pe_type)
    return jnp.dot(x_q, w_q, preferred_element_type=jnp.float32)


def im2col(x, kernel, stride, padding):
    """Unfold NHWC feature maps into matmul rows.

    Returns ``(patches, out_hw)`` where ``patches`` has shape
    ``(N * out_hw * out_hw, kernel * kernel * C)`` matching the weight
    matrix layout ``(kernel * kernel * C, M)``.
    """
    n, h, w, c = x.shape
    x_pad = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    out_hw = (h + 2 * padding - kernel) // stride + 1
    idx = jnp.arange(out_hw) * stride
    # Gather kernel×kernel windows: (N, out, out, k, k, C).
    rows = idx[:, None] + jnp.arange(kernel)[None, :]  # (out, k)
    patches = x_pad[:, rows[:, None, :, None], rows[None, :, None, :], :]
    patches = patches.transpose(0, 1, 2, 3, 4, 5)  # (N, out, out, k, k, C)
    return patches.reshape(n * out_hw * out_hw, kernel * kernel * c), out_hw


def conv2d_ref(x, w, pe_type, stride=1, padding=1):
    """Quantized conv via im2col + the reference matmul.

    ``x: (N, H, W, C)``, ``w: (k, k, C, M)`` → ``(N, out, out, M)``.
    """
    k = w.shape[0]
    m = w.shape[3]
    w_q = quantize_weights(w, pe_type).reshape(k * k * w.shape[2], m)
    patches, out_hw = im2col(x, k, stride, padding)
    scale = act_scale_for(patches, pe_type)
    out = quant_matmul_ref(patches, w_q, scale, pe_type)
    return out.reshape(x.shape[0], out_hw, out_hw, m)
