"""Quantizer semantics: properties + exact cross-checks against the rust
golden model's documented behaviour (rust/src/quant/quantizer.rs)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

floats = st.floats(
    min_value=-4.0, max_value=4.0, allow_nan=False, allow_infinity=False, width=32
)


@settings(max_examples=50, deadline=None)
@given(st.lists(floats, min_size=2, max_size=64))
def test_affine_roundtrip_error_bounded(values):
    w = jnp.array(values, jnp.float32)
    w_q = ref.quantize_weights(w, "int16")
    max_abs = float(jnp.max(jnp.abs(w)))
    if max_abs < 1e-9:
        np.testing.assert_array_equal(np.asarray(w_q), np.zeros_like(values))
        return
    step = max_abs / (2**15 - 1)
    err = np.max(np.abs(np.asarray(w_q) - np.asarray(w)))
    assert err <= step / 2 + 1e-7, f"err {err} > half-step {step / 2}"


@settings(max_examples=50, deadline=None)
@given(st.lists(floats, min_size=2, max_size=64), st.sampled_from(["lightpe1", "lightpe2"]))
def test_po2_outputs_are_representable(values, pe_type):
    """Every quantized weight must be ±(sum of ≤ shift_count powers of 2)."""
    w = jnp.array(values, jnp.float32)
    max_abs = float(jnp.max(jnp.abs(w)))
    if max_abs < 1e-9:
        return
    codebook = np.asarray(ref.po2_codebook(jnp.float32(max_abs), pe_type))
    w_q = np.asarray(ref.quantize_weights(w, pe_type))
    for v in w_q.ravel():
        assert np.any(np.isclose(abs(v), codebook, rtol=1e-6, atol=1e-12)), (
            f"{v} not representable for {pe_type}"
        )


def test_lightpe2_superset_of_lightpe1():
    """LightPE-2's codebook contains LightPE-1's → error never worse."""
    cb1 = np.asarray(ref.po2_codebook(jnp.float32(1.0), "lightpe1"))
    cb2 = np.asarray(ref.po2_codebook(jnp.float32(1.0), "lightpe2"))
    for v in cb1:
        assert np.any(np.isclose(v, cb2)), f"{v} missing from LightPE-2 codebook"


@settings(max_examples=30, deadline=None)
@given(st.lists(floats, min_size=4, max_size=64))
def test_lightpe2_error_not_worse_than_lightpe1(values):
    w = jnp.array(values, jnp.float32)
    if float(jnp.max(jnp.abs(w))) < 1e-9:
        return
    err1 = np.abs(np.asarray(ref.quantize_weights(w, "lightpe1")) - np.asarray(w)).sum()
    err2 = np.abs(np.asarray(ref.quantize_weights(w, "lightpe2")) - np.asarray(w)).sum()
    assert err2 <= err1 + 1e-6


def test_po2_exact_on_powers_of_two():
    """Mirrors rust `po2_exact_on_powers`."""
    w = jnp.array([1.0, 0.5, 0.25, 0.125, -0.5], jnp.float32)
    w_q = np.asarray(ref.quantize_weights(w, "lightpe1"))
    np.testing.assert_allclose(w_q, np.asarray(w), rtol=1e-7)


def test_po2_two_term_exact_on_sums():
    """0.75 = 2⁻¹ + 2⁻² — exact for LightPE-2, inexact for LightPE-1
    (mirrors rust `po2_two_term_beats_one_term`)."""
    w = jnp.array([0.75, 1.0], jnp.float32)
    err2 = abs(float(ref.quantize_weights(w, "lightpe2")[0]) - 0.75)
    err1 = abs(float(ref.quantize_weights(w, "lightpe1")[0]) - 0.75)
    assert err2 < 1e-7
    assert err1 > 1e-3


def test_round_ties_even_semantics():
    """jnp.round is ties-to-even — the rust AffineQuantizer contract."""
    vals = jnp.array([0.5, 1.5, 2.5, -0.5, -1.5], jnp.float32)
    got = np.asarray(jnp.round(vals))
    np.testing.assert_array_equal(got, [0.0, 2.0, 2.0, -0.0, -2.0])


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(ref.PE_TYPES), st.integers(0, 500))
def test_fake_quant_idempotent(pe_type, seed):
    """Quantizing an already-quantized tensor is the identity."""
    w = jnp.array(
        np.random.RandomState(seed).randn(24).astype(np.float32) * 0.5
    )
    once = ref.quantize_weights(w, pe_type)
    twice = ref.quantize_weights(once, pe_type)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)


def test_act_scale_covers_max():
    x = jnp.array([[3.0, -7.0], [1.0, 2.0]], jnp.float32)
    for pe_type in ("int16", "lightpe1"):
        bits = ref.ACT_BITS[pe_type]
        scale = float(ref.act_scale_for(x, pe_type))
        qmax = 2 ** (bits - 1) - 1
        assert abs(scale * qmax - 7.0) < 1e-5
