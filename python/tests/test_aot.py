"""AOT artifact tests: signatures, manifest consistency, HLO-text format.

These run after `make artifacts`; if artifacts are missing they exercise
the lowering path in-memory instead (so `pytest` is self-contained).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_in_memory():
    """The lowering path must produce parseable-looking HLO text with the
    right entry signature, without touching the filesystem."""
    lowered = jax.jit(aot.kernel_smoke).lower(
        aot.spec((32, 27)), aot.spec((27, 8))
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[32,27]" in text
    assert "f32[27,8]" in text


def test_train_flat_signature_consistent():
    """Flat train step: output pytree arity and shapes match the manifest
    convention (params, momenta, loss)."""
    fn = aot.train_flat("int16")
    images, labels = aot.batch_specs()
    out = jax.eval_shape(
        fn,
        *aot.param_specs(),
        *aot.param_specs(),
        images,
        labels,
    )
    assert len(out) == 7
    for spec_out, name in zip(out[:3], model.param_order()):
        assert tuple(spec_out.shape) == tuple(model.PARAM_SHAPES[name])
    assert out[6].shape == ()


def test_eval_flat_signature():
    fn = aot.eval_flat("fp32")
    images, labels = aot.batch_specs()
    out = jax.eval_shape(fn, *aot.param_specs(), images, labels)
    assert len(out) == 2
    assert out[0].shape == () and out[1].shape == ()


def test_train_step_numerics_match_model_module():
    """The flat AOT wrapper must compute the same update as model.train_step
    (guards against argument-ordering bugs in the AOT interface)."""
    params = model.init_params()
    momentum = model.init_momentum()
    images, labels = model.synthetic_batch(jax.random.PRNGKey(5))
    flat_out = aot.train_flat("int16")(
        params["conv1"], params["conv2"], params["fc"],
        momentum["conv1"], momentum["conv2"], momentum["fc"],
        images, labels,
    )
    ref_params, ref_momentum, ref_loss = model.train_step(
        dict(params), dict(momentum), images, labels, "int16"
    )
    np.testing.assert_allclose(
        np.asarray(flat_out[0]), np.asarray(ref_params["conv1"]), atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(flat_out[5]), np.asarray(ref_momentum["fc"]), atol=1e-6
    )
    assert abs(float(flat_out[6]) - float(ref_loss)) < 1e-6


def test_batch_generator_deterministic_per_seed():
    a_images, a_labels = aot.batch_flat(jnp.array([7], jnp.int32))
    b_images, b_labels = aot.batch_flat(jnp.array([7], jnp.int32))
    np.testing.assert_array_equal(np.asarray(a_images), np.asarray(b_images))
    np.testing.assert_array_equal(np.asarray(a_labels), np.asarray(b_labels))
    c_images, _ = aot.batch_flat(jnp.array([8], jnp.int32))
    assert not np.array_equal(np.asarray(a_images), np.asarray(c_images))


def test_kernel_smoke_matches_ref():
    x = jnp.array(np.random.RandomState(0).randn(32, 27), jnp.float32)
    w = jnp.array(np.random.RandomState(1).randn(27, 8) * 0.3, jnp.float32)
    (got,) = aot.kernel_smoke(x, w)
    w_q = ref.quantize_weights(w, "int16")
    scale = ref.act_scale_for(x, "int16")
    want = ref.quant_matmul_ref(x, w_q, scale, "int16")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_covers_all_artifacts():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    expected = (
        [f"train_{pe}" for pe in ref.PE_TYPES]
        + [f"eval_{pe}" for pe in ref.PE_TYPES]
        + ["init", "batch", "kernel_smoke"]
    )
    for name in expected:
        assert name in manifest["artifacts"], name
        path = os.path.join(ARTIFACTS, manifest["artifacts"][name]["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


@needs_artifacts
def test_manifest_shapes_match_model():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["batch"] == model.BATCH
    assert manifest["img_hw"] == model.IMG_HW
    assert manifest["param_order"] == model.param_order()
    train = manifest["artifacts"]["train_int16"]
    assert len(train["inputs"]) == 8  # 3 params + 3 momenta + images + labels
    assert train["inputs"][6]["shape"] == [
        model.BATCH,
        model.IMG_HW,
        model.IMG_HW,
        model.IMG_C,
    ]
