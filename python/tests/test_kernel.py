"""L1 kernel correctness: Pallas quant_matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes, PE types, block sizes and value ranges; every
case asserts allclose against `ref.quant_matmul_ref` (the project's
required L1 validation contract).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant_matmul as qm
from compile.kernels import ref

ATOL = 2e-4
RTOL = 2e-4


def rand(shape, seed, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


def run_pair(m, k, n, pe_type, seed, block_m=qm.BLOCK_M, block_n=qm.BLOCK_N):
    x = jnp.array(rand((m, k), seed))
    w = jnp.array(rand((k, n), seed + 1, scale=0.4))
    w_q = ref.quantize_weights(w, pe_type)
    scale = ref.act_scale_for(x, pe_type)
    got = qm.quant_matmul_fwd_impl(x, w_q, scale, pe_type, block_m, block_n)
    want = ref.quant_matmul_ref(x, w_q, scale, pe_type)
    return np.asarray(got), np.asarray(want)


@pytest.mark.parametrize("pe_type", ref.PE_TYPES)
def test_kernel_matches_ref_basic(pe_type):
    got, want = run_pair(32, 27, 8, pe_type, seed=0)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 40),
    pe_type=st.sampled_from(ref.PE_TYPES),
    seed=st.integers(0, 1000),
)
def test_kernel_matches_ref_shape_sweep(m, k, n, pe_type, seed):
    got, want = run_pair(m, k, n, pe_type, seed)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(
    block_m=st.sampled_from([8, 16, 64, 128]),
    block_n=st.sampled_from([128, 256]),
    pe_type=st.sampled_from(ref.PE_TYPES),
)
def test_block_shape_invariance(block_m, block_n, pe_type):
    """Tiling must not change numerics (padding handled correctly)."""
    got, want = run_pair(50, 33, 17, pe_type, seed=3, block_m=block_m, block_n=block_n)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(scale_exp=st.integers(-6, 4), pe_type=st.sampled_from(ref.PE_TYPES))
def test_value_range_sweep(scale_exp, pe_type):
    """Numerics hold across input magnitudes (scale calibration tracks)."""
    factor = float(2.0**scale_exp)
    x = jnp.array(rand((16, 24), 7) * factor)
    w = jnp.array(rand((24, 12), 8, scale=0.4) * factor)
    w_q = ref.quantize_weights(w, pe_type)
    scale = ref.act_scale_for(x, pe_type)
    got = qm.quant_matmul_fwd_impl(x, w_q, scale, pe_type)
    want = ref.quant_matmul_ref(x, w_q, scale, pe_type)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=ATOL * factor * factor, rtol=RTOL
    )


def test_zero_inputs():
    for pe_type in ref.PE_TYPES:
        x = jnp.zeros((8, 8), jnp.float32)
        w = jnp.zeros((8, 8), jnp.float32)
        w_q = ref.quantize_weights(w, pe_type)
        scale = ref.act_scale_for(x, pe_type)
        out = qm.quant_matmul_fwd_impl(x, w_q, scale, pe_type)
        assert np.all(np.asarray(out) == 0.0)


def test_gradients_flow_through_ste():
    """The custom VJP must deliver finite, nonzero grads for both operands."""
    x = jnp.array(rand((16, 12), 1))
    w = jnp.array(rand((12, 8), 2, scale=0.4))

    def loss(x_, w_):
        w_q = ref.quantize_weights_ste(w_, "int16")
        scale = jax.lax.stop_gradient(ref.act_scale_for(x_, "int16"))
        return jnp.sum(qm.quant_matmul(x_, w_q, scale, "int16") ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert np.all(np.isfinite(gx)) and np.all(np.isfinite(gw))
    assert float(jnp.abs(gx).max()) > 0.0
    assert float(jnp.abs(gw).max()) > 0.0


def test_ste_gradient_matches_fp_path_shape():
    """STE: dL/dx ≈ g @ w_qᵀ — verify against a manual computation."""
    x = jnp.array(rand((8, 6), 3))
    w = jnp.array(rand((6, 4), 4, scale=0.4))
    w_q = ref.quantize_weights(w, "int16")
    scale = ref.act_scale_for(x, "int16")

    def loss(x_):
        return jnp.sum(qm.quant_matmul(x_, w_q, scale, "int16"))

    gx = jax.grad(loss)(x)
    manual = jnp.ones((8, 4)) @ w_q.T
    np.testing.assert_allclose(np.asarray(gx), np.asarray(manual), atol=1e-5)


def test_conv2d_matches_ref():
    x = jnp.array(rand((2, 8, 8, 3), 5))
    w = jnp.array(rand((3, 3, 3, 4), 6, scale=0.3))
    for pe_type in ref.PE_TYPES:
        got = qm.conv2d(x, w, pe_type)
        want = ref.conv2d_ref(x, w, pe_type)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=ATOL, rtol=RTOL
        )


def test_vmem_footprint_and_mxu_estimates():
    """§Perf helpers: sane ranges and monotonicity."""
    fp = qm.vmem_footprint_bytes(256, 64, 256)
    assert 0 < fp < 16 * 1024 * 1024, "tile must fit VMEM (16 MiB)"
    # Aligned problems hit 100% MXU-lane utilization; ragged ones less.
    assert qm.mxu_utilization_estimate(128, 64, 128) == 1.0
    ragged = qm.mxu_utilization_estimate(130, 64, 130)
    assert 0.0 < ragged < 1.0
