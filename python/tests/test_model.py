"""L2 model tests: shapes, loss behaviour, and short QAT training runs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def batch(seed=0):
    return model.synthetic_batch(jax.random.PRNGKey(seed))


@pytest.mark.parametrize("pe_type", ref.PE_TYPES)
def test_forward_shapes(pe_type):
    params = model.init_params()
    images, _ = batch()
    logits = model.forward(params, images, pe_type)
    assert logits.shape == (model.BATCH, model.NUM_CLASSES)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_loss_positive_and_finite():
    params = model.init_params()
    images, labels = batch()
    for pe_type in ref.PE_TYPES:
        loss = float(model.loss_fn(params, images, labels, pe_type))
        assert np.isfinite(loss) and loss > 0.0


def test_initial_loss_near_chance():
    """Untrained model ≈ uniform predictions → loss ≈ ln(10)."""
    params = model.init_params()
    images, labels = batch()
    loss = float(model.loss_fn(params, images, labels, "fp32"))
    assert abs(loss - np.log(model.NUM_CLASSES)) < 0.8, loss


@pytest.mark.parametrize("pe_type", ["fp32", "lightpe1"])
def test_training_reduces_loss(pe_type):
    """A short QAT run must reduce the loss for both the float path and the
    most aggressive quantizer (the STE must deliver useful gradients)."""
    params = model.init_params()
    momentum = model.init_momentum()
    losses = []
    for step in range(30):
        images, labels = batch(step)
        params, momentum, loss = model.train_step(
            params, momentum, images, labels, pe_type
        )
        losses.append(float(loss))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.8, f"{pe_type}: loss {first:.3f} → {last:.3f}"


def test_trained_accuracy_beats_chance():
    params = model.init_params()
    momentum = model.init_momentum()
    for step in range(40):
        images, labels = batch(step)
        params, momentum, _ = model.train_step(
            params, momentum, images, labels, "int16"
        )
    images, labels = batch(999)
    accuracy, _ = model.evaluate(params, images, labels, "int16")
    assert float(accuracy) > 2.0 / model.NUM_CLASSES, float(accuracy)


def test_synthetic_batches_are_learnable_structure():
    """Same label ⇒ same template: distances within a class are smaller."""
    images, labels = batch(0)
    images = np.asarray(images).reshape(model.BATCH, -1)
    labels = np.asarray(labels)
    same, diff = [], []
    for i in range(model.BATCH):
        for j in range(i + 1, model.BATCH):
            d = np.linalg.norm(images[i] - images[j])
            (same if labels[i] == labels[j] else diff).append(d)
    if same and diff:
        assert np.mean(same) < np.mean(diff)


def test_state_flatten_roundtrip():
    params = model.init_params()
    momentum = model.init_momentum()
    flat = model.flatten_state(params, momentum)
    p2, m2 = model.unflatten_state(flat)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(p2[k]))
        np.testing.assert_array_equal(np.asarray(momentum[k]), np.asarray(m2[k]))


def test_avgpool_halves_dims():
    x = jnp.arange(2 * 8 * 8 * 3, dtype=jnp.float32).reshape(2, 8, 8, 3)
    out = model.avgpool2(x)
    assert out.shape == (2, 4, 4, 3)
    # Top-left 2×2 window average, channel 0.
    want = float(x[0, 0:2, 0:2, 0].mean())
    assert abs(float(out[0, 0, 0, 0]) - want) < 1e-6
