//! Design-space exploration campaign (the Fig. 4 workflow).
//!
//! Shards the full design space across a worker pool, evaluates every
//! (config × model) pair for a dataset, normalizes against the best INT16
//! configuration, prints the per-model headline ratios and the dataset
//! geomean — the numbers §IV-A quotes (4.8×/4.1× perf/area, 4.7×/4× energy).
//!
//! Run: `cargo run --release --example dse_sweep [-- cifar10|cifar100|imagenet]`

use qadam::arch::SweepSpec;
use qadam::coordinator::{default_workers, Coordinator};
use qadam::dnn::Dataset;
use qadam::dse;
use qadam::util::table::{format_sig, Table};

fn main() {
    let dataset = std::env::args()
        .nth(1)
        .and_then(|arg| Dataset::parse(&arg))
        .unwrap_or(Dataset::Cifar10);
    let spec = SweepSpec::default();
    let coordinator = Coordinator::new(default_workers(), 7);
    println!(
        "exploring {} design points x {} models on {} workers...",
        spec.len(),
        dataset.paper_models().len(),
        coordinator.workers
    );
    let db = coordinator.campaign(&spec, dataset);
    println!(
        "done in {:.2}s ({:.0} evaluations/s)\n",
        db.stats.wall_seconds,
        db.stats.evals_per_sec()
    );

    let mut table = Table::new(&["model", "pe", "perf/area gain", "energy gain", "best config"]);
    for space in &db.spaces {
        for (pe, ppa_gain, energy_gain) in dse::headline_ratios(&space.evals) {
            let best = dse::best_perf_per_area(&space.evals, pe).unwrap();
            table.row(&[
                space.model_name.clone(),
                pe.name().into(),
                format_sig(ppa_gain, 3),
                format_sig(energy_gain, 3),
                best.config.id(),
            ]);
        }
    }
    print!("{}", table.render());

    println!("\n{} geomean vs best INT16 (paper: L1 4.8x/4.7x, L2 4.1x/4.0x):", dataset.name());
    for (pe, ppa, energy) in db.headline_geomean() {
        println!(
            "  {:<10} {}x perf/area   {}x less energy",
            pe.name(),
            format_sig(ppa, 3),
            format_sig(energy, 3)
        );
    }
}
