//! Design-space exploration campaign (the Fig. 4 workflow) through the
//! unified [`Explorer`] API.
//!
//! Streams the design space across a worker pool with live progress,
//! evaluates every (config × model) pair for a dataset, normalizes against
//! the best INT16 configuration, prints the per-model headline ratios and
//! the dataset geomean — the numbers §IV-A quotes (4.8×/4.1× perf/area,
//! 4.7×/4× energy).
//!
//! Run: `cargo run --release --example dse_sweep [-- cifar10|cifar100|imagenet]`

use qadam::arch::SweepSpec;
use qadam::dnn::Dataset;
use qadam::dse;
use qadam::explore::Explorer;
use qadam::util::table::{format_sig, Table};

fn main() -> qadam::Result<()> {
    let dataset = std::env::args()
        .nth(1)
        .and_then(|arg| Dataset::parse(&arg))
        .unwrap_or(Dataset::Cifar10);
    let spec = SweepSpec::default();
    let explorer = Explorer::over(spec.clone()).dataset(dataset).seed(7);
    println!(
        "exploring {} design points x {} models...",
        explorer.design_points(),
        dataset.paper_models().len(),
    );

    // Streaming pass: consume design points as they finish (no full-space
    // buffering) — here just a progress line every 100 points.
    let progress_every = 100;
    let stats = explorer.stream(|point| {
        if (point.index + 1) % progress_every == 0 {
            println!("  evaluated {:>5} / {} design points", point.index + 1, spec.len());
        }
    })?;
    println!(
        "streamed {} points in {:.2}s ({:.0} evaluations/s)",
        stats.design_points,
        stats.wall_seconds,
        stats.evals_per_sec()
    );

    // Aggregated pass for the figure products (same pipeline, same seed,
    // bit-identical results).
    let db = explorer.run()?;
    println!(
        "aggregated in {:.2}s ({:.0} evaluations/s)\n",
        db.stats.wall_seconds,
        db.stats.evals_per_sec()
    );

    let mut table = Table::new(&["model", "pe", "perf/area gain", "energy gain", "best config"]);
    for space in &db.spaces {
        for (pe, ppa_gain, energy_gain) in dse::headline_ratios(&space.evals)? {
            let best = dse::best_perf_per_area(&space.evals, pe)
                .expect("headline ratios imply a best config");
            table.row(&[
                space.model_name.clone(),
                pe.name().into(),
                format_sig(ppa_gain, 3),
                format_sig(energy_gain, 3),
                best.config.id(),
            ]);
        }
    }
    print!("{}", table.render());

    println!("\n{} geomean vs best INT16 (paper: L1 4.8x/4.7x, L2 4.1x/4.0x):", dataset.name());
    for (pe, ppa, energy) in db.headline_geomean()? {
        println!(
            "  {:<10} {}x perf/area   {}x less energy",
            pe.name(),
            format_sig(ppa, 3),
            format_sig(energy, 3)
        );
    }
    Ok(())
}
