//! Edge-deployment scenario: the workload the paper's introduction
//! motivates — pick an accelerator for an on-device vision stack under a
//! hard area and power budget.
//!
//! Sweeps the design space for a multi-model workload (the device runs
//! ResNet-56 *and* VGG-16 on CIFAR-100-sized inputs), filters by the edge
//! budget (≤ 6 mm², ≤ 600 mW), and reports the budget-feasible Pareto
//! front over (throughput, energy) — the decision a deployment engineer
//! would actually make with QADAM.
//!
//! Run: `cargo run --release --example edge_deployment`

use qadam::arch::SweepSpec;
use qadam::dnn::Dataset;
use qadam::dse::{pareto_front, Orientation};
use qadam::explore::Explorer;
use qadam::quant::PeType;
use qadam::util::table::{format_sig, Table};

const AREA_BUDGET_MM2: f64 = 6.0;
const POWER_BUDGET_MW: f64 = 600.0;

fn main() -> qadam::Result<()> {
    println!(
        "edge budget: ≤ {AREA_BUDGET_MM2} mm², ≤ {POWER_BUDGET_MW} mW — workload: VGG-16 + ResNet-56 / CIFAR-100\n"
    );
    let db = Explorer::over(SweepSpec::default())
        .dataset(Dataset::Cifar100)
        .seed(7)
        .run()?;

    // Combine the two target models per config: worst-case latency, summed
    // energy (the device alternates between them).
    let vgg = db.spaces.iter().find(|s| s.model_name == "VGG-16").unwrap();
    let resnet = db.spaces.iter().find(|s| s.model_name == "ResNet-56").unwrap();

    struct Candidate {
        id: String,
        pe: PeType,
        area: f64,
        total_latency_ms: f64,
        total_energy_uj: f64,
        power_mw: f64,
    }
    let mut candidates = Vec::new();
    for (a, b) in vgg.evals.iter().zip(&resnet.evals) {
        assert_eq!(a.config.id(), b.config.id());
        let total_latency_ms = a.latency_ms + b.latency_ms;
        let total_energy_uj = a.energy_uj + b.energy_uj;
        // Average power over the duty cycle.
        let power_mw = total_energy_uj / total_latency_ms; // µJ/ms = mW
        candidates.push(Candidate {
            id: a.config.id(),
            pe: a.config.pe,
            area: a.area_mm2,
            total_latency_ms,
            total_energy_uj,
            power_mw,
        });
    }

    let feasible: Vec<&Candidate> = candidates
        .iter()
        .filter(|c| c.area <= AREA_BUDGET_MM2 && c.power_mw <= POWER_BUDGET_MW)
        .collect();
    println!(
        "{} / {} design points meet the budget",
        feasible.len(),
        candidates.len()
    );
    let mut by_pe = [0usize; 4];
    for c in &feasible {
        by_pe[PeType::ALL.iter().position(|&p| p == c.pe).unwrap()] += 1;
    }
    for (pe, count) in PeType::ALL.iter().zip(by_pe) {
        println!("  {:<10} {count} feasible", pe.name());
    }

    // Pareto over (throughput ↑ = 1/latency, energy ↓).
    let points: Vec<Vec<f64>> = feasible
        .iter()
        .map(|c| vec![1.0 / c.total_latency_ms, c.total_energy_uj])
        .collect();
    let front = pareto_front(&points, &[Orientation::Maximize, Orientation::Minimize]);

    let mut table =
        Table::new(&["config", "pe", "area_mm2", "latency_ms", "energy_uJ", "power_mW"]);
    for &idx in &front {
        let c = feasible[idx];
        table.row(&[
            c.id.clone(),
            c.pe.name().into(),
            format_sig(c.area, 3),
            format_sig(c.total_latency_ms, 4),
            format_sig(c.total_energy_uj, 4),
            format_sig(c.power_mw, 4),
        ]);
    }
    println!("\nbudget-feasible Pareto front (workload = both models):");
    print!("{}", table.render());

    let light_on_front =
        front.iter().filter(|&&i| feasible[i].pe.is_shift_add()).count();
    println!(
        "\n{light_on_front}/{} front points are LightPE designs — quantization-aware PEs dominate the edge regime.",
        front.len()
    );
    Ok(())
}
