// Manual phase profiler for the DSE campaign hot path (§Perf).
use qadam::arch::SweepSpec;
use qadam::dataflow::{map_model, Dataflow};
use qadam::dnn::{models_for, Dataset};
use qadam::dse::evaluate_with_synth;
use qadam::energy::energy_of;
use qadam::synth::synthesize;
use std::time::Instant;

fn main() {
    let spec = SweepSpec::default();
    let configs = spec.enumerate();
    let models = models_for(Dataset::ImageNet);
    // Phase 1: synthesis only.
    let t = Instant::now();
    let synths: Vec<_> = configs.iter().map(|c| synthesize(c, 7)).collect();
    let t_synth = t.elapsed().as_secs_f64();
    // Phase 2: mapping only.
    let t = Instant::now();
    let mut cycle_sum = 0u64;
    for s in &synths {
        for m in &models {
            cycle_sum += map_model(m, &s.config, Dataflow::RowStationary).total_cycles;
        }
    }
    let t_map = t.elapsed().as_secs_f64();
    // Phase 3: energy only (re-map inside evaluate for apples-to-apples).
    let t = Instant::now();
    let mut e_sum = 0.0;
    for s in &synths {
        for m in &models {
            let mapping = map_model(m, &s.config, Dataflow::RowStationary);
            e_sum += energy_of(&mapping, s).total_uj();
        }
    }
    let t_map_energy = t.elapsed().as_secs_f64();
    // Phase 4: full evaluate.
    let t = Instant::now();
    let mut ppa_sum = 0.0;
    for s in &synths {
        for m in &models {
            ppa_sum += evaluate_with_synth(s, m).perf_per_area;
        }
    }
    let t_eval = t.elapsed().as_secs_f64();
    println!("configs={} models={}", configs.len(), models.len());
    println!("synthesis : {:.4}s ({:.1}us/config)", t_synth, 1e6*t_synth/configs.len() as f64);
    println!("mapping   : {:.4}s ({:.1}us/(config,model))", t_map, 1e6*t_map/(configs.len()*3) as f64);
    println!("map+energy: {:.4}s", t_map_energy);
    println!("evaluate  : {:.4}s", t_eval);
    println!("checks: {} {} {}", cycle_sum, e_sum as u64, ppa_sum as u64);
}
