//! Resumable campaigns end-to-end: checkpoint journaling, the
//! content-addressed point cache, and the persistent evaluation database
//! feeding a figure without re-running the sweep.
//!
//! The production story this demonstrates: a DSE service campaign gets
//! killed mid-run, restarts with the same command, replays the journaled
//! prefix, serves overlapping work from the cache, and ships the exact
//! bytes an uninterrupted run would have produced.
//!
//! Run: `cargo run --release --example resumable_campaign`

use std::sync::{Arc, Mutex};

use qadam::arch::SweepSpec;
use qadam::dnn::Dataset;
use qadam::explore::{EvalDatabase, Explorer, PointCache};
use qadam::report;

fn main() -> qadam::Result<()> {
    let dir = std::env::temp_dir().join("qadam_resumable_demo");
    std::fs::create_dir_all(&dir)?;
    let journal = dir.join("campaign.journal");
    let db_path = dir.join("db.json");
    let cache_path = dir.join("cache.json");
    let _ = std::fs::remove_file(&journal);

    let cache = Arc::new(Mutex::new(PointCache::new()));
    let explorer = Explorer::over(SweepSpec::default())
        .dataset(Dataset::Cifar10)
        .seed(7)
        .cache(cache.clone())
        .checkpoint(&journal, 32);

    // First run: journals every 32 points and fills the cache.
    let db = explorer.run()?;
    println!(
        "campaign: {} design points x {} models in {:.2}s",
        db.stats.design_points,
        db.spaces.len(),
        db.stats.wall_seconds
    );

    // "Restart after a kill": the journal is complete, so this replays
    // every point without evaluating anything — and the database is
    // byte-identical to the first run's.
    let resumed = explorer.run()?;
    assert_eq!(
        resumed.to_json().to_string_pretty(),
        db.to_json().to_string_pretty(),
        "resumed campaign must reproduce the database byte-for-byte"
    );
    println!("resume: byte-identical database replayed from {}", journal.display());

    {
        let cache = cache.lock().expect("cache lock");
        println!(
            "cache: {} design points cached ({} hits / {} misses so far)",
            cache.len(),
            cache.hits(),
            cache.misses()
        );
        cache.save(&cache_path)?;
    }

    // Persist the database, reload it, and render Fig. 4 from disk — the
    // exact figure a live `qadam report --fig 4` run would produce.
    db.save(&db_path)?;
    let loaded = EvalDatabase::load(&db_path)?;
    let figure = report::fig4_from_db(&loaded)?;
    print!("{}", figure.render());
    println!("(rendered from {} without re-running the sweep)", db_path.display());
    Ok(())
}
