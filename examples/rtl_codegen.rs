//! RTL generation walkthrough — the paper's "automatically generated RTL
//! code to follow the design synthesis flow" (§III-A).
//!
//! Generates Verilog bundles for one design point per PE type, writes them
//! under `rtl_out/`, and prints a diffable summary (module/line counts,
//! multiplier-vs-shifter audit) showing the LightPE datapaths really have
//! no multiplier.
//!
//! Run: `cargo run --release --example rtl_codegen`

use std::path::Path;

use qadam::arch::AcceleratorConfig;
use qadam::quant::PeType;
use qadam::rtl;
use qadam::util::table::Table;

fn main() -> qadam::Result<()> {
    let out_root = Path::new("rtl_out");
    let mut table =
        Table::new(&["pe", "files", "total_lines", "multiplies", "shifts", "dir"]);
    for pe in PeType::ALL {
        let config = AcceleratorConfig { pe, rows: 8, cols: 8, ..Default::default() };
        let bundle = rtl::generate(&config);
        let dir = out_root.join(pe.name().replace('-', "_").to_lowercase());
        rtl::write_bundle(&bundle, &dir)?;

        let total_lines: usize = bundle.files.iter().map(|f| f.source.lines().count()).sum();
        let pe_file = bundle.files.iter().find(|f| f.name == "pe.v").unwrap();
        let multiplies = pe_file.source.matches('*').count()
            - pe_file.source.matches("/*").count() * 2;
        let shifts = pe_file.source.matches("<<").count();
        table.row(&[
            pe.name().into(),
            bundle.files.len().to_string(),
            total_lines.to_string(),
            multiplies.to_string(),
            shifts.to_string(),
            dir.display().to_string(),
        ]);
    }
    print!("{}", table.render());
    println!("\nLightPE pe.v uses shifts only — the multiplier is gone, as §III-B describes.");
    Ok(())
}
