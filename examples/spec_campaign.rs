//! Spec-driven campaigns end-to-end: compile a QSL file, inspect the
//! resolved campaign, execute it, and show the canonical-form /
//! fingerprint machinery that makes spec-driven runs reproducible and
//! resume-safe.
//!
//! Run: `cargo run --release --example spec_campaign`

use qadam::spec;

/// The shipped custom-model example spec, compiled from source so this
/// example runs from any working directory.
const SOURCE: &str = include_str!("custom_model.qsl");

fn main() -> qadam::Result<()> {
    // Compile: lex + parse + semantic check + lowering, all diagnostics
    // at once on failure.
    let campaign = spec::compile(SOURCE, "custom_model.qsl")?;
    println!("=== resolved campaign ===");
    print!("{}", campaign.summary());

    // The canonical form is the spec with every default spelled out —
    // comment-free, deterministic, and a fixed point of parse→render.
    let canonical = campaign.canonical();
    let reparsed = spec::compile(&canonical, "canonical.qsl")?;
    assert_eq!(reparsed.canonical(), canonical);
    assert_eq!(reparsed.fingerprint(), campaign.fingerprint());
    println!("\ncanonical form: {} bytes, fingerprint {:016x}", canonical.len(), campaign.fingerprint());

    // A broken spec reports *all* its problems, with spans and
    // suggestions — not just the first.
    let broken = "sweep {\n  pe_typ = [int16]\n}\nworkload {\n  models = [resnet21]\n}\n";
    let (_, diags) = spec::check(broken);
    println!("\n=== diagnostics for a broken spec ===");
    print!("{}", diags.render(broken, "broken.qsl"));

    // Execute (dropping persistence so the example leaves no files):
    // custom models evaluate exactly like zoo models.
    let mut campaign = campaign;
    campaign.persist = spec::PersistPlan::new();
    let outcome = campaign.execute()?;
    println!("=== results ===");
    println!(
        "{} design points x {} models in {:.2}s",
        outcome.db.stats.design_points,
        outcome.db.spaces.len(),
        outcome.db.stats.wall_seconds
    );
    for space in &outcome.db.spaces {
        let best = space
            .evals
            .iter()
            .map(|e| e.perf_per_area)
            .fold(f64::NEG_INFINITY, f64::max);
        println!("  {:<10} best perf/area {best:.3}", space.model_name);
    }
    Ok(())
}
