//! End-to-end driver: the full three-layer stack on a real (small)
//! workload, proving all layers compose (DESIGN.md §1):
//!
//!   L1 Pallas quantized-matmul kernels (inside every conv/fc, fwd + bwd)
//!   L2 JAX QAT model, AOT-lowered to HLO text by `make artifacts`
//!   L3 this rust driver: PJRT-compiles the artifacts and runs the whole
//!      training loop — python never executes here.
//!
//! Trains the QAT CNN for a few hundred steps per PE type on synthetic
//! CIFAR-like data, logs the loss curves, evaluates accuracy, then joins
//! the measured accuracies with the DSE hardware metrics into the Fig. 5
//! accuracy-vs-efficiency trade-off. Results recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example qat_end_to_end [-- steps]`

use std::path::Path;

use qadam::arch::SweepSpec;
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::dse;
use qadam::explore::Explorer;
use qadam::quant::PeType;
use qadam::runtime::{QatDriver, Runtime};
use qadam::util::table::{format_sig, Table};

fn main() -> qadam::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        return Err(qadam::Error::Unsupported(
            "artifacts missing — run `make artifacts` first".into(),
        ));
    }
    let mut runtime = Runtime::new(&artifacts)?;
    println!(
        "PJRT runtime up ({} device); training {} steps per PE type\n",
        runtime.device_count(),
        steps
    );

    // --- Train all four PE types through the PJRT artifacts --------------
    let mut outcomes = Vec::new();
    for pe in PeType::ALL {
        let t0 = std::time::Instant::now();
        let outcome = QatDriver::train(&mut runtime, pe, steps, (steps / 8).max(1))?;
        let dt = t0.elapsed().as_secs_f64();
        print!("{:<10} loss:", pe.name());
        for record in &outcome.loss_curve {
            print!(" {:.3}", record.loss);
        }
        println!(
            "  -> eval acc {:.3} ({:.1} steps/s)",
            outcome.final_accuracy,
            steps as f64 / dt
        );
        outcomes.push(outcome);
    }

    // --- Sanity: every curve must have learned something ------------------
    for outcome in &outcomes {
        let first = outcome.loss_curve.first().unwrap().loss;
        let last = outcome.loss_curve.last().unwrap().loss;
        assert!(
            last < first,
            "{}: loss did not decrease ({first} -> {last})",
            outcome.pe.name()
        );
    }

    // --- Join with DSE hardware metrics (measured Fig. 5 analogue) --------
    println!("\njoining measured QAT accuracy with DSE hardware efficiency...");
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let db = Explorer::over(SweepSpec::default()).model(model).seed(7).run()?;
    let evals = &db.spaces[0].evals;
    let mut table = Table::new(&[
        "pe", "measured_acc", "final_loss", "norm_perf_per_area", "norm_energy",
    ]);
    let baseline = dse::best_perf_per_area(&evals, PeType::Int16).unwrap();
    let base_energy = dse::best_energy(&evals, PeType::Int16).unwrap().energy_uj;
    for outcome in &outcomes {
        let best = dse::best_perf_per_area(&evals, outcome.pe).unwrap();
        let best_e = dse::best_energy(&evals, outcome.pe).unwrap();
        table.row(&[
            outcome.pe.name().into(),
            format_sig(outcome.final_accuracy as f64, 3),
            format_sig(outcome.final_eval_loss as f64, 4),
            format_sig(best.perf_per_area / baseline.perf_per_area, 3),
            format_sig(best_e.energy_uj / base_energy, 3),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nall three layers composed: Pallas kernels -> AOT HLO -> rust/PJRT training loop OK"
    );
    Ok(())
}
