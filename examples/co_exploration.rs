//! Joint hardware × model co-exploration (the QUIDAM direction):
//! sweep width/depth multipliers of the workload models *jointly* with
//! the hardware axes, stream the joint Pareto frontier per base model
//! family, and group the results by scaled-model variant.
//!
//! The research story this demonstrates: QADAM's Pareto frontier moves
//! again when model hyperparameters join the search space — a
//! half-width ResNet-20 on a small array can dominate the full model on
//! a big one, and only a joint walk can see that.
//!
//! Run: `cargo run --release --example co_exploration`

use std::sync::{Arc, Mutex};

use qadam::arch::{DesignSpace, ModelAxes, SweepSpec};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::explore::{lock_shared, Explorer};
use qadam::pareto::CampaignFrontier;

fn main() -> qadam::Result<()> {
    // 2 widths x 2 depths = 4 variants of ResNet-20, each evaluated on
    // every hardware point of the tiny sweep: one joint indexed walk.
    let space = DesignSpace::new(
        SweepSpec::tiny(),
        ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1, 2] },
    );
    println!(
        "joint space: {} hardware points x {} model variants = {} design points",
        space.hw.len(),
        space.model.len(),
        space.len()
    );

    let frontier = Arc::new(Mutex::new(CampaignFrontier::new()));
    let db = Explorer::over(space.clone())
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .seed(7)
        .frontier(frontier.clone())
        .run()?;

    // One space per scaled-model variant, variant-major.
    println!("\nper-variant best perf/area:");
    for model_space in &db.spaces {
        let best = model_space
            .evals
            .iter()
            .map(|e| e.perf_per_area)
            .fold(f64::NEG_INFINITY, f64::max);
        println!(
            "  {:<20} variant {:<8} best {:.1} inf/s/mm2",
            model_space.model_name,
            model_space.variant_label().unwrap_or("base"),
            best
        );
    }

    // The streamed frontier is per *base* family: points from every
    // variant compete on (perf/area up, energy down), so the archive is
    // the joint Pareto set of the whole family.
    let guard = lock_shared(&frontier);
    let family = &guard.models()[0];
    println!(
        "\njoint frontier of {}: {} Pareto-optimal points out of {} offered",
        family.model_name(),
        family.front().len(),
        family.front().offered()
    );
    for entry in family.front().sorted() {
        let variant = space.variant_of(entry.payload.index).expect("front index in space");
        println!(
            "  w{} d{} on {:<24} perf/area {:.1}, energy {:.1} uJ",
            variant.width,
            variant.depth,
            entry.payload.eval.config.id(),
            entry.payload.eval.perf_per_area,
            entry.payload.eval.energy_uj
        );
    }
    Ok(())
}
