//! Quickstart: the paper's Fig. 1 flow in ~40 lines.
//!
//! Feed accelerator parameters + a DNN configuration into the framework
//! and read back power, performance, area, utilization, and memory-access
//! statistics — for all four PE types side by side.
//!
//! Run: `cargo run --release --example quickstart`

use qadam::arch::AcceleratorConfig;
use qadam::dataflow::{map_model, Dataflow};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::energy::energy_of;
use qadam::quant::PeType;
use qadam::synth::synthesize;
use qadam::util::table::{format_sig, Table};

fn main() {
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    println!(
        "QADAM quickstart — {} ({}, {} MMACs/inference)\n",
        model.name,
        model.dataset.name(),
        model.total_macs() / 1_000_000
    );

    let mut table = Table::new(&[
        "pe", "area_mm2", "power_mw", "clock_ghz", "latency_ms", "util",
        "chip_uJ", "dram_MB", "perf/area",
    ]);
    for pe in PeType::ALL {
        // 16×16 PE array, 128 KiB GLB, Eyeriss-like scratchpads.
        let config = AcceleratorConfig { pe, ..Default::default() };

        // 1. "Synthesize" the design (Synopsys DC stand-in).
        let synth = synthesize(&config, /*seed=*/ 7);

        // 2. Map the DNN with the row-stationary dataflow.
        let mapping = map_model(&model, &config, Dataflow::RowStationary);

        // 3. Combine into energy + the paper's efficiency metrics.
        let energy = energy_of(&mapping, &synth);
        let latency_ms = mapping.latency_s(synth.achieved_clock_ghz) * 1e3;
        let perf_per_area =
            (1e3 / latency_ms) / synth.area.total_mm2();

        table.row(&[
            pe.name().into(),
            format_sig(synth.area.total_mm2(), 4),
            format_sig(synth.total_power_mw(), 4),
            format_sig(synth.achieved_clock_ghz, 3),
            format_sig(latency_ms, 4),
            format_sig(mapping.avg_utilization, 3),
            format_sig(energy.chip_uj(), 4),
            format_sig(mapping.traffic.dram_bytes as f64 / 1e6, 4),
            format_sig(perf_per_area, 4),
        ]);
    }
    print!("{}", table.render());
    println!("\nLightPEs: smallest area, least energy — the paper's headline, in one table.");
}
