//! Bench: evaluation-database persistence — canonical JSON vs the
//! columnar binary `qadam.qdb` format — plus the million-point campaign
//! smoke: streaming a 10⁶-evaluation space through `QdbWriter` while a
//! sharded parallel fold maintains the Pareto front.
//!
//! The claim to quantify: the qdb path makes million-point campaigns
//! practical — save/load cost scales with bytes moved (108 B/row, no
//! string formatting or parsing), and the sharded frontier fold merges
//! to a result bit-identical to sequential insertion.

use std::path::PathBuf;

use qadam::arch::AcceleratorConfig;
use qadam::bench::{bench_with, section, BenchConfig};
use qadam::dnn::Dataset;
use qadam::dse::Evaluation;
use qadam::explore::{CampaignStats, EvalDatabase, ModelSpace, QdbPlan, QdbSpacePlan, QdbWriter};
use qadam::pareto::{FrontCore, OBJECTIVES};
use qadam::quant::PeType;

/// Deterministic synthetic evaluation `i` — a valid config plus scrambled
/// metrics, cheap enough to generate 10⁶ of without dominating the bench.
fn synth_eval(i: usize) -> Evaluation {
    let mut x = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    x ^= x >> 33;
    let unit = |shift: u32| ((x >> shift) & 0xffff) as f64 / 65536.0;
    let clock_ghz = 0.5 + (i % 16) as f64 * 0.25;
    let config = AcceleratorConfig {
        pe: PeType::ALL[i % PeType::ALL.len()],
        rows: 1 + (i % 64),
        cols: 1 + ((i / 64) % 64),
        glb_kib: 32 + (i % 8) * 32,
        dram_bw_gbps: 4.0 + (i % 4) as f64,
        clock_ghz,
        ..Default::default()
    };
    Evaluation {
        config,
        area_mm2: 1.0 + 30.0 * unit(0),
        clock_ghz,
        latency_ms: 0.1 + 10.0 * unit(8),
        inf_per_s: 10.0 + 1000.0 * unit(16),
        perf_per_area: 1.0 + 100.0 * unit(24),
        energy_uj: 10.0 + 500.0 * unit(32),
        dram_energy_uj: 1.0 + 50.0 * unit(40),
        utilization: unit(48),
    }
}

fn synthetic_db(n: usize) -> EvalDatabase {
    EvalDatabase {
        dataset: Dataset::Cifar10,
        shard: (0, 1),
        strategy: "exhaustive".into(),
        spaces: vec![ModelSpace {
            model_name: "synthetic".into(),
            dataset: Dataset::Cifar10,
            evals: (0..n).map(synth_eval).collect(),
        }],
        stats: CampaignStats {
            design_points: n,
            evaluations: n,
            wall_seconds: 0.0,
            workers: 0,
        },
    }
}

fn temp_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_bench_db_format_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench temp dir");
    dir
}

fn main() {
    let dir = temp_dir();

    section("database save/load: canonical JSON vs columnar qdb");
    for &n in &[1_000usize, 100_000] {
        let config = if n <= 1_000 {
            BenchConfig { warmup_iters: 1, measure_iters: 3 }
        } else {
            BenchConfig { warmup_iters: 0, measure_iters: 2 }
        };
        let db = synthetic_db(n);
        let json_path = dir.join(format!("db_{n}.json"));
        let qdb_path = dir.join(format!("db_{n}.qdb"));
        bench_with(&format!("json_save_{n}"), config, || {
            db.save(&json_path).expect("json save");
        });
        bench_with(&format!("qdb_save_{n}"), config, || {
            db.save_qdb(&qdb_path).expect("qdb save");
        });
        bench_with(&format!("json_load_{n}"), config, || {
            EvalDatabase::load(&json_path).expect("json load").stats.evaluations
        });
        bench_with(&format!("qdb_load_{n}"), config, || {
            EvalDatabase::load_qdb(&qdb_path).expect("qdb load").stats.evaluations
        });
    }

    // The acceptance smoke: a 10⁶-point synthetic campaign never holds the
    // database in RAM — evaluations stream straight into the QdbWriter
    // while 8 shard folds maintain sub-fronts that tree-merge into the
    // (bit-identical-to-sequential) campaign front.
    section("million-point campaign: streamed qdb write + parallel frontier");
    const MILLION: usize = 1_000_000;
    const SHARDS: usize = 8;
    bench_with("million_point_campaign", BenchConfig { warmup_iters: 0, measure_iters: 1 }, || {
        let path = dir.join("million.qdb");
        let plan = QdbPlan {
            dataset: Dataset::Cifar10,
            shard: (0, 1),
            strategy: "synthetic".into(),
            spaces: vec![QdbSpacePlan {
                model_name: "synthetic".into(),
                dataset: Dataset::Cifar10,
                rows: MILLION,
            }],
            design_points: MILLION,
            evaluations: MILLION,
        };
        let front = std::thread::scope(|scope| {
            let writer = scope.spawn(|| {
                let mut writer = QdbWriter::create(&path, &plan).expect("qdb create");
                for i in 0..MILLION {
                    writer.append(0, &synth_eval(i)).expect("qdb append");
                }
                writer.finish().expect("qdb finish");
            });
            let chunk = MILLION.div_ceil(SHARDS);
            let folds: Vec<_> = (0..SHARDS)
                .map(|shard| {
                    scope.spawn(move || {
                        let mut front = FrontCore::new(OBJECTIVES.to_vec());
                        let hi = ((shard + 1) * chunk).min(MILLION);
                        for i in (shard * chunk)..hi {
                            let eval = synth_eval(i);
                            front.offer_seq(i, vec![eval.perf_per_area, eval.energy_uj], ());
                        }
                        front
                    })
                })
                .collect();
            let shards = folds.into_iter().map(|h| h.join().expect("shard fold")).collect();
            writer.join().expect("qdb stream");
            FrontCore::merge_all(shards).expect("non-empty merge")
        });
        front.len()
    });

    qadam::bench::finish("db_format", &qadam::bench::HostMeta::from_env());
}
