//! Ablation benches for the design choices DESIGN.md §7 calls out:
//!
//! 1. Row-stationary vs weight-/output-stationary traffic (the §III-A
//!    "row stationary ... optimize[s] the data movement" claim).
//! 2. Polynomial-degree model-selection curve (k-fold CV, §III-C).
//! 3. Scratchpad-size sensitivity at a fixed array size.
//! 4. Tool-noise amplitude vs surrogate fit quality (robustness).

use qadam::arch::{AcceleratorConfig, ScratchpadCfg, SweepSpec};
use qadam::bench::section;
use qadam::dataflow::{alt::map_layer, map_model, Dataflow};
use qadam::dnn::{model_for, Dataset, Layer, ModelKind};
use qadam::ppa::regression::cv_rmse;
use qadam::ppa::{design_features, PpaModel};
use qadam::quant::PeType;
use qadam::synth::synthesize_sweep;
use qadam::util::stats;
use qadam::util::table::{format_sig, Table};

fn ablation_dataflows() {
    section("ablation 1 — dataflow traffic (RS vs WS vs OS)");
    let config = AcceleratorConfig::default();
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let mut table =
        Table::new(&["dataflow", "glb_accesses", "dram_MB", "vs_RS_glb", "vs_RS_dram"]);
    let rs = map_model(&model, &config, Dataflow::RowStationary);
    for dataflow in
        [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary]
    {
        let mapping = map_model(&model, &config, dataflow);
        table.row(&[
            dataflow.name().into(),
            mapping.traffic.glb.total().to_string(),
            format_sig(mapping.traffic.dram_bytes as f64 / 1e6, 4),
            format_sig(
                mapping.traffic.glb.total() as f64 / rs.traffic.glb.total() as f64,
                3,
            ),
            format_sig(
                mapping.traffic.dram_bytes as f64 / rs.traffic.dram_bytes as f64,
                3,
            ),
        ]);
    }
    print!("{}", table.render());
    println!("RS moves the least data through the hierarchy — §III-A's design choice.\n");
}

fn ablation_poly_degree() {
    section("ablation 2 — polynomial degree selection curve (k-fold CV)");
    let dataset = synthesize_sweep(&SweepSpec::default(), PeType::Int16, 7);
    let xs: Vec<Vec<f64>> = dataset.records.iter().map(|r| design_features(&r.config)).collect();
    let mut table = Table::new(&["metric", "degree1_rmse", "degree2_rmse", "degree3_rmse"]);
    for metric in ["area", "power", "perf"] {
        let ys = dataset.targets(metric);
        let rmses: Vec<f64> =
            (1..=3).map(|degree| cv_rmse(&xs, &ys, degree, 5, 7)).collect();
        table.row(&[
            metric.into(),
            format_sig(rmses[0], 4),
            format_sig(rmses[1], 4),
            format_sig(rmses[2], 4),
        ]);
    }
    print!("{}", table.render());
    println!("degree 2 captures the area/power surface; degree 3 buys little.\n");
}

fn ablation_spad_sensitivity() {
    section("ablation 3 — scratchpad size sensitivity (16x16 INT16 array)");
    let model = model_for(ModelKind::ResNet56, Dataset::Cifar10);
    let mut table =
        Table::new(&["filter_spad", "glb_reads", "dram_MB", "cycles", "pe_area_um2"]);
    for filter_entries in [28, 56, 112, 224, 448] {
        let config = AcceleratorConfig {
            spad: ScratchpadCfg { filter_entries, ..Default::default() },
            ..Default::default()
        };
        let mapping = map_model(&model, &config, Dataflow::RowStationary);
        let synth = qadam::synth::synthesize_clean(&config);
        table.row(&[
            filter_entries.to_string(),
            mapping.traffic.glb.reads.to_string(),
            format_sig(mapping.traffic.dram_bytes as f64 / 1e6, 4),
            mapping.total_cycles.to_string(),
            format_sig(synth.pe.total.area_um2, 4),
        ]);
    }
    print!("{}", table.render());
    println!("bigger filter spads trade PE area for GLB/DRAM traffic — the paper's knob.\n");
}

fn ablation_noise_robustness() {
    section("ablation 4 — tool-noise amplitude vs surrogate fit");
    // Fit quality across synthesis seeds: the surrogate must be robust to
    // which synthesis run produced the training data.
    let mut pearsons = Vec::new();
    for seed in 0..5 {
        let dataset = synthesize_sweep(&SweepSpec::default(), PeType::LightPe1, seed);
        let model = PpaModel::fit(&dataset, 5, seed);
        pearsons.push(model.reports[0].pearson); // area fit
    }
    println!(
        "area-fit Pearson r across 5 synthesis seeds: mean {} min {} (stable fit)\n",
        format_sig(stats::mean(&pearsons), 4),
        format_sig(stats::min(&pearsons), 4)
    );
}

fn ablation_single_layer_dataflow_detail() {
    section("ablation 1b — per-layer dataflow detail (conv3_1 of VGG-16)");
    let layer = Layer::conv("conv3_1", 8, 256, 256, 3, 1, 1);
    let config = AcceleratorConfig::default();
    let mut table = Table::new(&["dataflow", "spad_accesses", "glb_accesses", "utilization"]);
    for dataflow in
        [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary]
    {
        let mapping = map_layer(dataflow, &layer, &config);
        table.row(&[
            dataflow.name().into(),
            mapping.traffic.spad.total().to_string(),
            mapping.traffic.glb.total().to_string(),
            format_sig(mapping.utilization, 3),
        ]);
    }
    print!("{}", table.render());
}

fn main() {
    ablation_dataflows();
    ablation_poly_degree();
    ablation_spad_sensitivity();
    ablation_noise_robustness();
    ablation_single_layer_dataflow_detail();
    // No timed benches here (the ablations are analytical), but emitting
    // the (empty) artifact keeps the QADAM_BENCH_OUT layout uniform: one
    // file per target, so `qadam bench merge <dir>` never special-cases.
    qadam::bench::finish("ablations", &qadam::bench::HostMeta::from_env());
}
