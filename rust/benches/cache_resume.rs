//! Bench: the persistence layer — cold campaigns vs warm content-addressed
//! cache hits, and the overhead of checkpoint journaling. The warm-cache
//! number is the "near-free repeat campaign" headline behind
//! `qadam dse --cache`; the journal number bounds what `--resume` costs an
//! uninterrupted run.

use std::sync::{Arc, Mutex};

use qadam::arch::SweepSpec;
use qadam::bench::{bench_with, section, BenchConfig};
use qadam::dnn::Dataset;
use qadam::explore::{Explorer, PointCache};

fn main() {
    let spec = SweepSpec::default();

    section("content-addressed point cache");
    let cold = bench_with("dse_cold_no_cache", BenchConfig::heavy(), || {
        Explorer::over(spec.clone())
            .dataset(Dataset::Cifar10)
            .seed(7)
            .run()
            .expect("cold campaign")
    });
    println!("{}", cold.render());

    let cache = Arc::new(Mutex::new(PointCache::new()));
    // One warm-up campaign fills the cache; the measured runs are all hits.
    Explorer::over(spec.clone())
        .dataset(Dataset::Cifar10)
        .seed(7)
        .cache(cache.clone())
        .run()
        .expect("cache warm-up");
    let warm = bench_with("dse_warm_cache_all_hits", BenchConfig::heavy(), || {
        Explorer::over(spec.clone())
            .dataset(Dataset::Cifar10)
            .seed(7)
            .cache(cache.clone())
            .run()
            .expect("warm campaign")
    });
    println!("{}", warm.render());
    println!(
        "warm-cache speedup: {:.1}x ({} cached design points)",
        cold.summary.mean / warm.summary.mean.max(1e-9),
        cache.lock().unwrap().len()
    );

    section("checkpoint journal overhead");
    let dir = std::env::temp_dir().join("qadam_bench_checkpoint");
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    let journaled = bench_with("dse_checkpoint_every_64", BenchConfig::heavy(), || {
        let path = dir.join("bench.journal");
        let _ = std::fs::remove_file(&path);
        Explorer::over(spec.clone())
            .dataset(Dataset::Cifar10)
            .seed(7)
            .checkpoint(&path, 64)
            .run()
            .expect("journaled campaign")
    });
    println!("{}", journaled.render());
    println!(
        "journal overhead vs cold: {:+.1}%",
        (journaled.summary.mean / cold.summary.mean - 1.0) * 100.0
    );
    let _ = std::fs::remove_dir_all(&dir);

    println!("CSV:");
    for result in [&cold, &warm, &journaled] {
        println!("{}", result.to_csv_row());
    }

    qadam::bench::finish("cache_resume", &qadam::bench::HostMeta::from_env());
}
