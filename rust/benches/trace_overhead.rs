//! Bench: tracing overhead on the Explorer hot path — the same fully
//! instrumented campaign (cache + frontier attached, so every emission
//! site is live) run untraced, with the no-op [`NullSink`], and with a
//! recording [`TraceRecorder`]. The DESIGN.md §11 contract is that the
//! no-op sink stays within noise of the untraced baseline (<2%), and
//! the recorder's cost is dominated by one mutex push per event.

use std::sync::{Arc, Mutex};

use qadam::arch::SweepSpec;
use qadam::bench::{bench_with, section, BenchConfig};
use qadam::coordinator::default_workers;
use qadam::dnn::Dataset;
use qadam::explore::{Explorer, PointCache};
use qadam::obs::{NullSink, TraceRecorder, TraceSink};
use qadam::pareto::CampaignFrontier;

/// A mid-size slice of the default space: big enough that per-point
/// evaluation dominates, small enough for the heavy bench config.
fn sweep() -> SweepSpec {
    let d = SweepSpec::default();
    SweepSpec {
        pe_types: d.pe_types.clone(),
        array_dims: d.array_dims[..2.min(d.array_dims.len())].to_vec(),
        glb_kib: d.glb_kib[..2.min(d.glb_kib.len())].to_vec(),
        spads: d.spads[..1].to_vec(),
        dram_bw_gbps: d.dram_bw_gbps[..1].to_vec(),
        clock_ghz: d.clock_ghz[..1].to_vec(),
    }
}

/// One instrumented campaign: fresh cache and frontier per iteration so
/// every run pays the same (cold) evaluation cost and every emission
/// site — dispatch, cache, frontier, deliver — fires.
fn run(sink: Option<Arc<dyn TraceSink>>) -> usize {
    let mut explorer = Explorer::over(sweep())
        .dataset(Dataset::Cifar10)
        .workers(default_workers())
        .seed(7)
        .cache(Arc::new(Mutex::new(PointCache::new())))
        .frontier(Arc::new(Mutex::new(CampaignFrontier::new())));
    if let Some(sink) = sink {
        explorer = explorer.trace_sink(sink);
    }
    explorer.run().expect("bench campaign").stats.design_points
}

fn overhead_pct(baseline: f64, measured: f64) -> f64 {
    100.0 * (measured - baseline) / baseline.max(1e-9)
}

fn main() {
    let points = run(None);
    section(&format!("trace overhead ({points} design points per campaign)"));

    let untraced = bench_with("campaign_untraced", BenchConfig::heavy(), || run(None));
    println!("{}", untraced.render());

    let null_sink = bench_with("campaign_null_sink", BenchConfig::heavy(), || {
        run(Some(Arc::new(NullSink)))
    });
    println!("{}", null_sink.render());

    let recorder = bench_with("campaign_trace_recorder", BenchConfig::heavy(), || {
        let recorder = Arc::new(TraceRecorder::new());
        let points = run(Some(recorder.clone()));
        assert!(!recorder.is_empty(), "recorder must capture events");
        points
    });
    println!("{}", recorder.render());

    println!(
        "null-sink overhead: {:+.2}% mean vs untraced (target < 2%); \
         recorder overhead: {:+.2}%",
        overhead_pct(untraced.summary.mean, null_sink.summary.mean),
        overhead_pct(untraced.summary.mean, recorder.summary.mean),
    );

    println!("CSV:");
    for result in [&untraced, &null_sink, &recorder] {
        println!("{}", result.to_csv_row());
    }

    qadam::bench::finish("trace_overhead", &qadam::bench::HostMeta::from_env());
}
