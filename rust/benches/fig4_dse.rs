//! Bench: regenerate **Fig. 4** — normalized performance-per-area vs
//! normalized energy for every (model × dataset) panel the paper shows:
//! {VGG-16, ResNet-20, ResNet-56} × {CIFAR-10, CIFAR-100} and
//! {VGG-16, ResNet-34, ResNet-50} × ImageNet. Ends with the paper's
//! summary ratios (LightPE-1 4.8×/4.7×, LightPE-2 4.1×/4×, INT16 1.8×/1.5×
//! vs FP32).

use qadam::bench::{bench_with, section, BenchConfig};
use qadam::coordinator::default_workers;
use qadam::dnn::Dataset;
use qadam::report;

fn main() {
    let workers = default_workers();
    for dataset in Dataset::ALL {
        section(&format!("Fig. 4 panel — {}", dataset.name()));
        let mut figure = None;
        bench_with(
            &format!("fig4_{}", dataset.name()),
            BenchConfig { warmup_iters: 0, measure_iters: 1 },
            || {
                figure = Some(report::fig4(dataset, workers, 7).expect("fig4 generation"));
            },
        );
        let figure = figure.unwrap();
        print!("{}", figure.render());
        println!("CSV:\n{}", figure.table.to_csv());
    }

    qadam::bench::finish("fig4_dse", &qadam::bench::HostMeta::from_env());
}
