//! Bench: regenerate **Fig. 5** — Pareto front of top-1 accuracy vs
//! normalized performance per area for CIFAR-10 and CIFAR-100
//! ("LightPEs are consistently on Pareto-front ... up to 5.7× and 4.9×
//! more performance per area when compared to INT16").

use qadam::bench::{bench_with, section, BenchConfig};
use qadam::coordinator::default_workers;
use qadam::dnn::Dataset;
use qadam::report;

fn main() {
    let workers = default_workers();
    for dataset in [Dataset::Cifar10, Dataset::Cifar100] {
        section(&format!("Fig. 5 — accuracy vs perf/area ({})", dataset.name()));
        let mut figure = None;
        bench_with(
            &format!("fig5_{}", dataset.name()),
            BenchConfig { warmup_iters: 0, measure_iters: 1 },
            || {
                figure = Some(report::fig5(dataset, workers, 7).expect("fig5 generation"));
            },
        );
        let figure = figure.unwrap();
        print!("{}", figure.render());
        println!("CSV:\n{}", figure.table.to_csv());
    }

    qadam::bench::finish("fig5_pareto_ppa", &qadam::bench::HostMeta::from_env());
}
