//! Bench: the batch scheduler — a 4-campaign `qadam serve` batch whose
//! sweeps overlap pairwise, measured cold (empty shared cache, half the
//! space deduped within the batch) and warm (`cache.json` already on
//! disk, every design point a hit). The gap is the headline for
//! re-serving a recurring batch; the cold number bounds what the
//! scheduler itself adds on top of the campaigns it runs.

use std::fs;
use std::path::{Path, PathBuf};

use qadam::bench::{bench_with, section, BenchConfig};
use qadam::serve::{serve, BatchOutcome, BatchQueue, ServeConfig};

/// Shared base spec: tenants override the `glb_kib` axis so each pair of
/// neighbours shares half its design points (8 unique points across 16).
const BASE: &str = "campaign { seed = 7 }\n\
    sweep {\n  pe_type = [int16]\n  array = [8x8, 16x16]\n  glb_kib = [64, 128]\n  \
    spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
    workload {\n  dataset = cifar10\n  models = [tiny]\n}\n\
    model tiny {\n  fc head { in = 64, out = 10 }\n}\n";

const GLB_OVERRIDES: [&str; 4] = ["[64, 128]", "[128, 192]", "[192, 256]", "[256, 64]"];

/// Drop everything the previous serve left in `out` except, optionally,
/// the shared `cache.json` — per-campaign dirs and the status journal go
/// either way, so a re-serve always re-executes every campaign.
fn reset_out_dir(out: &Path, keep_cache: bool) {
    let Ok(entries) = fs::read_dir(out) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if keep_cache && path.file_name().is_some_and(|n| n == "cache.json") {
            continue;
        }
        if path.is_dir() {
            let _ = fs::remove_dir_all(&path);
        } else {
            let _ = fs::remove_file(&path);
        }
    }
}

fn batch_hits(outcome: &BatchOutcome) -> (u64, u64) {
    outcome
        .reports
        .iter()
        .fold((0, 0), |(h, m), r| (h + r.hits, m + r.misses))
}

fn main() {
    let root = std::env::temp_dir().join(format!("qadam_bench_serve_{}", std::process::id()));
    let spec_dir = root.join("specs");
    fs::create_dir_all(&spec_dir).expect("bench spec dir");
    fs::write(spec_dir.join("base.qsl"), BASE).expect("write base spec");
    let specs: Vec<PathBuf> = GLB_OVERRIDES
        .iter()
        .enumerate()
        .map(|(i, glb)| {
            let path = spec_dir.join(format!("tenant_{i}.qsl"));
            let body = format!("include \"base.qsl\"\noverride sweep {{ glb_kib = {glb} }}\n");
            fs::write(&path, body).expect("write tenant spec");
            path
        })
        .collect();
    let queue = BatchQueue::build(&specs).expect("build batch queue");

    let out = root.join("batch");
    let config = ServeConfig::new(&out);

    section("4-campaign batch, shared-cache dedupe");
    let cold = bench_with("serve_cold_4_campaigns", BenchConfig::heavy(), || {
        reset_out_dir(&out, false);
        serve(&queue, &config).expect("cold batch")
    });
    println!("{}", cold.render());
    // One priming batch leaves cache.json covering the whole joint space;
    // the measured re-serves evaluate nothing.
    reset_out_dir(&out, false);
    let primed = serve(&queue, &config).expect("cache priming batch");
    let (prime_hits, prime_misses) = batch_hits(&primed);
    let warm = bench_with("serve_warm_4_campaigns", BenchConfig::heavy(), || {
        reset_out_dir(&out, true);
        serve(&queue, &config).expect("warm batch")
    });
    println!("{}", warm.render());
    println!(
        "warm-cache speedup: {:.1}x (cold batch: {prime_hits} in-batch hits / \
         {prime_misses} misses over {} cached points)",
        cold.summary.mean / warm.summary.mean.max(1e-9),
        primed.cache_entries,
    );

    let _ = fs::remove_dir_all(&root);

    println!("CSV:");
    for result in [&cold, &warm] {
        println!("{}", result.to_csv_row());
    }

    qadam::bench::finish("serve_batch", &qadam::bench::HostMeta::from_env());
}
