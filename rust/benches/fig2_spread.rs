//! Bench: regenerate **Fig. 2** — the perf/area and energy spread across
//! PE types and precisions that motivates the framework ("more than 5×
//! and 35×, respectively"). Prints the figure data + timing.

use qadam::bench::{bench_with, section, BenchConfig};
use qadam::coordinator::default_workers;
use qadam::report;

fn main() {
    section("Fig. 2 — design-space spread across PE types");
    let workers = default_workers();
    let mut figure = None;
    bench_with("fig2_generation", BenchConfig::heavy(), || {
        figure = Some(report::fig2(workers, 7).expect("fig2 generation"));
    });
    let figure = figure.unwrap();
    print!("{}", figure.render());
    println!("\nCSV:\n{}", figure.table.to_csv());

    qadam::bench::finish("fig2_spread", &qadam::bench::HostMeta::from_env());
}
