//! Bench: regenerate **Fig. 3** — actual vs polynomial-estimated power /
//! performance / area per PE type ("the proposed polynomial model agrees
//! closely with the actual values extracted from the synthesis tools").
//! Also times the synthesis sweep vs the fitted-surrogate prediction to
//! quantify the speed-up the surrogate buys the DSE.

use qadam::arch::SweepSpec;
use qadam::bench::{bench, bench_with, section, BenchConfig};
use qadam::ppa::{design_features, PpaModel};
use qadam::quant::PeType;
use qadam::report;
use qadam::synth::synthesize_sweep;

fn main() {
    section("Fig. 3 — PPA surrogate fit quality");
    let mut figure = None;
    bench_with("fig3_generation", BenchConfig::heavy(), || {
        figure = Some(report::fig3(7).expect("fig3 generation"));
    });
    let figure = figure.unwrap();
    print!("{}", figure.render());
    println!("\nCSV:\n{}", figure.table.to_csv());

    section("surrogate speed-up (synthesis vs polynomial prediction)");
    let spec = SweepSpec::default();
    let dataset = synthesize_sweep(&spec, PeType::Int16, 7);
    let model = PpaModel::fit(&dataset, 5, 7);
    let configs = spec.clone().for_pe(PeType::Int16).enumerate();
    let synth_result = bench("synthesize_180_configs", || {
        synthesize_sweep(&spec, PeType::Int16, 7)
    });
    let features: Vec<Vec<f64>> = configs.iter().map(design_features).collect();
    let predict_result = bench("surrogate_predict_180_configs", || {
        features.iter().map(|x| model.area.predict(x)).sum::<f64>()
    });
    println!(
        "\nsurrogate is {:.0}x faster than re-synthesis (the paper's \"significantly\n\
         speed up the design space exploration\")",
        synth_result.summary.p50 / predict_result.summary.p50.max(1e-12)
    );

    qadam::bench::finish("fig3_model_fit", &qadam::bench::HostMeta::from_env());
}
