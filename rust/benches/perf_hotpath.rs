//! Hot-path micro/meso benches for the §Perf pass (EXPERIMENTS.md):
//!
//! * mapper throughput (layers/s) — the inner loop of every DSE eval,
//! * synthesis throughput (configs/s),
//! * full-campaign throughput (evals/s) at several worker counts,
//! * joint hardware × model campaign throughput (the large-space case),
//! * linalg / regression kernels backing the PPA surrogates,
//! * PJRT runtime step latency (if artifacts are present),
//! * cycle-level simulator throughput (MACs/s).
//!
//! With `QADAM_BENCH_OUT=dir` set, the run emits `dir/perf_hotpath.json`
//! (`qadam.bench` schema 1) for `qadam bench merge` / `qadam bench diff`.

use qadam::arch::{AcceleratorConfig, ModelAxes, SweepSpec};
use qadam::bench::{bench, bench_with, finish, section, BenchConfig, HostMeta};
use qadam::dataflow::{map_model, Dataflow};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::explore::Explorer;
use qadam::ppa::linalg::{cholesky, normal_equations, ridge_fit, solve_spd, Matrix};
use qadam::ppa::regression::{PolyModel, PredictScratch};
use qadam::quant::PeType;
use qadam::sim;
use qadam::synth;
use qadam::util::rng::Pcg64;

fn main() {
    section("L3 hot path — analytical mapper");
    let config = AcceleratorConfig::default();
    let cifar = model_for(ModelKind::ResNet56, Dataset::Cifar10);
    let imagenet = model_for(ModelKind::ResNet50, Dataset::ImageNet);
    let result = bench("map_resnet56_cifar10", || {
        map_model(&cifar, &config, Dataflow::RowStationary)
    });
    println!(
        "  -> {:.0} model-mappings/s ({} layers each)",
        1.0 / result.summary.p50,
        cifar.layers.len()
    );
    bench("map_resnet50_imagenet", || {
        map_model(&imagenet, &config, Dataflow::RowStationary)
    });

    section("L3 hot path — synthesis engine");
    let result = bench("synthesize_one_config", || synth::synthesize(&config, 7));
    println!("  -> {:.0} syntheses/s", 1.0 / result.summary.p50);

    section("L3 hot path — full campaign scaling (ImageNet, heaviest workload)");
    for workers in [1, 2, 4, qadam::coordinator::default_workers()] {
        let explorer = Explorer::over(SweepSpec::default())
            .dataset(Dataset::ImageNet)
            .workers(workers)
            .seed(7);
        let result = bench_with(
            &format!("campaign_workers_{workers}"),
            BenchConfig { warmup_iters: 1, measure_iters: 3 },
            || explorer.run().expect("campaign"),
        );
        let evals = SweepSpec::default().len() * 3;
        println!("  -> {:.0} evals/s at {workers} workers", evals as f64 / result.summary.p50);
    }

    section("L3 hot path — joint hardware x model campaign (CIFAR-10, 4 variants/model)");
    // Non-trivial ModelAxes quadruple the workload set: every zoo model is
    // evaluated at {0.5, 1.0} width x {1, 2} depth. This is the large-space
    // configuration the streaming rewrite targets.
    let axes = ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1, 2] };
    for workers in [2, qadam::coordinator::default_workers()] {
        let explorer = Explorer::over(SweepSpec::default())
            .dataset(Dataset::Cifar10)
            .model_axes(axes.clone())
            .workers(workers)
            .seed(7);
        let mut db = None;
        let result = bench_with(
            &format!("joint_campaign_workers_{workers}"),
            BenchConfig { warmup_iters: 1, measure_iters: 3 },
            || db = Some(explorer.run().expect("joint campaign")),
        );
        let evals = db.expect("at least one measured run").stats.evaluations;
        println!("  -> {:.0} evals/s at {workers} workers ({evals} evals: {} variants/model)",
            evals as f64 / result.summary.p50,
            axes.len()
        );
    }

    section("surrogate kernels — linalg (240x24 design)");
    // Sized like a degree-2 polynomial basis over the synthesis sweep:
    // a tall-thin design matrix and its SPD normal equations.
    let (rows, p) = (240, 24);
    let mut rng = Pcg64::new(11);
    let design = Matrix {
        rows,
        cols: p,
        data: (0..rows * p).map(|_| rng.uniform(-1.0, 1.0)).collect(),
    };
    let targets: Vec<f64> = (0..rows).map(|_| rng.uniform(0.0, 10.0)).collect();
    bench("normal_equations_240x24", || normal_equations(&design, &targets));
    let (mut gram, moment) = normal_equations(&design, &targets);
    for i in 0..p {
        gram.data[i * p + i] += 1.0; // ridge shift => comfortably SPD
    }
    bench("cholesky_24x24", || cholesky(&gram).expect("SPD"));
    bench("solve_spd_24x24", || solve_spd(&gram, &moment).expect("SPD"));
    bench("ridge_fit_240x24", || ridge_fit(&design, &targets, 1e-6).expect("SPD"));

    section("surrogate kernels — polynomial regression (200x5, degree 2)");
    let xs: Vec<Vec<f64>> =
        (0..200).map(|_| (0..5).map(|_| rng.uniform(0.5, 4.0)).collect()).collect();
    let ys: Vec<f64> =
        xs.iter().map(|x| x[0] * x[1] + 0.3 * x[2] * x[2] + x[3] - x[4]).collect();
    bench("poly_fit_200x5_deg2", || PolyModel::fit(&xs, &ys, 2, 1e-6));
    let model = PolyModel::fit(&xs, &ys, 2, 1e-6);
    let mut scratch = PredictScratch::default();
    let result = bench("poly_predict_200_reused_scratch", || {
        xs.iter().map(|x| model.predict_with(x, &mut scratch)).sum::<f64>()
    });
    println!(
        "  -> {:.2} M predictions/s",
        xs.len() as f64 / result.summary.p50 / 1e6
    );

    section("cycle-level simulator");
    let layer = qadam::dnn::Layer::conv("bench", 16, 8, 16, 3, 1, 1);
    let mut rng = Pcg64::new(3);
    let ifmap: Vec<f64> = (0..layer.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let weights: Vec<f64> = (0..layer.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect();
    let sim_config = AcceleratorConfig { pe: PeType::Int16, rows: 6, cols: 16, ..Default::default() };
    let result = bench_with("simulate_conv_16x16x8_to_16", BenchConfig::heavy(), || {
        sim::simulate_layer(&layer, &sim_config, &ifmap, &weights)
    });
    println!(
        "  -> {:.1} M simulated MACs/s",
        layer.macs() as f64 / result.summary.p50 / 1e6
    );

    section("PJRT runtime (needs `make artifacts` and the `pjrt` feature)");
    bench_pjrt_runtime();

    finish("perf_hotpath", &HostMeta::from_env());
}

#[cfg(feature = "pjrt")]
fn bench_pjrt_runtime() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let mut runtime = qadam::runtime::Runtime::new(&artifacts).unwrap();
        runtime.prepare("train_lightpe1").unwrap();
        runtime.prepare("batch").unwrap();
        let mut driver =
            qadam::runtime::QatDriver::new(&mut runtime, PeType::LightPe1).unwrap();
        let mut step = 0i32;
        let result = bench_with("qat_train_step_lightpe1", BenchConfig::heavy(), || {
            step += 1;
            driver.step(&mut runtime, step).unwrap()
        });
        println!("  -> {:.1} train steps/s", 1.0 / result.summary.p50);
    } else {
        println!("  skipped (no artifacts)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt_runtime() {
    println!("  skipped (built without the `pjrt` feature)");
}
