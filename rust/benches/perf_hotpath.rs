//! Hot-path micro/meso benches for the §Perf pass (EXPERIMENTS.md):
//!
//! * mapper throughput (layers/s) — the inner loop of every DSE eval,
//! * synthesis throughput (configs/s),
//! * full-campaign throughput (evals/s) at several worker counts,
//! * PJRT runtime step latency (if artifacts are present),
//! * cycle-level simulator throughput (MACs/s).

use qadam::arch::{AcceleratorConfig, SweepSpec};
use qadam::bench::{bench, bench_with, section, BenchConfig};
use qadam::dataflow::{map_model, Dataflow};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::explore::Explorer;
use qadam::quant::PeType;
use qadam::sim;
use qadam::synth;
use qadam::util::rng::Pcg64;

fn main() {
    section("L3 hot path — analytical mapper");
    let config = AcceleratorConfig::default();
    let cifar = model_for(ModelKind::ResNet56, Dataset::Cifar10);
    let imagenet = model_for(ModelKind::ResNet50, Dataset::ImageNet);
    let result = bench("map_resnet56_cifar10", || {
        map_model(&cifar, &config, Dataflow::RowStationary)
    });
    println!(
        "  -> {:.0} model-mappings/s ({} layers each)",
        1.0 / result.summary.p50,
        cifar.layers.len()
    );
    bench("map_resnet50_imagenet", || {
        map_model(&imagenet, &config, Dataflow::RowStationary)
    });

    section("L3 hot path — synthesis engine");
    let result = bench("synthesize_one_config", || synth::synthesize(&config, 7));
    println!("  -> {:.0} syntheses/s", 1.0 / result.summary.p50);

    section("L3 hot path — full campaign scaling (ImageNet, heaviest workload)");
    for workers in [1, 2, 4, qadam::coordinator::default_workers()] {
        let explorer = Explorer::over(SweepSpec::default())
            .dataset(Dataset::ImageNet)
            .workers(workers)
            .seed(7);
        let result = bench_with(
            &format!("campaign_workers_{workers}"),
            BenchConfig { warmup_iters: 1, measure_iters: 3 },
            || explorer.run().expect("campaign"),
        );
        let evals = SweepSpec::default().len() * 3;
        println!("  -> {:.0} evals/s at {workers} workers", evals as f64 / result.summary.p50);
    }

    section("cycle-level simulator");
    let layer = qadam::dnn::Layer::conv("bench", 16, 8, 16, 3, 1, 1);
    let mut rng = Pcg64::new(3);
    let ifmap: Vec<f64> = (0..layer.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let weights: Vec<f64> = (0..layer.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect();
    let sim_config = AcceleratorConfig { pe: PeType::Int16, rows: 6, cols: 16, ..Default::default() };
    let result = bench_with("simulate_conv_16x16x8_to_16", BenchConfig::heavy(), || {
        sim::simulate_layer(&layer, &sim_config, &ifmap, &weights)
    });
    println!(
        "  -> {:.1} M simulated MACs/s",
        layer.macs() as f64 / result.summary.p50 / 1e6
    );

    section("PJRT runtime (needs `make artifacts` and the `pjrt` feature)");
    bench_pjrt_runtime();
}

#[cfg(feature = "pjrt")]
fn bench_pjrt_runtime() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let mut runtime = qadam::runtime::Runtime::new(&artifacts).unwrap();
        runtime.prepare("train_lightpe1").unwrap();
        runtime.prepare("batch").unwrap();
        let mut driver =
            qadam::runtime::QatDriver::new(&mut runtime, PeType::LightPe1).unwrap();
        let mut step = 0i32;
        let result = bench_with("qat_train_step_lightpe1", BenchConfig::heavy(), || {
            step += 1;
            driver.step(&mut runtime, step).unwrap()
        });
        println!("  -> {:.1} train steps/s", 1.0 / result.summary.p50);
    } else {
        println!("  skipped (no artifacts)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt_runtime() {
    println!("  skipped (built without the `pjrt` feature)");
}
