//! Bench: regenerate **Fig. 6** — Pareto front of top-1 error vs
//! normalized energy for CIFAR-10 and CIFAR-100 ("LightPE-1 and LightPE-2
//! achieve 4.7× and 4× less energy on average ... LightPEs are
//! systematically on Pareto-front").

use qadam::bench::{bench_with, section, BenchConfig};
use qadam::coordinator::default_workers;
use qadam::dnn::Dataset;
use qadam::report;

fn main() {
    let workers = default_workers();
    for dataset in [Dataset::Cifar10, Dataset::Cifar100] {
        section(&format!("Fig. 6 — error vs energy ({})", dataset.name()));
        let mut figure = None;
        bench_with(
            &format!("fig6_{}", dataset.name()),
            BenchConfig { warmup_iters: 0, measure_iters: 1 },
            || {
                figure = Some(report::fig6(dataset, workers, 7).expect("fig6 generation"));
            },
        );
        let figure = figure.unwrap();
        print!("{}", figure.render());
        println!("CSV:\n{}", figure.table.to_csv());
    }

    qadam::bench::finish("fig6_pareto_energy", &qadam::bench::HostMeta::from_env());
}
