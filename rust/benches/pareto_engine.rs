//! Bench: the online Pareto engine vs the post-hoc quadratic scan, and
//! non-exhaustive search strategies vs the exhaustive walk.
//!
//! Two claims to quantify: (1) streaming dominance pruning turns front
//! maintenance from O(n²)-after-the-fact into O(front) per insert, so
//! the front is available live at a fraction of the batch cost; (2) a
//! `random:N` / `halving:K` strategy campaign does work proportional to
//! its selection, not to the cross-product.

use qadam::bench::{bench, bench_with, section, BenchConfig};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::dse::{pareto_front, pareto_front_reference, Orientation};
use qadam::explore::Explorer;
use qadam::pareto::{FrontCore, RandomSample, SuccessiveHalving};
use qadam::util::rng::Pcg64;

fn synthetic_cloud(n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            // Correlated trade-off cloud: perf up, energy up, plus noise —
            // produces realistic front sizes (tens, not thousands).
            let x = rng.uniform(0.0, 100.0);
            let y = x * rng.uniform(0.5, 1.5) + rng.uniform(0.0, 20.0);
            vec![x, y]
        })
        .collect()
}

fn main() {
    let orientations = [Orientation::Maximize, Orientation::Minimize];

    section("front maintenance: streaming engine vs post-hoc scan");
    for &n in &[1_000usize, 10_000] {
        let cloud = synthetic_cloud(n, 42);
        bench(&format!("stream_insert_{n}"), || {
            let mut front = FrontCore::new(orientations.to_vec());
            for point in &cloud {
                front.insert(point.clone(), ());
            }
            front.len()
        });
        bench(&format!("batch_engine_{n}"), || pareto_front(&cloud, &orientations).len());
        // The quadratic oracle only at the smaller size (it is the point
        // of the comparison, not something to wait on).
        if n <= 1_000 {
            bench(&format!("batch_reference_{n}"), || {
                pareto_front_reference(&cloud, &orientations).len()
            });
        }
    }

    // The sharded fold produces a front bit-identical to the sequential
    // insertion (global seq numbers preserve tie-breaks), so this section
    // measures pure overhead/speedup, not a quality trade.
    section("frontier merge: sequential fold vs sharded tree-merge");
    let cloud = synthetic_cloud(100_000, 42);
    let merge_config = BenchConfig { warmup_iters: 0, measure_iters: 2 };
    bench_with("merge_sequential_100000", merge_config, || {
        let mut front = FrontCore::new(orientations.to_vec());
        for point in &cloud {
            front.insert(point.clone(), ());
        }
        front.len()
    });
    let shard_fold = |shards: usize, parallel: bool| {
        let chunk = cloud.len().div_ceil(shards).max(1);
        let fold = |idx: usize, slice: &[Vec<f64>]| {
            let mut front = FrontCore::new(orientations.to_vec());
            for (off, point) in slice.iter().enumerate() {
                front.offer_seq(idx * chunk + off, point.clone(), ());
            }
            front
        };
        let fronts: Vec<_> = if parallel {
            std::thread::scope(|scope| {
                let handles: Vec<_> = cloud
                    .chunks(chunk)
                    .enumerate()
                    .map(|(idx, slice)| scope.spawn(move || fold(idx, slice)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("shard fold")).collect()
            })
        } else {
            cloud.chunks(chunk).enumerate().map(|(idx, slice)| fold(idx, slice)).collect()
        };
        FrontCore::merge_all(fronts).map(|front| front.len()).unwrap_or(0)
    };
    for &shards in &[4usize, 16] {
        bench_with(&format!("merge_sharded_{shards}x_100000"), merge_config, || {
            shard_fold(shards, false)
        });
    }
    bench_with("merge_parallel_4x_100000", merge_config, || shard_fold(4, true));

    section("campaign wall-clock: exhaustive vs strategy walks");
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let build = || {
        Explorer::over(qadam::arch::SweepSpec::default())
            .model(model.clone())
            .workers(0)
            .seed(7)
    };
    let config = BenchConfig { warmup_iters: 0, measure_iters: 2 };
    bench_with("campaign_exhaustive", config, || {
        build().run().expect("exhaustive campaign").stats.evaluations
    });
    bench_with("campaign_random_32", config, || {
        build()
            .strategy(RandomSample { n: 32, seed: 11 })
            .run()
            .expect("random campaign")
            .stats
            .evaluations
    });
    bench_with("campaign_halving_32", config, || {
        build()
            .strategy(SuccessiveHalving { keep: 32, rounds: 3 })
            .run()
            .expect("halving campaign")
            .stats
            .evaluations
    });

    qadam::bench::finish("pareto_engine", &qadam::bench::HostMeta::from_env());
}
