//! Cross-module tests for the unified `Explorer` API and the lazy
//! `SweepSpec`/`DesignSpace` iteration underneath it: property tests
//! that the lazy cross-product matches an eager golden reference,
//! equivalence of `Explorer::run` with the serial path, typed-error
//! behavior for baseline-free spaces, joint hardware × model campaigns
//! (end-to-end run, byte-identical resume, per-family frontiers), and
//! the differential persistence guarantees (warm cache ≡ cold run,
//! resumed checkpoint ≡ uninterrupted run, bit-for-bit).

use std::sync::{Arc, Mutex};

use qadam::arch::{AcceleratorConfig, ModelAxes, SweepSpec};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::dse;
use qadam::explore::{Explorer, PointCache};
use qadam::quant::PeType;
use qadam::util::prop::{check_with, pair, usize_in, Config};
use qadam::Error;

/// Eager golden reference: the nested-loop cross-product the lazy decoder
/// must reproduce exactly, order included.
fn golden_cross_product(spec: &SweepSpec) -> Vec<AcceleratorConfig> {
    let mut out = Vec::with_capacity(spec.len());
    for &pe in &spec.pe_types {
        for &(rows, cols) in &spec.array_dims {
            for &glb_kib in &spec.glb_kib {
                for &spad in &spec.spads {
                    for &dram_bw_gbps in &spec.dram_bw_gbps {
                        for &clock_ghz in &spec.clock_ghz {
                            out.push(AcceleratorConfig {
                                pe,
                                rows,
                                cols,
                                spad,
                                glb_kib,
                                dram_bw_gbps,
                                clock_ghz,
                            });
                        }
                    }
                }
            }
        }
    }
    out
}

/// Truncate the default spec's axes to randomized lengths.
fn random_subspec(npe: usize, ndims: usize, nglb: usize, nbw: usize) -> SweepSpec {
    let d = SweepSpec::default();
    SweepSpec {
        pe_types: d.pe_types[..npe.min(d.pe_types.len())].to_vec(),
        array_dims: d.array_dims[..ndims.min(d.array_dims.len())].to_vec(),
        glb_kib: d.glb_kib[..nglb.min(d.glb_kib.len())].to_vec(),
        spads: d.spads[..2].to_vec(),
        dram_bw_gbps: d.dram_bw_gbps[..nbw.min(d.dram_bw_gbps.len())].to_vec(),
        clock_ghz: d.clock_ghz.clone(),
    }
}

#[test]
fn prop_lazy_iter_matches_eager_cross_product() {
    let gen = pair(pair(usize_in(1, 4), usize_in(1, 5)), pair(usize_in(1, 4), usize_in(1, 3)));
    check_with(
        &Config { cases: 64, ..Default::default() },
        &gen,
        |&((npe, ndims), (nglb, nbw))| {
            let spec = random_subspec(npe, ndims, nglb, nbw);
            let golden = golden_cross_product(&spec);
            if spec.iter().len() != golden.len() || spec.len() != golden.len() {
                return false;
            }
            spec.iter().zip(&golden).all(|(lazy, eager)| lazy == *eager)
        },
    );
}

#[test]
fn prop_shard_iters_partition_every_space() {
    let gen = pair(pair(usize_in(1, 4), usize_in(1, 5)), usize_in(1, 7));
    check_with(
        &Config { cases: 48, ..Default::default() },
        &gen,
        |&((npe, ndims), num_shards)| {
            let spec = random_subspec(npe, ndims, 2, 2);
            let mut recombined: Vec<String> = (0..num_shards)
                .flat_map(|shard| spec.shard_iter(shard, num_shards))
                .map(|c| c.id())
                .collect();
            recombined.sort();
            let mut expected: Vec<String> = spec.iter().map(|c| c.id()).collect();
            expected.sort();
            recombined == expected
        },
    );
}

#[test]
fn explorer_run_matches_serial_evaluate() {
    let spec = SweepSpec::tiny();
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let serial: Vec<dse::Evaluation> =
        spec.iter().map(|c| dse::evaluate(&c, &model, 7)).collect();
    let db = Explorer::over(spec).model(model).workers(4).seed(7).run().unwrap();
    let parallel = &db.spaces[0].evals;
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(parallel) {
        assert_eq!(a.config.id(), b.config.id());
        assert_eq!(a.perf_per_area, b.perf_per_area);
        assert_eq!(a.energy_uj, b.energy_uj);
        assert_eq!(a.latency_ms, b.latency_ms);
    }
}

#[test]
fn joint_campaign_runs_end_to_end_and_resumes_byte_identically() {
    // A joint hardware × model campaign: 2 widths × 2 depths over the
    // tiny sweep, checkpointed, killed, and resumed — the acceptance
    // path of the co-exploration refactor.
    let dir = std::env::temp_dir().join(format!("qadam_joint_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("joint.journal");
    let axes = ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1, 2] };
    let build = || {
        Explorer::over(SweepSpec::tiny())
            .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
            .model_axes(axes.clone())
            .workers(3)
            .seed(7)
    };
    let uninterrupted = build().run().unwrap();
    let reference = uninterrupted.to_json().to_string_pretty();
    // One space per variant, all four variants of the base family.
    assert_eq!(uninterrupted.spaces.len(), 4);
    assert_eq!(uninterrupted.stats.design_points, 4 * SweepSpec::tiny().len());
    assert!(uninterrupted.has_model_variants());
    // Joint databases claim schema v4 so pre-joint readers reject them
    // cleanly instead of misreading variants as independent models.
    let rendered = uninterrupted.to_json().to_string_canonical();
    assert!(rendered.contains("\"schema\":4"), "joint db must claim v4");
    let parsed = qadam::explore::EvalDatabase::from_json(
        &qadam::util::json::Json::parse(&rendered).unwrap(),
    )
    .unwrap();
    assert_eq!(parsed.spaces, uninterrupted.spaces, "v4 db must round-trip");

    // Checkpointed run matches; then simulate a kill after a few points.
    let full = build().checkpoint(&journal, 1).run().unwrap();
    assert_eq!(full.to_json().to_string_pretty(), reference);
    let text = std::fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.split_inclusive('\n').collect();
    assert!(lines.len() > 5, "joint campaign must journal several points");
    let mut partial: String = lines[..5].concat();
    partial.push_str("{\"evals\":[{\"area_m"); // torn write
    std::fs::write(&journal, &partial).unwrap();
    let resumed = build().checkpoint(&journal, 2).run().unwrap();
    assert_eq!(
        resumed.to_json().to_string_pretty(),
        reference,
        "joint resume must be byte-identical to the uninterrupted run"
    );

    // Resuming under different model axes is rejected by name.
    let err = Explorer::over(SweepSpec::tiny())
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .model_axes(ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] })
        .workers(2)
        .seed(7)
        .checkpoint(&journal, 2)
        .run()
        .unwrap_err();
    assert_eq!(err.kind(), "invalid_config");
    assert!(err.to_string().contains("model axes"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn joint_frontier_accumulates_all_variants_per_base_family() {
    use qadam::pareto::CampaignFrontier;
    let spec = SweepSpec::tiny();
    let axes = ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] };
    let frontier = Arc::new(Mutex::new(CampaignFrontier::new()));
    Explorer::over(spec.clone())
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .model_axes(axes)
        .workers(2)
        .seed(7)
        .frontier(frontier.clone())
        .run()
        .unwrap();
    let guard = frontier.lock().unwrap();
    // One front per *base* model, offered every joint point.
    assert_eq!(guard.models().len(), 1);
    assert_eq!(guard.models()[0].front().offered(), 2 * spec.len());
}

#[test]
fn stream_equals_run() {
    let spec = SweepSpec::tiny();
    let explorer = Explorer::over(spec)
        .dataset(Dataset::Cifar10)
        .workers(4)
        .seed(7);
    let mut streamed: Vec<(usize, String, Vec<f64>)> = Vec::new();
    explorer
        .stream(|point| {
            let energies = point.evals.iter().map(|e| e.energy_uj).collect();
            streamed.push((point.index, point.config.id(), energies));
        })
        .unwrap();
    let db = explorer.run().unwrap();
    // Transpose the database back to per-point order and compare.
    for (pos, (index, config_id, energies)) in streamed.iter().enumerate() {
        assert_eq!(*index, pos);
        for (space, energy) in db.spaces.iter().zip(energies) {
            assert_eq!(space.evals[pos].config.id(), *config_id);
            assert_eq!(space.evals[pos].energy_uj, *energy);
        }
    }
}

#[test]
fn int16_free_space_yields_missing_baseline_not_panic() {
    let spec = SweepSpec { pe_types: vec![PeType::LightPe1, PeType::Fp32], ..SweepSpec::tiny() };
    let db = Explorer::over(spec)
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .workers(2)
        .seed(7)
        .run()
        .unwrap();
    let evals = &db.spaces[0].evals;
    assert!(!evals.is_empty());
    assert!(matches!(dse::normalize(evals), Err(Error::MissingBaseline(_))));
    assert!(matches!(dse::headline_ratios(evals), Err(Error::MissingBaseline(_))));
    assert!(matches!(db.headline_geomean(), Err(Error::MissingBaseline(_))));
}

#[test]
fn degenerate_sweep_yields_invalid_config() {
    let mut spec = SweepSpec::tiny();
    spec.dram_bw_gbps.clear();
    let err = Explorer::over(spec)
        .dataset(Dataset::Cifar10)
        .run()
        .unwrap_err();
    assert!(matches!(err, Error::InvalidConfig(_)));
}

#[test]
fn warm_point_cache_run_is_bit_identical_to_cold() {
    let spec = SweepSpec::tiny();
    let cold = Explorer::over(spec.clone())
        .dataset(Dataset::Cifar10)
        .workers(3)
        .seed(7)
        .run()
        .unwrap();
    let reference = cold.to_json().to_string_pretty();
    let cache = Arc::new(Mutex::new(PointCache::new()));
    let build = || {
        Explorer::over(spec.clone())
            .dataset(Dataset::Cifar10)
            .workers(3)
            .seed(7)
            .cache(cache.clone())
    };
    let first = build().run().unwrap(); // fills the cache
    let second = build().run().unwrap(); // served entirely from it
    assert_eq!(first.to_json().to_string_pretty(), reference);
    assert_eq!(second.to_json().to_string_pretty(), reference);
    let guard = cache.lock().unwrap();
    assert_eq!(guard.len(), spec.len());
    assert_eq!(guard.misses() as usize, spec.len(), "cold pass misses once per point");
    assert_eq!(guard.hits() as usize, spec.len(), "warm pass hits every point");
}

#[test]
fn resumed_checkpoint_run_is_byte_identical_to_uninterrupted() {
    let dir = std::env::temp_dir()
        .join(format!("qadam_explorer_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("campaign.journal");
    let build = || {
        Explorer::over(SweepSpec::tiny()).dataset(Dataset::Cifar10).workers(3).seed(7)
    };
    let uninterrupted = build().run().unwrap();
    let reference = uninterrupted.to_json().to_string_pretty();

    // A full checkpointed run matches the plain run.
    let full = build().checkpoint(&journal, 1).run().unwrap();
    assert_eq!(full.to_json().to_string_pretty(), reference);

    // Simulate a mid-campaign kill: keep the header plus the first three
    // flushed entries, then a torn trailing fragment of the fourth.
    let text = std::fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.split_inclusive('\n').collect();
    assert!(lines.len() > 4, "tiny campaign must journal several points");
    let mut partial: String = lines[..4].concat();
    partial.push_str("{\"evals\":[{\"area_m"); // killed mid-write
    std::fs::write(&journal, &partial).unwrap();

    // Resume: the flushed prefix replays in order without re-evaluation,
    // the tail is recomputed, and the database is byte-identical.
    let mut delivered = Vec::new();
    let explorer = build().checkpoint(&journal, 2);
    explorer.stream(|point| delivered.push(point.index)).unwrap();
    assert_eq!(delivered, (0..SweepSpec::tiny().len()).collect::<Vec<_>>());
    let resumed = explorer.run().unwrap();
    assert_eq!(resumed.to_json().to_string_pretty(), reference);

    // The journal is complete again: a further resume replays everything
    // (zero evaluation work) and still reproduces the same bytes.
    let replayed = build().checkpoint(&journal, 5).run().unwrap();
    assert_eq!(replayed.to_json().to_string_pretty(), reference);
    let _ = std::fs::remove_dir_all(&dir);
}
