//! Golden-snapshot regression suite: pins the `evaluate` numerics, the
//! INT16 normalization, the headline ratios, and the Fig. 5/6 Pareto
//! fronts bit-for-bit over a small pinned sweep.
//!
//! Cached campaign results (`explore::persist`) are only trustworthy if
//! the evaluation math is frozen, so any numeric drift — an energy-model
//! tweak, a synthesis-noise change, a float reassociation — fails these
//! tests until the fixtures are deliberately regenerated with
//!
//! ```text
//! QADAM_BLESS=1 cargo test --test golden
//! ```
//!
//! and the resulting `rust/tests/golden/*.json` diffs are reviewed and
//! committed. A missing fixture is blessed on first run (and should be
//! committed); a present fixture is compared byte-for-byte, and on
//! mismatch the fresh rendering is written next to it as `<name>.new`.
//! Every test also recomputes its snapshot twice and asserts the two
//! renderings agree, so even the blessing run proves determinism.

mod common;

use common::assert_snapshot;
use qadam::accuracy;
use qadam::arch::{ScratchpadCfg, SweepSpec};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::dse::{self, Orientation};
use qadam::explore::{EvalDatabase, Explorer};
use qadam::quant::PeType;
use qadam::util::json::{num, obj, s, Json};

const SEED: u64 = 7;

/// The pinned sweep: all four PE types over two array sizes — small
/// enough to snapshot wholesale, wide enough that the INT16 baseline,
/// the LightPE wins, and every Fig. 5/6 best-point exist.
fn pinned_spec() -> SweepSpec {
    SweepSpec {
        pe_types: PeType::ALL.to_vec(),
        array_dims: vec![(8, 8), (16, 16)],
        glb_kib: vec![128],
        spads: vec![ScratchpadCfg::default()],
        dram_bw_gbps: vec![8.0],
        clock_ghz: vec![2.0],
    }
}

fn pinned_db() -> EvalDatabase {
    Explorer::over(pinned_spec())
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(SEED)
        .run()
        .expect("pinned campaign")
}

/// Snapshot of the raw `evaluate` outputs (every metric, full f64
/// precision) for ResNet-20 across the pinned sweep.
#[test]
fn golden_evaluate_outputs() {
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let render = || {
        let evals: Vec<Json> = pinned_spec()
            .iter()
            .map(|config| dse::evaluate(&config, &model, SEED).to_json())
            .collect();
        Json::Arr(evals).to_string_pretty()
    };
    let first = render();
    assert_eq!(first, render(), "evaluate must be deterministic given (config, model, seed)");
    assert_snapshot("evaluate_resnet20.json", &first);
}

/// Snapshot of the paper's normalization: per-model headline ratios and
/// the full normalized ResNet-20 cloud, all at full precision.
#[test]
fn golden_headline_ratios_and_normalization() {
    let render = || {
        let db = pinned_db();
        let mut models = Vec::new();
        for space in &db.spaces {
            let ratios: Vec<Json> = dse::headline_ratios(&space.evals)
                .expect("pinned sweep has an INT16 baseline")
                .into_iter()
                .map(|(pe, ppa, energy)| {
                    obj(vec![
                        ("pe", s(pe.name())),
                        ("perf_per_area_gain", num(ppa)),
                        ("energy_gain", num(energy)),
                    ])
                })
                .collect();
            models.push(obj(vec![
                ("model", s(&space.model_name)),
                ("headline", Json::Arr(ratios)),
            ]));
        }
        let resnet20 = db
            .spaces
            .iter()
            .find(|space| space.model_name == "ResNet-20")
            .expect("ResNet-20 space");
        let normalized: Vec<Json> = dse::normalize(&resnet20.evals)
            .expect("pinned sweep has an INT16 baseline")
            .into_iter()
            .map(|p| {
                obj(vec![
                    ("config", s(&p.config_id)),
                    ("pe", s(p.pe.name())),
                    ("norm_perf_per_area", num(p.norm_perf_per_area)),
                    ("norm_energy", num(p.norm_energy)),
                ])
            })
            .collect();
        obj(vec![
            ("per_model", Json::Arr(models)),
            ("resnet20_normalized", Json::Arr(normalized)),
        ])
        .to_string_pretty()
    };
    let first = render();
    assert_eq!(first, render(), "normalization must be deterministic");
    assert_snapshot("headline_ratios.json", &first);
}

/// Render the Fig. 5/6 per-model best points and front membership, with
/// the front computed by `front_of` — shared by the post-hoc and
/// streaming-engine golden tests so their fixtures are comparable
/// byte-for-byte.
fn render_fig45(front_of: &dyn Fn(&[Vec<f64>], &[Orientation; 2]) -> Vec<usize>) -> String {
    let db = pinned_db();
    let mut panels = Vec::new();
    for space in &db.spaces {
        let kind = ModelKind::parse(&space.model_name).expect("paper model name");
        let baseline = dse::best_perf_per_area(&space.evals, PeType::Int16)
            .expect("pinned sweep has INT16 points");
        let base_energy =
            dse::best_energy(&space.evals, PeType::Int16).expect("INT16 energy baseline");
        for (figure, orientations) in [
            ("fig5", [Orientation::Maximize, Orientation::Maximize]),
            ("fig6", [Orientation::Minimize, Orientation::Minimize]),
        ] {
            let points: Vec<(PeType, f64, f64)> = PeType::ALL
                .iter()
                .map(|&pe| {
                    let entry = accuracy::registry(kind, Dataset::Cifar10, pe)
                        .expect("registry covers CIFAR-10");
                    if figure == "fig5" {
                        let best = dse::best_perf_per_area(&space.evals, pe)
                            .expect("pinned sweep covers every PE type");
                        (pe, best.perf_per_area / baseline.perf_per_area, entry.top1)
                    } else {
                        let best = dse::best_energy(&space.evals, pe)
                            .expect("pinned sweep covers every PE type");
                        (pe, best.energy_uj / base_energy.energy_uj, entry.top1_error())
                    }
                })
                .collect();
            let coords: Vec<Vec<f64>> = points.iter().map(|&(_, x, y)| vec![x, y]).collect();
            let front = front_of(&coords, &orientations);
            let rendered: Vec<Json> = points
                .iter()
                .enumerate()
                .map(|(idx, &(pe, x, y))| {
                    obj(vec![
                        ("pe", s(pe.name())),
                        ("x", num(x)),
                        ("y", num(y)),
                        ("on_front", Json::Bool(front.contains(&idx))),
                    ])
                })
                .collect();
            panels.push(obj(vec![
                ("model", s(&space.model_name)),
                ("figure", s(figure)),
                ("points", Json::Arr(rendered)),
            ]));
        }
    }
    Json::Arr(panels).to_string_pretty()
}

/// Snapshot of the Fig. 5 (accuracy vs perf/area) and Fig. 6 (error vs
/// energy) per-model best points and Pareto-front membership, computed
/// post-hoc (the quadratic reference oracle).
#[test]
fn golden_fig45_pareto_fronts() {
    let render = || render_fig45(&|points, o| dse::pareto_front_reference(points, o));
    let first = render();
    assert_eq!(first, render(), "Pareto extraction must be deterministic");
    assert_snapshot("fig45_pareto_fronts.json", &first);
}

/// The same Fig. 5/6 frontier produced by the *streaming engine*
/// ([`qadam::pareto::ParetoFront`]): must match the post-hoc rendering —
/// and therefore the post-hoc fixture — byte-for-byte.
#[test]
fn golden_fig56_engine_frontier() {
    let engine_front = |points: &[Vec<f64>], orientations: &[Orientation; 2]| {
        let mut front = qadam::pareto::ParetoFront::<2>::new(*orientations);
        for point in points {
            front.insert([point[0], point[1]], ());
        }
        front.indices()
    };
    let rendered = render_fig45(&engine_front);
    // Streaming engine ≡ post-hoc oracle, byte-for-byte, in-process.
    assert_eq!(
        rendered,
        render_fig45(&|points, o| dse::pareto_front_reference(points, o)),
        "engine frontier must reproduce the post-hoc Fig. 5/6 fronts exactly"
    );
    // The in-process equality above plus each test's own snapshot pin
    // the two fixtures to identical bytes transitively (comparing the
    // files directly here would race `golden_fig45_pareto_fronts`'s
    // bless of its fixture on a first run).
    assert_snapshot("fig56_engine_frontier.json", &rendered);
}

/// The paper's qualitative shape must hold on the pinned sweep even
/// before any fixture exists: LightPEs beat the INT16 baseline on both
/// axes. Guards against blessing a nonsensical snapshot.
#[test]
fn pinned_sweep_preserves_paper_shape() {
    let db = pinned_db();
    for space in &db.spaces {
        let ratios = dse::headline_ratios(&space.evals).unwrap();
        let light1 = ratios.iter().find(|(pe, _, _)| *pe == PeType::LightPe1).unwrap();
        assert!(light1.1 > 1.0, "{}: LightPE-1 perf/area gain {}", space.model_name, light1.1);
        assert!(light1.2 > 1.0, "{}: LightPE-1 energy gain {}", space.model_name, light1.2);
    }
}
