//! Property-based tests over the framework's invariants, using the
//! in-repo shrinking property-test harness (`qadam::util::prop`).
//!
//! Covered invariants:
//! * quantizers: bounded error, idempotence, monotone-in-bits accuracy;
//! * mapper: utilization ∈ (0, 1], cycles ≥ ideal, traffic conservation,
//!   monotone responses to array/scratchpad/bandwidth knobs;
//! * synthesis: positivity, monotone area in every size knob;
//! * Pareto: front members are mutually non-dominating and dominate the
//!   rest; normalization keeps the baseline at 1.0;
//! * regression: prediction exactness on polynomial ground truth;
//! * joint design spaces: lazy iteration ≡ eager cross-product, exact
//!   shard partition, scaling-sensitive cache keys, and hardware-only
//!   campaigns bit-identical to the pre-joint pipeline (the `joint`
//!   test-name prefix is the CI golden-job filter).

use qadam::arch::{AcceleratorConfig, DesignSpace, ModelAxes, ScratchpadCfg, SweepSpec};
use qadam::dataflow::map_layer_rs;
use qadam::dnn::{model_for, scale_model, Dataset, Layer, ModelKind};
use qadam::dse::{dominates, pareto_front, Orientation};
use qadam::explore::{point_key, Explorer};
use qadam::quant::{AffineQuantizer, PeType, Po2Quantizer};
use qadam::synth::synthesize_clean;
use qadam::util::prop::{check, check_with, f64_in, pair, usize_in, vec_of, Config};
use qadam::util::rng::Pcg64;

// ---------------------------------------------------------------- quantizers

#[test]
fn prop_affine_error_within_half_step() {
    let gen = pair(usize_in(3, 16), f64_in(-8.0, 8.0));
    check(&gen, |&(bits, x)| {
        let q = AffineQuantizer::with_scale(bits as u32, 0.05);
        let err = (q.fake_quantize(x) - x).abs();
        // Inside the representable range, error ≤ half a step.
        let limit = q.scale * q.qmax() as f64;
        if x.abs() <= limit {
            err <= q.scale / 2.0 + 1e-12
        } else {
            // Saturation: error bounded by the overshoot.
            (q.fake_quantize(x).abs() - limit).abs() < 1e-9
        }
    });
}

#[test]
fn prop_affine_idempotent() {
    let gen = f64_in(-4.0, 4.0);
    check(&gen, |&x| {
        let q = AffineQuantizer::with_scale(8, 0.03);
        let once = q.fake_quantize(x);
        (q.fake_quantize(once) - once).abs() < 1e-12
    });
}

#[test]
fn prop_po2_representable_and_idempotent() {
    let gen = vec_of(f64_in(-2.0, 2.0), 2, 32);
    check(&gen, |weights| {
        let max_abs = weights.iter().fold(0.0f64, |m, &w| m.max(w.abs()));
        if max_abs < 1e-9 {
            return true;
        }
        for pe in [PeType::LightPe1, PeType::LightPe2] {
            let q = Po2Quantizer::calibrate(pe, weights);
            for &w in weights {
                let (v, _) = q.quantize(w);
                let (v2, _) = q.quantize(v);
                if (v - v2).abs() > 1e-12 {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_lightpe2_never_worse_than_lightpe1() {
    let gen = vec_of(f64_in(-2.0, 2.0), 2, 24);
    check(&gen, |weights| {
        let max_abs = weights.iter().fold(0.0f64, |m, &w| m.max(w.abs()));
        if max_abs < 1e-9 {
            return true;
        }
        let q1 = Po2Quantizer::calibrate(PeType::LightPe1, weights);
        let q2 = Po2Quantizer::calibrate(PeType::LightPe2, weights);
        let err = |q: &Po2Quantizer| -> f64 {
            weights.iter().map(|&w| (q.fake_quantize(w) - w).abs()).sum()
        };
        err(&q2) <= err(&q1) + 1e-9
    });
}

// -------------------------------------------------------------------- mapper

fn random_layer(seed: &(usize, usize, usize, usize)) -> Layer {
    let &(hw, in_c, out_c, kernel) = seed;
    let kernel = kernel.min(hw); // keep geometry valid
    Layer::conv("prop", hw, in_c, out_c, kernel, 1, kernel / 2)
}

#[test]
fn prop_mapper_invariants() {
    let gen = pair(
        pair(usize_in(4, 64), usize_in(1, 64)),
        pair(usize_in(1, 128), usize_in(1, 5)),
    );
    check_with(&Config { cases: 128, ..Default::default() }, &gen, |&((hw, in_c), (out_c, k))| {
        let layer = random_layer(&(hw, in_c, out_c, k));
        let config = AcceleratorConfig::default();
        let mapping = map_layer_rs(&layer, &config);
        let ideal = layer.macs().div_ceil(config.num_pes() as u64);
        mapping.utilization > 0.0
            && mapping.utilization <= 1.0 + 1e-12
            && mapping.cycles >= ideal
            && mapping.cycles >= mapping.compute_cycles.min(mapping.cycles)
            && mapping.traffic.spad.reads >= 3 * mapping.macs
            && mapping.traffic.glb.reads >= mapping.traffic.glb_weight_reads
            && mapping.traffic.dram_bytes > 0
    });
}

#[test]
fn prop_bigger_array_never_slower() {
    let gen = pair(usize_in(8, 48), usize_in(8, 128));
    check_with(&Config { cases: 64, ..Default::default() }, &gen, |&(hw, out_c)| {
        let layer = Layer::conv("p", hw, 16, out_c, 3, 1, 1);
        let small = AcceleratorConfig { rows: 8, cols: 8, ..Default::default() };
        let big = AcceleratorConfig { rows: 32, cols: 32, ..Default::default() };
        map_layer_rs(&layer, &big).compute_cycles <= map_layer_rs(&layer, &small).compute_cycles
    });
}

#[test]
fn prop_more_bandwidth_never_slower() {
    let gen = pair(usize_in(8, 56), usize_in(8, 256));
    check_with(&Config { cases: 64, ..Default::default() }, &gen, |&(hw, channels)| {
        let layer = Layer::conv("p", hw, channels, channels, 3, 1, 1);
        let slow = AcceleratorConfig { dram_bw_gbps: 4.0, ..Default::default() };
        let fast = AcceleratorConfig { dram_bw_gbps: 64.0, ..Default::default() };
        map_layer_rs(&layer, &fast).cycles <= map_layer_rs(&layer, &slow).cycles
    });
}

#[test]
fn prop_bigger_spads_never_more_glb_traffic() {
    let gen = pair(usize_in(8, 48), usize_in(8, 128));
    check_with(&Config { cases: 64, ..Default::default() }, &gen, |&(hw, out_c)| {
        let layer = Layer::conv("p", hw, 32, out_c, 3, 1, 1);
        let small = AcceleratorConfig {
            spad: ScratchpadCfg { ifmap_entries: 6, filter_entries: 28, psum_entries: 8 },
            ..Default::default()
        };
        let big = AcceleratorConfig {
            spad: ScratchpadCfg { ifmap_entries: 24, filter_entries: 448, psum_entries: 32 },
            ..Default::default()
        };
        map_layer_rs(&layer, &big).traffic.glb.reads
            <= map_layer_rs(&layer, &small).traffic.glb.reads
    });
}

// ----------------------------------------------------------------- synthesis

#[test]
fn prop_synthesis_monotone_in_size_knobs() {
    let gen = pair(pair(usize_in(4, 32), usize_in(4, 32)), usize_in(64, 512));
    check_with(&Config { cases: 48, ..Default::default() }, &gen, |&((rows, cols), glb)| {
        let base = AcceleratorConfig { rows, cols, glb_kib: glb, ..Default::default() };
        let bigger_array =
            AcceleratorConfig { rows: rows + 4, ..base.clone() };
        let bigger_glb = AcceleratorConfig { glb_kib: glb + 64, ..base.clone() };
        let area = |c: &AcceleratorConfig| synthesize_clean(c).area.total_um2();
        area(&bigger_array) > area(&base) && area(&bigger_glb) > area(&base)
    });
}

#[test]
fn prop_synthesis_positive_everywhere() {
    let gen = pair(pair(usize_in(1, 64), usize_in(1, 64)), usize_in(1, 1024));
    check_with(&Config { cases: 64, ..Default::default() }, &gen, |&((rows, cols), glb)| {
        let config = AcceleratorConfig { rows, cols, glb_kib: glb, ..Default::default() };
        let report = synthesize_clean(&config);
        report.area.total_um2() > 0.0
            && report.dynamic_power_mw > 0.0
            && report.leakage_power_mw > 0.0
            && report.max_clock_ghz > 0.0
    });
}

// -------------------------------------------------------------------- pareto

#[test]
fn prop_pareto_front_mutually_nondominating() {
    let gen = vec_of(pair(f64_in(0.0, 10.0), f64_in(0.0, 10.0)), 1, 40);
    let orientations = [Orientation::Maximize, Orientation::Minimize];
    check(&gen, |points| {
        let coords: Vec<Vec<f64>> = points.iter().map(|&(x, y)| vec![x, y]).collect();
        let front = pareto_front(&coords, &orientations);
        if front.is_empty() {
            return false; // non-empty input must yield a non-empty front
        }
        // No front member dominates another.
        for &i in &front {
            for &j in &front {
                if i != j && dominates(&coords[i], &coords[j], &orientations) {
                    return false;
                }
            }
        }
        // Every non-front point is dominated by some front member.
        for idx in 0..coords.len() {
            if !front.contains(&idx)
                && !front.iter().any(|&f| dominates(&coords[f], &coords[idx], &orientations))
            {
                return false;
            }
        }
        true
    });
}

// ---------------------------------------------------------------- regression

#[test]
fn prop_regression_exact_on_linear_ground_truth() {
    // For random linear data, a degree-1 fit must reproduce targets.
    let gen = usize_in(1, 10_000);
    check_with(&Config { cases: 32, ..Default::default() }, &gen, |&seed| {
        let mut rng = Pcg64::new(seed as u64);
        let xs: Vec<Vec<f64>> =
            (0..30).map(|_| vec![rng.uniform(0.0, 5.0), rng.uniform(0.0, 5.0)]).collect();
        let (a, b, c) = (rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0));
        let ys: Vec<f64> = xs.iter().map(|x| a + b * x[0] + c * x[1]).collect();
        let model = qadam::ppa::PolyModel::fit(&xs, &ys, 1, 1e-10);
        xs.iter().zip(&ys).all(|(x, &y)| (model.predict(x) - y).abs() < 1e-6)
    });
}

// --------------------------------------------------------- failure injection

#[test]
fn prop_config_validation_rejects_degenerate() {
    let gen = usize_in(0, 3);
    check(&gen, |&which| {
        let mut config = AcceleratorConfig::default();
        match which {
            0 => config.rows = 0,
            1 => config.glb_kib = 0,
            2 => config.spad.psum_entries = 0,
            _ => config.dram_bw_gbps = 0.0,
        }
        config.validate().is_err()
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_configs() {
    let gen = pair(pair(usize_in(1, 64), usize_in(1, 64)), usize_in(1, 512));
    check(&gen, |&((rows, cols), glb)| {
        let config = AcceleratorConfig { rows, cols, glb_kib: glb, ..Default::default() };
        let json = config.to_json().to_string_pretty();
        let parsed = qadam::util::json::Json::parse(&json).unwrap();
        AcceleratorConfig::from_json(&parsed).unwrap() == config
    });
}

// --------------------------------------------------- joint design spaces

/// A randomized joint space: truncated default hardware axes × model
/// axes drawn from fixed pools (exact-float widths so equality checks
/// are sound).
fn random_joint_space(
    npe: usize,
    ndims: usize,
    nwidth: usize,
    ndepth: usize,
) -> DesignSpace {
    let d = SweepSpec::default();
    let hw = SweepSpec {
        pe_types: d.pe_types[..npe.clamp(1, d.pe_types.len())].to_vec(),
        array_dims: d.array_dims[..ndims.clamp(1, d.array_dims.len())].to_vec(),
        glb_kib: d.glb_kib[..2].to_vec(),
        spads: d.spads[..1].to_vec(),
        dram_bw_gbps: d.dram_bw_gbps[..1].to_vec(),
        clock_ghz: d.clock_ghz.clone(),
    };
    const WIDTHS: [f64; 4] = [0.25, 0.5, 1.0, 2.0];
    const DEPTHS: [usize; 3] = [1, 2, 3];
    let model = ModelAxes {
        width_mults: WIDTHS[..nwidth.clamp(1, WIDTHS.len())].to_vec(),
        depth_mults: DEPTHS[..ndepth.clamp(1, DEPTHS.len())].to_vec(),
    };
    DesignSpace::new(hw, model)
}

#[test]
fn prop_joint_lazy_iteration_matches_eager_cross_product() {
    let gen = pair(pair(usize_in(1, 4), usize_in(1, 5)), pair(usize_in(1, 4), usize_in(1, 3)));
    check_with(
        &Config { cases: 48, ..Default::default() },
        &gen,
        |&((npe, ndims), (nwidth, ndepth))| {
            let space = random_joint_space(npe, ndims, nwidth, ndepth);
            // Eager golden reference: variants outermost (width before
            // depth), hardware cross-product order within each block.
            let mut golden = Vec::with_capacity(space.len());
            for &width in &space.model.width_mults {
                for &depth in &space.model.depth_mults {
                    for config in space.hw.iter() {
                        golden.push((width, depth, config));
                    }
                }
            }
            if golden.len() != space.len() {
                return false;
            }
            space.iter().zip(&golden).all(|(point, (width, depth, config))| {
                point.variant.width == *width
                    && point.variant.depth == *depth
                    && point.config == *config
            })
        },
    );
}

#[test]
fn prop_joint_shard_partition_is_exact() {
    let gen = pair(pair(usize_in(1, 3), usize_in(1, 4)), pair(usize_in(1, 3), usize_in(1, 7)));
    check_with(
        &Config { cases: 48, ..Default::default() },
        &gen,
        |&((npe, nwidth), (ndepth, num_shards))| {
            let space = random_joint_space(npe, 2, nwidth, ndepth);
            // Every joint index appears in exactly one shard, in order.
            let mut recombined: Vec<usize> = Vec::new();
            for shard in 0..num_shards {
                let mut last: Option<usize> = None;
                for (pos, point) in space.shard_iter(shard, num_shards).enumerate() {
                    let index = shard + pos * num_shards;
                    if space.get(index) != Some(point.clone()) {
                        return false;
                    }
                    if let Some(prev) = last {
                        if index <= prev {
                            return false;
                        }
                    }
                    last = Some(index);
                    recombined.push(index);
                }
            }
            recombined.sort_unstable();
            recombined == (0..space.len()).collect::<Vec<_>>()
        },
    );
}

#[test]
fn prop_joint_scaling_changes_point_cache_key() {
    // Width/depth scaling must reach the content-addressed cache key —
    // two variants of the same base model can never alias.
    const WIDTHS: [f64; 4] = [0.25, 0.5, 1.0, 2.0];
    const DEPTHS: [usize; 3] = [1, 2, 3];
    let base = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let config = AcceleratorConfig::default();
    let gen = pair(pair(usize_in(0, 3), usize_in(0, 2)), pair(usize_in(0, 3), usize_in(0, 2)));
    check_with(
        &Config { cases: 64, ..Default::default() },
        &gen,
        |&((wa, da), (wb, db))| {
            let a = scale_model(&base, WIDTHS[wa], DEPTHS[da]);
            let b = scale_model(&base, WIDTHS[wb], DEPTHS[db]);
            let key_a = point_key(&config, 7, std::slice::from_ref(&a));
            let key_b = point_key(&config, 7, std::slice::from_ref(&b));
            if (wa, da) == (wb, db) {
                key_a == key_b
            } else {
                key_a != key_b
            }
        },
    );
}

#[test]
fn joint_trivial_axes_campaign_is_bit_identical_to_hardware_only() {
    // The backward-compatibility acceptance property: a campaign with
    // explicit trivial model axes produces byte-identical artifacts to
    // the hardware-only pipeline (whose numerics the golden fixtures
    // pin), and their checkpoint journals are interchangeable.
    let dir = std::env::temp_dir().join(format!("qadam_joint_compat_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("compat.journal");
    let hardware_only = Explorer::over(SweepSpec::tiny())
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(7)
        .checkpoint(&journal, 1)
        .run()
        .unwrap();
    // Resume the hardware-only journal from a trivially-joint campaign:
    // accepted, full replay, identical bytes.
    let joint = Explorer::over(DesignSpace::new(SweepSpec::tiny(), ModelAxes::default()))
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(7)
        .checkpoint(&journal, 1)
        .run()
        .unwrap();
    assert_eq!(
        hardware_only.to_json().to_string_pretty(),
        joint.to_json().to_string_pretty(),
        "trivial axes must keep artifacts byte-identical"
    );
    // The journal header carries no joint-space fields at all.
    let header = std::fs::read_to_string(&journal).unwrap();
    let header_line = header.lines().next().unwrap();
    assert!(!header_line.contains("model_axes"), "{header_line}");
    assert!(header_line.contains("\"schema\":3"), "{header_line}");
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------- json adversarial input

#[test]
fn prop_json_deep_nesting_is_rejected_without_crashing() {
    use qadam::util::json::{Json, MAX_DEPTH};
    // Any nesting depth — including far past the limit — must return a
    // Result, never blow the stack. Mixed [ / { nesting included.
    let gen = pair(usize_in(0, 4096), usize_in(0, 1));
    check_with(&Config { cases: 64, ..Default::default() }, &gen, |&(depth, flavor)| {
        let (open, close) = if flavor == 0 { ("[", "]") } else { (r#"{"k":"#, "}") };
        let text = format!("{}0{}", open.repeat(depth), close.repeat(depth));
        match Json::parse(&text) {
            Ok(_) => depth <= MAX_DEPTH,
            Err(err) => depth > MAX_DEPTH && err.msg.contains("nesting"),
        }
    });
}

#[test]
fn prop_json_control_and_unicode_strings_round_trip() {
    use qadam::util::json::Json;
    // Strings mixing control characters, escapes' targets, and
    // multi-byte UTF-8 must survive write → parse bit-for-bit.
    let char_gen = usize_in(0, 9).map(|which| match which {
        0 => '\u{0}',
        1 => '\u{1}',
        2 => '\n',
        3 => '\t',
        4 => '\r',
        5 => '"',
        6 => '\\',
        7 => 'é',
        8 => '😀',
        _ => 'a',
    });
    let gen = vec_of(char_gen, 0, 32);
    check(&gen, |chars| {
        let original = Json::Str(chars.iter().collect());
        let text = original.to_string_compact();
        Json::parse(&text).map(|parsed| parsed == original).unwrap_or(false)
    });
}

#[test]
fn prop_json_torn_inputs_never_panic() {
    use qadam::util::json::Json;
    // Truncate a valid document (with escapes, unicode, and nesting) at
    // every byte prefix, re-validating as UTF-8: parsing must always
    // return a Result. Catches torn files and mid-escape truncation.
    let source = Json::parse(
        r#"{"a": [1, -2.5e3, "café 😀 \n\t\"x\""], "b": {"c": [true, null]}}"#,
    )
    .unwrap()
    .to_string_pretty();
    let bytes = source.as_bytes();
    let gen = usize_in(0, bytes.len());
    check(&gen, |&cut| {
        let torn = String::from_utf8_lossy(&bytes[..cut]);
        // Either outcome is fine; reaching it without a panic is the
        // property. The full document must still parse.
        let _ = Json::parse(&torn);
        cut < bytes.len() || Json::parse(&torn).is_ok()
    });
}
