//! End-to-end runtime tests: load the AOT artifacts (built by
//! `make artifacts`) into the PJRT CPU client and execute them from rust.
//! Skipped gracefully when artifacts are missing; compiled only with the
//! `pjrt` feature (the XLA-backed runtime).
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use qadam::quant::PeType;
use qadam::runtime::{QatDriver, Runtime, Tensor};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime e2e: run `make artifacts` first");
        None
    }
}

#[test]
fn kernel_smoke_executes_and_matches_quantized_matmul() {
    let Some(dir) = artifacts_dir() else { return };
    let mut runtime = Runtime::new(&dir).unwrap();
    // Deterministic inputs; golden computed with the rust quantizers.
    let m = 32;
    let k = 27;
    let n = 8;
    let mut rng = qadam::util::rng::Pcg64::new(11);
    let x: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-0.4, 0.4) as f32).collect();
    let outputs = runtime
        .execute(
            "kernel_smoke",
            &[Tensor::f32(&[m, k], x.clone()), Tensor::f32(&[k, n], w.clone())],
        )
        .unwrap();
    assert_eq!(outputs.len(), 1);
    assert_eq!(outputs[0].shape(), &[m, n]);

    // Golden: INT16 fake-quant matmul with the rust quantizer semantics.
    let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let aq = qadam::quant::AffineQuantizer::calibrate(16, &xf);
    let wq = qadam::quant::AffineQuantizer::calibrate(16, &wf);
    let got = outputs[0].as_f32().unwrap();
    for row in 0..m {
        for col in 0..n {
            let mut acc = 0.0f64;
            for inner in 0..k {
                acc += aq.fake_quantize(xf[row * k + inner])
                    * wq.fake_quantize(wf[inner * n + col]);
            }
            let err = (acc - got[row * n + col] as f64).abs();
            assert!(err < 2e-3, "({row},{col}): rust {acc} vs xla {}", got[row * n + col]);
        }
    }
}

#[test]
fn init_artifact_produces_param_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut runtime = Runtime::new(&dir).unwrap();
    let params = runtime.execute("init", &[]).unwrap();
    assert_eq!(params.len(), runtime.manifest.param_order.len());
    // conv1 must be 3x3x3x8 per the manifest's model constants.
    assert_eq!(params[0].shape(), &[3, 3, 3, 8]);
}

#[test]
fn batch_artifact_deterministic_per_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let mut runtime = Runtime::new(&dir).unwrap();
    let a = runtime.execute("batch", &[Tensor::i32(&[1], vec![3])]).unwrap();
    let b = runtime.execute("batch", &[Tensor::i32(&[1], vec![3])]).unwrap();
    assert_eq!(a[0], b[0]);
    let c = runtime.execute("batch", &[Tensor::i32(&[1], vec![4])]).unwrap();
    assert_ne!(a[0], c[0]);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut runtime = Runtime::new(&dir).unwrap();
    let result = runtime.execute("kernel_smoke", &[Tensor::zeros(&[2, 2])]);
    assert!(result.is_err(), "arity mismatch must error");
    let result = runtime.execute(
        "kernel_smoke",
        &[Tensor::zeros(&[2, 2]), Tensor::zeros(&[27, 8])],
    );
    assert!(result.is_err(), "shape mismatch must error");
    assert!(runtime.execute("no_such_artifact", &[]).is_err());
}

#[test]
fn qat_short_run_reduces_loss_for_every_pe_type() {
    let Some(dir) = artifacts_dir() else { return };
    let mut runtime = Runtime::new(&dir).unwrap();
    for pe in [PeType::Fp32, PeType::LightPe1] {
        let outcome = QatDriver::train(&mut runtime, pe, 20, 5).unwrap();
        let first = outcome.loss_curve.first().unwrap().loss;
        let last = outcome.loss_curve.last().unwrap().loss;
        assert!(
            last < first,
            "{}: loss must decrease ({first} -> {last})",
            pe.name()
        );
        assert!(outcome.final_accuracy >= 0.0 && outcome.final_accuracy <= 1.0);
    }
}
