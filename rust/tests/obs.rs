//! Observability integration tests (DESIGN.md §11): the deterministic
//! event trace must be byte-identical across worker counts and across
//! kill/resume, a no-op sink must leave every campaign artifact
//! untouched, the spec-level `persist.trace` key must write both the
//! trace and its wall-clock sidecar (and only the sidecar may carry
//! time), and the `trace show|merge|diff` surfaces must round-trip
//! saved traces.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use qadam::arch::SweepSpec;
use qadam::dnn::Dataset;
use qadam::explore::{Explorer, PointCache};
use qadam::obs::view::{render_diff, render_merge, render_show};
use qadam::obs::{sidecar_path, NullSink, TimingSidecar, Trace, TraceEvent, TraceRecorder};
use qadam::pareto::CampaignFrontier;
use qadam::serve::{serve, BatchQueue, ServeConfig};
use qadam::spec;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_obs_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// A fully instrumented tiny campaign: cache + frontier + checkpoint +
/// recorder, returning the trace's canonical text.
fn traced_run(workers: usize, journal: &Path, every: usize) -> (Trace, TimingSidecar) {
    let recorder = Arc::new(TraceRecorder::new());
    Explorer::over(SweepSpec::tiny())
        .dataset(Dataset::Cifar10)
        .workers(workers)
        .seed(7)
        .cache(Arc::new(Mutex::new(PointCache::new())))
        .frontier(Arc::new(Mutex::new(CampaignFrontier::new())))
        .checkpoint(journal, every)
        .trace_sink(recorder.clone())
        .run()
        .unwrap();
    recorder.snapshot()
}

// ------------------------------------------------------ byte determinism

/// The acceptance criterion: identical campaigns at different worker
/// counts produce byte-identical `qadam.trace` documents — only the
/// timing sidecar may differ.
#[test]
fn trace_bytes_are_identical_across_worker_counts() {
    let dir = temp_dir("workers");
    let total = SweepSpec::tiny().len();
    let (serial, serial_timing) = traced_run(1, &dir.join("serial.journal"), 2);
    let (threaded, threaded_timing) = traced_run(4, &dir.join("threaded.journal"), 2);
    assert_eq!(
        serial.to_json().to_string_pretty(),
        threaded.to_json().to_string_pretty(),
        "worker count must not leak into the deterministic trace"
    );
    // Every event carries one timing sample, whatever the schedule was.
    assert_eq!(serial_timing.samples.len(), serial.len());
    assert_eq!(threaded_timing.samples.len(), threaded.len());
    // A fresh cache misses once per point; every point is dispatched,
    // observed by the frontier, and delivered exactly once.
    let counts = serial.counts();
    assert_eq!(counts.get("cache.miss"), Some(&total));
    assert_eq!(counts.get("cache.hit"), None);
    assert_eq!(counts.get("point.dispatch"), Some(&total));
    assert_eq!(counts.get("frontier.observe"), Some(&total));
    assert_eq!(counts.get("point.deliver"), Some(&total));
    assert_eq!(counts.get("campaign.begin"), Some(&1));
    assert_eq!(counts.get("campaign.end"), Some(&1));
    match serial.events().first() {
        Some(TraceEvent::CampaignBegin { strategy, total: t, seed, .. }) => {
            assert_eq!(strategy, "exhaustive");
            assert_eq!((*t, *seed), (total, 7));
        }
        other => panic!("trace must open with campaign.begin, got {other:?}"),
    }
    let _ = fs::remove_dir_all(&dir);
}

/// Kill the campaign at a flush boundary and resume: the replayed
/// prefix plus the recomputed tail must reproduce the uninterrupted
/// trace byte for byte.
#[test]
fn resumed_run_reproduces_the_trace_byte_for_byte() {
    let dir = temp_dir("resume");
    let journal = dir.join("run.journal");
    let (reference, _) = traced_run(3, &journal, 2);
    let reference_text = reference.to_json().to_string_pretty();

    // Keep the header plus the first two flushed entries — a kill at
    // the first checkpoint boundary.
    let text = fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.split_inclusive('\n').collect();
    assert!(lines.len() > 3, "tiny campaign must journal several points");
    fs::write(&journal, lines[..3].concat()).unwrap();

    // Fresh recorder, fresh (cold) cache, fresh frontier: replay emits
    // the prefix's events, live workers emit the tail's.
    let (resumed, resumed_timing) = traced_run(3, &journal, 2);
    assert_eq!(
        resumed.to_json().to_string_pretty(),
        reference_text,
        "kill/resume must not leak into the deterministic trace"
    );
    assert_eq!(resumed_timing.samples.len(), resumed.len());
    let _ = fs::remove_dir_all(&dir);
}

/// A no-op sink must not perturb campaign results: the database bytes
/// match an entirely untraced run.
#[test]
fn null_sink_run_matches_untraced_artifacts() {
    let build = || Explorer::over(SweepSpec::tiny()).dataset(Dataset::Cifar10).workers(3).seed(7);
    let untraced = build().run().unwrap();
    let traced = build().trace_sink(Arc::new(NullSink)).run().unwrap();
    assert_eq!(
        traced.to_json().to_string_pretty(),
        untraced.to_json().to_string_pretty(),
        "a no-op sink must leave the database byte-identical"
    );
}

// ------------------------------------------------------ spec-level wiring

const SPEC_BODY: &str = "campaign { seed = 7 }\n\
    sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [64, 128]\n  \
    spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
    workload {\n  dataset = cifar10\n  models = [tiny]\n}\n\
    model tiny {\n  fc head { in = 64, out = 10 }\n}\n";

/// `persist { trace = ... }` writes the trace and its `.timing` sidecar;
/// the trace itself must be wall-clock-free.
#[test]
fn spec_persist_trace_writes_trace_and_sidecar() {
    let dir = temp_dir("spec");
    let trace_path = dir.join("trace.json");
    let source = format!(
        "{SPEC_BODY}persist {{\n  db = \"{}\"\n  checkpoint = \"{}\"\n  every = 2\n  \
         trace = \"{}\"\n}}\n",
        dir.join("db.json").display(),
        dir.join("run.journal").display(),
        trace_path.display()
    );
    let campaign = spec::compile(&source, "obs.qsl").unwrap();
    let outcome = campaign.execute().unwrap();
    let trace_outcome = outcome.trace.expect("persist.trace must produce a trace outcome");
    assert_eq!(trace_outcome.path, trace_path);
    assert_eq!(trace_outcome.timing, sidecar_path(&trace_path));

    let trace = Trace::load(&trace_path).unwrap();
    assert_eq!(trace.len(), trace_outcome.events);
    let text = fs::read_to_string(&trace_path).unwrap();
    assert!(!text.contains("at_ns"), "wall-clock fields must stay out of qadam.trace");
    assert!(!text.contains("eval_ns"), "eval timings must stay out of qadam.trace");

    let timing = TimingSidecar::load(&sidecar_path(&trace_path)).unwrap();
    assert_eq!(timing.samples.len(), trace.len());
    // The spec fingerprint is pinned into the opening event.
    match trace.events().first() {
        Some(TraceEvent::CampaignBegin { fingerprint, .. }) => {
            assert_eq!(*fingerprint, Some(campaign.fingerprint()));
        }
        other => panic!("trace must open with campaign.begin, got {other:?}"),
    }
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------------------- show / merge / diff

/// `trace show`/`merge`/`diff` surfaces round-trip a saved trace: the
/// rendered views name what the campaign did, a self-merge doubles and
/// reseqs cleanly, and diff localizes a divergence.
#[test]
fn show_merge_and_diff_round_trip_saved_traces() {
    let dir = temp_dir("views");
    let (trace, timing) = traced_run(2, &dir.join("run.journal"), 2);
    let path = dir.join("trace.json");
    trace.save(&path).unwrap();
    timing.save(&sidecar_path(&path)).unwrap();

    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded, trace, "save/load must round-trip the event stream");
    let sidecar = TimingSidecar::load(&sidecar_path(&path)).unwrap();
    let shown = render_show(&loaded, Some(&sidecar));
    assert!(shown.contains("exhaustive"), "show must name the strategy:\n{shown}");
    assert!(shown.contains("cache"), "show must report cache stats:\n{shown}");

    // A self-merge concatenates with a dense reseq: the merged document
    // still parses (from_json validates seq density).
    let merged = Trace::merge([&loaded, &loaded]);
    assert_eq!(merged.len(), 2 * loaded.len());
    let reparsed =
        Trace::from_json(&qadam::util::json::Json::parse(&merged.to_json().to_string_pretty()).unwrap())
            .unwrap();
    assert_eq!(reparsed, merged);
    let merge_view = render_merge(&[
        ("a.json".to_string(), loaded.clone()),
        ("b.json".to_string(), loaded.clone()),
    ]);
    assert!(merge_view.contains("a.json") && merge_view.contains("b.json"));

    // Identical traces: no divergence. A truncated copy diverges where
    // the events stop agreeing on campaign.end vs nothing.
    assert!(loaded.diff(&trace).identical());
    let diff_view = render_diff("left", "right", &loaded, &trace);
    assert!(diff_view.contains("identical"), "{diff_view}");
    let mut shorter = Trace::new();
    for event in loaded.events().iter().take(loaded.len() - 1) {
        shorter.push(event.clone());
    }
    let diff = loaded.diff(&shorter);
    assert_eq!(diff.divergence, Some(loaded.len() - 1));
    assert!(!render_diff("left", "short", &loaded, &shorter).contains("identical"));
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ serve trace

/// The batch-level serve trace opens with `serve.begin`, walks every
/// campaign linted → running → done, records one shared-cache save per
/// completed campaign, and closes with tallies that match the reports.
#[test]
fn serve_batch_trace_records_every_transition() {
    let dir = temp_dir("serve");
    fs::write(dir.join("base.qsl"), SPEC_BODY).unwrap();
    let specs = [
        {
            fs::write(dir.join("a.qsl"), "include \"base.qsl\"\n").unwrap();
            dir.join("a.qsl")
        },
        {
            fs::write(
                dir.join("b.qsl"),
                "include \"base.qsl\"\noverride sweep { glb_kib = [128, 192] }\n",
            )
            .unwrap();
            dir.join("b.qsl")
        },
    ];
    let queue = BatchQueue::build(&specs).unwrap();
    let out = dir.join("out");
    let mut config = ServeConfig::new(&out);
    let trace_path = out.join("batch_trace.json");
    config.trace = Some(trace_path.clone());
    let outcome = serve(&queue, &config).unwrap();
    assert_eq!(outcome.failures(), 0);
    assert_eq!(outcome.trace.as_deref(), Some(trace_path.as_path()));

    let trace = Trace::load(&trace_path).unwrap();
    assert!(matches!(trace.events().first(), Some(TraceEvent::ServeBegin { campaigns: 2 })));
    match trace.events().last() {
        Some(TraceEvent::ServeEnd { done, failed, skipped }) => {
            assert_eq!((*done, *failed, *skipped), (2, 0, 0));
        }
        other => panic!("serve trace must close with serve.end, got {other:?}"),
    }
    // Each campaign walks linted -> running -> done, in that order.
    for (index, report) in outcome.reports.iter().enumerate() {
        let states: Vec<&str> = trace
            .events()
            .iter()
            .filter_map(|event| match event {
                TraceEvent::ServeTransition { index: i, fingerprint, state, .. }
                    if *i == index =>
                {
                    assert_eq!(*fingerprint, report.fingerprint);
                    Some(state.as_str())
                }
                _ => None,
            })
            .collect();
        assert_eq!(states, ["linted", "running", "done"], "campaign {index}");
    }
    // One shared-cache save per completed campaign; the last one holds
    // the batch's final entry count.
    let saves: Vec<(usize, u64)> = trace
        .events()
        .iter()
        .filter_map(|event| match event {
            TraceEvent::ServeCacheSave { entries, generation, .. } => {
                Some((*entries, *generation))
            }
            _ => None,
        })
        .collect();
    assert_eq!(saves.len(), 2);
    assert_eq!(saves.last().map(|(entries, _)| *entries), Some(outcome.cache_entries));
    assert!(saves.windows(2).all(|w| w[0].1 < w[1].1), "generations must increase: {saves:?}");
    // The timing sidecar rides along.
    let timing = TimingSidecar::load(&sidecar_path(&trace_path)).unwrap();
    assert_eq!(timing.samples.len(), trace.len());
    let _ = fs::remove_dir_all(&dir);
}
