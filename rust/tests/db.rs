//! Integration tests for million-point campaign storage: `qadam.qdb`
//! round trips on real campaign databases (JSON → qdb → JSON is
//! byte-identical, so every f64 survives bit-exactly), the parallel
//! sharded frontier fold against sequential streaming and the quadratic
//! batch oracle, and batched vs per-point checkpoint-journal writes.

use std::fs;
use std::path::PathBuf;

use qadam::arch::{AcceleratorConfig, ModelAxes, ScratchpadCfg, SweepSpec};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::dse::pareto_front_reference;
use qadam::explore::persist::{CampaignManifest, JournalWriter};
use qadam::explore::{EvalDatabase, Explorer, PointResult};
use qadam::pareto::{parallel_model_front, FrontSample, ParetoFront, OBJECTIVES};
use qadam::quant::PeType;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_db_test_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// 8-point hardware sweep: small enough for per-test campaigns, with two
/// PE types so the spaces carry realistic metric spreads.
fn tiny_sweep() -> SweepSpec {
    SweepSpec {
        pe_types: vec![PeType::Int16, PeType::LightPe1],
        array_dims: vec![(8, 8), (16, 16)],
        glb_kib: vec![64, 128],
        spads: vec![ScratchpadCfg { ifmap_entries: 12, filter_entries: 224, psum_entries: 24 }],
        dram_bw_gbps: vec![8.0],
        clock_ghz: vec![2.0],
    }
}

fn tiny_campaign() -> EvalDatabase {
    Explorer::over(tiny_sweep())
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .workers(2)
        .seed(7)
        .run()
        .unwrap()
}

#[test]
fn qdb_round_trip_is_byte_lossless_for_a_real_campaign() {
    let dir = temp_dir("roundtrip");
    let db = tiny_campaign();
    let json_before = dir.join("before.json");
    db.save(&json_before).unwrap();
    let qdb = dir.join("db.qdb");
    db.save_qdb(&qdb).unwrap();
    let reloaded = EvalDatabase::load_qdb(&qdb).unwrap();
    let json_after = dir.join("after.json");
    reloaded.save(&json_after).unwrap();
    // JSON → qdb → JSON is byte-identical. The JSON layer prints
    // shortest-round-trip floats, so byte equality implies bit equality
    // of every metric and config field.
    assert_eq!(fs::read(&json_before).unwrap(), fs::read(&json_after).unwrap());
    // Format sniffing reads both representations into the same value.
    assert_eq!(EvalDatabase::load_any(&qdb).unwrap(), reloaded);
    assert_eq!(EvalDatabase::load_any(&json_before).unwrap(), reloaded);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn qdb_round_trip_preserves_joint_variant_spaces() {
    let dir = temp_dir("joint");
    let db = Explorer::over(tiny_sweep())
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .model_axes(ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] })
        .workers(2)
        .seed(7)
        .run()
        .unwrap();
    assert!(db.has_model_variants());
    assert!(db.spaces.iter().any(|s| s.model_name.contains('@')), "variant names expected");
    let json_before = dir.join("before.json");
    db.save(&json_before).unwrap();
    let qdb = dir.join("db.qdb");
    db.save_qdb(&qdb).unwrap();
    let reloaded = EvalDatabase::load_qdb(&qdb).unwrap();
    let json_after = dir.join("after.json");
    reloaded.save(&json_after).unwrap();
    assert_eq!(fs::read(&json_before).unwrap(), fs::read(&json_after).unwrap());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn parallel_front_matches_sequential_streaming_and_the_batch_oracle() {
    let db = tiny_campaign();
    assert!(!db.spaces.is_empty());
    for space in &db.spaces {
        // Sequential streaming front over the space's walk order.
        let mut seq = ParetoFront::new(OBJECTIVES);
        for (index, eval) in space.evals.iter().enumerate() {
            seq.insert(
                [eval.perf_per_area, eval.energy_uj],
                FrontSample { index, eval: eval.clone() },
            );
        }
        // Quadratic batch oracle over the same cloud.
        let points: Vec<Vec<f64>> =
            space.evals.iter().map(|e| vec![e.perf_per_area, e.energy_uj]).collect();
        let mut oracle = pareto_front_reference(&points, &OBJECTIVES);
        oracle.sort_unstable();
        for workers in [1usize, 2, 3, 8] {
            let merged = parallel_model_front(&space.evals, workers);
            assert_eq!(merged.offered(), seq.offered(), "workers {workers}");
            assert_eq!(merged.len(), seq.len(), "workers {workers}");
            for (got, want) in merged.entries().iter().zip(seq.entries()) {
                assert_eq!(got.seq, want.seq, "workers {workers}");
                assert_eq!(got.payload.index, want.payload.index, "workers {workers}");
                let got_bits: Vec<u64> = got.point.iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u64> = want.point.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, want_bits, "workers {workers}");
            }
            let mut indices: Vec<usize> =
                merged.entries().iter().map(|e| e.payload.index).collect();
            indices.sort_unstable();
            assert_eq!(indices, oracle, "workers {workers}");
        }
    }
}

#[test]
fn batched_journal_writes_are_byte_identical_to_per_point_appends() {
    let dir = temp_dir("journal");
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let points: Vec<PointResult> = (0..7)
        .map(|i| {
            let config = AcceleratorConfig { rows: 8 + i, ..Default::default() };
            let eval = qadam::dse::evaluate(&config, &model, 7);
            PointResult { index: i, config, evals: vec![eval] }
        })
        .collect();
    let manifest = CampaignManifest {
        spec_fingerprint: 0x51ab,
        seed: 7,
        shard: 0,
        num_shards: 1,
        total: points.len(),
        dataset: "CIFAR-10".into(),
        models: vec!["ResNet-20".into()],
        strategy: "exhaustive".into(),
        model_axes: ModelAxes::default(),
        campaign_fp: None,
    };
    let index_for = |pos: usize| pos;
    // every_n = 3 puts flush boundaries both inside and across batches.
    for group in [1usize, 2, 3, 7] {
        let unbatched = dir.join(format!("unbatched_{group}.journal"));
        let (mut writer, replay) =
            JournalWriter::open(&unbatched, &manifest, 3, &index_for).unwrap();
        assert!(replay.is_empty());
        for point in &points {
            writer.append(point).unwrap();
        }
        writer.finish().unwrap();
        let batched = dir.join(format!("batched_{group}.journal"));
        let (mut writer, _) = JournalWriter::open(&batched, &manifest, 3, &index_for).unwrap();
        for chunk in points.chunks(group) {
            writer.append_batch(chunk).unwrap();
        }
        writer.finish().unwrap();
        assert_eq!(
            fs::read(&unbatched).unwrap(),
            fs::read(&batched).unwrap(),
            "group size {group}: batched journal bytes diverge"
        );
    }
    let _ = fs::remove_dir_all(&dir);
}
