//! Cross-module integration tests: the full modeling pipeline
//! (synth → dataflow → energy → dse → report) plus RTL/simulator
//! consistency — everything except the PJRT runtime (see runtime_e2e.rs).

use qadam::arch::{AcceleratorConfig, SweepSpec};
use qadam::dataflow::{map_model, Dataflow};
use qadam::dnn::{model_for, models_for, Dataset, ModelKind};
use qadam::dse;
use qadam::energy::energy_of;
use qadam::explore::Explorer;
use qadam::ppa::PpaModel;
use qadam::quant::PeType;
use qadam::report;
use qadam::rtl;
use qadam::sim;
use qadam::synth::{synthesize, synthesize_sweep};
use qadam::util::rng::Pcg64;

#[test]
fn full_pipeline_for_every_model_and_pe() {
    // Every (paper model × PE type) must flow through the whole pipeline
    // and produce finite, positive metrics.
    for dataset in Dataset::ALL {
        for model in models_for(dataset) {
            for pe in PeType::ALL {
                let config = AcceleratorConfig { pe, ..Default::default() };
                let synth = synthesize(&config, 3);
                let mapping = map_model(&model, &config, Dataflow::RowStationary);
                let energy = energy_of(&mapping, &synth);
                assert!(mapping.total_cycles > 0, "{} {pe}", model.name);
                assert!(mapping.avg_utilization > 0.0 && mapping.avg_utilization <= 1.0);
                assert!(energy.chip_uj().is_finite() && energy.chip_uj() > 0.0);
                assert!(energy.dram_uj > 0.0);
            }
        }
    }
}

#[test]
fn paper_headline_shape_holds_everywhere() {
    // The paper's central ordering must hold for every (model, dataset)
    // panel: LightPE-1 ≥ LightPE-2 > INT16 > FP32 on both axes.
    for dataset in [Dataset::Cifar10, Dataset::ImageNet] {
        let db = Explorer::over(SweepSpec::default())
            .dataset(dataset)
            .workers(2)
            .seed(7)
            .run()
            .unwrap();
        for space in &db.spaces {
            let ratios = dse::headline_ratios(&space.evals).unwrap();
            let get = |pe: PeType| {
                ratios
                    .iter()
                    .find(|(p, _, _)| *p == pe)
                    .map(|(_, a, b)| (*a, *b))
                    .unwrap()
            };
            let (l1_ppa, l1_energy) = get(PeType::LightPe1);
            let (l2_ppa, l2_energy) = get(PeType::LightPe2);
            let (fp_ppa, fp_energy) = get(PeType::Fp32);
            assert!(l1_ppa >= l2_ppa, "{}: L1 {l1_ppa} < L2 {l2_ppa}", space.model_name);
            assert!(l2_ppa > 1.0, "{}: LightPE-2 must beat INT16", space.model_name);
            assert!(fp_ppa < 1.0, "{}: FP32 must lose to INT16", space.model_name);
            assert!(l1_energy >= l2_energy && l2_energy > 1.0 && fp_energy < 1.0);
        }
    }
}

#[test]
fn surrogate_agrees_with_synthesis_out_of_sample() {
    // Fit on the default sweep, predict a config *outside* it.
    let dataset = synthesize_sweep(&SweepSpec::default(), PeType::Int16, 5);
    let model = PpaModel::fit(&dataset, 5, 5);
    let unseen = AcceleratorConfig {
        pe: PeType::Int16,
        rows: 20,
        cols: 20,
        glb_kib: 192,
        ..Default::default()
    };
    let actual = synthesize(&unseen, 5);
    let (area, power, perf) = model.predict(&unseen);
    assert!(qadam::util::rel_diff(area, actual.area.total_mm2()) < 0.25, "area {area} vs {}", actual.area.total_mm2());
    assert!(qadam::util::rel_diff(power, actual.total_power_mw()) < 0.35, "power {power} vs {}", actual.total_power_mw());
    assert!(qadam::util::rel_diff(perf, actual.max_clock_ghz) < 0.25, "perf {perf} vs {}", actual.max_clock_ghz);
}

#[test]
fn simulator_validates_mapper_on_odd_shapes() {
    // Mapper's compute-cycle model vs the cycle-level simulator across
    // awkward layer shapes (stride-2, 1×1 kernels, narrow arrays).
    let shapes = [
        qadam::dnn::Layer::conv("s2", 9, 2, 5, 3, 2, 1),
        qadam::dnn::Layer::conv("k1", 7, 4, 6, 1, 1, 0),
        qadam::dnn::Layer::conv("deep", 5, 8, 4, 3, 1, 1),
    ];
    let config = AcceleratorConfig { rows: 5, cols: 7, ..Default::default() };
    let mut rng = Pcg64::new(17);
    for layer in &shapes {
        let ifmap: Vec<f64> = (0..layer.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let weights: Vec<f64> = (0..layer.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect();
        let sim_result = sim::simulate_layer(layer, &config, &ifmap, &weights);
        assert!(sim_result.verified, "{}: sim diverged", layer.name);
        let mapped = qadam::dataflow::map_layer_rs(layer, &config);
        let ratio = sim_result.cycles as f64 / mapped.compute_cycles as f64;
        assert!(
            (0.3..3.0).contains(&ratio),
            "{}: sim {} vs mapper {}",
            layer.name,
            sim_result.cycles,
            mapped.compute_cycles
        );
    }
}

#[test]
fn rtl_generated_for_every_sweep_point_is_wellformed() {
    for config in SweepSpec::tiny().enumerate() {
        let bundle = rtl::generate(&config);
        assert_eq!(bundle.files.len(), 5);
        for file in &bundle.files {
            assert_eq!(
                file.count_token("module"),
                file.count_token("endmodule"),
                "{} in {}",
                file.name,
                config.id()
            );
        }
    }
}

#[test]
fn figures_2_through_6_generate() {
    // Smoke the full report layer (small worker count to keep CI fast).
    let fig2 = report::fig2(2, 7).unwrap();
    assert!(!fig2.table.is_empty());
    let fig3 = report::fig3(7).unwrap();
    assert_eq!(fig3.table.len(), 12); // 4 PE types × 3 metrics
    let fig4 = report::fig4(Dataset::Cifar10, 2, 7).unwrap();
    assert_eq!(fig4.table.len(), 12); // 3 models × 4 PE types
    let fig5 = report::fig5(Dataset::Cifar100, 2, 7).unwrap();
    assert_eq!(fig5.table.len(), 12);
    let fig6 = report::fig6(Dataset::Cifar10, 2, 7).unwrap();
    assert_eq!(fig6.table.len(), 12);
}

#[test]
fn accuracy_registry_joins_with_dse() {
    // The Fig. 5 join: every CIFAR model × PE type must have both an
    // accuracy entry and a best-config evaluation.
    let db = Explorer::over(SweepSpec::tiny())
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(7)
        .run()
        .unwrap();
    for space in &db.spaces {
        let kind = ModelKind::parse(&space.model_name).unwrap();
        for pe in [PeType::Int16, PeType::LightPe1] {
            assert!(qadam::accuracy::registry(kind, Dataset::Cifar10, pe).is_some());
            assert!(dse::best_perf_per_area(&space.evals, pe).is_some());
        }
    }
}

#[test]
fn energy_breakdown_consistent_with_totals() {
    let config = AcceleratorConfig::default();
    let model = model_for(ModelKind::Vgg16, Dataset::Cifar10);
    let synth = synthesize(&config, 11);
    let mapping = map_model(&model, &config, Dataflow::RowStationary);
    let energy = energy_of(&mapping, &synth);
    assert!((energy.chip_uj() + energy.dram_uj - energy.total_uj()).abs() < 1e-9);
    // DSE evaluation must agree with the direct pipeline.
    let eval = dse::evaluate_with_synth(&synth, &model);
    assert!((eval.energy_uj - energy.chip_uj()).abs() < 1e-9);
    assert!((eval.dram_energy_uj - energy.dram_uj).abs() < 1e-9);
}
