//! End-to-end tests for the bench-artifact pipeline behind the perf
//! trajectory: harness recording → `qadam.bench` canonical JSON on disk →
//! merge → regression diff, plus the empty-sample stats edges the
//! artifacts depend on (a panicking `Summary::of` would take down every
//! bench target).

use std::path::PathBuf;

use qadam::bench::{
    bench_with, take_records, BenchArtifact, BenchConfig, BenchRecord, HostMeta,
};
use qadam::util::json::Json;
use qadam::util::stats::Summary;

/// Per-test temp dir (process id + name keeps parallel test binaries and
/// repeated runs from colliding).
fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_bench_it_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("test temp dir");
    dir
}

fn record(name: &str, p50: f64) -> BenchRecord {
    BenchRecord {
        name: name.to_string(),
        warmup_iters: 1,
        measure_iters: 7,
        summary: Summary {
            n: 7,
            mean: p50 * 1.05,
            stddev: p50 * 0.1,
            min: p50 * 0.8,
            p50,
            p95: p50 * 1.4,
            max: p50 * 1.5,
        },
    }
}

#[test]
fn recorded_bench_round_trips_through_artifact_file() {
    // Run a real (tiny) bench, capture its record, and push it through
    // the same save/load path `finish` + `qadam bench merge` use.
    let result = bench_with(
        "it_roundtrip_probe",
        BenchConfig { warmup_iters: 0, measure_iters: 3 },
        || std::hint::black_box((0..512u64).sum::<u64>()),
    );
    let mine = take_records()
        .into_iter()
        .find(|r| r.name == "it_roundtrip_probe")
        .expect("bench recorded");
    assert_eq!(mine, result.to_record());

    let dir = temp_dir("roundtrip");
    let path = dir.join("probe.json");
    let artifact = BenchArtifact::new(HostMeta::with_label("it-host"), vec![mine]);
    artifact.save(&path).expect("save artifact");
    let loaded = BenchArtifact::load(&path).expect("load artifact");
    assert_eq!(loaded, artifact);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn canonical_text_is_byte_deterministic() {
    let host = HostMeta::with_label("determinism");
    let forward =
        BenchArtifact::new(host.clone(), vec![record("a", 1e-3), record("b", 2e-3)]);
    let reversed = BenchArtifact::new(host, vec![record("b", 2e-3), record("a", 1e-3)]);
    // Same records, either insertion order, rendered twice: four
    // identical byte strings.
    let text = forward.to_canonical_text();
    assert_eq!(text, forward.to_canonical_text());
    assert_eq!(text, reversed.to_canonical_text());
    // Canonical form is one line with the envelope present.
    assert_eq!(text.matches('\n').count(), 1);
    assert!(text.contains(r#""kind":"qadam.bench""#));
    assert!(text.contains(r#""schema":1"#));
    // And it parses back to a structurally equal value.
    let reparsed = BenchArtifact::from_json(&Json::parse(&text).expect("parse")).expect("check");
    assert_eq!(reparsed.to_canonical_text(), text);
}

#[test]
fn merged_trajectory_diff_flags_injected_regression() {
    let dir = temp_dir("diff");
    // Two per-target artifacts, as QADAM_BENCH_OUT would lay them out.
    let host = HostMeta::with_label("ci");
    BenchArtifact::new(host.clone(), vec![record("mapper", 1e-3)])
        .save(&dir.join("perf_hotpath.json"))
        .expect("save target 1");
    BenchArtifact::new(host.clone(), vec![record("cache_warm", 5e-3)])
        .save(&dir.join("cache_resume.json"))
        .expect("save target 2");

    let baseline = BenchArtifact::merge(vec![
        BenchArtifact::load(&dir.join("perf_hotpath.json")).expect("load 1"),
        BenchArtifact::load(&dir.join("cache_resume.json")).expect("load 2"),
    ])
    .expect("merge");
    assert_eq!(baseline.benches.len(), 2);

    // Inject a 30% p50 regression into one bench and a harmless 5% wobble
    // into the other.
    let mut candidate = baseline.clone();
    for bench in &mut candidate.benches {
        bench.summary.p50 *= if bench.name == "mapper" { 1.3 } else { 1.05 };
    }
    let diff = baseline.diff(&candidate, 10.0);
    assert!(diff.has_regressions());
    assert_eq!(diff.regressions(), vec!["mapper"]);
    assert!(diff.render().contains("REGRESSION"));

    // The same candidate passes a looser gate.
    assert!(!baseline.diff(&candidate, 50.0).has_regressions());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn diff_is_total_on_degenerate_inputs() {
    let host = HostMeta::with_label("edge");
    // Zero-p50 baseline (a smoke run can measure below timer resolution):
    // the delta is defined as 0%, never a division-by-zero NaN.
    let zero = BenchArtifact::new(host.clone(), vec![record("instant", 0.0)]);
    let nonzero = BenchArtifact::new(host.clone(), vec![record("instant", 1e-3)]);
    let diff = zero.diff(&nonzero, 10.0);
    assert!(!diff.has_regressions());
    assert!(diff.entries[0].delta_pct == 0.0);
    // Disjoint artifacts compare as pure added/removed.
    let other = BenchArtifact::new(host, vec![record("elsewhere", 1e-3)]);
    let diff = nonzero.diff(&other, 10.0);
    assert!(diff.entries.is_empty());
    assert_eq!(diff.added, vec!["elsewhere".to_string()]);
    assert_eq!(diff.removed, vec!["instant".to_string()]);
    assert!(!diff.has_regressions());
}

#[test]
fn empty_sample_stats_cannot_panic_the_harness() {
    // The harness builds Summary::of over measured samples; these edges
    // used to assert!-panic and would have taken the bench process down.
    let empty = Summary::of(&[]);
    assert_eq!(empty.n, 0);
    assert_eq!(empty.mean, 0.0);
    assert_eq!(empty.p50, 0.0);
    // A zero-iteration config is normalized up to one sample.
    let result = bench_with(
        "it_zero_iters",
        BenchConfig { warmup_iters: 0, measure_iters: 0 },
        || (),
    );
    assert_eq!(result.summary.n, 1);
    // And a record built from it survives the artifact round-trip.
    let artifact =
        BenchArtifact::new(HostMeta::with_label("edge"), vec![result.to_record()]);
    let text = artifact.to_canonical_text();
    let back = BenchArtifact::from_json(&Json::parse(&text).expect("parse")).expect("load");
    assert_eq!(back, artifact);
}
