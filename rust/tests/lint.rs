//! `qadam lint` integration suite.
//!
//! Three layers of lockdown, mirroring `spec.rs`:
//!
//! * **Golden findings** — for every rule `Q001`…`Q012`, one
//!   mis-specified campaign whose rendered findings (line/column
//!   spans, excerpts, `[Qnnn]` prefixes, help lines) are pinned as a
//!   snapshot fixture, plus a near-miss spec that must NOT fire the
//!   rule. Fixtures bless on first run (`QADAM_BLESS=1` to
//!   regenerate, strict in CI under `QADAM_GOLDEN_REQUIRE=1`).
//! * **Determinism** — repeated lint passes over the same source are
//!   byte-identical and ordered by `(span.start, span.end, code)`.
//! * **Shipped specs are clean** — `STARTER_SPEC` and every
//!   `examples/*.qsl` pass `--deny all` with zero findings, and the
//!   JSON document round-trips through the crate's own parser.

mod common;

use std::fs;
use std::path::PathBuf;

use common::assert_snapshot;
use qadam::spec::lint::{self, Finding, Level, LintOptions};
use qadam::spec::{self, STARTER_SPEC};
use qadam::util::json::Json;

/// Lint a spec that must resolve cleanly (rules never see broken specs).
fn lint(source: &str) -> Vec<Finding> {
    let (campaign, diags, findings) = lint::lint_source(source, &LintOptions::default());
    assert!(
        campaign.is_some() && !diags.has_errors(),
        "spec must resolve before linting:\n{}",
        diags.render(source, "test.qsl")
    );
    findings
}

/// Pin a rule's rendered findings as a golden fixture: the spec must
/// fire `code` (and nothing else), and the rendering must match the
/// checked-in snapshot byte-for-byte.
fn golden_rule(fixture: &str, code: &str, source: &str) {
    let findings = lint(source);
    assert!(!findings.is_empty(), "{fixture}: expected {code} findings");
    for finding in &findings {
        assert_eq!(finding.code, code, "{fixture}: stray finding {finding:?}");
    }
    let keys: Vec<(usize, usize, &str)> =
        findings.iter().map(|f| (f.span.start, f.span.end, f.code)).collect();
    let mut sorted = keys.clone();
    sorted.sort_unstable();
    assert_eq!(keys, sorted, "{fixture}: findings must order by (start, end, code)");
    assert_snapshot(fixture, &lint::render(&findings, source, "campaign.qsl"));
}

/// The near-miss side of a rule: a corrected spec must not fire it.
fn assert_clean_of(source: &str, code: &str) {
    let findings = lint(source);
    assert!(
        findings.iter().all(|f| f.code != code),
        "{code} fired on the corrected spec: {findings:?}"
    );
}

/// A sweep block with every axis pinned to one value — a 1-point space
/// with no duplicates, so space-arithmetic rules stay quiet unless a
/// test deliberately perturbs an axis.
const PINNED_SWEEP: &str = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n  \
                            glb_kib = [64]\n  spad = [spad(12, 112, 16)]\n  \
                            dram_gbps = [8]\n  clock_ghz = [2]\n}\n";

// ---------------------------------------------------- per-rule goldens

#[test]
fn q001_dead_axis_value() {
    // A duplicated pe_type entry and an identity-only model_axes block:
    // two findings, both Q001.
    let source = "sweep {\n  pe_type = [int16, int16]\n  array = [8x8]\n  \
                  glb_kib = [64]\n  spad = [spad(12, 112, 16)]\n  \
                  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
                  model_axes {\n  width = [1]\n  depth = [1]\n}\n";
    golden_rule("spec_lint_q001.txt", "Q001", source);

    let clean = "sweep {\n  pe_type = [int16, fp32]\n  array = [8x8]\n  \
                 glb_kib = [64]\n  spad = [spad(12, 112, 16)]\n  \
                 dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
                 model_axes {\n  width = [0.5, 1]\n  depth = [1]\n}\n";
    assert_clean_of(clean, "Q001");
}

#[test]
fn q002_budget_covers_space() {
    // random(4) over a 4-point space degrades to an exhaustive walk.
    let source = "sweep {\n  pe_type = [int16]\n  array = [8x8, 16x16]\n  \
                  glb_kib = [64, 128]\n  spad = [spad(12, 112, 16)]\n  \
                  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
                  strategy = random(4)\n";
    golden_rule("spec_lint_q002.txt", "Q002", source);

    let clean = source.replace("random(4)", "random(3)");
    assert_clean_of(&clean, "Q002");
}

#[test]
fn q003_halving_rounds_excess() {
    // 16 points halve to 2 survivors in 3 rounds; rounds = 6 leaves the
    // final ranking at 1/8 fidelity.
    let source = "sweep {\n  pe_type = [int16, lightpe1]\n  array = [8x8, 16x16]\n  \
                  glb_kib = [64, 128]\n  spad = [spad(12, 112, 16)]\n  \
                  dram_gbps = [8, 16]\n  clock_ghz = [2]\n}\n\
                  strategy = halving(2, rounds = 6)\n";
    golden_rule("spec_lint_q003.txt", "Q003", source);

    let clean = source.replace("rounds = 6", "rounds = 3");
    assert_clean_of(&clean, "Q003");
}

#[test]
fn q004_spad_insufficient() {
    // spad(2, 2, 8) cannot hold one 3x3 kernel row of resnet20; every
    // workload model is affected, so the finding self-escalates to deny.
    let source = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [64]\n  \
                  spad = [spad(2, 2, 8)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
                  workload {\n  dataset = cifar10\n  models = [resnet20]\n}\n";
    golden_rule("spec_lint_q004.txt", "Q004", source);
    let deny = lint(source);
    assert!(deny.iter().all(|f| f.level == Level::Deny), "whole-workload Q004 must deny");

    let clean = source.replace("spad(2, 2, 8)", "spad(12, 112, 16)");
    assert_clean_of(&clean, "Q004");
}

#[test]
fn q005_glb_below_working_set() {
    // A 1 KiB GLB cannot hold even the smallest layer's 12 KiB ifmap
    // (32x32x3 at 32-bit activations).
    let source = "sweep {\n  pe_type = [fp32]\n  array = [8x8]\n  glb_kib = [1]\n  \
                  spad = [spad(12, 112, 16)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
                  workload {\n  dataset = cifar10\n  models = [tiny]\n}\n\
                  model tiny {\n  \
                  conv stem { in = 32, channels = 3, out = 8, kernel = 3, stride = 1, pad = 1 }\n\
                  }\n";
    golden_rule("spec_lint_q005.txt", "Q005", source);

    let clean = source.replace("glb_kib = [1]", "glb_kib = [64]");
    assert_clean_of(&clean, "Q005");
}

#[test]
fn q006_accuracy_unswept_precision() {
    // The fp32 accuracy entry is never consulted: the sweep only
    // evaluates int16.
    let source = format!(
        "{PINNED_SWEEP}workload {{\n  dataset = cifar10\n  models = [tiny]\n}}\n\
         model tiny {{\n  accuracy {{ int16 = 91.0, fp32 = 92.5 }}\n  \
         conv stem {{ in = 32, channels = 3, out = 8, kernel = 3, stride = 1, pad = 1 }}\n}}\n"
    );
    golden_rule("spec_lint_q006.txt", "Q006", &source);

    let clean = source.replace(", fp32 = 92.5", "");
    assert_clean_of(&clean, "Q006");
}

#[test]
fn q007_shadowed_override() {
    // The second `layer fc` override silently wins on overlapping keys.
    let source = format!(
        "{PINNED_SWEEP}workload {{\n  dataset = cifar10\n  models = [wide]\n}}\n\
         model wide like resnet20 {{\n  layer fc {{ out = 100 }}\n  layer fc {{ out = 10 }}\n}}\n"
    );
    golden_rule("spec_lint_q007.txt", "Q007", &source);

    let clean = format!(
        "{PINNED_SWEEP}workload {{\n  dataset = cifar10\n  models = [wide]\n}}\n\
         model wide like resnet20 {{\n  layer fc {{ out = 10 }}\n}}\n"
    );
    assert_clean_of(&clean, "Q007");
}

#[test]
fn q008_layer_chain_mismatch() {
    // Two breaks in one stack: 'mid' disagrees with 'stem' on both map
    // size and channels, and 'head' expects 10 of mid's 4096 outputs.
    let source = format!(
        "{PINNED_SWEEP}workload {{\n  dataset = cifar10\n  models = [broken]\n}}\n\
         model broken {{\n  \
         conv stem {{ in = 32, channels = 3, out = 16, kernel = 3, stride = 1, pad = 1 }}\n  \
         conv mid  {{ in = 16, channels = 8, out = 16, kernel = 3, stride = 1, pad = 1 }}\n  \
         fc head   {{ in = 10, out = 10 }}\n}}\n"
    );
    golden_rule("spec_lint_q008.txt", "Q008", &source);
    assert!(lint(&source).iter().all(|f| f.level == Level::Deny));

    let clean = format!(
        "{PINNED_SWEEP}workload {{\n  dataset = cifar10\n  models = [fixed]\n}}\n\
         model fixed {{\n  \
         conv stem {{ in = 32, channels = 3, out = 16, kernel = 3, stride = 1, pad = 1 }}\n  \
         conv mid  {{ in = 32, channels = 16, out = 16, kernel = 3, stride = 1, pad = 1 }}\n  \
         fc head   {{ in = 16384, out = 10 }}\n}}\n"
    );
    assert_clean_of(&clean, "Q008");
}

#[test]
fn q009_collapsed_variants() {
    // round(16 x 1.01) == 16: the w1.01 variant lowers to the same
    // stack as the base model, so half the joint space is duplicates.
    let source = format!(
        "{PINNED_SWEEP}model_axes {{\n  width = [1, 1.01]\n  depth = [1]\n}}\n\
         workload {{\n  dataset = cifar10\n  models = [tiny]\n}}\n\
         model tiny {{\n  \
         conv stem {{ in = 32, channels = 3, out = 16, kernel = 3, stride = 1, pad = 1 }}\n}}\n"
    );
    golden_rule("spec_lint_q009.txt", "Q009", &source);

    let clean = source.replace("width = [1, 1.01]", "width = [0.5, 1]");
    assert_clean_of(&clean, "Q009");
}

#[test]
fn q010_persist_hazard() {
    // A checkpoint with the implicit flush interval, and a streamed
    // frontier with no database behind it: two findings. The paths do
    // not exist, so Q011 stays quiet.
    let source = format!(
        "{PINNED_SWEEP}persist {{\n  \
         checkpoint = \"target/lint_nonexistent/run.journal\"\n  \
         frontier = \"target/lint_nonexistent/frontier.json\"\n}}\n"
    );
    golden_rule("spec_lint_q010.txt", "Q010", &source);

    let clean = format!(
        "{PINNED_SWEEP}persist {{\n  \
         db = \"target/lint_nonexistent/db.json\"\n  \
         checkpoint = \"target/lint_nonexistent/run.journal\"\n  \
         every = 8\n  \
         frontier = \"target/lint_nonexistent/frontier.json\"\n}}\n"
    );
    assert_clean_of(&clean, "Q010");
}

#[test]
fn q011_resume_mismatch() {
    // Plant incompatible artifacts at the paths the spec persists to.
    // Integration tests run with the manifest dir as cwd, so these
    // relative paths are stable across machines — the fixture stays
    // byte-deterministic.
    let dir = PathBuf::from("target/lint_artifacts");
    fs::create_dir_all(&dir).unwrap();
    fs::write(dir.join("torn.journal"), "{\"kind\": \"bogus\"}\n").unwrap();
    fs::write(dir.join("stale_db.json"), "{}").unwrap();
    let source = format!(
        "{PINNED_SWEEP}persist {{\n  \
         checkpoint = \"target/lint_artifacts/torn.journal\"\n  \
         every = 4\n  \
         db = \"target/lint_artifacts/stale_db.json\"\n}}\n"
    );
    golden_rule("spec_lint_q011.txt", "Q011", &source);
    assert!(lint(&source).iter().all(|f| f.level == Level::Deny));

    // Fresh paths: nothing on disk to collide with.
    let clean = source.replace("lint_artifacts", "lint_nonexistent");
    assert_clean_of(&clean, "Q011");
}

#[test]
fn q011_reports_every_manifest_field_drift() {
    // A healthy journal written by a *different* campaign: the lint
    // pass must name the drifted fields instead of just failing.
    let dir = std::env::temp_dir().join(format!("qadam_lint_drift_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("run.journal");
    let spec_for = |seed: u64| {
        format!(
            "campaign {{\n  seed = {seed}\n}}\n\
             sweep {{\n  pe_type = [int16]\n  array = [4x4]\n  glb_kib = [64]\n  \
             spad = [spad(12, 112, 16)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}}\n\
             workload {{\n  models = [tiny]\n}}\n\
             model tiny {{\n  \
             conv c {{ in = 8, channels = 3, out = 4, kernel = 3, stride = 1, pad = 1 }}\n}}\n\
             persist {{\n  checkpoint = \"{}\"\n  every = 1\n}}\n",
            journal.display()
        )
    };
    spec::compile(&spec_for(1), "a.qsl").unwrap().execute().unwrap();

    // Same spec, new seed: resuming would be rejected, and the finding
    // says why.
    let (_, _, findings) = lint::lint_source(&spec_for(2), &LintOptions::default());
    let q011: Vec<&Finding> = findings.iter().filter(|f| f.code == "Q011").collect();
    assert_eq!(q011.len(), 1, "{findings:?}");
    assert_eq!(q011[0].level, Level::Deny);
    assert!(
        q011[0].message.contains("seed (journal: 1, spec: 2)"),
        "finding must name the drifted field: {}",
        q011[0].message
    );

    // The campaign that wrote the journal resumes without findings.
    let (_, _, findings) = lint::lint_source(&spec_for(1), &LintOptions::default());
    assert!(findings.iter().all(|f| f.code != "Q011"), "{findings:?}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn q012_empty_selection() {
    // Shard index 3 of a 1-point space walks nothing.
    let source = format!("campaign {{\n  shard = 3 / 4\n}}\n{PINNED_SWEEP}");
    golden_rule("spec_lint_q012.txt", "Q012", &source);
    assert!(lint(&source).iter().all(|f| f.level == Level::Deny));

    let clean = "campaign {\n  shard = 1 / 2\n}\n\
                 sweep {\n  pe_type = [int16]\n  array = [8x8, 16x16]\n  glb_kib = [64]\n  \
                 spad = [spad(12, 112, 16)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n";
    assert_clean_of(clean, "Q012");
}

// ------------------------------------------------- output contracts

/// A spec that trips three rules at three distinct spans, pinning the
/// cross-rule ordering contract in one rendering.
const MULTI_RULE: &str = "sweep {\n  pe_type = [int16, int16]\n  array = [8x8]\n  \
                          glb_kib = [64]\n  spad = [spad(12, 112, 16)]\n  \
                          dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
                          strategy = random(99)\n\
                          persist {\n  \
                          checkpoint = \"target/lint_nonexistent/run.journal\"\n}\n";

#[test]
fn multi_rule_findings_render_in_span_order() {
    let findings = lint(MULTI_RULE);
    let codes: Vec<&str> = findings.iter().map(|f| f.code).collect();
    assert_eq!(codes, ["Q001", "Q002", "Q010"], "{findings:?}");
    let keys: Vec<(usize, usize, &str)> =
        findings.iter().map(|f| (f.span.start, f.span.end, f.code)).collect();
    let mut sorted = keys.clone();
    sorted.sort_unstable();
    assert_eq!(keys, sorted, "findings must order by (start, end, code)");
    assert_snapshot("spec_lint_multi.txt", &lint::render(&findings, MULTI_RULE, "campaign.qsl"));
}

#[test]
fn lint_is_deterministic_across_runs() {
    let first = lint::render(&lint(MULTI_RULE), MULTI_RULE, "campaign.qsl");
    let first_json = lint::to_json("campaign.qsl", MULTI_RULE, &lint(MULTI_RULE));
    for _ in 0..10 {
        let findings = lint(MULTI_RULE);
        assert_eq!(lint::render(&findings, MULTI_RULE, "campaign.qsl"), first);
        assert_eq!(lint::to_json("campaign.qsl", MULTI_RULE, &findings), first_json);
    }
}

#[test]
fn json_document_round_trips_through_the_crate_parser() {
    let findings = lint(MULTI_RULE);
    let json = lint::to_json("campaign.qsl", MULTI_RULE, &findings);
    assert_eq!(Json::parse(&json.to_string_pretty()).unwrap(), json);
    assert_eq!(Json::parse(&json.to_string_canonical()).unwrap(), json);
    assert_eq!(Json::parse(&json.to_string_compact()).unwrap(), json);
    assert_eq!(json.get("kind").and_then(Json::as_str), Some("qadam.lint"));
    assert_eq!(json.get("warn_count").and_then(Json::as_i64), Some(3));
    assert_eq!(json.get("deny_count").and_then(Json::as_i64), Some(0));
    let arr = json.get("findings").and_then(Json::as_arr).unwrap();
    assert_eq!(arr.len(), findings.len());
}

// ------------------------------------------------ shipped specs are clean

#[test]
fn starter_spec_is_lint_clean_under_deny_all() {
    let opts = LintOptions::parse("all", "").unwrap();
    let (campaign, diags, findings) = lint::lint_source(STARTER_SPEC, &opts);
    assert!(campaign.is_some() && !diags.has_errors());
    assert!(findings.is_empty(), "STARTER_SPEC must lint clean: {findings:?}");
}

#[test]
fn example_specs_are_lint_clean_under_deny_all() {
    let opts = LintOptions::parse("all", "").unwrap();
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples");
    let mut seen = 0;
    for entry in fs::read_dir(&dir).expect("examples directory") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("qsl") {
            continue;
        }
        seen += 1;
        let source = fs::read_to_string(&path).unwrap();
        let (campaign, diags, findings) = lint::lint_source(&source, &opts);
        assert!(
            campaign.is_some() && !diags.has_errors(),
            "{}: must resolve\n{}",
            path.display(),
            diags.render(&source, &path.display().to_string())
        );
        assert!(findings.is_empty(), "{}: {findings:?}", path.display());
    }
    assert!(seen >= 3, "expected the shipped example specs, found {seen}");
}
