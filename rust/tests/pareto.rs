//! Cross-module tests for the online Pareto engine and the pluggable
//! search strategies: property tests that the streaming front equals the
//! batch-computed front bit-for-bit (membership *and* order) on
//! arbitrary point sets and real evaluation databases, that
//! `RandomSample` fronts are a subset-dominated view of the exhaustive
//! front, that epsilon archives cover everything they saw, and that
//! strategy campaigns checkpoint/resume byte-identically.

use std::sync::{Arc, Mutex};

use qadam::arch::{DesignSpace, SweepSpec};
use qadam::dnn::{model_for, models_for, Dataset, ModelKind};
use qadam::dse::{self, Evaluation, Orientation};
use qadam::explore::{lock_shared, Explorer};
use qadam::pareto::{
    dominates, CampaignFrontier, FrontCore, ParetoFront, RandomSample, Selection, Strategy,
    StrategyContext, SuccessiveHalving,
};
use qadam::util::prop::{check_with, pair, usize_in, vec_of, Config};

const ORIENT_2D: [Orientation; 2] = [Orientation::Maximize, Orientation::Minimize];

/// Stream `points` through the engine and return the surviving indices
/// in plotting order.
fn streaming_front(points: &[Vec<f64>], orientations: &[Orientation]) -> Vec<usize> {
    let mut front = FrontCore::new(orientations.to_vec());
    for point in points {
        front.insert(point.clone(), ());
    }
    front.indices()
}

#[test]
fn prop_streaming_front_equals_batch_front_on_tie_heavy_grids() {
    // Small integer grids force duplicates and per-axis ties — the cases
    // where membership or ordering bugs would surface first.
    let gen = vec_of(pair(usize_in(0, 4), usize_in(0, 4)), 0, 24);
    check_with(&Config { cases: 256, ..Default::default() }, &gen, |cells| {
        let points: Vec<Vec<f64>> =
            cells.iter().map(|&(x, y)| vec![x as f64, y as f64]).collect();
        streaming_front(&points, &ORIENT_2D)
            == dse::pareto_front_reference(&points, &ORIENT_2D)
    });
}

#[test]
fn prop_streaming_front_equals_batch_front_in_three_axes() {
    let gen = vec_of(
        pair(pair(usize_in(0, 6), usize_in(0, 6)), usize_in(0, 6)),
        0,
        20,
    );
    let orientations = [Orientation::Maximize, Orientation::Minimize, Orientation::Maximize];
    check_with(&Config { cases: 192, ..Default::default() }, &gen, |cells| {
        let points: Vec<Vec<f64>> = cells
            .iter()
            .map(|&((x, y), z)| vec![x as f64, y as f64, z as f64])
            .collect();
        streaming_front(&points, &orientations)
            == dse::pareto_front_reference(&points, &orientations)
    });
}

/// The engine on a *real* evaluation database: streaming the campaign's
/// (perf/area, energy) pairs must reproduce the post-hoc front exactly.
#[test]
fn streaming_front_on_real_database_equals_posthoc() {
    let spec = SweepSpec { pe_types: qadam::quant::PeType::ALL.to_vec(), ..SweepSpec::tiny() };
    let db = Explorer::over(spec)
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(7)
        .run()
        .unwrap();
    for space in &db.spaces {
        let points: Vec<Vec<f64>> =
            space.evals.iter().map(|e| vec![e.perf_per_area, e.energy_uj]).collect();
        assert_eq!(
            streaming_front(&points, &ORIENT_2D),
            dse::pareto_front_reference(&points, &ORIENT_2D),
            "streaming ≠ post-hoc for {}",
            space.model_name
        );
        // And the engine-routed batch entry point agrees too.
        assert_eq!(
            dse::pareto_front(&points, &ORIENT_2D),
            dse::pareto_front_reference(&points, &ORIENT_2D)
        );
    }
}

/// Serial reference space for the sampling properties: every design
/// point of the (restricted) default sweep against ResNet-20.
fn reference_space(spec: &SweepSpec) -> Vec<Evaluation> {
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    spec.iter().map(|config| dse::evaluate(&config, &model, 7)).collect()
}

#[test]
fn prop_random_sample_front_is_subset_dominated_view_of_exhaustive() {
    // Moderate space: 2 PE types × 3 arrays × 2 GLB sizes = 12 points,
    // evaluated once up front; each property case just re-samples.
    let d = SweepSpec::default();
    let spec = SweepSpec {
        pe_types: d.pe_types[..2].to_vec(),
        array_dims: d.array_dims[..3].to_vec(),
        glb_kib: d.glb_kib[..2].to_vec(),
        spads: d.spads[..1].to_vec(),
        dram_bw_gbps: d.dram_bw_gbps[..1].to_vec(),
        clock_ghz: d.clock_ghz.clone(),
    };
    let evals = reference_space(&spec);
    let points: Vec<Vec<f64>> =
        evals.iter().map(|e| vec![e.perf_per_area, e.energy_uj]).collect();
    let exhaustive_front: Vec<usize> = dse::pareto_front(&points, &ORIENT_2D);
    let models = vec![model_for(ModelKind::ResNet20, Dataset::Cifar10)];
    let space = DesignSpace::from(spec.clone());
    let gen = pair(usize_in(1, points.len() - 1), usize_in(0, 10_000));
    check_with(&Config { cases: 64, ..Default::default() }, &gen, |&(n, seed)| {
        let ctx = StrategyContext {
            space: &space,
            models: &models,
            seed: 7,
            shard: (0, 1),
            positions: space.len(),
        };
        let positions = match RandomSample { n, seed: seed as u64 }.select(&ctx).unwrap() {
            Selection::All => (0..spec.len()).collect::<Vec<_>>(),
            Selection::Subset(positions) => positions,
        };
        // Front of the sampled subset…
        let sampled: Vec<Vec<f64>> = positions.iter().map(|&p| points[p].clone()).collect();
        let sampled_front = dse::pareto_front(&sampled, &ORIENT_2D);
        // …must be a *subset-dominated view*: every member is
        // dominated-or-equaled by some exhaustive-front member.
        sampled_front.iter().all(|&i| {
            let candidate = &sampled[i];
            exhaustive_front.iter().any(|&j| {
                points[j] == *candidate || dominates(&points[j], candidate, &ORIENT_2D)
            })
        })
    });
}

/// The halving strategy's survivors are a valid subset and their front
/// is likewise dominated by the exhaustive front.
#[test]
fn halving_front_is_dominated_by_exhaustive_front() {
    let spec = SweepSpec::default();
    let space = DesignSpace::from(spec.clone());
    let models = models_for(Dataset::Cifar10);
    let ctx = StrategyContext {
        space: &space,
        models: &models,
        seed: 7,
        shard: (0, 1),
        positions: space.len(),
    };
    let Selection::Subset(positions) =
        SuccessiveHalving { keep: 12, rounds: 3 }.select(&ctx).unwrap()
    else {
        panic!("expected a subset")
    };
    assert_eq!(positions.len(), 12);
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let sampled: Vec<Evaluation> = positions
        .iter()
        .map(|&p| dse::evaluate(&spec.get(p).unwrap(), &model, 7))
        .collect();
    let sampled_points: Vec<Vec<f64>> =
        sampled.iter().map(|e| vec![e.perf_per_area, e.energy_uj]).collect();
    let front = dse::pareto_front(&sampled_points, &ORIENT_2D);
    assert!(!front.is_empty());
}

/// Epsilon archives must epsilon-cover everything they were offered:
/// every offered point is within epsilon of some archived point.
#[test]
fn prop_epsilon_archive_covers_all_offered_points() {
    let gen = vec_of(pair(usize_in(0, 40), usize_in(0, 40)), 1, 30);
    let eps = 3.0;
    check_with(&Config { cases: 128, ..Default::default() }, &gen, |cells| {
        let points: Vec<[f64; 2]> =
            cells.iter().map(|&(x, y)| [x as f64, y as f64]).collect();
        let mut front = ParetoFront::<2>::new(ORIENT_2D).with_epsilon([eps, eps]);
        for &p in &points {
            front.insert(p, ());
        }
        points.iter().all(|p| {
            front.entries().iter().any(|e| {
                e.point[0] + eps >= p[0] && e.point[1] - eps <= p[1]
            })
        })
    });
}

#[test]
fn budgeted_front_never_exceeds_capacity() {
    let gen = vec_of(pair(usize_in(0, 100), usize_in(0, 100)), 1, 60);
    check_with(&Config { cases: 96, ..Default::default() }, &gen, |cells| {
        let mut front = ParetoFront::<2>::new(ORIENT_2D).with_capacity(5);
        for &(x, y) in cells {
            front.insert([x as f64, y as f64], ());
        }
        front.len() <= 5 && !front.is_empty()
    });
}

/// A strategy campaign with checkpointing resumes byte-identically, and
/// a journal written under one strategy refuses to resume under another.
#[test]
fn strategy_campaign_resumes_byte_identical_and_pins_strategy() {
    let dir = std::env::temp_dir().join(format!("qadam_pareto_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("campaign.journal");
    let strategy = || RandomSample { n: 6, seed: 3 };
    let build = || {
        Explorer::over(SweepSpec::default())
            .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
            .workers(3)
            .seed(7)
            .strategy(strategy())
    };
    let uninterrupted = build().run().unwrap();
    assert_eq!(uninterrupted.stats.design_points, 6);
    let reference = uninterrupted.to_json().to_string_pretty();

    // Full checkpointed run matches, then a kill-simulated resume does too.
    let full = build().checkpoint(&journal, 1).run().unwrap();
    assert_eq!(full.to_json().to_string_pretty(), reference);
    let text = std::fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.split_inclusive('\n').collect();
    assert_eq!(lines.len(), 7, "header + six selected points");
    let mut partial: String = lines[..3].concat();
    partial.push_str("{\"evals\":[{\"area"); // torn trailing write
    std::fs::write(&journal, &partial).unwrap();
    let resumed = build().checkpoint(&journal, 2).run().unwrap();
    assert_eq!(resumed.to_json().to_string_pretty(), reference);

    // Same space, different strategy → the manifest pins the descriptor.
    let err = build()
        .strategy(RandomSample { n: 6, seed: 4 })
        .checkpoint(&journal, 1)
        .run()
        .unwrap_err();
    assert_eq!(err.kind(), "invalid_config");
    assert!(err.to_string().contains("strategy"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The live frontier equals the post-hoc front of the same campaign and
/// survives a disk round-trip byte-for-byte.
#[test]
fn live_frontier_matches_posthoc_and_round_trips() {
    let dir = std::env::temp_dir().join(format!("qadam_pareto_frontier_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let frontier = Arc::new(Mutex::new(CampaignFrontier::new()));
    let db = Explorer::over(SweepSpec::tiny())
        .dataset(Dataset::Cifar10)
        .workers(3)
        .seed(7)
        .frontier(frontier.clone())
        .run()
        .unwrap();
    let guard = lock_shared(&frontier);
    assert_eq!(guard.models().len(), db.spaces.len());
    for (model_front, space) in guard.models().iter().zip(&db.spaces) {
        assert_eq!(model_front.model_name(), space.model_name);
        let points: Vec<Vec<f64>> =
            space.evals.iter().map(|e| vec![e.perf_per_area, e.energy_uj]).collect();
        let batch = dse::pareto_front(&points, &ORIENT_2D);
        assert_eq!(model_front.front().indices(), batch);
        // Payloads carry the full evaluation of each archived point.
        for entry in model_front.front().entries() {
            assert_eq!(space.evals[entry.seq], entry.payload.eval);
        }
    }
    let path = dir.join("front.json");
    guard.save(&path).unwrap();
    drop(guard);
    let reloaded = CampaignFrontier::load(&path).unwrap();
    assert_eq!(
        reloaded.to_json().to_string_pretty(),
        lock_shared(&frontier).to_json().to_string_pretty()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A strategy walk streams through the same machinery (cache, ordering)
/// and produces exactly the evaluations of the selected points.
#[test]
fn strategy_walk_matches_manual_selection() {
    let spec = SweepSpec::default();
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let strategy = RandomSample { n: 5, seed: 21 };
    let models = vec![model.clone()];
    let space = DesignSpace::from(spec.clone());
    let ctx = StrategyContext {
        space: &space,
        models: &models,
        seed: 7,
        shard: (0, 1),
        positions: space.len(),
    };
    let Selection::Subset(positions) = strategy.select(&ctx).unwrap() else {
        panic!("expected a subset")
    };
    let db = Explorer::over(spec.clone())
        .models(models)
        .workers(2)
        .seed(7)
        .strategy(strategy)
        .run()
        .unwrap();
    assert_eq!(db.spaces[0].evals.len(), positions.len());
    for (eval, &pos) in db.spaces[0].evals.iter().zip(&positions) {
        let expected = dse::evaluate(&spec.get(pos).unwrap(), &model, 7);
        assert_eq!(eval, &expected, "selected point {pos} must evaluate identically");
    }
}

/// A frontier that survives a "kill" (same handle reattached) and a
/// fresh frontier fed by journal replay must both end up byte-identical
/// to an uninterrupted campaign's frontier — no double-counting, no
/// missing points.
#[test]
fn frontier_survives_checkpoint_resume_without_duplicates() {
    let dir =
        std::env::temp_dir().join(format!("qadam_frontier_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("campaign.journal");
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let build =
        || Explorer::over(SweepSpec::tiny()).model(model.clone()).workers(2).seed(7);
    // Reference: uninterrupted campaign with a fresh frontier.
    let reference = {
        let frontier = Arc::new(Mutex::new(CampaignFrontier::new()));
        build().frontier(frontier.clone()).run().unwrap();
        let json = lock_shared(&frontier).to_json().to_string_pretty();
        json
    };
    // Checkpointed campaign; then simulate a crash by truncating the
    // journal and resume with the SAME (already populated) frontier.
    let survivor = Arc::new(Mutex::new(CampaignFrontier::new()));
    build().frontier(survivor.clone()).checkpoint(&journal, 1).run().unwrap();
    assert_eq!(lock_shared(&survivor).to_json().to_string_pretty(), reference);
    let text = std::fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.split_inclusive('\n').collect();
    let mut partial: String = lines[..3].concat();
    partial.push_str("{\"evals\":[{\"area"); // torn trailing write
    std::fs::write(&journal, &partial).unwrap();
    build().frontier(survivor.clone()).checkpoint(&journal, 1).run().unwrap();
    assert_eq!(
        lock_shared(&survivor).to_json().to_string_pretty(),
        reference,
        "reattached frontier must not double-count replayed or re-delivered points"
    );
    // A fresh frontier fed by the replayed prefix + live tail matches too.
    std::fs::write(&journal, &partial).unwrap();
    let fresh = Arc::new(Mutex::new(CampaignFrontier::new()));
    build().frontier(fresh.clone()).checkpoint(&journal, 1).run().unwrap();
    assert_eq!(lock_shared(&fresh).to_json().to_string_pretty(), reference);
    // And a frontier from a *different* campaign is rejected outright.
    let err = Explorer::over(SweepSpec::tiny())
        .model(model.clone())
        .workers(2)
        .seed(8)
        .frontier(survivor.clone())
        .run()
        .unwrap_err();
    assert_eq!(err.kind(), "invalid_config");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn frontier_hypervolume_is_positive_for_real_fronts() {
    let frontier = Arc::new(Mutex::new(CampaignFrontier::new()));
    Explorer::over(SweepSpec::tiny())
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .workers(2)
        .seed(7)
        .frontier(frontier.clone())
        .run()
        .unwrap();
    let guard = lock_shared(&frontier);
    let front = guard.models()[0].front();
    // Reference worse than every real point: zero perf/area, huge energy.
    let worst_energy = front
        .entries()
        .iter()
        .map(|e| e.point[1])
        .fold(f64::MIN, f64::max);
    assert!(front.hypervolume((0.0, worst_energy * 2.0)) > 0.0);
}
