//! QSL integration suite.
//!
//! Three layers of lockdown:
//!
//! * **Golden diagnostics** — bad specs must render *exactly* the
//!   pinned error text (line/column spans, source excerpts, "did you
//!   mean" suggestions), via the shared bless-on-missing snapshot
//!   helper (`QADAM_BLESS=1` to regenerate, strict in CI under
//!   `QADAM_GOLDEN_REQUIRE=1`).
//! * **Canonical fixed point** — for random campaigns,
//!   `parse → resolve → canonical` re-parses to the same canonical
//!   bytes and the same fingerprint.
//! * **Spec ≡ flags** — executing a spec produces a byte-identical
//!   `EvalDatabase` to the equivalent flag-built campaign and to a
//!   direct `Explorer` run, and checkpoint journals written by one are
//!   resumable by the other — while an *edited* spec is rejected with
//!   a typed error.

mod common;

use std::fs;
use std::path::PathBuf;

use common::assert_snapshot;
use qadam::arch::SweepSpec;
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::explore::{point_key, Explorer};
use qadam::pareto::RandomSample;
use qadam::spec::{self, PersistPlan, ResolvedCampaign, StrategyChoice, WorkloadModel};
use qadam::util::prop::{check_with, usize_in, Config};
use qadam::util::rng::Pcg64;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_spec_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

// ------------------------------------------------------ golden diagnostics

fn rendered_diags(source: &str, filename: &str) -> String {
    let (campaign, diags) = spec::check(source);
    assert!(campaign.is_none(), "{filename}: expected errors");
    diags.render(source, filename)
}

/// Unknown names at every level — axis, PE type, dataset, model — must
/// each produce a located error with a suggestion, all in one pass.
#[test]
fn golden_diag_unknown_names() {
    let source = "sweep {\n  pe_typ = [int16]\n  pe_type = [int17, lightpe1]\n}\n\
                  workload {\n  dataset = cifra10\n  models = [resnet21, vgg16]\n}\n";
    assert_snapshot("spec_diag_unknown_names.txt", &rendered_diags(source, "bad_names.qsl"));
}

/// Layer-level mistakes: unknown fields, missing required fields,
/// impossible geometry, and an override of a layer that does not exist.
#[test]
fn golden_diag_bad_layers() {
    let source = "workload {\n  models = [tiny, wide]\n}\n\
                  model tiny {\n  conv c1 { in = 32, chanels = 3, out = 16, kernel = 3 }\n  \
                  conv c2 { in = 4, channels = 16, out = 8, kernel = 9 }\n  fc head { in = 128 }\n}\n\
                  model wide like resnet20 {\n  layer s1b1_conv9 { out = 32 }\n}\n";
    assert_snapshot("spec_diag_bad_layers.txt", &rendered_diags(source, "bad_layers.qsl"));
}

/// Syntax-level recovery: an unknown section, a missing '=', and an
/// unterminated string must all be reported, not just the first.
#[test]
fn golden_diag_syntax() {
    let source = "campaing {\n  seed = 7\n}\n\
                  campaign {\n  seed 7\n}\n\
                  persist {\n  db = \"unterminated\n}\n";
    assert_snapshot("spec_diag_syntax.txt", &rendered_diags(source, "bad_syntax.qsl"));
}

/// The acceptance shape: a spec with >= 3 distinct mistakes reports all
/// of them in one pass, each with a line/column span.
#[test]
fn multi_error_specs_report_everything_with_spans() {
    let source = "sweep {\n  pe_typ = [int16]\n  glb_kib = [0]\n}\n\
                  strategy = random()\n\
                  workload {\n  models = [resnet99]\n}\n";
    let (campaign, diags) = spec::check(source);
    assert!(campaign.is_none());
    assert!(diags.error_count() >= 3, "wanted >= 3 errors, got {}:\n{diags}", diags.error_count());
    let rendered = diags.render(source, "multi.qsl");
    // Every error carries a file:line:col location.
    let located = rendered.matches("--> multi.qsl:").count();
    assert!(located >= 3, "wanted >= 3 located errors:\n{rendered}");
}

// ------------------------------------------------- canonical fixed point

/// Derive a random-but-valid spec source from one seed.
fn random_spec_source(seed: u64) -> String {
    let mut rng = Pcg64::new(seed);
    let mut out = String::new();
    out.push_str(&format!(
        "campaign {{\n  seed = {}\n  workers = {}\n}}\n",
        rng.below(1000),
        rng.below(4)
    ));
    let pe_pool = ["int16", "lightpe1", "fp32", "lightpe2"];
    let pe_count = 1 + rng.below(3) as usize;
    let arrays = ["4x4", "8x8", "12x14", "16x16"];
    let array_count = 1 + rng.below(3) as usize;
    out.push_str(&format!(
        "sweep {{\n  pe_type = [{}]\n  array = [{}]\n  glb_kib = [{}]\n  \
         spad = [spad({}, {}, {})]\n  dram_gbps = [{}]\n  clock_ghz = [2]\n}}\n",
        pe_pool[..pe_count].join(", "),
        arrays[..array_count].join(", "),
        64 << rng.below(3),
        6 + rng.below(20),
        28 + rng.below(200),
        8 + rng.below(32),
        [8, 16, 32][rng.below(3) as usize],
    ));
    if rng.below(2) == 1 {
        let widths = ["0.25", "0.5", "1", "1.5"];
        let wcount = 1 + rng.below(4) as usize;
        let dcount = 1 + rng.below(3) as usize;
        out.push_str(&format!(
            "model_axes {{\n  width = [{}]\n  depth = [{}]\n}}\n",
            widths[..wcount].join(", "),
            ["1", "2", "3"][..dcount].join(", "),
        ));
    }
    match rng.below(3) {
        0 => {}
        1 => out.push_str(&format!("strategy = random({})\n", 1 + rng.below(8))),
        _ => out.push_str(&format!(
            "strategy = halving({}, rounds = {})\n",
            1 + rng.below(4),
            1 + rng.below(3)
        )),
    }
    let with_custom = rng.below(2) == 1;
    let models = if with_custom { "resnet20, randnet" } else { "vgg16, resnet56" };
    out.push_str(&format!(
        "workload {{\n  dataset = {}\n  models = [{models}]\n}}\n",
        ["cifar10", "cifar100"][rng.below(2) as usize],
    ));
    if with_custom {
        let in_hw = 8 + rng.below(24);
        let channels = 1 + rng.below(8);
        let width = 1 + rng.below(16);
        out.push_str(&format!(
            "model randnet {{\n  conv stem {{ in = {in_hw}, channels = {channels}, \
             out = {width}, kernel = 3, stride = 1, pad = 1 }}\n  \
             fc head {{ in = {}, out = 10 }}\n}}\n",
            in_hw * in_hw * width,
        ));
    }
    if rng.below(2) == 1 {
        out.push_str("persist {\n  db = \"out/db.json\"\n  checkpoint = \"out/j.journal\"\n}\n");
    }
    out
}

/// `spec → lower → canonical → re-parse → lower → canonical` is a fixed
/// point, and the fingerprint survives the round trip.
#[test]
fn prop_canonical_form_is_a_fixed_point() {
    let gen = usize_in(1, 1_000_000);
    check_with(&Config { cases: 64, ..Default::default() }, &gen, |&seed| {
        let source = random_spec_source(seed as u64);
        let campaign = match spec::compile(&source, "prop.qsl") {
            Ok(campaign) => campaign,
            Err(err) => panic!("generated spec must be valid:\n{source}\n{err}"),
        };
        let canonical = campaign.canonical();
        let reparsed = match spec::compile(&canonical, "prop.canonical.qsl") {
            Ok(campaign) => campaign,
            Err(err) => panic!("canonical form must re-parse:\n{canonical}\n{err}"),
        };
        reparsed.canonical() == canonical && reparsed.fingerprint() == campaign.fingerprint()
    });
}

// --------------------------------------------------------- spec ≡ flags

const DEMO_SPEC: &str = "campaign {\n  seed = 9\n}\n\
    sweep {\n  pe_type = [int16, lightpe1]\n  array = [8x8, 16x16]\n  glb_kib = [128]\n  \
    spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
    strategy = random(3)\n\
    workload {\n  dataset = cifar10\n  models = [resnet20]\n}\n";

/// The flag-built equivalent of [`DEMO_SPEC`] — what
/// `qadam dse --strategy random:3 --seed 9` (with a matching sweep)
/// constructs.
fn demo_flag_campaign(db_path: PathBuf) -> ResolvedCampaign {
    ResolvedCampaign::new(
        SweepSpec::tiny(),
        Dataset::Cifar10,
        vec![WorkloadModel::Zoo(ModelKind::ResNet20)],
        9,
        0,
        (0, 1),
        StrategyChoice::Random { n: 3, seed: 9 },
        PersistPlan { db: Some(db_path), ..PersistPlan::new() },
    )
}

#[test]
fn run_spec_equals_flag_invocation_bit_for_bit() {
    let dir = temp_dir("e2e");
    // `qadam run demo.qsl --save ...`
    let mut from_spec = spec::compile(DEMO_SPEC, "demo.qsl").unwrap();
    from_spec.persist.db = Some(dir.join("spec_db.json"));
    let spec_outcome = from_spec.execute().unwrap();
    // The equivalent flag invocation.
    let from_flags = demo_flag_campaign(dir.join("flag_db.json"));
    let flag_outcome = from_flags.execute().unwrap();
    // Same campaign identity, same bytes on disk.
    assert_eq!(from_spec.fingerprint(), from_flags.fingerprint());
    let spec_bytes = fs::read(dir.join("spec_db.json")).unwrap();
    let flag_bytes = fs::read(dir.join("flag_db.json")).unwrap();
    assert_eq!(spec_bytes, flag_bytes, "spec and flag campaigns must save identical bytes");
    assert_eq!(spec_outcome.db.stats.design_points, 3);
    assert_eq!(flag_outcome.db.stats.design_points, 3);
    // And both equal the direct library path.
    let direct = Explorer::over(SweepSpec::tiny())
        .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
        .seed(9)
        .strategy(RandomSample { n: 3, seed: 9 })
        .run()
        .unwrap();
    assert_eq!(direct.to_json().to_string_pretty().into_bytes(), spec_bytes);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn spec_and_flag_journals_are_interchangeable() {
    let dir = temp_dir("journal_interop");
    let journal = dir.join("campaign.journal");
    let mut from_spec = spec::compile(DEMO_SPEC, "demo.qsl").unwrap();
    from_spec.persist.checkpoint = Some(journal.clone());
    let first = from_spec.execute().unwrap();
    // The flag-built equivalent resumes the spec-written journal (same
    // fingerprint), replaying every point to an identical database.
    let mut from_flags = demo_flag_campaign(dir.join("db.json"));
    from_flags.persist.checkpoint = Some(journal.clone());
    let resumed = from_flags.execute().unwrap();
    assert_eq!(
        resumed.db.to_json().to_string_pretty(),
        first.db.to_json().to_string_pretty(),
        "journal replay must reproduce the database byte-for-byte"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn resuming_under_an_edited_spec_is_rejected() {
    let dir = temp_dir("edited_spec");
    let journal = dir.join("campaign.journal");
    // A campaign whose only mutable identity lives in a *custom model
    // shape* — the sweep fingerprint, seed, model names, dataset, and
    // strategy all stay identical under the edit, so only the QSL
    // fingerprint can catch it.
    let source_a = "campaign {\n  seed = 5\n}\n\
        sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [128]\n  \
        spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
        workload {\n  dataset = cifar10\n  models = [tiny]\n}\n\
        model tiny {\n  fc head { in = 64, out = 10 }\n}\n";
    let source_b = source_a.replace("in = 64", "in = 32");
    let mut campaign_a = spec::compile(source_a, "a.qsl").unwrap();
    campaign_a.persist.checkpoint = Some(journal.clone());
    campaign_a.execute().unwrap();
    // Unedited spec: resumes (full replay) cleanly.
    campaign_a.execute().unwrap();
    // Edited spec: typed rejection, not silent replay of foreign points.
    let mut campaign_b = spec::compile(&source_b, "b.qsl").unwrap();
    assert_ne!(campaign_a.fingerprint(), campaign_b.fingerprint());
    campaign_b.persist.checkpoint = Some(journal.clone());
    let err = campaign_b.execute().unwrap_err();
    assert_eq!(err.kind(), "invalid_config");
    assert!(err.to_string().contains("spec"), "{err}");
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- custom models

#[test]
fn custom_model_shapes_reach_the_cache_key() {
    // Two specs differing only in a custom layer's shape must produce
    // different point-cache keys — the cache must never alias them.
    let base = "workload {\n  models = [tiny]\n}\n\
                model tiny {\n  fc head { in = 64, out = 10 }\n}\n";
    let edited = base.replace("in = 64", "in = 32");
    let a = spec::compile(base, "a.qsl").unwrap();
    let b = spec::compile(&edited, "b.qsl").unwrap();
    let config = qadam::arch::AcceleratorConfig::default();
    assert_ne!(
        point_key(&config, 7, &a.models()),
        point_key(&config, 7, &b.models()),
        "layer-shape edits must change the cache key"
    );
}

#[test]
fn custom_and_like_models_flow_through_a_campaign() {
    let source = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [128]\n  \
        spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
        workload {\n  dataset = cifar10\n  models = [resnet20, tiny, narrow]\n}\n\
        model tiny {\n  conv stem { in = 32, channels = 3, out = 8, kernel = 3, pad = 1 }\n  \
        fc head { in = 8192, out = 10 }\n}\n\
        model narrow like resnet20 {\n  layer conv1 { out = 8 }\n  layer s1b1_conv1 { channels = 8 }\n}\n";
    let campaign = spec::compile(source, "t.qsl").unwrap();
    let outcome = campaign.execute().unwrap();
    assert_eq!(outcome.db.spaces.len(), 3);
    for space in &outcome.db.spaces {
        assert_eq!(space.evals.len(), 1, "{}", space.model_name);
        assert!(space.evals[0].perf_per_area > 0.0);
    }
    assert_eq!(outcome.db.spaces[1].model_name, "tiny");
    assert_eq!(outcome.db.spaces[2].model_name, "narrow");
}

// ------------------------------------------------ joint model axes & accuracy

/// A `model_axes` block resolves into the campaign, changes the
/// fingerprint, and survives the canonical fixed point.
#[test]
fn model_axes_resolve_and_pin_identity() {
    let source = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n}\n\
                  model_axes {\n  width = [0.5, 1]\n  depth = [1, 2]\n}\n";
    let campaign = spec::compile(source, "axes.qsl").unwrap();
    assert_eq!(campaign.model_axes.width_mults, vec![0.5, 1.0]);
    assert_eq!(campaign.model_axes.depth_mults, vec![1, 2]);
    // Identity: axes move the fingerprint.
    let base = spec::compile("sweep {\n  pe_type = [int16]\n  array = [8x8]\n}\n", "b.qsl")
        .unwrap();
    assert_ne!(campaign.fingerprint(), base.fingerprint());
    // Canonical fixed point with axes present.
    let canonical = campaign.canonical();
    assert!(canonical.contains("model_axes {"), "{canonical}");
    let reparsed = spec::compile(&canonical, "axes.canonical.qsl").unwrap();
    assert_eq!(reparsed.canonical(), canonical);
    assert_eq!(reparsed.fingerprint(), campaign.fingerprint());
    // Explicit trivial axes are the base campaign (canonical omits them).
    let trivial = spec::compile(
        "sweep {\n  pe_type = [int16]\n  array = [8x8]\n}\n\
         model_axes {\n  width = [1]\n  depth = [1]\n}\n",
        "t.qsl",
    )
    .unwrap();
    assert_eq!(trivial.fingerprint(), base.fingerprint());
    assert!(!trivial.canonical().contains("model_axes"), "{}", trivial.canonical());
}

/// Bad model_axes values are all reported with spans and suggestions.
#[test]
fn golden_diag_model_axes() {
    let source = "model_axes {\n  widht = [0.5]\n  width = [0, 0.5, 0.5]\n  depth = [0, 2, 2]\n}\n";
    assert_snapshot("spec_diag_model_axes.txt", &rendered_diags(source, "bad_axes.qsl"));
}

/// A joint spec campaign executes end to end: spaces per scaled-model
/// variant, and `qadam run` ≡ the flag-built path, byte for byte.
#[test]
fn joint_spec_campaign_executes_and_matches_flag_path() {
    let dir = temp_dir("joint");
    let source = "campaign {\n  seed = 9\n}\n\
        sweep {\n  pe_type = [int16, lightpe1]\n  array = [8x8, 16x16]\n  glb_kib = [128]\n  \
        spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
        model_axes {\n  width = [0.5, 1]\n  depth = [1]\n}\n\
        workload {\n  dataset = cifar10\n  models = [resnet20]\n}\n";
    let mut from_spec = spec::compile(source, "joint.qsl").unwrap();
    from_spec.persist.db = Some(dir.join("spec_db.json"));
    let outcome = from_spec.execute().unwrap();
    assert_eq!(outcome.db.spaces.len(), 2);
    assert_eq!(outcome.db.spaces[0].model_name, "ResNet-20@w0.5d1");
    assert_eq!(outcome.db.spaces[1].model_name, "ResNet-20");
    assert_eq!(outcome.db.stats.design_points, 2 * SweepSpec::tiny().len());
    // The flag path (`qadam dse --width-mults 0.5,1.0`) builds the same
    // campaign and must save identical bytes.
    let mut from_flags = ResolvedCampaign::new(
        SweepSpec::tiny(),
        Dataset::Cifar10,
        vec![WorkloadModel::Zoo(ModelKind::ResNet20)],
        9,
        0,
        (0, 1),
        StrategyChoice::Exhaustive,
        PersistPlan { db: Some(dir.join("flag_db.json")), ..PersistPlan::new() },
    );
    from_flags.model_axes =
        qadam::arch::ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] };
    from_flags.execute().unwrap();
    assert_eq!(from_spec.fingerprint(), from_flags.fingerprint());
    assert_eq!(
        fs::read(dir.join("spec_db.json")).unwrap(),
        fs::read(dir.join("flag_db.json")).unwrap(),
        "spec and flag joint campaigns must save identical bytes"
    );
    let _ = fs::remove_dir_all(&dir);
}

/// Declared accuracy resolves, re-renders canonically, and reaches the
/// accuracy book (variants inherit the base declaration).
#[test]
fn accuracy_blocks_resolve_into_the_book() {
    let source = "workload {\n  models = [tiny]\n}\n\
                  model tiny {\n  accuracy { int16 = 91.2, lightpe1 = 90.1 }\n  \
                  fc head { in = 64, out = 10 }\n}\n";
    let campaign = spec::compile(source, "acc.qsl").unwrap();
    assert_eq!(campaign.accuracy.len(), 1);
    let book = campaign.accuracy_book();
    use qadam::quant::PeType;
    assert_eq!(book.lookup("tiny", Dataset::Cifar10, PeType::Int16), Some(91.2));
    assert_eq!(book.lookup("tiny@w0.5d2", Dataset::Cifar10, PeType::LightPe1), Some(90.1));
    assert_eq!(book.lookup("tiny", Dataset::Cifar10, PeType::Fp32), None);
    // Canonical keeps the block (full form) but not in the identity:
    // editing accuracy must not invalidate a resume.
    let canonical = campaign.canonical();
    assert!(canonical.contains("accuracy { int16 = 91.2, lightpe1 = 90.1 }"), "{canonical}");
    let reparsed = spec::compile(&canonical, "acc.canonical.qsl").unwrap();
    assert_eq!(reparsed.canonical(), canonical);
    let edited = source.replace("91.2", "92.5");
    let other = spec::compile(&edited, "acc2.qsl").unwrap();
    assert_eq!(campaign.fingerprint(), other.fingerprint());
}

/// Unknown precision keys in accuracy blocks get did-you-mean help.
#[test]
fn golden_diag_accuracy_typos() {
    let source = "workload {\n  models = [tiny]\n}\n\
                  model tiny {\n  accuracy { int61 = 91.2, int16 = 150 }\n  \
                  accuracy { fp32 = 93.0 }\n  fc head { in = 64, out = 10 }\n}\n";
    assert_snapshot("spec_diag_accuracy.txt", &rendered_diags(source, "bad_accuracy.qsl"));
}

// ----------------------------------------------------- shipped spec files

/// Every shipped spec — the starter and the examples — must validate.
#[test]
fn shipped_specs_compile_cleanly() {
    let (campaign, diags) = spec::check(spec::STARTER_SPEC);
    assert!(campaign.is_some(), "starter spec:\n{}", diags.render(spec::STARTER_SPEC, "init.qsl"));
    let examples = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples");
    let mut seen = 0;
    for entry in fs::read_dir(&examples).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("qsl") {
            continue;
        }
        seen += 1;
        let source = fs::read_to_string(&path).unwrap();
        let (campaign, diags) = spec::check(&source);
        assert!(
            campaign.is_some(),
            "{}:\n{}",
            path.display(),
            diags.render(&source, &path.display().to_string())
        );
    }
    assert!(seen >= 2, "expected at least two example specs, found {seen}");
}

/// The validate-style resolved summary stays stable (golden-pinned) for
/// a representative spec.
#[test]
fn golden_validate_summary() {
    let campaign = spec::compile(DEMO_SPEC, "demo.qsl").unwrap();
    assert_snapshot("spec_validate_summary.txt", &campaign.summary());
}
