//! Source-tree hygiene.
//!
//! A literal NUL byte once hid inside a `util/json.rs` string literal:
//! the file compiled fine, but every byte-oriented text tool (ripgrep,
//! diff-driven review, some editors) silently treated it as binary and
//! stopped searching it. This suite pins the repair: every source file
//! in the crate — and every shipped `.qsl` example — must be valid
//! UTF-8 containing no control bytes other than `\n`, `\r`, and `\t`.

use std::fs;
use std::path::{Path, PathBuf};

/// Recursively collect files under `dir` whose extension is in `exts`.
fn collect(dir: &Path, exts: &[&str], out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries {
        let path = entry.unwrap().path();
        if path.is_dir() {
            // Build output can nest anywhere a workspace override puts
            // it; never descend into it.
            if path.file_name().and_then(|n| n.to_str()) != Some("target") {
                collect(&path, exts, out);
            }
        } else if path
            .extension()
            .and_then(|e| e.to_str())
            .is_some_and(|e| exts.contains(&e))
        {
            out.push(path);
        }
    }
}

#[test]
fn sources_are_clean_utf8_text() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut files = Vec::new();
    collect(&root.join("src"), &["rs"], &mut files);
    collect(&root.join("tests"), &["rs"], &mut files);
    collect(&root.join("../examples"), &["qsl"], &mut files);
    files.push(root.join("Cargo.toml"));
    files.push(root.join("clippy.toml"));
    assert!(files.len() > 30, "hygiene walk found only {} files", files.len());

    for path in files {
        let bytes = fs::read(&path).unwrap();
        let text = match std::str::from_utf8(&bytes) {
            Ok(text) => text,
            Err(err) => panic!("{}: not valid UTF-8: {err}", path.display()),
        };
        for (line_idx, line) in text.lines().enumerate() {
            if let Some(bad) = line.chars().find(|&c| c.is_control() && c != '\t') {
                panic!(
                    "{}:{}: control byte U+{:04X} in source text — binary-detecting \
                     tools (ripgrep, diff) silently skip such files",
                    path.display(),
                    line_idx + 1,
                    bad as u32
                );
            }
        }
    }
}
