//! `qadam serve` integration suite: cross-tenant shared-cache dedupe,
//! batch/solo byte-identity, queue-order invariance, matrix expansion
//! through the scheduler, duplicate-fingerprint and lint-denial skips,
//! and the cache save-generation counter under parallel savers.
//!
//! Every campaign here is tiny (a 2-point sweep over a one-layer custom
//! model) so the whole batch machinery — expansion, lint gate, worker
//! pool, per-fingerprint artifact directories — runs in milliseconds.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use qadam::arch::AcceleratorConfig;
use qadam::dse::Evaluation;
use qadam::explore::PointCache;
use qadam::serve::{serve, BatchQueue, BatchStatus, CampaignState, ServeConfig};
use qadam::util::json::Json;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_serve_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn write(dir: &Path, name: &str, text: &str) -> PathBuf {
    let path = dir.join(name);
    fs::write(&path, text).unwrap();
    path
}

/// The shared base: seed 7, a 2-point GLB sweep, one tiny custom model.
const BASE: &str = "campaign { seed = 7 }\n\
    sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [64, 128]\n  \
    spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
    workload {\n  dataset = cifar10\n  models = [tiny]\n}\n\
    model tiny {\n  fc head { in = 64, out = 10 }\n}\n";

/// Tenant A: the base sweep verbatim (glb 64, 128).
const TENANT_A: &str = "include \"base.qsl\"\n";

/// Tenant B: overlaps tenant A at glb = 128, adds 192.
const TENANT_B: &str = "include \"base.qsl\"\noverride sweep { glb_kib = [128, 192] }\n";

/// Write the base + both tenants into `dir`, returning the tenant paths.
fn tenant_specs(dir: &Path) -> (PathBuf, PathBuf) {
    write(dir, "base.qsl", BASE);
    (write(dir, "tenant_a.qsl", TENANT_A), write(dir, "tenant_b.qsl", TENANT_B))
}

fn config_for(out: &Path) -> ServeConfig {
    // max_concurrent 1: the deterministic schedule the exact-counter
    // assertions rely on (see the scheduler docs).
    ServeConfig::new(out)
}

/// Read one campaign's three artifacts as bytes.
fn artifact_bytes(dir: &Path) -> [(String, Vec<u8>); 3] {
    ["db.json", "frontier.json", "run.journal"].map(|name| {
        let path = dir.join(name);
        (name.to_string(), fs::read(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display())))
    })
}

// ------------------------------------------------------- shared-cache dedupe

/// The acceptance property: two tenants including the same base run
/// through one batch, and every design point the second tenant shares
/// with the first is a cache hit — counted exactly.
#[test]
fn overlapping_tenants_dedupe_through_the_shared_cache() {
    let dir = temp_dir("dedupe");
    let (a, b) = tenant_specs(&dir);
    let out = dir.join("out");
    let queue = BatchQueue::build(&[a, b]).unwrap();
    assert_eq!(queue.len(), 2);
    let outcome = serve(&queue, &config_for(&out)).unwrap();
    assert_eq!(outcome.failures(), 0);
    assert!(!outcome.cache_recovered);

    // Tenant A runs cold: 2 misses. Tenant B shares glb=128 with A
    // (same seed, same model set → same point key): 1 hit, 1 miss.
    let [a_report, b_report] = &outcome.reports[..] else {
        panic!("expected 2 reports, got {}", outcome.reports.len())
    };
    assert_eq!(a_report.state, CampaignState::Done);
    assert_eq!(b_report.state, CampaignState::Done);
    assert_eq!((a_report.hits, a_report.misses), (0, 2), "{}", a_report.detail);
    assert_eq!((b_report.hits, b_report.misses), (1, 1), "{}", b_report.detail);
    // 3 distinct design points across the batch.
    assert_eq!(outcome.cache_entries, 3);

    // Every campaign owns a full artifact directory.
    for report in &outcome.reports {
        let campaign_dir = report.dir.as_ref().unwrap();
        for (_, bytes) in artifact_bytes(campaign_dir) {
            assert!(!bytes.is_empty());
        }
    }

    // The saved cache reloads with both tenants' entries and one save
    // generation per completed campaign.
    let mut cache = PointCache::load(&outcome.cache_path).unwrap();
    assert_eq!(cache.len(), 3);
    assert_eq!(cache.generation(), 2, "one save per completed campaign");
    // Re-saving keeps counting.
    cache.save(&outcome.cache_path).unwrap();
    assert_eq!(PointCache::load(&outcome.cache_path).unwrap().generation(), 3);

    // The status journal streamed the full lifecycle with dense seqs.
    let status = BatchStatus::load(&outcome.status_path).unwrap();
    assert!(status.campaigns().iter().all(|c| c.state == CampaignState::Done));
    let seqs: Vec<u64> = status.transitions().iter().map(|t| t.seq).collect();
    assert_eq!(seqs, (0..seqs.len() as u64).collect::<Vec<u64>>());
    // queued → linted → running → done, in order, for each campaign.
    for index in 0..2 {
        let states: Vec<CampaignState> = status
            .transitions()
            .iter()
            .filter(|t| t.index == index)
            .map(|t| t.state)
            .collect();
        assert_eq!(
            states,
            [
                CampaignState::Queued,
                CampaignState::Linted,
                CampaignState::Running,
                CampaignState::Done
            ]
        );
    }
    let _ = fs::remove_dir_all(&dir);
}

/// Batch artifacts are byte-identical to solo runs: cache warmth (B ran
/// warm in the batch, cold solo) must not change a single artifact byte.
#[test]
fn batch_campaigns_match_solo_runs_bit_for_bit() {
    let dir = temp_dir("solo_vs_batch");
    let (a, b) = tenant_specs(&dir);
    let batch = serve(
        &BatchQueue::build(&[a.clone(), b.clone()]).unwrap(),
        &config_for(&dir.join("batch")),
    )
    .unwrap();
    let solo_a =
        serve(&BatchQueue::build(&[a]).unwrap(), &config_for(&dir.join("solo_a"))).unwrap();
    let solo_b =
        serve(&BatchQueue::build(&[b]).unwrap(), &config_for(&dir.join("solo_b"))).unwrap();
    for (solo, index) in [(&solo_a, 0), (&solo_b, 1)] {
        let solo_dir = solo.reports[0].dir.as_ref().unwrap();
        let batch_dir = batch.reports[index].dir.as_ref().unwrap();
        for ((name, solo_bytes), (_, batch_bytes)) in
            artifact_bytes(solo_dir).iter().zip(artifact_bytes(batch_dir).iter())
        {
            assert_eq!(solo_bytes, batch_bytes, "campaign {index}: {name} differs solo vs batch");
        }
    }
    // Solo B ran cold: its one batch-time hit became a miss — artifacts
    // above prove that changed nothing.
    assert_eq!((solo_b.reports[0].hits, solo_b.reports[0].misses), (0, 2));
    let _ = fs::remove_dir_all(&dir);
}

/// Shuffling the queue changes scheduling and cache warmth, but no
/// artifact bytes.
#[test]
fn queue_order_changes_no_artifact_bytes() {
    let dir = temp_dir("order");
    let (a, b) = tenant_specs(&dir);
    let forward = serve(
        &BatchQueue::build(&[a.clone(), b.clone()]).unwrap(),
        &config_for(&dir.join("fwd")),
    )
    .unwrap();
    let reverse =
        serve(&BatchQueue::build(&[b, a]).unwrap(), &config_for(&dir.join("rev"))).unwrap();
    // Match campaigns by fingerprint (their queue indices swapped).
    for fwd_report in &forward.reports {
        let rev_report = reverse
            .reports
            .iter()
            .find(|r| r.fingerprint == fwd_report.fingerprint)
            .expect("same campaign set under both orders");
        let fwd_dir = fwd_report.dir.as_ref().unwrap();
        let rev_dir = rev_report.dir.as_ref().unwrap();
        for ((name, fwd_bytes), (_, rev_bytes)) in
            artifact_bytes(fwd_dir).iter().zip(artifact_bytes(rev_dir).iter())
        {
            assert_eq!(fwd_bytes, rev_bytes, "{name} depends on queue order");
        }
    }
    // The dedupe flipped direction: now B is cold and A gets the hit.
    assert_eq!((reverse.reports[0].hits, reverse.reports[0].misses), (0, 2));
    assert_eq!((reverse.reports[1].hits, reverse.reports[1].misses), (1, 1));
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------------------ expansion through serve

/// A matrix spec expands into several campaigns inside one queue entry
/// file, each with its own fingerprint directory.
#[test]
fn matrix_specs_expand_into_separate_campaigns() {
    let dir = temp_dir("matrix");
    let spec = write(&dir, "grid.qsl", &format!("{BASE}matrix {{ seed = [1, 2] }}\n"));
    let queue = BatchQueue::build(&[spec]).unwrap();
    assert_eq!(queue.len(), 2);
    assert_eq!(queue.entries[0].label, "seed=1");
    assert_eq!(queue.entries[1].label, "seed=2");
    let outcome = serve(&queue, &config_for(&dir.join("out"))).unwrap();
    assert_eq!(outcome.failures(), 0);
    let dirs: Vec<&PathBuf> =
        outcome.reports.iter().map(|r| r.dir.as_ref().unwrap()).collect();
    assert_ne!(dirs[0], dirs[1], "each matrix combination owns a directory");
    // Different seeds address different cache keys: no cross-seed hits.
    assert_eq!(outcome.cache_entries, 4);
    assert!(outcome.reports.iter().all(|r| r.hits == 0));
    let _ = fs::remove_dir_all(&dir);
}

/// Concurrent batches produce the same campaign artifacts as sequential
/// ones — the worker pool changes wall-clock, not bytes.
#[test]
fn concurrent_batches_match_sequential_artifacts() {
    let dir = temp_dir("concurrent");
    let spec = write(&dir, "grid.qsl", &format!("{BASE}matrix {{ seed = [1, 2, 3] }}\n"));
    let queue = BatchQueue::build(&[spec]).unwrap();
    let sequential = serve(&queue, &config_for(&dir.join("seq"))).unwrap();
    let mut config = config_for(&dir.join("par"));
    config.max_concurrent = 3;
    let parallel = serve(&queue, &config).unwrap();
    assert_eq!(parallel.failures(), 0);
    for (seq_report, par_report) in sequential.reports.iter().zip(&parallel.reports) {
        assert_eq!(seq_report.fingerprint, par_report.fingerprint);
        let seq_dir = seq_report.dir.as_ref().unwrap();
        let par_dir = par_report.dir.as_ref().unwrap();
        for ((name, seq_bytes), (_, par_bytes)) in
            artifact_bytes(seq_dir).iter().zip(artifact_bytes(par_dir).iter())
        {
            assert_eq!(seq_bytes, par_bytes, "{name} depends on concurrency");
        }
    }
    let _ = fs::remove_dir_all(&dir);
}

// --------------------------------------------------------- pre-flight gates

/// The same campaign queued twice runs once; the duplicate is skipped,
/// not re-run and not failed.
#[test]
fn duplicate_fingerprints_skip_the_later_campaign() {
    let dir = temp_dir("dup");
    let (a, _) = tenant_specs(&dir);
    let again = write(&dir, "tenant_a_again.qsl", TENANT_A);
    let outcome = serve(
        &BatchQueue::build(&[a, again]).unwrap(),
        &config_for(&dir.join("out")),
    )
    .unwrap();
    assert_eq!(outcome.failures(), 0);
    assert_eq!(outcome.reports[0].state, CampaignState::Done);
    assert_eq!(outcome.reports[1].state, CampaignState::Skipped);
    assert!(
        outcome.reports[1].detail.contains("duplicate"),
        "{}",
        outcome.reports[1].detail
    );
    let _ = fs::remove_dir_all(&dir);
}

/// A deny-level lint finding (Q012: a shard past the space size selects
/// nothing) skips that campaign only — the rest of the batch runs.
#[test]
fn lint_denials_skip_only_the_offending_campaign() {
    let dir = temp_dir("lint_gate");
    let (a, _) = tenant_specs(&dir);
    let empty = write(
        &dir,
        "empty_shard.qsl",
        "include \"base.qsl\"\noverride campaign { shard = 3 / 8 }\n",
    );
    let outcome = serve(
        &BatchQueue::build(&[empty, a]).unwrap(),
        &config_for(&dir.join("out")),
    )
    .unwrap();
    assert_eq!(outcome.failures(), 0, "a lint skip is not a failure");
    assert_eq!(outcome.reports[0].state, CampaignState::Skipped);
    assert!(
        outcome.reports[0].detail.contains("Q012"),
        "{}",
        outcome.reports[0].detail
    );
    assert!(outcome.reports[0].dir.is_none(), "skipped campaigns write no artifacts");
    assert_eq!(outcome.reports[1].state, CampaignState::Done);
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------------- cache save-generation counter

fn sample_eval(rows: usize) -> Evaluation {
    Evaluation {
        config: AcceleratorConfig { rows, ..Default::default() },
        area_mm2: 1.0,
        clock_ghz: 1.0,
        latency_ms: 1.0,
        inf_per_s: 1.0,
        perf_per_area: 1.0,
        energy_uj: 1.0,
        dram_energy_uj: 1.0,
        utilization: 0.5,
    }
}

/// Two tenants saving the shared cache in parallel must never persist a
/// file missing either tenant's entries: saves are serialized under the
/// cache mutex, the file always carries the merged entry set, and the
/// save-generation counter counts every save that reached disk.
#[test]
fn parallel_savers_never_lose_a_tenants_entries() {
    let dir = temp_dir("parallel_save");
    let path = dir.join("cache.json");
    let shared = Arc::new(Mutex::new(PointCache::new()));
    let tenants = 4;
    std::thread::scope(|scope| {
        for tenant in 0..tenants {
            let shared = shared.clone();
            let path = path.clone();
            scope.spawn(move || {
                // Store-then-save atomically under the mutex — exactly
                // what the scheduler's run_campaign does.
                let mut cache = shared.lock().unwrap();
                cache.store(tenant as u64, vec![sample_eval(8 + tenant)]);
                cache.save(&path).unwrap();
            });
        }
    });
    let on_disk = PointCache::load(&path).unwrap();
    // The last save to land happened-after every store: all entries
    // present, one generation per save.
    assert_eq!(on_disk.len(), tenants);
    assert_eq!(on_disk.generation(), tenants as u64);
    for tenant in 0..tenants {
        assert!(on_disk.get(tenant as u64).is_some(), "tenant {tenant} entry lost");
    }

    // A pre-generation cache file (schema without the counter) loads as
    // generation 0 — old artifacts stay readable.
    let legacy = dir.join("legacy.json");
    let mut json = Json::parse(&fs::read_to_string(&path).unwrap()).unwrap();
    if let Json::Obj(fields) = &mut json {
        assert!(fields.remove("generation").is_some());
    }
    fs::write(&legacy, json.to_string_pretty()).unwrap();
    assert_eq!(PointCache::load(&legacy).unwrap().generation(), 0);
    let _ = fs::remove_dir_all(&dir);
}
