//! Persistence-layer tests: property tests for the JSON round-trip and
//! the content-addressed cache key, plus corrupt-input behavior — every
//! truncated/garbled artifact must surface a typed error, never a panic,
//! and a checkpoint journal from a different campaign must be rejected.

use std::fs;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use qadam::arch::{AcceleratorConfig, ScratchpadCfg, SweepSpec};
use qadam::dnn::{models_for, Dataset};
use qadam::dse::Evaluation;
use qadam::explore::{point_key, CampaignStats, EvalDatabase, Explorer, ModelSpace, PointCache};
use qadam::quant::PeType;
use qadam::util::json::Json;
use qadam::util::prop::{check_with, Config, Gen};
use qadam::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Generators (structurally valid, numerically arbitrary).

fn random_config(rng: &mut Pcg64) -> AcceleratorConfig {
    AcceleratorConfig {
        pe: *rng.choose(&PeType::ALL),
        rows: 1 + rng.below(64) as usize,
        cols: 1 + rng.below(64) as usize,
        spad: ScratchpadCfg {
            ifmap_entries: 1 + rng.below(64) as usize,
            filter_entries: 1 + rng.below(512) as usize,
            psum_entries: 1 + rng.below(64) as usize,
        },
        glb_kib: 1 + rng.below(1024) as usize,
        dram_bw_gbps: rng.uniform(0.5, 64.0),
        clock_ghz: rng.uniform(0.1, 5.0),
    }
}

fn random_eval(rng: &mut Pcg64) -> Evaluation {
    Evaluation {
        config: random_config(rng),
        area_mm2: rng.uniform(1e-3, 500.0),
        clock_ghz: rng.uniform(0.1, 5.0),
        latency_ms: rng.uniform(1e-4, 1e4),
        inf_per_s: rng.uniform(1e-2, 1e6),
        perf_per_area: rng.uniform(1e-6, 1e5),
        energy_uj: rng.uniform(1e-3, 1e7),
        dram_energy_uj: rng.uniform(1e-3, 1e7),
        utilization: rng.uniform(0.0, 1.0),
    }
}

fn random_db(rng: &mut Pcg64) -> EvalDatabase {
    let dataset = *rng.choose(&Dataset::ALL);
    let spaces: Vec<ModelSpace> = (0..1 + rng.below(3) as usize)
        .map(|i| ModelSpace {
            model_name: format!("model-{i}"),
            dataset,
            evals: (0..rng.below(4)).map(|_| random_eval(rng)).collect(),
        })
        .collect();
    let design_points = spaces.iter().map(|s| s.evals.len()).max().unwrap_or(0);
    let evaluations = spaces.iter().map(|s| s.evals.len()).sum();
    let num_shards = 1 + rng.below(4) as usize;
    let strategy = if rng.chance(0.5) {
        "exhaustive".to_string()
    } else {
        format!("random:{}:7", 1 + rng.below(64))
    };
    EvalDatabase {
        dataset,
        shard: (rng.below(num_shards as u64) as usize, num_shards),
        strategy,
        spaces,
        // The persisted normal form: transient throughput fields zeroed.
        stats: CampaignStats { design_points, evaluations, wall_seconds: 0.0, workers: 0 },
    }
}

// ---------------------------------------------------------------------------
// Property tests.

#[test]
fn prop_evaluation_json_round_trips_bit_for_bit() {
    let gen = Gen::new(random_eval, |_| Vec::new());
    check_with(&Config { cases: 96, ..Default::default() }, &gen, |eval| {
        let text = eval.to_json().to_string_compact();
        match Json::parse(&text).ok().and_then(|json| Evaluation::from_json(&json).ok()) {
            Some(parsed) => parsed == *eval,
            None => false,
        }
    });
}

#[test]
fn prop_database_json_round_trips_and_reserializes_identically() {
    let gen = Gen::new(random_db, |_| Vec::new());
    check_with(&Config { cases: 32, ..Default::default() }, &gen, |db| {
        let text = db.to_json().to_string_pretty();
        match Json::parse(&text).ok().and_then(|json| EvalDatabase::from_json(&json).ok()) {
            Some(parsed) => parsed == *db && parsed.to_json().to_string_pretty() == text,
            None => false,
        }
    });
}

#[test]
fn prop_cache_key_stable_and_sensitive_to_every_field() {
    let models = models_for(Dataset::Cifar10);
    let gen = Gen::new(random_config, |_| Vec::new());
    check_with(&Config { cases: 96, ..Default::default() }, &gen, |config| {
        let key = point_key(config, 7, &models);
        // Stability: structural equality implies key equality.
        if key != point_key(&config.clone(), 7, &models) {
            return false;
        }
        // Sensitivity: any config field change must change the key.
        let mutations: Vec<AcceleratorConfig> = vec![
            {
                let mut c = config.clone();
                c.pe = if c.pe == PeType::Fp32 { PeType::Int16 } else { PeType::Fp32 };
                c
            },
            {
                let mut c = config.clone();
                c.rows += 1;
                c
            },
            {
                let mut c = config.clone();
                c.cols += 1;
                c
            },
            {
                let mut c = config.clone();
                c.spad.ifmap_entries += 1;
                c
            },
            {
                let mut c = config.clone();
                c.spad.filter_entries += 1;
                c
            },
            {
                let mut c = config.clone();
                c.spad.psum_entries += 1;
                c
            },
            {
                let mut c = config.clone();
                c.glb_kib += 1;
                c
            },
            {
                let mut c = config.clone();
                c.dram_bw_gbps += 0.5;
                c
            },
            {
                let mut c = config.clone();
                c.clock_ghz *= 0.5;
                c
            },
        ];
        if mutations.iter().any(|mutated| point_key(mutated, 7, &models) == key) {
            return false;
        }
        // The seed and the model set are part of the address too.
        point_key(config, 8, &models) != key && point_key(config, 7, &models[..1]) != key
    });
}

// ---------------------------------------------------------------------------
// Corrupt-input behavior (typed errors, never panics).

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_persist_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_db() -> EvalDatabase {
    Explorer::over(SweepSpec::tiny())
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(7)
        .run()
        .unwrap()
}

#[test]
fn corrupt_database_files_yield_typed_errors() {
    let dir = temp_dir("db");
    // Missing file → Io.
    assert_eq!(EvalDatabase::load(&dir.join("missing.json")).unwrap_err().kind(), "io");
    // Garbage → ParseError.
    let garbage = dir.join("garbage.json");
    fs::write(&garbage, "{not json!").unwrap();
    assert_eq!(EvalDatabase::load(&garbage).unwrap_err().kind(), "parse_error");
    // Truncated (torn save) → ParseError.
    let db = small_db();
    let full = dir.join("db.json");
    db.save(&full).unwrap();
    let text = fs::read_to_string(&full).unwrap();
    let truncated = dir.join("truncated.json");
    fs::write(&truncated, &text[..text.len() / 2]).unwrap();
    assert_eq!(EvalDatabase::load(&truncated).unwrap_err().kind(), "parse_error");
    // Wrong document kind → ParseError.
    let cache_file = dir.join("cache.json");
    PointCache::new().save(&cache_file).unwrap();
    assert_eq!(EvalDatabase::load(&cache_file).unwrap_err().kind(), "parse_error");
    // Future schema version → ParseError. (Databases without joint
    // content emit the base version; anything past SCHEMA_VERSION must
    // be rejected.)
    let future = dir.join("future.json");
    let schema_field = format!("\"schema\": {}", qadam::explore::BASE_SCHEMA_VERSION);
    let replaced = text.replacen(&schema_field, "\"schema\": 99", 1);
    assert_ne!(replaced, text, "schema envelope must be present to corrupt");
    fs::write(&future, replaced).unwrap();
    assert_eq!(EvalDatabase::load(&future).unwrap_err().kind(), "parse_error");
    // A pre-joint (v3) document parses under this build.
    assert!(text.contains(&schema_field), "hardware-only db must emit the base schema");
    assert!(EvalDatabase::load(&full).is_ok());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_cache_files_yield_typed_errors() {
    let dir = temp_dir("cache");
    assert_eq!(PointCache::load(&dir.join("missing.json")).unwrap_err().kind(), "io");
    let bad = dir.join("bad.json");
    fs::write(&bad, "[1, 2").unwrap();
    assert_eq!(PointCache::load(&bad).unwrap_err().kind(), "parse_error");
    let bad_key = dir.join("bad_key.json");
    fs::write(
        &bad_key,
        r#"{"kind":"qadam.pointcache","schema":3,"entries":[{"key":"zzzz","evals":[]}]}"#,
    )
    .unwrap();
    assert_eq!(PointCache::load(&bad_key).unwrap_err().kind(), "parse_error");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_or_mismatched_journals_yield_typed_errors() {
    let dir = temp_dir("journal");
    let journal = dir.join("campaign.journal");
    let explorer =
        || Explorer::over(SweepSpec::tiny()).dataset(Dataset::Cifar10).workers(2).seed(7);
    // Produce a complete, healthy journal.
    explorer().checkpoint(&journal, 1).run().unwrap();
    let text = fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.split_inclusive('\n').collect();
    assert!(lines.len() >= 3, "tiny campaign must journal several points");

    // Garbled middle entry (newline-terminated) → ParseError.
    let mut garbled = String::new();
    for (i, line) in lines.iter().enumerate() {
        if i == 2 {
            garbled.push_str("{garbled}\n");
        } else {
            garbled.push_str(line);
        }
    }
    fs::write(&journal, &garbled).unwrap();
    assert_eq!(explorer().checkpoint(&journal, 1).run().unwrap_err().kind(), "parse_error");

    // A garbled-but-complete header line → ParseError.
    fs::write(&journal, "{garbled header}\n").unwrap();
    assert_eq!(explorer().checkpoint(&journal, 1).run().unwrap_err().kind(), "parse_error");

    // A torn header (killed between create and flush, no newline) is the
    // crash case: the suspect file is renamed aside (never deleted), the
    // journal restarts fresh, and the campaign succeeds.
    fs::write(&journal, &lines[0][..lines[0].len() / 2]).unwrap();
    let restarted = explorer().checkpoint(&journal, 1).run().unwrap();
    assert_eq!(
        restarted.to_json().to_string_pretty(),
        explorer().run().unwrap().to_json().to_string_pretty()
    );
    assert!(dir.join("campaign.journal.torn").exists(), "torn file must be preserved aside");
    // ... and an empty file behaves the same way.
    fs::write(&journal, "").unwrap();
    explorer().checkpoint(&journal, 1).run().unwrap();

    // Same journal, different seed → InvalidConfig (campaign mismatch).
    fs::write(&journal, &text).unwrap();
    let err = Explorer::over(SweepSpec::tiny())
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(8)
        .checkpoint(&journal, 1)
        .run()
        .unwrap_err();
    assert_eq!(err.kind(), "invalid_config");

    // Same journal, different sweep → InvalidConfig (fingerprint mismatch).
    let mut wider = SweepSpec::tiny();
    wider.glb_kib.push(256);
    let err = Explorer::over(wider)
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(7)
        .checkpoint(&journal, 1)
        .run()
        .unwrap_err();
    assert_eq!(err.kind(), "invalid_config");
    assert!(err.to_string().contains("fingerprint"), "{err}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn sharded_database_round_trips_shard_and_refuses_normalization() {
    let db = Explorer::over(SweepSpec::tiny())
        .dataset(Dataset::Cifar10)
        .workers(2)
        .seed(7)
        .shard(1, 3)
        .run()
        .unwrap();
    assert_eq!(db.shard, (1, 3));
    let parsed =
        EvalDatabase::from_json(&Json::parse(&db.to_json().to_string_pretty()).unwrap()).unwrap();
    assert_eq!(parsed.shard, (1, 3));
    // A shard's local best INT16 is not the campaign baseline: normalized
    // summaries must refuse rather than silently produce wrong ratios.
    assert_eq!(parsed.headline_geomean().unwrap_err().kind(), "invalid_config");
}

#[test]
fn cache_reloaded_from_disk_serves_identical_results() {
    let dir = temp_dir("cache_reuse");
    let cache_file = dir.join("cache.json");
    let run = |cache: Arc<Mutex<PointCache>>| {
        Explorer::over(SweepSpec::tiny())
            .dataset(Dataset::Cifar10)
            .workers(2)
            .seed(7)
            .cache(cache)
            .run()
            .unwrap()
    };
    let cache = Arc::new(Mutex::new(PointCache::new()));
    let cold = run(cache.clone());
    cache.lock().unwrap().save(&cache_file).unwrap();
    let reloaded = Arc::new(Mutex::new(PointCache::load(&cache_file).unwrap()));
    // Hit/miss counters are lifetime totals persisted with the cache, so
    // the reloaded lineage arrives carrying the cold pass's misses:
    // snapshot the baseline and assert on this run's deltas.
    let (h0, m0) = {
        let guard = reloaded.lock().unwrap();
        (guard.hits(), guard.misses())
    };
    let warm = run(reloaded.clone());
    // The disk round-trip preserves every bit of every evaluation.
    assert_eq!(warm.to_json().to_string_pretty(), cold.to_json().to_string_pretty());
    let guard = reloaded.lock().unwrap();
    assert_eq!(guard.misses() - m0, 0, "every lookup must hit the reloaded cache");
    assert_eq!((guard.hits() - h0) as usize, cold.stats.design_points);
    drop(guard);
    let _ = fs::remove_dir_all(&dir);
}
