//! Fault-injection suite: every artifact `qadam serve` writes is torn
//! at **every byte offset** and the batch re-run must uphold its
//! recovery contract (see `serve::sched`'s module docs):
//!
//! | torn artifact        | recovery                                     |
//! |----------------------|----------------------------------------------|
//! | `run.journal` tail   | truncate to last complete line, resume       |
//! | `run.journal` header | journal set aside (`.torn`), fresh start     |
//! | `cache.json`         | cold cache — correct, just no dedupe         |
//! | `db.json`/`frontier` | rewritten whole on completion (atomic saves) |
//! | `serve.status.json`  | ignored — state lives in campaign journals   |
//!
//! plus a kill-at-every-checkpoint-boundary sweep over a 3-campaign
//! batch (two campaigns sharing an included base) asserting that a
//! killed-and-resumed batch produces byte-identical campaign artifacts
//! to an uninterrupted one.

use std::fs;
use std::path::{Path, PathBuf};

use qadam::obs::{sidecar_path, TimingSidecar, Trace};
use qadam::serve::{campaign_dir, serve, BatchOutcome, BatchQueue, BatchStatus, ServeConfig};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadam_faults_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn write(dir: &Path, name: &str, text: &str) -> PathBuf {
    let path = dir.join(name);
    fs::write(&path, text).unwrap();
    path
}

/// Truncate-at-offset writer: the whole fault model. A torn write (or a
/// kill mid-write) leaves a prefix of the intended bytes; sweeping every
/// prefix length covers every possible tear point of an artifact.
fn tear(source: &[u8], offset: usize, dest: &Path) {
    fs::write(dest, &source[..offset]).unwrap();
}

/// The shared base: seed 7, a 2-point GLB sweep, one tiny custom model
/// (kept minimal so the every-byte-offset sweeps stay fast).
const BASE: &str = "campaign { seed = 7 }\n\
    sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [64, 128]\n  \
    spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n\
    workload {\n  dataset = cifar10\n  models = [tiny]\n}\n\
    model tiny {\n  fc head { in = 64, out = 10 }\n}\n";

/// Per-campaign artifact file names, the byte-identity contract's scope
/// (`cache.json` is excluded: its save generation counts saves).
const ARTIFACTS: [&str; 3] = ["db.json", "frontier.json", "run.journal"];

fn assert_campaign_bytes_match(reference: &Path, rerun: &Path, context: &str) {
    for name in ARTIFACTS {
        let want = fs::read(reference.join(name)).unwrap();
        let got = fs::read(rerun.join(name))
            .unwrap_or_else(|e| panic!("{context}: {name} missing after recovery: {e}"));
        assert_eq!(got, want, "{context}: {name} differs from the uninterrupted run");
    }
}

/// Run a single-tenant batch to completion and return its outcome.
fn reference_run(specs: &[PathBuf], out: &Path) -> BatchOutcome {
    let queue = BatchQueue::build(specs).unwrap();
    let outcome = serve(&queue, &ServeConfig::new(out)).unwrap();
    assert_eq!(outcome.failures(), 0);
    outcome
}

// ------------------------------------------------------- journal tearing

/// Tear the checkpoint journal at every byte offset. A torn header
/// (offset inside the first line) is set aside as `.torn` and the
/// campaign restarts fresh; a torn tail resumes from the last complete
/// entry. Either way the re-run's artifacts are byte-identical to the
/// uninterrupted run.
#[test]
fn journal_torn_at_every_byte_offset_recovers_byte_identically() {
    let dir = temp_dir("journal");
    let spec = write(&dir, "solo.qsl", BASE);
    let reference = reference_run(&[spec.clone()], &dir.join("ref"));
    let ref_dir = reference.reports[0].dir.clone().unwrap();
    let fingerprint = reference.reports[0].fingerprint;
    let journal = fs::read(ref_dir.join("run.journal")).unwrap();
    let header_len = journal.iter().position(|&b| b == b'\n').unwrap() + 1;
    assert!(journal.len() > header_len, "journal must carry entries past the header");

    let queue = BatchQueue::build(&[spec]).unwrap();
    for offset in 0..journal.len() {
        let out = dir.join("rerun");
        let _ = fs::remove_dir_all(&out);
        let campaign = campaign_dir(&out, fingerprint);
        fs::create_dir_all(&campaign).unwrap();
        tear(&journal, offset, &campaign.join("run.journal"));
        let outcome = serve(&queue, &ServeConfig::new(&out)).unwrap();
        assert_eq!(outcome.failures(), 0, "offset {offset}");
        assert_campaign_bytes_match(&ref_dir, &campaign, &format!("journal offset {offset}"));
        // A tear inside the header line is the kill-between-create-and-
        // flush crash: the suspect bytes must survive aside, never be
        // deleted.
        let torn_aside = campaign.join("run.journal.torn").exists();
        assert_eq!(torn_aside, offset < header_len, "offset {offset} (header {header_len}B)");
    }
    let _ = fs::remove_dir_all(&dir);
}

// --------------------------------------------------------- cache tearing

/// Tear the shared cache at every byte offset: an unreadable cache
/// degrades to a cold start (flagged via `cache_recovered`), and cache
/// warmth — torn, cold, or whole — never changes campaign artifacts.
#[test]
fn cache_torn_at_every_byte_offset_is_cold_but_correct() {
    let dir = temp_dir("cache");
    write(&dir, "base.qsl", BASE);
    let tenant_a = write(&dir, "a.qsl", "include \"base.qsl\"\n");
    let tenant_b =
        write(&dir, "b.qsl", "include \"base.qsl\"\noverride sweep { glb_kib = [128, 192] }\n");
    let specs = [tenant_a, tenant_b];
    let reference = reference_run(&specs, &dir.join("ref"));
    let ref_dirs: Vec<PathBuf> =
        reference.reports.iter().map(|r| r.dir.clone().unwrap()).collect();
    let cache = fs::read(&reference.cache_path).unwrap();

    let queue = BatchQueue::build(&specs).unwrap();
    let mut recovered = 0usize;
    for offset in 0..cache.len() {
        let out = dir.join("rerun");
        let _ = fs::remove_dir_all(&out);
        fs::create_dir_all(&out).unwrap();
        tear(&cache, offset, &out.join("cache.json"));
        let outcome = serve(&queue, &ServeConfig::new(&out)).unwrap();
        assert_eq!(outcome.failures(), 0, "offset {offset}");
        recovered += outcome.cache_recovered as usize;
        for (report, ref_dir) in outcome.reports.iter().zip(&ref_dirs) {
            assert_campaign_bytes_match(
                ref_dir,
                report.dir.as_ref().unwrap(),
                &format!("cache offset {offset}"),
            );
        }
        // The re-saved cache is whole again and carries the batch's
        // full entry set.
        assert_eq!(outcome.cache_entries, reference.cache_entries, "offset {offset}");
    }
    // Truncation almost always breaks the JSON document; every such
    // offset must have taken the cold-start path rather than erroring.
    assert!(recovered > cache.len() / 2, "{recovered} of {} offsets recovered", cache.len());
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------------- db / frontier / status tearing

/// Tear `db.json` and `frontier.json` at every byte offset: both are
/// whole-file atomic rewrites derived from the journal, so a re-run
/// replays the (complete) journal and restores their exact bytes.
#[test]
fn db_and_frontier_torn_at_every_byte_offset_are_rewritten() {
    let dir = temp_dir("db");
    let spec = write(&dir, "solo.qsl", BASE);
    let reference = reference_run(&[spec.clone()], &dir.join("ref"));
    let ref_dir = reference.reports[0].dir.clone().unwrap();
    let fingerprint = reference.reports[0].fingerprint;
    let journal = fs::read(ref_dir.join("run.journal")).unwrap();

    let queue = BatchQueue::build(&[spec]).unwrap();
    for artifact in ["db.json", "frontier.json"] {
        let bytes = fs::read(ref_dir.join(artifact)).unwrap();
        for offset in 0..bytes.len() {
            let out = dir.join("rerun");
            let _ = fs::remove_dir_all(&out);
            let campaign = campaign_dir(&out, fingerprint);
            fs::create_dir_all(&campaign).unwrap();
            // The kill window: journal finished, artifact save torn.
            fs::write(campaign.join("run.journal"), &journal).unwrap();
            tear(&bytes, offset, &campaign.join(artifact));
            let outcome = serve(&queue, &ServeConfig::new(&out)).unwrap();
            assert_eq!(outcome.failures(), 0, "{artifact} offset {offset}");
            assert_campaign_bytes_match(
                &ref_dir,
                &campaign,
                &format!("{artifact} offset {offset}"),
            );
        }
    }
    let _ = fs::remove_dir_all(&dir);
}

/// Tear `serve.status.json` at every byte offset: the scheduler never
/// reads it back, so a torn batch journal loses nothing — the re-run
/// reconstructs every campaign from its checkpoint journal and rewrites
/// a whole status document.
#[test]
fn status_torn_at_every_byte_offset_loses_nothing() {
    let dir = temp_dir("status");
    let spec = write(&dir, "solo.qsl", BASE);
    let out = dir.join("out");
    let reference = reference_run(&[spec.clone()], &out);
    let ref_dir = reference.reports[0].dir.clone().unwrap();
    let keep = dir.join("keep");
    fs::create_dir_all(&keep).unwrap();
    for name in ARTIFACTS {
        fs::copy(ref_dir.join(name), keep.join(name)).unwrap();
    }
    let status = fs::read(&reference.status_path).unwrap();

    let queue = BatchQueue::build(&[spec]).unwrap();
    for offset in 0..status.len() {
        tear(&status, offset, &reference.status_path);
        let outcome = serve(&queue, &ServeConfig::new(&out)).unwrap();
        assert_eq!(outcome.failures(), 0, "offset {offset}");
        assert_campaign_bytes_match(&keep, &ref_dir, &format!("status offset {offset}"));
        // The status document is whole again after the re-run.
        let reloaded = BatchStatus::load(&reference.status_path)
            .unwrap_or_else(|e| panic!("offset {offset}: status not rewritten whole: {e}"));
        assert_eq!(reloaded.campaigns().len(), 1);
    }
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------------------- trace / sidecar tearing

/// Tear the deterministic event trace and its wall-clock sidecar at
/// every byte offset. Both are write-only whole-file atomic rewrites,
/// so a re-run (replaying the complete journal over a cold shared
/// cache, exactly the reference's warmth) must restore `trace.json`
/// byte-identically; the sidecar records fresh wall-clock samples, so
/// its contract is weaker — whole and parseable, one sample per event.
#[test]
fn trace_and_sidecar_torn_at_every_byte_offset_recover() {
    let dir = temp_dir("trace");
    let spec = write(
        &dir,
        "solo.qsl",
        &format!("{BASE}persist {{\n  trace = \"trace.json\"\n}}\n"),
    );
    let reference = reference_run(&[spec.clone()], &dir.join("ref"));
    let ref_dir = reference.reports[0].dir.clone().unwrap();
    let fingerprint = reference.reports[0].fingerprint;
    let journal = fs::read(ref_dir.join("run.journal")).unwrap();
    let trace_ref = fs::read(ref_dir.join("trace.json")).unwrap();
    let sidecar_name = "trace.json.timing";
    let sidecar_ref = fs::read(ref_dir.join(sidecar_name)).unwrap();
    assert!(!trace_ref.is_empty() && !sidecar_ref.is_empty());

    let queue = BatchQueue::build(&[spec]).unwrap();
    for (artifact, bytes) in [("trace.json", &trace_ref), (sidecar_name, &sidecar_ref)] {
        for offset in 0..bytes.len() {
            let context = format!("{artifact} offset {offset}");
            let out = dir.join("rerun");
            let _ = fs::remove_dir_all(&out);
            let campaign = campaign_dir(&out, fingerprint);
            fs::create_dir_all(&campaign).unwrap();
            // The kill window: journal finished, trace/sidecar save torn.
            fs::write(campaign.join("run.journal"), &journal).unwrap();
            tear(bytes, offset, &campaign.join(artifact));
            let outcome = serve(&queue, &ServeConfig::new(&out)).unwrap();
            assert_eq!(outcome.failures(), 0, "{context}");
            assert_campaign_bytes_match(&ref_dir, &campaign, &context);
            // The deterministic trace is byte-identical again.
            let rerun_trace = fs::read(campaign.join("trace.json")).unwrap();
            assert_eq!(rerun_trace, trace_ref, "{context}: trace.json differs");
            // The sidecar is whole and paired 1:1 with the trace.
            let trace = Trace::load(&campaign.join("trace.json")).unwrap();
            let timing = TimingSidecar::load(&sidecar_path(&campaign.join("trace.json")))
                .unwrap_or_else(|e| panic!("{context}: sidecar not rewritten whole: {e}"));
            assert_eq!(timing.samples.len(), trace.len(), "{context}");
        }
    }
    let _ = fs::remove_dir_all(&dir);
}

// ----------------------------------------------------------- qdb tearing

/// Tear a columnar `qadam.qdb` database at every byte offset, and flip
/// every single byte: a truncated header/string-table/column/footer —
/// or any corrupt byte the integrity footer covers — must surface as a
/// typed `ParseError`, never a panic or a silent short read.
#[test]
fn qdb_torn_or_flipped_at_every_byte_is_a_typed_parse_error() {
    use qadam::arch::AcceleratorConfig;
    use qadam::dnn::{model_for, Dataset, ModelKind};
    use qadam::explore::{CampaignStats, EvalDatabase, ModelSpace};

    let dir = temp_dir("qdb");
    let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    let evals: Vec<_> = (0..3)
        .map(|i| {
            let config = AcceleratorConfig { rows: 8 + 4 * i, ..Default::default() };
            qadam::dse::evaluate(&config, &model, 7)
        })
        .collect();
    let db = EvalDatabase {
        dataset: Dataset::Cifar10,
        shard: (0, 1),
        strategy: "exhaustive".into(),
        spaces: vec![
            ModelSpace {
                model_name: "ResNet-20".into(),
                dataset: Dataset::Cifar10,
                evals: evals.clone(),
            },
            ModelSpace {
                model_name: "ResNet-20@w0.5d2".into(),
                dataset: Dataset::Cifar10,
                evals,
            },
        ],
        stats: CampaignStats {
            design_points: 6,
            evaluations: 6,
            wall_seconds: 0.0,
            workers: 0,
        },
    };
    let whole = dir.join("db.qdb");
    db.save_qdb(&whole).unwrap();
    let bytes = fs::read(&whole).unwrap();
    let torn = dir.join("torn.qdb");
    for offset in 0..bytes.len() {
        tear(&bytes, offset, &torn);
        let err = EvalDatabase::load_qdb(&torn)
            .expect_err(&format!("offset {offset}: a truncated qdb must not load"));
        assert_eq!(err.kind(), "parse_error", "offset {offset}: {err}");
    }
    for offset in 0..bytes.len() {
        let mut flipped = bytes.clone();
        flipped[offset] ^= 0x40;
        fs::write(&torn, &flipped).unwrap();
        let err = EvalDatabase::load_qdb(&torn)
            .expect_err(&format!("offset {offset}: a corrupt byte must not load"));
        assert_eq!(err.kind(), "parse_error", "offset {offset}: {err}");
    }
    // The sweep tore the right artifact: the untouched file still loads.
    assert_eq!(EvalDatabase::load_qdb(&whole).unwrap(), db);
    let _ = fs::remove_dir_all(&dir);
}

// ------------------------------------- kill-at-checkpoint-boundary batches

/// The acceptance sweep: a 3-campaign batch (two tenants sharing an
/// included base + one standalone spec) killed at every checkpoint
/// boundary of every campaign, then re-run — every campaign's artifacts
/// must be byte-identical to the uninterrupted batch.
///
/// A kill while campaign `i` is mid-flight leaves: full artifacts for
/// campaigns before `i` (they completed), a journal prefix at a flush
/// boundary for `i` (header + k entries; `every = 1` flushes per
/// entry), nothing for campaigns after `i`, and whatever shared-cache
/// save last completed.
#[test]
fn kill_at_every_checkpoint_boundary_resumes_byte_identically() {
    let dir = temp_dir("kill");
    write(&dir, "base.qsl", BASE);
    let specs = [
        write(&dir, "a.qsl", "include \"base.qsl\"\n"),
        write(&dir, "b.qsl", "include \"base.qsl\"\noverride sweep { glb_kib = [128, 192] }\n"),
        write(&dir, "c.qsl", &BASE.replace("seed = 7", "seed = 11")),
    ];
    let reference = reference_run(&specs, &dir.join("ref"));
    let ref_dirs: Vec<PathBuf> =
        reference.reports.iter().map(|r| r.dir.clone().unwrap()).collect();
    // Per-campaign journal split into header + entry lines (every = 1:
    // each entry is flushed, so every line boundary is a kill point).
    let journals: Vec<Vec<Vec<u8>>> = ref_dirs
        .iter()
        .map(|d| {
            let text = fs::read_to_string(d.join("run.journal")).unwrap();
            text.split_inclusive('\n').map(|line| line.as_bytes().to_vec()).collect()
        })
        .collect();

    let queue = BatchQueue::build(&specs).unwrap();
    for victim in 0..specs.len() {
        let entries = journals[victim].len() - 1; // minus the header line
        for kept in 0..=entries {
            let context = format!("kill: campaign {victim} at boundary {kept}");
            let out = dir.join("rerun");
            let _ = fs::remove_dir_all(&out);
            fs::create_dir_all(&out).unwrap();
            // Completed campaigns keep everything; the victim keeps a
            // journal prefix; later campaigns haven't started.
            for done in 0..victim {
                let dest = campaign_dir(&out, reference.reports[done].fingerprint);
                fs::create_dir_all(&dest).unwrap();
                for name in ARTIFACTS {
                    fs::copy(ref_dirs[done].join(name), dest.join(name)).unwrap();
                }
            }
            let victim_dir = campaign_dir(&out, reference.reports[victim].fingerprint);
            fs::create_dir_all(&victim_dir).unwrap();
            let prefix: Vec<u8> =
                journals[victim][..1 + kept].iter().flatten().copied().collect();
            fs::write(victim_dir.join("run.journal"), &prefix).unwrap();
            // The shared cache as of the last completed campaign.
            fs::copy(&reference.cache_path, out.join("cache.json")).unwrap();

            let outcome = serve(&queue, &ServeConfig::new(&out)).unwrap();
            assert_eq!(outcome.failures(), 0, "{context}");
            for (report, ref_dir) in outcome.reports.iter().zip(&ref_dirs) {
                assert_campaign_bytes_match(ref_dir, report.dir.as_ref().unwrap(), &context);
            }
            assert_eq!(outcome.cache_entries, reference.cache_entries, "{context}");
        }
    }
    let _ = fs::remove_dir_all(&dir);
}
