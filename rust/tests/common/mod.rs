//! Helpers shared by the golden-snapshot test crates (`golden.rs`,
//! `spec.rs`). Not a test target itself — Cargo only builds top-level
//! files under `tests/` as integration tests.

use std::fs;
use std::path::PathBuf;

/// The checked-in fixture directory (`rust/tests/golden/`).
pub fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Compare `rendered` against the checked-in fixture, blessing it when
/// missing or when `QADAM_BLESS=1`. With `QADAM_GOLDEN_REQUIRE=1` (the
/// CI gate) a missing fixture is still written — so it can be collected
/// as an artifact and committed — but the test FAILS instead of
/// vacuously passing against its own fresh output.
pub fn assert_snapshot(name: &str, rendered: &str) {
    let dir = golden_dir();
    fs::create_dir_all(&dir).expect("golden fixture dir");
    let path = dir.join(name);
    let bless = std::env::var("QADAM_BLESS").map(|v| v == "1").unwrap_or(false);
    let require = std::env::var("QADAM_GOLDEN_REQUIRE").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        fs::write(&path, rendered).expect("write golden fixture");
        if !bless {
            if require {
                panic!(
                    "golden fixture '{name}' is not committed; a fresh rendering was written \
                     to {} — review and commit it to arm the drift gate",
                    path.display()
                );
            }
            eprintln!(
                "golden: blessed missing fixture '{name}' — commit {} to pin these numerics",
                path.display()
            );
        }
        return;
    }
    let expected = fs::read_to_string(&path).expect("read golden fixture");
    if rendered != expected {
        let new_path = dir.join(format!("{name}.new"));
        fs::write(&new_path, rendered).expect("write drift rendering");
        panic!(
            "golden snapshot '{name}' drifted from the checked-in fixture.\n\
             fresh rendering written to {}.\n\
             If the change is intentional, regenerate with \
             `QADAM_BLESS=1 cargo test` and commit the diff.",
            new_path.display()
        );
    }
}
