//! Host tensors and conversion to/from `xla::Literal`.
//!
//! The runtime's calling convention is flat positional argument lists of
//! f32/i32 tensors (see `python/compile/aot.py`); this module is the only
//! place that touches the PJRT literal API, so the rest of L3 stays
//! backend-agnostic.

use crate::error::{Error, Result};

/// A host-side dense tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    /// 32-bit float tensor.
    F32 {
        /// Dimension sizes, outermost first.
        shape: Vec<usize>,
        /// Elements, row-major.
        data: Vec<f32>,
    },
    /// 32-bit integer tensor.
    I32 {
        /// Dimension sizes, outermost first.
        shape: Vec<usize>,
        /// Elements, row-major.
        data: Vec<i32>,
    },
}

impl Tensor {
    /// f32 tensor; checks element count against the shape.
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    /// i32 tensor; checks element count against the shape.
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    /// Zero-filled f32 tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    /// Scalar f32 extraction (rank-0 or single-element tensors).
    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            Tensor::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            _ => Err(Error::Runtime("not a scalar f32 tensor".into())),
        }
    }

    /// Shape accessor.
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// f32 data view (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::Runtime("tensor is not f32".into())),
        }
    }

    /// Convert to an `xla::Literal`.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let literal = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.len() == 1 {
            return Ok(literal);
        }
        Ok(literal.reshape(&dims)?)
    }

    /// Convert from an `xla::Literal` (f32 or i32; other dtypes rejected).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(literal: &xla::Literal) -> Result<Tensor> {
        let shape = literal.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: literal.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: literal.to_vec::<i32>()?,
            }),
            other => Err(Error::Runtime(format!(
                "unsupported literal element type {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn roundtrip_f32() {
        let t = Tensor::f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let literal = t.to_literal().unwrap();
        let back = Tensor::from_literal(&literal).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn roundtrip_i32() {
        let t = Tensor::i32(&[4], vec![1, -2, 3, -4]);
        let literal = t.to_literal().unwrap();
        let back = Tensor::from_literal(&literal).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_extraction() {
        let t = Tensor::f32(&[], vec![2.5]);
        assert_eq!(t.scalar_f32().unwrap(), 2.5);
        let not_scalar = Tensor::f32(&[2], vec![1.0, 2.0]);
        assert!(not_scalar.scalar_f32().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        Tensor::f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn zeros_shape() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
