//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! rust hot path. Python never runs here — the artifacts in `artifacts/`
//! are self-contained XLA programs (see `python/compile/aot.py`).
//!
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` (the pattern from /opt/xla-example/load_hlo);
//! executables are compiled once and cached, execution converts between
//! [`Tensor`] and `xla::Literal` at the boundary.

pub mod tensor;
pub mod qat;

pub use qat::QatDriver;
pub use tensor::Tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Input/output signature of one artifact (from `manifest.json`).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub input_dtypes: Vec<String>,
    pub n_outputs: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub img_hw: usize,
    pub img_c: usize,
    pub num_classes: usize,
    pub param_order: Vec<String>,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Parse `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let get_usize = |key: &str| -> Result<usize> {
            json.get(key)
                .and_then(Json::as_i64)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("manifest missing '{key}'"))
        };
        let param_order: Vec<String> = json
            .get("param_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing param_order"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut artifacts = HashMap::new();
        if let Some(Json::Obj(map)) = json.get("artifacts") {
            for (name, spec) in map {
                let file = spec
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                    .to_string();
                let inputs = spec
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?;
                let mut input_shapes = Vec::new();
                let mut input_dtypes = Vec::new();
                for input in inputs {
                    let shape: Vec<usize> = input
                        .get("shape")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_i64().map(|v| v as usize))
                        .collect();
                    input_shapes.push(shape);
                    input_dtypes.push(
                        input
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string(),
                    );
                }
                let n_outputs = spec
                    .get("n_outputs")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow!("artifact {name} missing n_outputs"))?
                    as usize;
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        name: name.clone(),
                        file,
                        input_shapes,
                        input_dtypes,
                        n_outputs,
                    },
                );
            }
        }
        Ok(Manifest {
            batch: get_usize("batch")?,
            img_hw: get_usize("img_hw")?,
            img_c: get_usize("img_c")?,
            num_classes: get_usize("num_classes")?,
            param_order,
            artifacts,
        })
    }
}

/// The PJRT runtime: a CPU client plus a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (compiles lazily).
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, executables: HashMap::new() })
    }

    /// Number of PJRT devices (CPU client: 1).
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile (and cache) an artifact's executable.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let executable = self.client.compile(&computation)?;
        self.executables.insert(name.to_string(), executable);
        Ok(())
    }

    /// Execute an artifact with positional tensor inputs; returns the
    /// flattened outputs (the AOT side lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.prepare(name)?;
        let spec = &self.manifest.artifacts[name];
        if inputs.len() != spec.input_shapes.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (tensor, shape)) in inputs.iter().zip(&spec.input_shapes).enumerate() {
            if tensor.shape() != shape.as_slice() {
                bail!(
                    "artifact '{name}' input {i}: expected shape {:?}, got {:?}",
                    shape,
                    tensor.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let executable = &self.executables[name];
        let result = executable.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let elements = tuple.to_tuple()?;
        if elements.len() != spec.n_outputs {
            bail!(
                "artifact '{name}': expected {} outputs, got {}",
                spec.n_outputs,
                elements.len()
            );
        }
        elements.iter().map(Tensor::from_literal).collect()
    }

    /// Artifact names available in the manifest (sorted).
    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/runtime_e2e.rs
    // (they require `make artifacts`). Manifest parsing is testable inline.

    #[test]
    fn manifest_parse_minimal() {
        let dir = std::env::temp_dir().join("qadam_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "batch": 32, "img_hw": 8, "img_c": 3, "num_classes": 10,
              "param_order": ["conv1", "conv2", "fc"],
              "param_shapes": {"conv1": [3,3,3,8]},
              "artifacts": {
                "kernel_smoke": {
                  "file": "kernel_smoke.hlo.txt",
                  "inputs": [{"shape": [32, 27], "dtype": "float32"},
                             {"shape": [27, 8], "dtype": "float32"}],
                  "n_outputs": 1
                }
              }
            }"#,
        )
        .unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        assert_eq!(manifest.batch, 32);
        assert_eq!(manifest.param_order, vec!["conv1", "conv2", "fc"]);
        let spec = &manifest.artifacts["kernel_smoke"];
        assert_eq!(spec.input_shapes, vec![vec![32, 27], vec![27, 8]]);
        assert_eq!(spec.n_outputs, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_missing_fields_rejected() {
        let dir = std::env::temp_dir().join("qadam_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"batch": 1}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
