//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! rust hot path. Python never runs here — the artifacts in `artifacts/`
//! are self-contained XLA programs (see `python/compile/aot.py`).
//!
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` (the pattern from /opt/xla-example/load_hlo);
//! executables are compiled once and cached, execution converts between
//! [`Tensor`] and `xla::Literal` at the boundary.
//!
//! The XLA backend needs the vendored `xla` crate and is gated behind the
//! `pjrt` cargo feature. Without it, [`Runtime`] still parses manifests
//! but `prepare`/`execute` return [`Error::Unsupported`], so offline
//! builds compile and every other subsystem stays fully functional.

pub mod qat;
pub mod tensor;

pub use qat::QatDriver;
pub use tensor::Tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Input/output signature of one artifact (from `manifest.json`).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// HLO text file relative to the artifacts directory.
    pub file: String,
    /// Expected input shapes, in call order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Expected input dtypes (`"f32"` / `"i32"`), in call order.
    pub input_dtypes: Vec<String>,
    /// Number of outputs the executable returns.
    pub n_outputs: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Training batch size the artifacts were compiled for.
    pub batch: usize,
    /// Input image height = width.
    pub img_hw: usize,
    /// Input image channels.
    pub img_c: usize,
    /// Classifier output classes.
    pub num_classes: usize,
    /// Parameter tensor names, in the executables' calling order.
    pub param_order: Vec<String>,
    /// Artifact signatures by name.
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Parse `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)?;
        let json = Json::parse(&text)
            .map_err(|e| Error::ParseError(format!("manifest {}: {e}", path.display())))?;
        let get_usize = |key: &str| -> Result<usize> {
            json.get(key)
                .and_then(Json::as_i64)
                .map(|v| v as usize)
                .ok_or_else(|| Error::ParseError(format!("manifest missing '{key}'")))
        };
        let param_order: Vec<String> = json
            .get("param_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::ParseError("manifest missing param_order".into()))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut artifacts = HashMap::new();
        if let Some(Json::Obj(map)) = json.get("artifacts") {
            for (name, spec) in map {
                let file = spec
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::ParseError(format!("artifact {name} missing file")))?
                    .to_string();
                let inputs = spec
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| {
                        Error::ParseError(format!("artifact {name} missing inputs"))
                    })?;
                let mut input_shapes = Vec::new();
                let mut input_dtypes = Vec::new();
                for input in inputs {
                    let shape: Vec<usize> = input
                        .get("shape")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_i64().map(|v| v as usize))
                        .collect();
                    input_shapes.push(shape);
                    input_dtypes.push(
                        input
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string(),
                    );
                }
                let n_outputs = spec
                    .get("n_outputs")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| {
                        Error::ParseError(format!("artifact {name} missing n_outputs"))
                    })? as usize;
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        name: name.clone(),
                        file,
                        input_shapes,
                        input_dtypes,
                        n_outputs,
                    },
                );
            }
        }
        Ok(Manifest {
            batch: get_usize("batch")?,
            img_hw: get_usize("img_hw")?,
            img_c: get_usize("img_c")?,
            num_classes: get_usize("num_classes")?,
            param_order,
            artifacts,
        })
    }
}

/// The PJRT runtime: a CPU client plus a compiled-executable cache.
/// Without the `pjrt` feature this is a manifest-only stub whose
/// `prepare`/`execute` fail with [`Error::Unsupported`].
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    dir: PathBuf,
    /// The parsed artifact manifest.
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (compiles lazily).
    #[cfg(feature = "pjrt")]
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, executables: HashMap::new() })
    }

    /// Create a manifest-only stub runtime (no `pjrt` feature).
    #[cfg(not(feature = "pjrt"))]
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { dir: dir.to_path_buf(), manifest })
    }

    /// Number of PJRT devices (CPU client: 1; stub: 0).
    pub fn device_count(&self) -> usize {
        #[cfg(feature = "pjrt")]
        {
            self.client.device_count()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            0
        }
    }

    /// Compile (and cache) an artifact's executable.
    #[cfg(feature = "pjrt")]
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?;
        let path = self.dir.join(&spec.file);
        let text_path = path
            .to_str()
            .ok_or_else(|| Error::Runtime(format!("non-utf8 path {}", path.display())))?;
        let proto = xla::HloModuleProto::from_text_file(text_path)
            .map_err(|e| Error::Runtime(format!("loading HLO text {}: {e}", path.display())))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let executable = self.client.compile(&computation)?;
        self.executables.insert(name.to_string(), executable);
        Ok(())
    }

    /// Stub: the XLA backend is not compiled in.
    #[cfg(not(feature = "pjrt"))]
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        Err(Error::Unsupported(format!(
            "cannot compile artifact '{name}' from {}: this build lacks the 'pjrt' \
             feature (vendored xla crate)",
            self.dir.display()
        )))
    }

    /// Execute an artifact with positional tensor inputs; returns the
    /// flattened outputs (the AOT side lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.prepare(name)?;
        let spec = &self.manifest.artifacts[name];
        if inputs.len() != spec.input_shapes.len() {
            return Err(Error::Runtime(format!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.input_shapes.len(),
                inputs.len()
            )));
        }
        for (i, (tensor, shape)) in inputs.iter().zip(&spec.input_shapes).enumerate() {
            if tensor.shape() != shape.as_slice() {
                return Err(Error::Runtime(format!(
                    "artifact '{name}' input {i}: expected shape {:?}, got {:?}",
                    shape,
                    tensor.shape()
                )));
            }
        }
        let n_outputs = spec.n_outputs;
        self.execute_prepared(name, inputs, n_outputs)
    }

    #[cfg(feature = "pjrt")]
    fn execute_prepared(
        &mut self,
        name: &str,
        inputs: &[Tensor],
        n_outputs: usize,
    ) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let executable = &self.executables[name];
        let result = executable.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let elements = tuple.to_tuple()?;
        if elements.len() != n_outputs {
            return Err(Error::Runtime(format!(
                "artifact '{name}': expected {n_outputs} outputs, got {}",
                elements.len()
            )));
        }
        elements.iter().map(Tensor::from_literal).collect()
    }

    #[cfg(not(feature = "pjrt"))]
    fn execute_prepared(
        &mut self,
        name: &str,
        _inputs: &[Tensor],
        _n_outputs: usize,
    ) -> Result<Vec<Tensor>> {
        // Unreachable in practice: `prepare` already failed.
        Err(Error::Unsupported(format!(
            "cannot execute artifact '{name}': this build lacks the 'pjrt' feature"
        )))
    }

    /// Artifact names available in the manifest (sorted).
    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/runtime_e2e.rs
    // (they require `make artifacts` and the `pjrt` feature). Manifest
    // parsing and the stub error path are testable inline.

    fn write_manifest(dir_name: &str, body: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(dir_name);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        dir
    }

    #[test]
    fn manifest_parse_minimal() {
        let dir = write_manifest(
            "qadam_manifest_test",
            r#"{
              "batch": 32, "img_hw": 8, "img_c": 3, "num_classes": 10,
              "param_order": ["conv1", "conv2", "fc"],
              "param_shapes": {"conv1": [3,3,3,8]},
              "artifacts": {
                "kernel_smoke": {
                  "file": "kernel_smoke.hlo.txt",
                  "inputs": [{"shape": [32, 27], "dtype": "float32"},
                             {"shape": [27, 8], "dtype": "float32"}],
                  "n_outputs": 1
                }
              }
            }"#,
        );
        let manifest = Manifest::load(&dir).unwrap();
        assert_eq!(manifest.batch, 32);
        assert_eq!(manifest.param_order, vec!["conv1", "conv2", "fc"]);
        let spec = &manifest.artifacts["kernel_smoke"];
        assert_eq!(spec.input_shapes, vec![vec![32, 27], vec![27, 8]]);
        assert_eq!(spec.n_outputs, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_missing_fields_rejected() {
        let dir = write_manifest("qadam_manifest_bad", r#"{"batch": 1}"#);
        let err = Manifest::load(&dir).unwrap_err();
        assert_eq!(err.kind(), "parse_error");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_missing_dir_is_io_error() {
        let err = Manifest::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert_eq!(err.kind(), "io");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unsupported() {
        let dir = write_manifest(
            "qadam_manifest_stub",
            r#"{
              "batch": 1, "img_hw": 8, "img_c": 3, "num_classes": 10,
              "param_order": [],
              "artifacts": {
                "init": {"file": "init.hlo.txt", "inputs": [], "n_outputs": 1}
              }
            }"#,
        );
        let mut runtime = Runtime::new(&dir).unwrap();
        assert_eq!(runtime.device_count(), 0);
        assert_eq!(runtime.artifact_names(), vec!["init"]);
        let err = runtime.execute("init", &[]).unwrap_err();
        assert_eq!(err.kind(), "unsupported");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
