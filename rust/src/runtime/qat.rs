//! QAT driver: the rust-side training loop over the AOT train/eval
//! artifacts — the end-to-end path behind Figs. 5/6's accuracy axis and
//! `examples/qat_end_to_end.rs`.
//!
//! State (params + momenta) lives in host [`Tensor`]s and cycles through
//! the PJRT executable each step; the synthetic batch generator is itself
//! an artifact (`batch.hlo.txt`), so the whole loop is XLA programs driven
//! by rust — python appears nowhere.

use super::{Runtime, Tensor};
use crate::error::{Error, Result};
use crate::quant::PeType;

/// Map a rust PE type to the artifact naming convention.
pub fn pe_artifact_key(pe: PeType) -> &'static str {
    match pe {
        PeType::Fp32 => "fp32",
        PeType::Int16 => "int16",
        PeType::LightPe1 => "lightpe1",
        PeType::LightPe2 => "lightpe2",
    }
}

/// One recorded training step.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    /// Zero-based step index.
    pub step: usize,
    /// Training loss at this step.
    pub loss: f32,
}

/// Result of a QAT run.
#[derive(Debug, Clone)]
pub struct QatOutcome {
    /// PE type the model was trained for.
    pub pe: PeType,
    /// Steps executed.
    pub steps: usize,
    /// Sampled training losses.
    pub loss_curve: Vec<StepRecord>,
    /// Final evaluation accuracy in [0, 1].
    pub final_accuracy: f32,
    /// Final evaluation loss.
    pub final_eval_loss: f32,
}

/// Driver owning the model state between steps.
pub struct QatDriver {
    pe: PeType,
    params: Vec<Tensor>,
    momentum: Vec<Tensor>,
}

impl QatDriver {
    /// Initialize from the `init` artifact (deterministic He init).
    pub fn new(runtime: &mut Runtime, pe: PeType) -> Result<QatDriver> {
        let params = runtime.execute("init", &[])?;
        let momentum = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        Ok(QatDriver { pe, params, momentum })
    }

    /// One training step on the batch generated from `seed`.
    pub fn step(&mut self, runtime: &mut Runtime, seed: i32) -> Result<f32> {
        let batch = runtime.execute("batch", &[Tensor::i32(&[1], vec![seed])])?;
        let mut inputs = Vec::with_capacity(self.params.len() * 2 + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.momentum.iter().cloned());
        inputs.extend(batch);
        let name = format!("train_{}", pe_artifact_key(self.pe));
        let mut outputs = runtime.execute(&name, &inputs)?;
        let loss = outputs
            .pop()
            .ok_or_else(|| Error::Runtime("train step returned no outputs".into()))?
            .scalar_f32()?;
        let n = self.params.len();
        self.momentum = outputs.split_off(n);
        self.params = outputs;
        Ok(loss)
    }

    /// Evaluate on the batch generated from `seed`: (accuracy, loss).
    pub fn evaluate(&self, runtime: &mut Runtime, seed: i32) -> Result<(f32, f32)> {
        let batch = runtime.execute("batch", &[Tensor::i32(&[1], vec![seed])])?;
        let mut inputs = self.params.clone();
        inputs.extend(batch);
        let name = format!("eval_{}", pe_artifact_key(self.pe));
        let outputs = runtime.execute(&name, &inputs)?;
        Ok((outputs[0].scalar_f32()?, outputs[1].scalar_f32()?))
    }

    /// Run a full training loop, recording the loss curve and final eval.
    pub fn train(
        runtime: &mut Runtime,
        pe: PeType,
        steps: usize,
        log_every: usize,
    ) -> Result<QatOutcome> {
        let mut driver = QatDriver::new(runtime, pe)?;
        let mut loss_curve = Vec::new();
        for step in 0..steps {
            let loss = driver.step(runtime, step as i32)?;
            if step % log_every == 0 || step + 1 == steps {
                loss_curve.push(StepRecord { step, loss });
            }
        }
        let (final_accuracy, final_eval_loss) = driver.evaluate(runtime, 999)?;
        Ok(QatOutcome { pe, steps, loss_curve, final_accuracy, final_eval_loss })
    }

    /// Current parameter tensors (for inspection/serialization).
    pub fn params(&self) -> &[Tensor] {
        &self.params
    }
}

// Integration tests for the driver live in rust/tests/runtime_e2e.rs —
// they need compiled artifacts and a PJRT client.
