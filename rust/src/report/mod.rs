//! Figure regeneration (§IV): one function per paper figure, producing a
//! CSV table plus a terminal scatter rendering. Shared by the CLI
//! (`qadam report`) and the benches (`rust/benches/fig*.rs`). All figure
//! builders run their campaigns through [`Explorer`] and surface typed
//! [`Error`]s instead of panicking.

use crate::accuracy;
use crate::arch::SweepSpec;
use crate::dnn::{Dataset, Model};
use crate::dse::{self, Evaluation, Orientation};
use crate::error::{Error, Result};
use crate::explore::{EvalDatabase, Explorer};
use crate::ppa::PpaModel;
use crate::quant::PeType;
use crate::synth::synthesize_sweep;
use crate::util::stats;
use crate::util::table::{format_sig, scatter, Series, Table};

/// A regenerated figure: CSV table, terminal plot, and summary lines.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Figure identifier and caption (e.g. `"Fig. 4 — normalized DSE"`).
    pub id: String,
    /// The figure's data as an aligned, CSV-exportable table.
    pub table: Table,
    /// Terminal scatter rendering.
    pub plot: String,
    /// Headline takeaways, one line each, with the paper's claims.
    pub summary: Vec<String>,
}

impl Figure {
    /// Render everything for terminal output.
    pub fn render(&self) -> String {
        let mut out = format!("=== {} ===\n{}\n{}", self.id, self.plot, self.table.render());
        for line in &self.summary {
            out.push_str(&format!("  {line}\n"));
        }
        out
    }
}

fn marker_for(pe: PeType) -> char {
    match pe {
        PeType::Fp32 => 'F',
        PeType::Int16 => 'I',
        PeType::LightPe1 => '1',
        PeType::LightPe2 => '2',
    }
}

/// Run the default sweep against one model (the single-space campaigns
/// behind Figs. 2 and the QAT join).
fn explore_single(model: Model, workers: usize, seed: u64) -> Result<Vec<Evaluation>> {
    let db = Explorer::over(SweepSpec::default())
        .model(model)
        .workers(workers)
        .seed(seed)
        .run()?;
    Ok(db.spaces.into_iter().next().map(|space| space.evals).unwrap_or_default())
}

/// **Fig. 2** — perf/area and energy spread across PE types & precisions
/// ("performance per area and energy varies more than 5× and 35×").
pub fn fig2(workers: usize, seed: u64) -> Result<Figure> {
    let model = crate::dnn::model_for(crate::dnn::ModelKind::ResNet20, Dataset::Cifar10);
    let evals = explore_single(model, workers, seed)?;
    let mut table = Table::new(&["pe", "min_ppa", "max_ppa", "min_energy_uj", "max_energy_uj"]);
    let mut series = Vec::new();
    for pe in PeType::ALL {
        let ppa: Vec<f64> = evals
            .iter()
            .filter(|e| e.config.pe == pe)
            .map(|e| e.perf_per_area)
            .collect();
        let energy: Vec<f64> =
            evals.iter().filter(|e| e.config.pe == pe).map(|e| e.energy_uj).collect();
        table.row_labeled(
            pe.name(),
            &[stats::min(&ppa), stats::max(&ppa), stats::min(&energy), stats::max(&energy)],
        );
        series.push(Series {
            name: pe.name().into(),
            marker: marker_for(pe),
            points: evals
                .iter()
                .filter(|e| e.config.pe == pe)
                .map(|e| (e.perf_per_area, e.energy_uj))
                .collect(),
        });
    }
    let all_ppa: Vec<f64> = evals.iter().map(|e| e.perf_per_area).collect();
    let all_energy: Vec<f64> = evals.iter().map(|e| e.energy_uj).collect();
    let ppa_spread = stats::max(&all_ppa) / stats::min(&all_ppa);
    let energy_spread = stats::max(&all_energy) / stats::min(&all_energy);
    Ok(Figure {
        id: "Fig. 2 — design-space spread (ResNet-20 / CIFAR-10)".into(),
        plot: scatter(
            "perf/area vs energy across the design space",
            "inferences/s/mm2",
            "uJ/inference",
            &series,
            64,
            18,
            true,
        ),
        table,
        summary: vec![
            format!(
                "perf/area spread: {}x (paper: >5x)",
                format_sig(ppa_spread, 3)
            ),
            format!("energy spread: {}x (paper: >35x)", format_sig(energy_spread, 3)),
        ],
    })
}

/// **Fig. 3** — actual vs polynomial-estimated power/perf/area per PE type.
pub fn fig3(seed: u64) -> Result<Figure> {
    let spec = SweepSpec::default();
    let mut table =
        Table::new(&["pe", "metric", "degree", "pearson_r", "r2", "mape_pct", "cv_rmse"]);
    let mut series = Vec::new();
    let mut worst_r: f64 = 1.0;
    for pe in PeType::ALL {
        let dataset = synthesize_sweep(&spec, pe, seed);
        let model = PpaModel::fit(&dataset, 5, seed);
        for report in &model.reports {
            table.row(&[
                pe.name().into(),
                report.metric.clone(),
                report.degree.to_string(),
                format_sig(report.pearson, 4),
                format_sig(report.r_squared, 4),
                format_sig(report.mape, 3),
                format_sig(report.cv_rmse, 3),
            ]);
            worst_r = worst_r.min(report.pearson);
        }
        // Scatter: actual vs predicted area (the bottom chart of Fig. 3).
        let xs: Vec<Vec<f64>> = dataset
            .records
            .iter()
            .map(|r| crate::ppa::design_features(&r.config))
            .collect();
        let predictions = model.area.predict_all(&xs);
        series.push(Series {
            name: pe.name().into(),
            marker: marker_for(pe),
            points: dataset
                .records
                .iter()
                .zip(&predictions)
                .map(|(r, &p)| (r.area_mm2, p))
                .collect(),
        });
    }
    Ok(Figure {
        id: "Fig. 3 — PPA model fit (actual vs estimated)".into(),
        plot: scatter(
            "actual vs estimated area (diagonal = perfect)",
            "actual mm2",
            "estimated mm2",
            &series,
            64,
            18,
            false,
        ),
        table,
        summary: vec![format!(
            "worst-case Pearson r across all PE types & metrics: {} (paper: \"agrees closely\")",
            format_sig(worst_r, 4)
        )],
    })
}

/// **Fig. 4** — normalized perf/area vs normalized energy per (model,
/// dataset); summary = the paper's average gains vs best INT16.
pub fn fig4(dataset: Dataset, workers: usize, seed: u64) -> Result<Figure> {
    let db = Explorer::over(SweepSpec::default())
        .dataset(dataset)
        .workers(workers)
        .seed(seed)
        .run()?;
    fig4_from_db(&db)
}

/// **Fig. 4** from a saved campaign database (`qadam report --fig 4
/// --load db.json`) — renders exactly what the live run would, since the
/// figure consumes nothing beyond the persisted evaluations.
pub fn fig4_from_db(db: &EvalDatabase) -> Result<Figure> {
    db.ensure_whole_space()?;
    let mut table = Table::new(&["model", "pe", "norm_perf_per_area", "norm_energy_gain"]);
    let mut series: Vec<Series> = PeType::ALL
        .iter()
        .map(|&pe| Series { name: pe.name().into(), marker: marker_for(pe), points: vec![] })
        .collect();
    for space in &db.spaces {
        let normalized = dse::normalize(&space.evals)?;
        for point in &normalized {
            // Every PeType value is a member of PeType::ALL.
            #[allow(clippy::unwrap_used)]
            let idx = PeType::ALL.iter().position(|&p| p == point.pe).unwrap();
            series[idx].points.push((point.norm_perf_per_area, point.norm_energy));
        }
        for (pe, ppa_gain, energy_gain) in dse::headline_ratios(&space.evals)? {
            table.row(&[
                space.model_name.clone(),
                pe.name().into(),
                format_sig(ppa_gain, 3),
                format_sig(energy_gain, 3),
            ]);
        }
    }
    let mut summary = Vec::new();
    for (pe, ppa, energy) in db.headline_geomean()? {
        summary.push(format!(
            "{}: {}x perf/area, {}x less energy vs best INT16 (geomean)",
            pe.name(),
            format_sig(ppa, 3),
            format_sig(energy, 3)
        ));
    }
    summary.push("paper: LightPE-1 4.8x/4.7x, LightPE-2 4.1x/4.0x, INT16 vs FP32 1.8x/1.5x".into());
    Ok(Figure {
        id: format!("Fig. 4 — normalized DSE ({})", db.dataset.name()),
        plot: scatter(
            "normalized perf/area vs normalized energy",
            "norm perf/area (vs best INT16)",
            "norm energy",
            &series,
            64,
            18,
            true,
        ),
        table,
        summary,
    })
}

/// **Fig. 5** — Pareto front: accuracy vs normalized perf/area (CIFAR).
pub fn fig5(dataset: Dataset, workers: usize, seed: u64) -> Result<Figure> {
    pareto_figure(dataset, workers, seed, true, &accuracy::AccuracyBook::new())
}

/// **Fig. 5** from a live run with an explicit
/// [`AccuracyBook`](accuracy::AccuracyBook) (see [`fig5_from_db_with`]).
pub fn fig5_with(
    dataset: Dataset,
    workers: usize,
    seed: u64,
    book: &accuracy::AccuracyBook,
) -> Result<Figure> {
    pareto_figure(dataset, workers, seed, true, book)
}

/// **Fig. 5** from a saved campaign database (paper-registry
/// accuracies; use [`fig5_from_db_with`] to supply user declarations).
pub fn fig5_from_db(db: &EvalDatabase) -> Result<Figure> {
    pareto_figure_from_db(db, true, &accuracy::AccuracyBook::new())
}

/// **Fig. 5** from a saved database with an explicit
/// [`AccuracyBook`](accuracy::AccuracyBook) — how custom QSL models and
/// scaled model variants (whose accuracy the paper registry cannot
/// know) get onto the accuracy front: declare it in the spec and pass
/// `campaign.accuracy_book()`.
pub fn fig5_from_db_with(db: &EvalDatabase, book: &accuracy::AccuracyBook) -> Result<Figure> {
    pareto_figure_from_db(db, true, book)
}

/// **Fig. 6** — Pareto front: top-1 error vs normalized energy (CIFAR).
pub fn fig6(dataset: Dataset, workers: usize, seed: u64) -> Result<Figure> {
    pareto_figure(dataset, workers, seed, false, &accuracy::AccuracyBook::new())
}

/// **Fig. 6** from a live run with an explicit
/// [`AccuracyBook`](accuracy::AccuracyBook) (see [`fig5_from_db_with`]).
pub fn fig6_with(
    dataset: Dataset,
    workers: usize,
    seed: u64,
    book: &accuracy::AccuracyBook,
) -> Result<Figure> {
    pareto_figure(dataset, workers, seed, false, book)
}

/// **Fig. 6** from a saved campaign database (paper-registry
/// accuracies; use [`fig6_from_db_with`] to supply user declarations).
pub fn fig6_from_db(db: &EvalDatabase) -> Result<Figure> {
    pareto_figure_from_db(db, false, &accuracy::AccuracyBook::new())
}

/// **Fig. 6** from a saved database with an explicit
/// [`AccuracyBook`](accuracy::AccuracyBook) (see [`fig5_from_db_with`]).
pub fn fig6_from_db_with(db: &EvalDatabase, book: &accuracy::AccuracyBook) -> Result<Figure> {
    pareto_figure_from_db(db, false, book)
}

fn pareto_figure(
    dataset: Dataset,
    workers: usize,
    seed: u64,
    perf_axis: bool,
    book: &accuracy::AccuracyBook,
) -> Result<Figure> {
    if dataset == Dataset::ImageNet {
        return Err(Error::InvalidConfig(
            "Figs. 5/6 are CIFAR-only in the paper".into(),
        ));
    }
    let db = Explorer::over(SweepSpec::default())
        .dataset(dataset)
        .workers(workers)
        .seed(seed)
        .run()?;
    pareto_figure_from_db(&db, perf_axis, book)
}

fn pareto_figure_from_db(
    db: &EvalDatabase,
    perf_axis: bool,
    book: &accuracy::AccuracyBook,
) -> Result<Figure> {
    db.ensure_whole_space()?;
    let dataset = db.dataset;
    if dataset == Dataset::ImageNet {
        return Err(Error::InvalidConfig(
            "Figs. 5/6 are CIFAR-only in the paper".into(),
        ));
    }
    let mut table = Table::new(&["model", "pe", "x_metric", "top1_or_err", "on_pareto_front"]);
    let mut series: Vec<Series> = PeType::ALL
        .iter()
        .map(|&pe| Series { name: pe.name().into(), marker: marker_for(pe), points: vec![] })
        .collect();
    let mut light_on_front = 0usize;
    let mut fronts = 0usize;
    for space in &db.spaces {
        let missing_baseline = || {
            Error::MissingBaseline(format!(
                "{}: no INT16 evaluations for the Fig. 5/6 baseline",
                space.model_name
            ))
        };
        let baseline =
            dse::best_perf_per_area(&space.evals, PeType::Int16).ok_or_else(missing_baseline)?;
        // One point per PE type: its best config on the figure's hardware
        // axis (highest perf/area for Fig. 5, lowest energy for Fig. 6).
        let mut points: Vec<(PeType, f64, f64)> = Vec::new();
        for pe in PeType::ALL {
            // Declared accuracy first (custom models, scaled variants),
            // paper registry as the fallback for zoo families.
            let top1 = book.lookup(&space.model_name, dataset, pe).ok_or_else(|| {
                Error::InvalidConfig(format!(
                    "no accuracy known for {} / {dataset} / {pe}; declare it in the spec's \
                     'accuracy {{ ... }}' block for custom or scaled models",
                    space.model_name
                ))
            })?;
            let (x, y) = if perf_axis {
                let best =
                    dse::best_perf_per_area(&space.evals, pe).ok_or_else(missing_baseline)?;
                (best.perf_per_area / baseline.perf_per_area, top1)
            } else {
                let best = dse::best_energy(&space.evals, pe).ok_or_else(missing_baseline)?;
                let base_energy = dse::best_energy(&space.evals, PeType::Int16)
                    .ok_or_else(missing_baseline)?;
                (best.energy_uj / base_energy.energy_uj, 100.0 - top1)
            };
            points.push((pe, x, y));
        }
        let coords: Vec<Vec<f64>> = points.iter().map(|&(_, x, y)| vec![x, y]).collect();
        let orientations = if perf_axis {
            [Orientation::Maximize, Orientation::Maximize]
        } else {
            [Orientation::Minimize, Orientation::Minimize]
        };
        // `dse::pareto_front` is itself routed through the streaming
        // engine, so this is the online-front computation — pinned
        // against the post-hoc oracle by the golden suite.
        let front = dse::pareto_front(&coords, &orientations);
        fronts += 1;
        if front.iter().any(|&i| points[i].0.is_shift_add()) {
            light_on_front += 1;
        }
        for (idx, &(pe, x, y)) in points.iter().enumerate() {
            let on_front = front.contains(&idx);
            table.row(&[
                space.model_name.clone(),
                pe.name().into(),
                format_sig(x, 3),
                format_sig(y, 3),
                on_front.to_string(),
            ]);
            // Every PeType value is a member of PeType::ALL.
            #[allow(clippy::unwrap_used)]
            let series_idx = PeType::ALL.iter().position(|&p| p == pe).unwrap();
            series[series_idx].points.push((x, y));
        }
    }
    let (id, xlabel, ylabel) = if perf_axis {
        (
            format!("Fig. 5 — Pareto: accuracy vs perf/area ({})", dataset.name()),
            "norm perf/area",
            "top-1 acc %",
        )
    } else {
        (
            format!("Fig. 6 — Pareto: error vs energy ({})", dataset.name()),
            "norm energy",
            "top-1 err %",
        )
    };
    Ok(Figure {
        id,
        plot: scatter("per-PE-type best points + Pareto front", xlabel, ylabel, &series, 64, 16, false),
        table,
        summary: vec![format!(
            "LightPE on the Pareto front in {light_on_front}/{fronts} model panels (paper: consistently)"
        )],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_spreads_exceed_paper_bounds() {
        let figure = fig2(2, 7).unwrap();
        assert!(figure.summary[0].contains("paper"));
        // Parse the spread values back out of the summary.
        let ppa_spread: f64 =
            figure.summary[0].split('x').next().unwrap().rsplit(' ').next().unwrap().parse().unwrap();
        assert!(ppa_spread > 5.0, "perf/area spread {ppa_spread}");
    }

    #[test]
    fn fig4_table_nonempty_and_renders() {
        let figure = fig4(Dataset::Cifar10, 2, 7).unwrap();
        assert!(figure.table.len() >= 12); // 3 models × 4 PE types
        assert!(figure.render().contains("Fig. 4"));
    }

    #[test]
    fn fig5_lightpe_always_on_front() {
        let figure = fig5(Dataset::Cifar10, 2, 7).unwrap();
        assert!(
            figure.summary[0].contains("3/3"),
            "LightPE must be on every CIFAR-10 front: {}",
            figure.summary[0]
        );
    }

    #[test]
    fn fig5_rejects_imagenet_with_typed_error() {
        let err = fig5(Dataset::ImageNet, 1, 7).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("CIFAR-only"));
    }

    #[test]
    fn figs_from_db_survive_json_round_trip() {
        use crate::arch::SweepSpec;
        use crate::quant::PeType;
        use crate::util::json::Json;
        // All four PE types so Figs. 4/5/6 have every best-point defined.
        let spec = SweepSpec { pe_types: PeType::ALL.to_vec(), ..SweepSpec::tiny() };
        let db = Explorer::over(spec)
            .dataset(Dataset::Cifar10)
            .workers(2)
            .seed(7)
            .run()
            .unwrap();
        let loaded =
            EvalDatabase::from_json(&Json::parse(&db.to_json().to_string_pretty()).unwrap())
                .unwrap();
        // Saved-and-reloaded databases reproduce the live figures exactly.
        assert_eq!(fig4_from_db(&loaded).unwrap().render(), fig4_from_db(&db).unwrap().render());
        assert_eq!(fig5_from_db(&loaded).unwrap().render(), fig5_from_db(&db).unwrap().render());
        assert_eq!(fig6_from_db(&loaded).unwrap().render(), fig6_from_db(&db).unwrap().render());
    }

    #[test]
    fn fig56_with_declared_accuracy_cover_custom_models() {
        use crate::arch::SweepSpec;
        use crate::quant::PeType;
        // A campaign over a *custom* model: the paper registry knows
        // nothing about it, so the default book fails with a typed
        // error that points at the spec's accuracy block…
        let mut model = crate::dnn::model_for(crate::dnn::ModelKind::ResNet20, Dataset::Cifar10);
        model.name = "customnet".into();
        let spec = SweepSpec { pe_types: PeType::ALL.to_vec(), ..SweepSpec::tiny() };
        let db = Explorer::over(spec).model(model).workers(2).seed(7).run().unwrap();
        let err = fig5_from_db(&db).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("accuracy"), "{err}");
        // …while declared accuracies render both figures.
        let mut book = accuracy::AccuracyBook::new();
        for (pe, top1) in [
            (PeType::Fp32, 92.0),
            (PeType::Int16, 91.8),
            (PeType::LightPe1, 90.5),
            (PeType::LightPe2, 91.1),
        ] {
            book.declare("customnet", pe, top1);
        }
        let fig5 = fig5_from_db_with(&db, &book).unwrap();
        assert!(fig5.render().contains("Fig. 5"));
        let fig6 = fig6_from_db_with(&db, &book).unwrap();
        assert!(fig6.render().contains("Fig. 6"));
    }

    #[test]
    fn figs_from_db_reject_imagenet() {
        let db = EvalDatabase {
            dataset: Dataset::ImageNet,
            shard: (0, 1),
            strategy: "exhaustive".into(),
            spaces: Vec::new(),
            stats: crate::explore::CampaignStats {
                design_points: 0,
                evaluations: 0,
                wall_seconds: 0.0,
                workers: 0,
            },
        };
        assert_eq!(fig5_from_db(&db).unwrap_err().kind(), "invalid_config");
        assert_eq!(fig6_from_db(&db).unwrap_err().kind(), "invalid_config");
    }
}
