//! Structured tracing & metrics for campaigns and serve batches
//! (DESIGN.md §11).
//!
//! The subsystem is built around a hard determinism split:
//!
//! * **`qadam.trace`** ([`Trace`]) — the deterministic event stream: a
//!   dense, monotonically sequenced list of typed [`TraceEvent`]s
//!   covering the campaign lifecycle (begin/end), the strategy funnel
//!   (per-round prune counts), the ordered point stream
//!   (dispatch/deliver), cache hits and misses, frontier insertion
//!   outcomes, the journal's logical flush schedule, and the serve
//!   scheduler's phase transitions. No wall clock anywhere: two
//!   identical runs produce byte-identical traces at any worker count,
//!   with or without a kill/resume in between.
//! * **`qadam.timing`** ([`TimingSidecar`]) — the wall-clock sidecar:
//!   per-event nanosecond offsets and per-point evaluation durations,
//!   keyed back to trace events by sequence number, written next to the
//!   trace (`<trace>.timing`, see [`sidecar_path`]). Never consulted by
//!   golden or bit-identity checks.
//!
//! Hot paths record through the [`TraceSink`] trait so an untraced
//! campaign pays only an `Option` check per event site ([`NullSink`] is
//! an empty inline call; `benches/trace_overhead.rs` pins the overhead
//! budget). [`TraceRecorder`] is the real sink: it appends the event to
//! an in-memory [`Trace`] and stamps a [`TimingSample`] per event, and
//! the pair is written once at end of run.

pub mod event;
pub mod timing;
pub mod trace;
pub mod view;

use std::sync::Mutex;
use std::time::Instant;

use crate::explore::lock_shared;

pub use event::TraceEvent;
pub use timing::{sidecar_path, PhaseSummary, TimingSample, TimingSidecar, TIMING_KIND, TIMING_SCHEMA};
pub use trace::{Trace, TraceDiff, TRACE_KIND, TRACE_SCHEMA};

/// A consumer of trace events, shared across the campaign's threads.
///
/// Emission sites are all on single-threaded code paths (strategy
/// selection, the replay loop, the ordered delivery loop, the serve
/// scheduler thread), which is what makes the event stream
/// deterministic — the trait still requires `Send + Sync` because the
/// sink handle rides inside [`Explorer`](crate::Explorer), which is
/// itself shared across workers. `fmt::Debug` is a supertrait for the
/// same reason [`Strategy`](crate::pareto::Strategy) requires it:
/// `Explorer` derives `Debug`.
pub trait TraceSink: std::fmt::Debug + Send + Sync {
    /// Record one event, optionally annotated with the evaluation time
    /// of the design point it describes (`point.dispatch` only). The
    /// annotation feeds the timing sidecar and never the trace.
    fn record_with(&self, event: TraceEvent, eval_ns: Option<u64>);

    /// Record one event with no timing annotation.
    fn record(&self, event: TraceEvent) {
        self.record_with(event, None);
    }
}

/// The do-nothing sink: every call compiles to an empty function. Used
/// by the overhead bench to price the instrumentation sites themselves.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn record_with(&self, _event: TraceEvent, _eval_ns: Option<u64>) {}
}

/// Recorder state behind one mutex so an event and its timing sample
/// can never tear apart.
#[derive(Debug, Default)]
struct RecorderState {
    trace: Trace,
    samples: Vec<TimingSample>,
}

/// The collecting sink: buffers a [`Trace`] and its [`TimingSidecar`]
/// in memory; the caller snapshots and saves both at end of run.
#[derive(Debug)]
pub struct TraceRecorder {
    origin: Instant,
    state: Mutex<RecorderState>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceRecorder {
    /// A fresh recorder; wall-clock offsets are measured from now.
    pub fn new() -> Self {
        Self { origin: Instant::now(), state: Mutex::new(RecorderState::default()) }
    }

    /// Number of events recorded so far.
    pub fn len(&self) -> usize {
        lock_shared(&self.state).trace.len()
    }

    /// Whether nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Clone out the trace and timing sidecar accumulated so far. The
    /// sidecar's host metadata is resolved here, from the environment
    /// only (same policy as `qadam.bench`).
    pub fn snapshot(&self) -> (Trace, TimingSidecar) {
        let state = lock_shared(&self.state);
        let mut sidecar = TimingSidecar::new(crate::bench::HostMeta::from_env());
        sidecar.samples = state.samples.clone();
        (state.trace.clone(), sidecar)
    }
}

impl TraceSink for TraceRecorder {
    fn record_with(&self, event: TraceEvent, eval_ns: Option<u64>) {
        let at_ns = self.origin.elapsed().as_nanos() as u64;
        let mut state = lock_shared(&self.state);
        let seq = state.trace.push(event);
        state.samples.push(TimingSample { seq, at_ns, eval_ns });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_pairs_every_event_with_a_sample() {
        let recorder = TraceRecorder::new();
        assert!(recorder.is_empty());
        recorder.record(TraceEvent::ServeBegin { campaigns: 1 });
        recorder.record_with(TraceEvent::PointDispatch { pos: 0, index: 0 }, Some(42));
        let (trace, sidecar) = recorder.snapshot();
        assert_eq!(trace.len(), 2);
        assert_eq!(sidecar.samples.len(), 2);
        assert_eq!(sidecar.samples[0].seq, 0);
        assert_eq!(sidecar.samples[1].seq, 1);
        assert_eq!(sidecar.samples[1].eval_ns, Some(42));
        // Offsets are monotone: emission is single-threaded per site.
        assert!(sidecar.samples[0].at_ns <= sidecar.samples[1].at_ns);
    }

    #[test]
    fn null_sink_accepts_everything() {
        NullSink.record(TraceEvent::ServeEnd { done: 0, failed: 0, skipped: 0 });
    }
}
