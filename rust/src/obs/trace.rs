//! The `qadam.trace` canonical-JSON document: a dense, monotonically
//! sequenced list of [`TraceEvent`]s.
//!
//! A trace is the deterministic half of the observability split: it
//! contains no wall-clock data, so two identical campaign runs — at any
//! worker count, with or without a kill/resume in between — produce
//! byte-identical trace files (enforced by `tests/obs.rs` and the fault
//! suite). The document versions independently of the campaign artifact
//! lineage: its envelope schema must equal [`TRACE_SCHEMA`] exactly.

use std::collections::BTreeMap;
use std::path::Path;

use super::event::TraceEvent;
use crate::error::{Error, Result};
use crate::explore::persist::{check_envelope_exact, envelope_at, field_arr, field_usize, write_atomic};
use crate::util::json::{num, Json};

/// Artifact kind tag in the `{"kind", "schema"}` envelope.
pub const TRACE_KIND: &str = "qadam.trace";

/// Trace document schema version. History: v1 — initial event taxonomy
/// (campaign lifecycle, strategy funnel, point stream, cache, frontier,
/// journal flushes, serve phases).
pub const TRACE_SCHEMA: usize = 1;

/// A deterministic event trace: events in emission order, each carrying
/// a dense sequence number derived from its position.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    events: Vec<TraceEvent>,
}

impl Trace {
    /// An empty trace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one event; returns the sequence number it was assigned.
    pub fn push(&mut self, event: TraceEvent) -> u64 {
        self.events.push(event);
        (self.events.len() - 1) as u64
    }

    /// The events in sequence order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Number of events recorded.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the trace holds no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Event tallies by wire kind, sorted by kind name.
    pub fn counts(&self) -> BTreeMap<&'static str, usize> {
        let mut counts = BTreeMap::new();
        for event in &self.events {
            *counts.entry(event.kind()).or_insert(0) += 1;
        }
        counts
    }

    /// Canonical-JSON document form. Each event object gains a `seq`
    /// field equal to its position, making saved traces greppable and
    /// letting the timing sidecar key samples back to events.
    pub fn to_json(&self) -> Json {
        let mut fields = envelope_at(TRACE_KIND, TRACE_SCHEMA);
        let events = self
            .events
            .iter()
            .enumerate()
            .map(|(seq, event)| {
                let mut json = event.to_json();
                if let Json::Obj(map) = &mut json {
                    map.insert("seq".to_string(), num(seq as f64));
                }
                json
            })
            .collect();
        fields.push(("events", Json::Arr(events)));
        crate::util::json::obj(fields)
    }

    /// Parse a trace document, validating the envelope and that the
    /// recorded `seq` fields are dense and monotonic from zero — a gap
    /// means the file was assembled by hand or truncated mid-edit.
    pub fn from_json(json: &Json) -> Result<Trace> {
        check_envelope_exact(json, TRACE_KIND, TRACE_SCHEMA)?;
        let mut events = Vec::new();
        for (expected, entry) in field_arr(json, "events")?.iter().enumerate() {
            let seq = field_usize(entry, "seq")?;
            if seq != expected {
                return Err(Error::ParseError(format!(
                    "trace event at position {expected} carries seq {seq}: \
                     the sequence must be dense and start at 0"
                )));
            }
            events.push(TraceEvent::from_json(entry)?);
        }
        Ok(Trace { events })
    }

    /// Save atomically (temp sibling + rename) as pretty-printed
    /// canonical JSON. Traces are written once, at end of run, so a
    /// torn write can never corrupt an existing trace — re-running the
    /// campaign rewrites the whole file (DESIGN.md §11 recovery matrix).
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_json().to_string_pretty())
    }

    /// Load a trace document from disk.
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }

    /// Concatenate traces in order into one document; sequence numbers
    /// are re-derived from the merged positions. Used by
    /// `qadam trace merge` to study a serve batch's tenants side by
    /// side (per-tenant cache-dedupe effectiveness).
    pub fn merge<'a, I>(parts: I) -> Trace
    where
        I: IntoIterator<Item = &'a Trace>,
    {
        let mut merged = Trace::new();
        for part in parts {
            merged.events.extend(part.events.iter().cloned());
        }
        merged
    }

    /// Structural comparison against another trace: lengths and the
    /// first sequence number where the two event streams diverge.
    pub fn diff(&self, other: &Trace) -> TraceDiff {
        let divergence = self
            .events
            .iter()
            .zip(&other.events)
            .position(|(a, b)| a != b)
            .or_else(|| {
                if self.events.len() == other.events.len() {
                    None
                } else {
                    Some(self.events.len().min(other.events.len()))
                }
            });
        TraceDiff { left: self.events.len(), right: other.events.len(), divergence }
    }
}

/// Result of [`Trace::diff`]: where (if anywhere) two traces diverge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceDiff {
    /// Event count of the left-hand trace.
    pub left: usize,
    /// Event count of the right-hand trace.
    pub right: usize,
    /// Sequence number of the first differing event (or, for a shared
    /// prefix, the length of the shorter trace); `None` when identical.
    pub divergence: Option<usize>,
}

impl TraceDiff {
    /// Whether the two traces are event-for-event identical.
    pub fn identical(&self) -> bool {
        self.divergence.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{obj, s};

    fn sample() -> Trace {
        let mut trace = Trace::new();
        trace.push(TraceEvent::ServeBegin { campaigns: 2 });
        trace.push(TraceEvent::ServeTransition {
            index: 0,
            fingerprint: 0xabc,
            state: "queued".into(),
            detail: String::new(),
        });
        trace.push(TraceEvent::ServeEnd { done: 2, failed: 0, skipped: 0 });
        trace
    }

    #[test]
    fn document_round_trips_to_a_fixed_point() {
        let trace = sample();
        let text = trace.to_json().to_string_pretty();
        let back = Trace::from_json(&Json::parse(&text).expect("parse")).expect("from_json");
        assert_eq!(trace, back);
        assert_eq!(text, back.to_json().to_string_pretty());
    }

    #[test]
    fn sparse_or_shuffled_seq_is_rejected() {
        let trace = sample();
        let mut json = trace.to_json();
        if let Json::Obj(map) = &mut json {
            if let Some(Json::Arr(events)) = map.get_mut("events") {
                if let Json::Obj(event) = &mut events[1] {
                    event.insert("seq".to_string(), num(5.0));
                }
            }
        }
        assert!(Trace::from_json(&json).is_err());
    }

    #[test]
    fn wrong_schema_version_is_rejected() {
        let mut json = sample().to_json();
        if let Json::Obj(map) = &mut json {
            map.insert("schema".to_string(), num(2.0));
        }
        let err = Trace::from_json(&json);
        assert!(err.is_err(), "schema 2 must not parse as schema {TRACE_SCHEMA}");
        let wrong_kind = obj(vec![("kind", s("qadam.evaldb")), ("schema", num(1.0))]);
        assert!(Trace::from_json(&wrong_kind).is_err());
    }

    #[test]
    fn merge_concatenates_and_diff_localizes() {
        let a = sample();
        let merged = Trace::merge([&a, &a]);
        assert_eq!(merged.len(), 2 * a.len());
        // Re-derived seqs stay dense: the merged doc round-trips.
        let back = Trace::from_json(&merged.to_json()).expect("merged round trip");
        assert_eq!(merged, back);

        assert!(a.diff(&a).identical());
        let mut b = sample();
        b.push(TraceEvent::ServeEnd { done: 1, failed: 1, skipped: 0 });
        let diff = a.diff(&b);
        assert_eq!(diff.divergence, Some(a.len()));
        let mut c = sample();
        c.events[1] = TraceEvent::ServeBegin { campaigns: 9 };
        assert_eq!(a.diff(&c).divergence, Some(1));
    }

    #[test]
    fn counts_tally_by_kind() {
        let counts = sample().counts();
        assert_eq!(counts.get("serve.begin"), Some(&1));
        assert_eq!(counts.get("serve.transition"), Some(&1));
        assert_eq!(counts.get("serve.end"), Some(&1));
    }
}
