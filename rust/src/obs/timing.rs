//! The `qadam.timing` sidecar: wall-clock samples keyed to trace events
//! by sequence number.
//!
//! This is the nondeterministic half of the observability split. Every
//! recorded event gets one sample — nanoseconds since the recorder was
//! created, plus (for `point.dispatch`) the point's evaluation time —
//! and the document carries the same env-only host metadata policy as
//! `qadam.bench` ([`HostMeta::from_env`]: the env var is the only
//! ambient input). The sidecar is never read by golden or bit-identity
//! checks; it exists solely for `qadam trace show`'s per-phase timing
//! tables. A torn sidecar needs no recovery protocol: re-running the
//! campaign atomically rewrites the whole file.

use std::ffi::OsString;
use std::path::{Path, PathBuf};

use super::trace::Trace;
use crate::bench::HostMeta;
use crate::error::{Error, Result};
use crate::explore::persist::{check_envelope_exact, envelope_at, field_arr, field_usize, write_atomic};
use crate::util::json::{num, obj, Json};
use crate::util::stats::Summary;

/// Artifact kind tag in the `{"kind", "schema"}` envelope.
pub const TIMING_KIND: &str = "qadam.timing";

/// Timing sidecar schema version. History: v1 — per-event nanosecond
/// offsets plus optional per-point evaluation durations.
pub const TIMING_SCHEMA: usize = 1;

/// One wall-clock sample, keyed to a trace event by sequence number.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingSample {
    /// Sequence number of the trace event this sample annotates.
    pub seq: u64,
    /// Nanoseconds since the recorder's origin when the event fired.
    pub at_ns: u64,
    /// For `point.dispatch` events: how long the point's evaluation
    /// took inside the worker (cache hits included — a hit is a fast
    /// evaluation, and the gap is the point of measuring).
    pub eval_ns: Option<u64>,
}

/// The timing sidecar document written next to a saved trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TimingSidecar {
    /// Host metadata (env-only, same policy as `qadam.bench`).
    pub host: HostMeta,
    /// Samples in sequence order, one per recorded event.
    pub samples: Vec<TimingSample>,
}

impl TimingSidecar {
    /// An empty sidecar for the given host.
    pub fn new(host: HostMeta) -> Self {
        Self { host, samples: Vec::new() }
    }

    /// Canonical-JSON document form.
    pub fn to_json(&self) -> Json {
        let mut fields = envelope_at(TIMING_KIND, TIMING_SCHEMA);
        fields.push(("host", self.host.to_json()));
        let samples = self
            .samples
            .iter()
            .map(|sample| {
                let eval = match sample.eval_ns {
                    Some(ns) => num(ns as f64),
                    None => Json::Null,
                };
                obj(vec![
                    ("seq", num(sample.seq as f64)),
                    ("at_ns", num(sample.at_ns as f64)),
                    ("eval_ns", eval),
                ])
            })
            .collect();
        fields.push(("samples", Json::Arr(samples)));
        obj(fields)
    }

    /// Parse a sidecar document, validating the envelope.
    pub fn from_json(json: &Json) -> Result<TimingSidecar> {
        check_envelope_exact(json, TIMING_KIND, TIMING_SCHEMA)?;
        let host = HostMeta::from_json(
            json.get("host")
                .ok_or_else(|| Error::ParseError("missing object field 'host'".into()))?,
        )?;
        let mut samples = Vec::new();
        for entry in field_arr(json, "samples")? {
            let eval_ns = match entry.get("eval_ns") {
                Some(Json::Null) | None => None,
                Some(value) => Some(value.as_f64().filter(|v| *v >= 0.0).ok_or_else(|| {
                    Error::ParseError("timing sample eval_ns is not a number".into())
                })? as u64),
            };
            samples.push(TimingSample {
                seq: field_usize(entry, "seq")? as u64,
                at_ns: field_usize(entry, "at_ns")? as u64,
                eval_ns,
            });
        }
        Ok(TimingSidecar { host, samples })
    }

    /// Save atomically as pretty-printed canonical JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_json().to_string_pretty())
    }

    /// Load a sidecar document from disk.
    pub fn load(path: &Path) -> Result<TimingSidecar> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }

    /// Per-phase wall-clock breakdown against the trace the sidecar was
    /// recorded for. Each event is charged the gap since the previous
    /// sample (the recorder is single-threaded at emission, so gaps
    /// partition the run); `point.dispatch` evaluation durations are
    /// additionally summarized under the synthetic `evaluate` phase.
    /// Samples whose seq falls outside the trace are ignored — that
    /// only happens when show is pointed at a mismatched pair.
    pub fn phase_summaries(&self, trace: &Trace) -> Vec<PhaseSummary> {
        let mut per_phase: std::collections::BTreeMap<&'static str, Vec<f64>> = Default::default();
        let mut prev_ns = 0u64;
        for sample in &self.samples {
            let Some(event) = trace.events().get(sample.seq as usize) else {
                continue;
            };
            let gap_ms = sample.at_ns.saturating_sub(prev_ns) as f64 / 1e6;
            prev_ns = sample.at_ns;
            per_phase.entry(event.phase()).or_default().push(gap_ms);
            if let Some(eval_ns) = sample.eval_ns {
                per_phase.entry("evaluate").or_default().push(eval_ns as f64 / 1e6);
            }
        }
        per_phase
            .into_iter()
            .map(|(phase, gaps_ms)| PhaseSummary {
                phase: phase.to_string(),
                events: gaps_ms.len(),
                total_ms: gaps_ms.iter().sum(),
                summary: Summary::of(&gaps_ms),
            })
            .collect()
    }
}

/// One row of the per-phase timing table: total wall-clock charged to a
/// phase plus the distribution of per-event gaps (milliseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSummary {
    /// Phase label ([`TraceEvent::phase`](super::TraceEvent::phase), or
    /// the synthetic `evaluate` phase for per-point evaluation times).
    pub phase: String,
    /// Samples charged to this phase.
    pub events: usize,
    /// Total milliseconds charged to this phase.
    pub total_ms: f64,
    /// Distribution of per-event milliseconds.
    pub summary: Summary,
}

/// The timing sidecar's on-disk location for a given trace path: the
/// full trace filename with `.timing` appended (`trace.json` →
/// `trace.json.timing`), the same sibling-suffix convention
/// `write_atomic` uses for its temp files.
pub fn sidecar_path(trace: &Path) -> PathBuf {
    let mut path = OsString::from(trace.as_os_str());
    path.push(".timing");
    PathBuf::from(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TraceEvent;

    fn sample() -> TimingSidecar {
        let mut sidecar = TimingSidecar::new(HostMeta::with_label("test-host"));
        sidecar.samples.push(TimingSample { seq: 0, at_ns: 10, eval_ns: None });
        sidecar.samples.push(TimingSample { seq: 1, at_ns: 25, eval_ns: Some(12) });
        sidecar
    }

    #[test]
    fn sidecar_round_trips_to_a_fixed_point() {
        let sidecar = sample();
        let text = sidecar.to_json().to_string_pretty();
        let back =
            TimingSidecar::from_json(&Json::parse(&text).expect("parse")).expect("from_json");
        assert_eq!(sidecar, back);
        assert_eq!(text, back.to_json().to_string_pretty());
    }

    #[test]
    fn sidecar_path_appends_to_the_full_filename() {
        assert_eq!(
            sidecar_path(Path::new("out/trace.json")),
            PathBuf::from("out/trace.json.timing")
        );
    }

    #[test]
    fn phase_summaries_charge_gaps_and_evaluations() {
        let mut trace = Trace::new();
        trace.push(TraceEvent::ServeBegin { campaigns: 1 });
        trace.push(TraceEvent::PointDispatch { pos: 0, index: 0 });
        let rows = sample().phase_summaries(&trace);
        let phases: Vec<&str> = rows.iter().map(|r| r.phase.as_str()).collect();
        assert_eq!(phases, vec!["evaluate", "point", "serve"]);
        let point = rows.iter().find(|r| r.phase == "point").expect("point row");
        // Second sample at 25ns, first at 10ns: the point event is
        // charged the 15ns gap.
        assert!((point.total_ms - 15.0 / 1e6).abs() < 1e-12);
    }

    #[test]
    fn wrong_envelope_is_rejected() {
        let mut json = sample().to_json();
        if let Json::Obj(map) = &mut json {
            map.insert("schema".to_string(), num(2.0));
        }
        assert!(TimingSidecar::from_json(&json).is_err());
    }
}
