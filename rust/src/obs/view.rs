//! Terminal renderings for saved traces: `qadam trace show|merge|diff`.
//!
//! Everything here is read-only presentation over [`Trace`] /
//! [`TimingSidecar`] documents — per-phase timing breakdowns, the
//! strategy funnel, cache effectiveness, and per-tenant dedupe tables
//! for merged serve batches.

use std::collections::BTreeSet;

use super::event::TraceEvent;
use super::timing::TimingSidecar;
use super::trace::Trace;
use crate::util::table::Table;

/// Percentage rendering shared by the cache and dedupe tables.
fn percent(part: u64, whole: u64) -> String {
    if whole == 0 {
        "-".to_string()
    } else {
        format!("{:.1}%", 100.0 * part as f64 / whole as f64)
    }
}

/// Render one trace: header, event tallies, strategy funnel, cache and
/// frontier effectiveness, serve phase summary, and (when the timing
/// sidecar is supplied) the per-phase wall-clock table.
pub fn render_show(trace: &Trace, timing: Option<&TimingSidecar>) -> String {
    let mut out = String::new();
    match trace.events().first() {
        Some(TraceEvent::CampaignBegin {
            space_fingerprint,
            seed,
            shard,
            num_shards,
            strategy,
            total,
            models,
            variants,
            ..
        }) => {
            out.push_str(&format!(
                "campaign space {space_fingerprint:016x} seed {seed} shard {shard}/{num_shards} \
                 strategy {strategy}\n{total} design points x {models} models ({variants} model \
                 variant(s)), {} events\n",
                trace.len()
            ));
        }
        Some(TraceEvent::ServeBegin { campaigns }) => {
            out.push_str(&format!(
                "serve batch: {campaigns} campaign(s), {} events\n",
                trace.len()
            ));
        }
        _ => out.push_str(&format!("trace: {} events\n", trace.len())),
    }

    let mut events = Table::new(&["event", "count"]);
    for (kind, count) in trace.counts() {
        events.row(&[kind.to_string(), count.to_string()]);
    }
    if !events.is_empty() {
        out.push('\n');
        out.push_str(&events.render());
    }

    let mut funnel = Table::new(&["round", "entered", "kept", "pruned"]);
    for event in trace.events() {
        if let TraceEvent::StrategyRound { round, entered, kept } = event {
            funnel.row(&[
                round.to_string(),
                entered.to_string(),
                kept.to_string(),
                entered.saturating_sub(*kept).to_string(),
            ]);
        }
    }
    if !funnel.is_empty() {
        out.push_str("\nstrategy funnel\n");
        out.push_str(&funnel.render());
    }
    for event in trace.events() {
        if let TraceEvent::StrategySelect { descriptor, selected, positions } = event {
            out.push_str(&format!(
                "selection: {descriptor} kept {selected} of {positions} positions\n"
            ));
        }
    }

    let (mut hits, mut misses) = (0u64, 0u64);
    let mut outcome_tally: [u64; 4] = [0; 4];
    for event in trace.events() {
        match event {
            TraceEvent::CacheHit { .. } => hits += 1,
            TraceEvent::CacheMiss { .. } => misses += 1,
            TraceEvent::FrontierObserve { outcomes, .. } => {
                for outcome in outcomes {
                    outcome_tally[*outcome as usize] += 1;
                }
            }
            _ => {}
        }
    }
    if hits + misses > 0 {
        out.push_str(&format!(
            "\ncache: {hits} hits / {misses} misses ({} hit rate)\n",
            percent(hits, hits + misses)
        ));
    }
    if outcome_tally.iter().any(|n| *n > 0) {
        out.push_str(&format!(
            "frontier inserts: {} added, {} dominated, {} evicted, {} invalid\n",
            outcome_tally[0], outcome_tally[1], outcome_tally[2], outcome_tally[3]
        ));
    }
    for event in trace.events() {
        if let TraceEvent::CampaignEnd { points, evaluations, fronts, .. } = event {
            let fronts: Vec<String> = fronts.iter().map(|n| n.to_string()).collect();
            out.push_str(&format!(
                "end: {points} points, {evaluations} evaluations, front sizes [{}]\n",
                fronts.join(", ")
            ));
        }
    }

    let mut states = Table::new(&["campaign", "state", "detail"]);
    let mut serve_end = None;
    for event in trace.events() {
        match event {
            TraceEvent::ServeTransition { fingerprint, state, detail, .. } => {
                states.row(&[format!("{fingerprint:016x}"), state.clone(), detail.clone()]);
            }
            TraceEvent::ServeEnd { done, failed, skipped } => {
                serve_end = Some((done, failed, skipped));
            }
            _ => {}
        }
    }
    if !states.is_empty() {
        out.push_str("\nserve transitions\n");
        out.push_str(&states.render());
    }
    if let Some((done, failed, skipped)) = serve_end {
        out.push_str(&format!("serve: {done} done, {failed} failed, {skipped} skipped\n"));
    }

    match timing {
        Some(sidecar) => {
            let mut table = Table::new(&["phase", "events", "total_ms", "p50_ms", "p95_ms", "max_ms"]);
            for row in sidecar.phase_summaries(trace) {
                table.row(&[
                    row.phase.clone(),
                    row.events.to_string(),
                    format!("{:.3}", row.total_ms),
                    format!("{:.4}", row.summary.p50),
                    format!("{:.4}", row.summary.p95),
                    format!("{:.4}", row.summary.max),
                ]);
            }
            if !table.is_empty() {
                out.push_str(&format!("\ntiming ({} on {}/{})\n", sidecar.host.label, sidecar.host.os, sidecar.host.arch));
                out.push_str(&table.render());
            }
        }
        None => out.push_str("\n(no timing sidecar: deterministic trace only)\n"),
    }
    out
}

/// Render the per-tenant dedupe table for a set of traces merged in
/// order — for each tenant, how many of its cache keys were already
/// touched by an earlier tenant (the shared-cache effectiveness a serve
/// batch gets from ordering that tenant later).
pub fn render_merge(tenants: &[(String, Trace)]) -> String {
    let mut out = String::new();
    let mut table = Table::new(&["tenant", "points", "keys", "hits", "misses", "shared_earlier", "dedupe"]);
    let mut earlier: BTreeSet<u64> = BTreeSet::new();
    for (label, trace) in tenants {
        let mut keys: BTreeSet<u64> = BTreeSet::new();
        let (mut points, mut hits, mut misses) = (0u64, 0u64, 0u64);
        for event in trace.events() {
            match event {
                TraceEvent::PointDeliver { .. } => points += 1,
                TraceEvent::CacheHit { key, .. } => {
                    hits += 1;
                    keys.insert(*key);
                }
                TraceEvent::CacheMiss { key, .. } => {
                    misses += 1;
                    keys.insert(*key);
                }
                _ => {}
            }
        }
        let shared = keys.iter().filter(|key| earlier.contains(key)).count() as u64;
        table.row(&[
            label.clone(),
            points.to_string(),
            keys.len().to_string(),
            hits.to_string(),
            misses.to_string(),
            shared.to_string(),
            percent(shared, keys.len() as u64),
        ]);
        earlier.extend(keys);
    }
    out.push_str(&table.render());
    out
}

/// Render the comparison of two traces: identical, or the lengths plus
/// the first divergent event from each side.
pub fn render_diff(left_name: &str, right_name: &str, left: &Trace, right: &Trace) -> String {
    let diff = left.diff(right);
    let Some(seq) = diff.divergence else {
        return format!("traces identical ({} events)\n", diff.left);
    };
    let mut out = format!(
        "traces diverge at seq {seq} ({left_name}: {} events, {right_name}: {} events)\n",
        diff.left, diff.right
    );
    for (name, trace) in [(left_name, left), (right_name, right)] {
        match trace.events().get(seq) {
            Some(event) => out.push_str(&format!(
                "  {name}: {}\n",
                event.to_json().to_string_canonical()
            )),
            None => out.push_str(&format!("  {name}: (no event at seq {seq})\n")),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn campaign_trace() -> Trace {
        let mut trace = Trace::new();
        trace.push(TraceEvent::CampaignBegin {
            fingerprint: None,
            space_fingerprint: 0xbeef,
            seed: 7,
            shard: 0,
            num_shards: 1,
            strategy: "halving(keep=2, rounds=1)".into(),
            total: 2,
            models: 1,
            variants: 1,
        });
        trace.push(TraceEvent::StrategyRound { round: 0, entered: 4, kept: 2 });
        trace.push(TraceEvent::StrategySelect {
            descriptor: "halving(keep=2, rounds=1)".into(),
            selected: 2,
            positions: 4,
        });
        for pos in 0..2usize {
            trace.push(TraceEvent::PointDispatch { pos, index: pos });
            trace.push(if pos == 0 {
                TraceEvent::CacheMiss { pos, key: 0x10 + pos as u64 }
            } else {
                TraceEvent::CacheHit { pos, key: 0x10 + pos as u64 }
            });
            trace.push(TraceEvent::PointDeliver { pos, index: pos });
        }
        trace.push(TraceEvent::CampaignEnd {
            points: 2,
            evaluations: 2,
            cache_hits: 1,
            cache_misses: 1,
            fronts: vec![2],
        });
        trace
    }

    #[test]
    fn show_renders_funnel_cache_and_header() {
        let text = render_show(&campaign_trace(), None);
        assert!(text.contains("strategy funnel"), "funnel missing:\n{text}");
        assert!(text.contains("1 hits / 1 misses (50.0% hit rate)"), "cache line missing:\n{text}");
        assert!(text.contains("campaign space 000000000000beef"), "header missing:\n{text}");
        assert!(text.contains("no timing sidecar"), "sidecar note missing:\n{text}");
    }

    #[test]
    fn merge_table_reports_shared_keys() {
        let a = campaign_trace();
        let b = campaign_trace();
        let text = render_merge(&[("a".into(), a), ("b".into(), b)]);
        // Tenant b touches exactly the keys tenant a did: 100% dedupe.
        assert!(text.contains("100.0%"), "dedupe column missing:\n{text}");
    }

    #[test]
    fn diff_renders_identity_and_divergence() {
        let a = campaign_trace();
        assert!(render_diff("a", "b", &a, &a).contains("traces identical"));
        let mut b = campaign_trace();
        b.push(TraceEvent::ServeEnd { done: 0, failed: 0, skipped: 0 });
        let text = render_diff("a", "b", &a, &b);
        assert!(text.contains("diverge at seq"), "divergence missing:\n{text}");
    }
}
