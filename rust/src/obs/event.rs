//! The trace event taxonomy: every typed record a [`Trace`] can hold.
//!
//! Events capture the *logical* schedule of a campaign or serve batch —
//! which design point was dispatched, whether its cache probe hit, what
//! the frontier did with its evaluations — and deliberately nothing
//! about when. Wall-clock data lives in the `qadam.timing` sidecar
//! (see [`crate::obs::timing`]), keyed back to events by sequence
//! number, so the trace itself stays byte-identical across runs,
//! worker counts, and kill/resume (DESIGN.md §11).
//!
//! [`Trace`]: crate::obs::Trace

use crate::error::{Error, Result};
use crate::explore::persist::{field_arr, field_str, field_u64_hex, field_usize, hex};
use crate::pareto::InsertOutcome;
use crate::util::json::{num, obj, s, Json};

/// One typed record in a deterministic event trace.
///
/// Wire form is a canonical-JSON object tagged by `"ev"` (see
/// [`TraceEvent::kind`]); the dense sequence number is supplied by the
/// enclosing [`Trace`](crate::obs::Trace) document, not the event.
/// 64-bit identifiers (fingerprints, seeds, cache keys) serialize as
/// 16-digit lowercase hex strings, the same convention the checkpoint
/// manifest and serve status journal use.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A campaign started streaming: identity and shape, recorded after
    /// strategy selection fixed the number of points to evaluate.
    CampaignBegin {
        /// QSL campaign-spec fingerprint, when the campaign came from a
        /// spec (`None` for direct [`Explorer`](crate::Explorer) use).
        fingerprint: Option<u64>,
        /// Joint design-space fingerprint (sweep axes + model axes).
        space_fingerprint: u64,
        /// Campaign RNG seed.
        seed: u64,
        /// This campaign's shard index.
        shard: usize,
        /// Total number of shards the space is partitioned into.
        num_shards: usize,
        /// Search-strategy descriptor, e.g. `halving(keep=8, rounds=3)`.
        strategy: String,
        /// Design points selected for evaluation in this shard.
        total: usize,
        /// Workload models evaluated per design point.
        models: usize,
        /// Scaled model variants in the joint space.
        variants: usize,
    },
    /// One pruning round inside a multi-round search strategy.
    StrategyRound {
        /// Round index, starting at 0.
        round: usize,
        /// Candidate positions entering the round.
        entered: usize,
        /// Positions surviving the round's cut.
        kept: usize,
    },
    /// Strategy selection finished: the funnel's final shape.
    StrategySelect {
        /// Strategy descriptor (matches `campaign.begin`).
        descriptor: String,
        /// Positions selected for full evaluation.
        selected: usize,
        /// Positions the shard offered the strategy.
        positions: usize,
    },
    /// A design point entered evaluation (worker dispatch order is
    /// nondeterministic, so this is recorded in delivery order — the
    /// trace pins the *logical* schedule, not thread interleaving).
    PointDispatch {
        /// Dense stream position within this campaign.
        pos: usize,
        /// Global joint-space index of the design point.
        index: usize,
    },
    /// The point cache already held this design point's evaluations.
    CacheHit {
        /// Dense stream position within this campaign.
        pos: usize,
        /// Content-addressed point key (config + seed + workloads).
        key: u64,
    },
    /// The point cache missed; the point was evaluated from scratch.
    CacheMiss {
        /// Dense stream position within this campaign.
        pos: usize,
        /// Content-addressed point key (config + seed + workloads).
        key: u64,
    },
    /// The streaming frontier ingested one point's evaluations.
    FrontierObserve {
        /// Dense stream position within this campaign.
        pos: usize,
        /// Per-model insertion outcome, in workload-model order.
        outcomes: Vec<InsertOutcome>,
    },
    /// A design point's evaluations were delivered in order.
    PointDeliver {
        /// Dense stream position within this campaign.
        pos: usize,
        /// Global joint-space index of the design point.
        index: usize,
    },
    /// The checkpoint journal's logical flush schedule reached a
    /// boundary: every point below `upto` is durable. Recorded as a
    /// pure function of the flush interval so it is identical across
    /// kill/resume, where *physical* flush offsets shift.
    JournalFlush {
        /// Number of points covered by this flush.
        upto: usize,
    },
    /// The campaign finished; end-of-run aggregates.
    CampaignEnd {
        /// Design points evaluated (equals `campaign.begin` total).
        points: usize,
        /// Model evaluations produced (`points x models`).
        evaluations: usize,
        /// Cache hits observed during this run.
        cache_hits: u64,
        /// Cache misses observed during this run.
        cache_misses: u64,
        /// Final per-model Pareto-front sizes, in model order (empty
        /// when no frontier was attached).
        fronts: Vec<usize>,
    },
    /// A serve batch started.
    ServeBegin {
        /// Campaigns admitted to the batch queue.
        campaigns: usize,
    },
    /// One campaign state transition in the serve status journal —
    /// the same record `serve.status.json` appends, so the trace and
    /// the status journal can be cross-checked event for event.
    ServeTransition {
        /// Queue position of the campaign.
        index: usize,
        /// Campaign-spec fingerprint.
        fingerprint: u64,
        /// New state label (`queued`, `linted`, `skipped`, `running`,
        /// `done`, `failed`).
        state: String,
        /// Human-readable transition detail (may be empty).
        detail: String,
    },
    /// The shared batch cache was persisted after a campaign finished.
    ServeCacheSave {
        /// Queue position of the campaign whose results were folded in.
        index: usize,
        /// Design points in the shared cache after the save.
        entries: usize,
        /// Cache save-generation counter after the save.
        generation: u64,
    },
    /// The serve batch finished; terminal-state tallies.
    ServeEnd {
        /// Campaigns that completed successfully.
        done: usize,
        /// Campaigns that failed.
        failed: usize,
        /// Campaigns skipped pre-flight (duplicate or lint-denied).
        skipped: usize,
    },
}

impl TraceEvent {
    /// The wire tag (`"ev"` field) identifying this event kind.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::CampaignBegin { .. } => "campaign.begin",
            TraceEvent::StrategyRound { .. } => "strategy.round",
            TraceEvent::StrategySelect { .. } => "strategy.select",
            TraceEvent::PointDispatch { .. } => "point.dispatch",
            TraceEvent::CacheHit { .. } => "cache.hit",
            TraceEvent::CacheMiss { .. } => "cache.miss",
            TraceEvent::FrontierObserve { .. } => "frontier.observe",
            TraceEvent::PointDeliver { .. } => "point.deliver",
            TraceEvent::JournalFlush { .. } => "journal.flush",
            TraceEvent::CampaignEnd { .. } => "campaign.end",
            TraceEvent::ServeBegin { .. } => "serve.begin",
            TraceEvent::ServeTransition { .. } => "serve.transition",
            TraceEvent::ServeCacheSave { .. } => "serve.cache_save",
            TraceEvent::ServeEnd { .. } => "serve.end",
        }
    }

    /// Coarse phase label used to group timing-sidecar samples into
    /// per-phase histograms (`qadam trace show`).
    pub fn phase(&self) -> &'static str {
        match self {
            TraceEvent::CampaignBegin { .. } | TraceEvent::CampaignEnd { .. } => "campaign",
            TraceEvent::StrategyRound { .. } | TraceEvent::StrategySelect { .. } => "strategy",
            TraceEvent::PointDispatch { .. } | TraceEvent::PointDeliver { .. } => "point",
            TraceEvent::CacheHit { .. } | TraceEvent::CacheMiss { .. } => "cache",
            TraceEvent::FrontierObserve { .. } => "frontier",
            TraceEvent::JournalFlush { .. } => "journal",
            TraceEvent::ServeBegin { .. }
            | TraceEvent::ServeTransition { .. }
            | TraceEvent::ServeCacheSave { .. }
            | TraceEvent::ServeEnd { .. } => "serve",
        }
    }

    /// The live stderr line `qadam serve` streams for this event, if it
    /// is one of the serve progress events (`None` otherwise). Sourced
    /// from the same values the trace records, so the stream and the
    /// saved trace can never disagree.
    pub fn announce(&self) -> Option<String> {
        match self {
            TraceEvent::ServeTransition { fingerprint, state, detail, .. } => {
                if detail.is_empty() {
                    Some(format!("serve: [{}] {state}", hex(*fingerprint)))
                } else {
                    Some(format!("serve: [{}] {state} - {detail}", hex(*fingerprint)))
                }
            }
            TraceEvent::ServeCacheSave { entries, generation, .. } => Some(format!(
                "serve: shared cache saved ({entries} design points, generation {generation})"
            )),
            _ => None,
        }
    }

    /// Canonical-JSON wire form (without the enclosing `seq` field,
    /// which the [`Trace`](crate::obs::Trace) document derives from the
    /// event's position).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("ev", s(self.kind()))];
        match self {
            TraceEvent::CampaignBegin {
                fingerprint,
                space_fingerprint,
                seed,
                shard,
                num_shards,
                strategy,
                total,
                models,
                variants,
            } => {
                let fp = match fingerprint {
                    Some(fp) => s(&hex(*fp)),
                    None => Json::Null,
                };
                fields.push(("fingerprint", fp));
                fields.push(("space_fingerprint", s(&hex(*space_fingerprint))));
                fields.push(("seed", s(&hex(*seed))));
                fields.push(("shard", num(*shard as f64)));
                fields.push(("num_shards", num(*num_shards as f64)));
                fields.push(("strategy", s(strategy)));
                fields.push(("total", num(*total as f64)));
                fields.push(("models", num(*models as f64)));
                fields.push(("variants", num(*variants as f64)));
            }
            TraceEvent::StrategyRound { round, entered, kept } => {
                fields.push(("round", num(*round as f64)));
                fields.push(("entered", num(*entered as f64)));
                fields.push(("kept", num(*kept as f64)));
            }
            TraceEvent::StrategySelect { descriptor, selected, positions } => {
                fields.push(("descriptor", s(descriptor)));
                fields.push(("selected", num(*selected as f64)));
                fields.push(("positions", num(*positions as f64)));
            }
            TraceEvent::PointDispatch { pos, index } | TraceEvent::PointDeliver { pos, index } => {
                fields.push(("pos", num(*pos as f64)));
                fields.push(("index", num(*index as f64)));
            }
            TraceEvent::CacheHit { pos, key } | TraceEvent::CacheMiss { pos, key } => {
                fields.push(("pos", num(*pos as f64)));
                fields.push(("key", s(&hex(*key))));
            }
            TraceEvent::FrontierObserve { pos, outcomes } => {
                fields.push(("pos", num(*pos as f64)));
                let labels = outcomes.iter().map(|o| s(o.label())).collect();
                fields.push(("outcomes", Json::Arr(labels)));
            }
            TraceEvent::JournalFlush { upto } => {
                fields.push(("upto", num(*upto as f64)));
            }
            TraceEvent::CampaignEnd { points, evaluations, cache_hits, cache_misses, fronts } => {
                fields.push(("points", num(*points as f64)));
                fields.push(("evaluations", num(*evaluations as f64)));
                fields.push(("cache_hits", num(*cache_hits as f64)));
                fields.push(("cache_misses", num(*cache_misses as f64)));
                let sizes = fronts.iter().map(|n| num(*n as f64)).collect();
                fields.push(("fronts", Json::Arr(sizes)));
            }
            TraceEvent::ServeBegin { campaigns } => {
                fields.push(("campaigns", num(*campaigns as f64)));
            }
            TraceEvent::ServeTransition { index, fingerprint, state, detail } => {
                fields.push(("index", num(*index as f64)));
                fields.push(("fingerprint", s(&hex(*fingerprint))));
                fields.push(("state", s(state)));
                fields.push(("detail", s(detail)));
            }
            TraceEvent::ServeCacheSave { index, entries, generation } => {
                fields.push(("index", num(*index as f64)));
                fields.push(("entries", num(*entries as f64)));
                fields.push(("generation", num(*generation as f64)));
            }
            TraceEvent::ServeEnd { done, failed, skipped } => {
                fields.push(("done", num(*done as f64)));
                fields.push(("failed", num(*failed as f64)));
                fields.push(("skipped", num(*skipped as f64)));
            }
        }
        obj(fields)
    }

    /// Parse one event from its wire form, dispatching on the `"ev"`
    /// tag. Unknown tags are a [`ParseError`](Error::ParseError): the
    /// trace schema is versioned as a whole, not per event.
    pub fn from_json(json: &Json) -> Result<TraceEvent> {
        let kind = field_str(json, "ev")?;
        let event = match kind {
            "campaign.begin" => {
                let fingerprint = match json.get("fingerprint") {
                    Some(Json::Null) | None => None,
                    Some(_) => Some(field_u64_hex(json, "fingerprint")?),
                };
                TraceEvent::CampaignBegin {
                    fingerprint,
                    space_fingerprint: field_u64_hex(json, "space_fingerprint")?,
                    seed: field_u64_hex(json, "seed")?,
                    shard: field_usize(json, "shard")?,
                    num_shards: field_usize(json, "num_shards")?,
                    strategy: field_str(json, "strategy")?.to_string(),
                    total: field_usize(json, "total")?,
                    models: field_usize(json, "models")?,
                    variants: field_usize(json, "variants")?,
                }
            }
            "strategy.round" => TraceEvent::StrategyRound {
                round: field_usize(json, "round")?,
                entered: field_usize(json, "entered")?,
                kept: field_usize(json, "kept")?,
            },
            "strategy.select" => TraceEvent::StrategySelect {
                descriptor: field_str(json, "descriptor")?.to_string(),
                selected: field_usize(json, "selected")?,
                positions: field_usize(json, "positions")?,
            },
            "point.dispatch" => TraceEvent::PointDispatch {
                pos: field_usize(json, "pos")?,
                index: field_usize(json, "index")?,
            },
            "cache.hit" => TraceEvent::CacheHit {
                pos: field_usize(json, "pos")?,
                key: field_u64_hex(json, "key")?,
            },
            "cache.miss" => TraceEvent::CacheMiss {
                pos: field_usize(json, "pos")?,
                key: field_u64_hex(json, "key")?,
            },
            "frontier.observe" => {
                let mut outcomes = Vec::new();
                for entry in field_arr(json, "outcomes")? {
                    let label = entry.as_str().ok_or_else(|| {
                        Error::ParseError("frontier.observe outcome is not a string".into())
                    })?;
                    let outcome = InsertOutcome::parse(label).ok_or_else(|| {
                        Error::ParseError(format!("unknown frontier insert outcome '{label}'"))
                    })?;
                    outcomes.push(outcome);
                }
                TraceEvent::FrontierObserve { pos: field_usize(json, "pos")?, outcomes }
            }
            "point.deliver" => TraceEvent::PointDeliver {
                pos: field_usize(json, "pos")?,
                index: field_usize(json, "index")?,
            },
            "journal.flush" => TraceEvent::JournalFlush { upto: field_usize(json, "upto")? },
            "campaign.end" => {
                let mut fronts = Vec::new();
                for entry in field_arr(json, "fronts")? {
                    let size = entry.as_i64().filter(|v| *v >= 0).ok_or_else(|| {
                        Error::ParseError("campaign.end front size is not an integer".into())
                    })?;
                    fronts.push(size as usize);
                }
                TraceEvent::CampaignEnd {
                    points: field_usize(json, "points")?,
                    evaluations: field_usize(json, "evaluations")?,
                    cache_hits: field_usize(json, "cache_hits")? as u64,
                    cache_misses: field_usize(json, "cache_misses")? as u64,
                    fronts,
                }
            }
            "serve.begin" => TraceEvent::ServeBegin { campaigns: field_usize(json, "campaigns")? },
            "serve.transition" => TraceEvent::ServeTransition {
                index: field_usize(json, "index")?,
                fingerprint: field_u64_hex(json, "fingerprint")?,
                state: field_str(json, "state")?.to_string(),
                detail: field_str(json, "detail")?.to_string(),
            },
            "serve.cache_save" => TraceEvent::ServeCacheSave {
                index: field_usize(json, "index")?,
                entries: field_usize(json, "entries")?,
                generation: field_usize(json, "generation")? as u64,
            },
            "serve.end" => TraceEvent::ServeEnd {
                done: field_usize(json, "done")?,
                failed: field_usize(json, "failed")?,
                skipped: field_usize(json, "skipped")?,
            },
            other => {
                return Err(Error::ParseError(format!("unknown trace event kind '{other}'")));
            }
        };
        Ok(event)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<TraceEvent> {
        vec![
            TraceEvent::CampaignBegin {
                fingerprint: Some(0xfeed),
                space_fingerprint: 0xbeef,
                seed: 7,
                shard: 0,
                num_shards: 1,
                strategy: "exhaustive".into(),
                total: 4,
                models: 2,
                variants: 1,
            },
            TraceEvent::StrategyRound { round: 0, entered: 16, kept: 8 },
            TraceEvent::StrategySelect { descriptor: "halving(keep=8)".into(), selected: 8, positions: 16 },
            TraceEvent::PointDispatch { pos: 0, index: 3 },
            TraceEvent::CacheHit { pos: 0, key: 0xabc },
            TraceEvent::CacheMiss { pos: 1, key: 0xdef },
            TraceEvent::FrontierObserve {
                pos: 0,
                outcomes: vec![InsertOutcome::Added, InsertOutcome::Dominated],
            },
            TraceEvent::PointDeliver { pos: 0, index: 3 },
            TraceEvent::JournalFlush { upto: 4 },
            TraceEvent::CampaignEnd {
                points: 4,
                evaluations: 8,
                cache_hits: 1,
                cache_misses: 3,
                fronts: vec![2, 3],
            },
            TraceEvent::ServeBegin { campaigns: 3 },
            TraceEvent::ServeTransition {
                index: 1,
                fingerprint: 0x1234,
                state: "running".into(),
                detail: String::new(),
            },
            TraceEvent::ServeCacheSave { index: 1, entries: 12, generation: 4 },
            TraceEvent::ServeEnd { done: 2, failed: 0, skipped: 1 },
        ]
    }

    #[test]
    fn every_event_round_trips_through_json() {
        for event in samples() {
            let json = event.to_json();
            let back = TraceEvent::from_json(&json).expect("round trip");
            assert_eq!(event, back, "round trip for {}", event.kind());
            assert_eq!(json.to_string_canonical(), back.to_json().to_string_canonical());
        }
    }

    #[test]
    fn campaign_begin_without_spec_fingerprint_round_trips() {
        let event = TraceEvent::CampaignBegin {
            fingerprint: None,
            space_fingerprint: 1,
            seed: 2,
            shard: 0,
            num_shards: 1,
            strategy: "exhaustive".into(),
            total: 1,
            models: 1,
            variants: 1,
        };
        let back = TraceEvent::from_json(&event.to_json()).expect("round trip");
        assert_eq!(event, back);
    }

    #[test]
    fn only_serve_progress_events_announce() {
        for event in samples() {
            let expect_line = matches!(
                event,
                TraceEvent::ServeTransition { .. } | TraceEvent::ServeCacheSave { .. }
            );
            assert_eq!(event.announce().is_some(), expect_line, "announce for {}", event.kind());
        }
    }

    #[test]
    fn unknown_event_kind_is_rejected() {
        let json = obj(vec![("ev", s("campaign.warp"))]);
        assert!(TraceEvent::from_json(&json).is_err());
    }
}
