//! Descriptive statistics over `f64` samples.
//!
//! Shared by the synthesis-noise calibration, the PPA regression metrics,
//! the bench harness, and the report generators.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Geometric mean of strictly positive samples; 0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Percentile via linear interpolation on the sorted copy (`p ∈ [0, 100]`).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        super::lerp(sorted[lo], sorted[hi], rank - lo as f64)
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum; NaN-free input assumed.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum; NaN-free input assumed.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Pearson correlation coefficient between paired samples.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Coefficient of determination of predictions vs observations.
pub fn r_squared(observed: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    let m = mean(observed);
    let ss_tot: f64 = observed.iter().map(|y| (y - m).powi(2)).sum();
    let ss_res: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(y, f)| (y - f).powi(2))
        .sum();
    if ss_tot <= 0.0 {
        return if ss_res <= 1e-24 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Mean absolute percentage error (%) of predictions vs observations.
pub fn mape(observed: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    if observed.is_empty() {
        return 0.0;
    }
    let total: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(y, f)| ((y - f) / y.abs().max(1e-30)).abs())
        .sum();
    100.0 * total / observed.len() as f64
}

/// Root-mean-square error.
pub fn rmse(observed: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    if observed.is_empty() {
        return 0.0;
    }
    let ss: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(y, f)| (y - f).powi(2))
        .sum();
    (ss / observed.len() as f64).sqrt()
}

/// Five-number-plus summary used by the bench harness and reports.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a non-empty sample.
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty());
        Self {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: min(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: max(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 10.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_linear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn r_squared_perfect_and_mean_predictor() {
        let obs = [1.0, 2.0, 3.0];
        assert!((r_squared(&obs, &obs) - 1.0).abs() < 1e-12);
        let mean_pred = [2.0, 2.0, 2.0];
        assert!(r_squared(&obs, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn mape_scale() {
        let obs = [100.0, 200.0];
        let pred = [110.0, 180.0];
        assert!((mape(&obs, &pred) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn rmse_simple() {
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.p50 > 49.0 && s.p50 < 52.0);
        assert!(s.p95 > 94.0 && s.p95 < 97.0);
    }
}
