//! Descriptive statistics over `f64` samples.
//!
//! Shared by the synthesis-noise calibration, the PPA regression metrics,
//! the bench harness, and the report generators.
//!
//! Every function here is **total**: degenerate inputs (empty slices,
//! mismatched lengths, non-positive samples for the geometric mean) map to
//! documented sentinel values instead of panicking. These helpers feed
//! canonical-JSON artifacts, so a panic — or worse, a silent NaN — in a
//! release build would either abort a campaign or poison a committed
//! artifact.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Geometric mean of strictly positive samples.
///
/// Total: returns the 0 sentinel for empty input **and** whenever any
/// sample is non-positive or non-finite (where the log-domain mean would
/// otherwise produce NaN/-inf that flows into headline ratios and
/// canonical-JSON artifacts undetected in release builds). A 0 result for
/// ratio-style inputs therefore always signals "not a valid sample set",
/// never a legitimate geometric mean.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| !(x > 0.0) || !x.is_finite()) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Percentile via linear interpolation on the sorted copy.
///
/// Total: returns 0 for an empty slice; `p` is clamped to `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        super::lerp(sorted[lo], sorted[hi], rank - lo as f64)
    }
}

/// Median (50th percentile); 0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum; NaN-free input assumed. 0 for an empty slice.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum; NaN-free input assumed. 0 for an empty slice.
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Pearson correlation coefficient between paired samples.
///
/// Total: pairs up to the shorter input (extra trailing samples on either
/// side are ignored); fewer than 2 pairs or a zero-variance side yields 0.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len().min(ys.len());
    if n < 2 {
        return 0.0;
    }
    let (xs, ys) = (&xs[..n], &ys[..n]);
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Coefficient of determination of predictions vs observations.
///
/// Total: pairs up to the shorter input; an empty pairing yields 1
/// (a vacuously perfect fit, matching the zero-residual branch).
pub fn r_squared(observed: &[f64], predicted: &[f64]) -> f64 {
    let n = observed.len().min(predicted.len());
    let (observed, predicted) = (&observed[..n], &predicted[..n]);
    let m = mean(observed);
    let ss_tot: f64 = observed.iter().map(|y| (y - m).powi(2)).sum();
    let ss_res: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(y, f)| (y - f).powi(2))
        .sum();
    if ss_tot <= 0.0 {
        return if ss_res <= 1e-24 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Mean absolute percentage error (%) of predictions vs observations.
///
/// Total: pairs up to the shorter input; an empty pairing yields 0.
pub fn mape(observed: &[f64], predicted: &[f64]) -> f64 {
    let n = observed.len().min(predicted.len());
    if n == 0 {
        return 0.0;
    }
    let total: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(y, f)| ((y - f) / y.abs().max(1e-30)).abs())
        .sum();
    100.0 * total / n as f64
}

/// Root-mean-square error.
///
/// Total: pairs up to the shorter input; an empty pairing yields 0.
pub fn rmse(observed: &[f64], predicted: &[f64]) -> f64 {
    let n = observed.len().min(predicted.len());
    if n == 0 {
        return 0.0;
    }
    let ss: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(y, f)| (y - f).powi(2))
        .sum();
    (ss / n as f64).sqrt()
}

/// Five-number-plus summary used by the bench harness and reports.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample. Total: an empty sample yields the zeroed
    /// summary (`n == 0`, every statistic 0) — check `n` before trusting
    /// the moments.
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::empty();
        }
        Self {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: min(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: max(xs),
        }
    }

    /// The zeroed summary returned for empty samples.
    pub fn empty() -> Self {
        Self { n: 0, mean: 0.0, stddev: 0.0, min: 0.0, p50: 0.0, p95: 0.0, max: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 10.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_sentinel_on_degenerate_input() {
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(geomean(&[1.0, 0.0, 4.0]), 0.0);
        assert_eq!(geomean(&[1.0, -2.0]), 0.0);
        assert_eq!(geomean(&[1.0, f64::NAN]), 0.0);
        assert_eq!(geomean(&[1.0, f64::INFINITY]), 0.0);
        // The sentinel must never leak NaN.
        assert!(geomean(&[f64::NAN]).is_finite());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_total_on_empty_and_clamped() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median(&[]), 0.0);
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, -10.0), 1.0);
        assert_eq!(percentile(&xs, 250.0), 3.0);
    }

    #[test]
    fn min_max_total_on_empty() {
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn pearson_perfect_linear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn paired_metrics_pair_to_shorter_input() {
        // Extra trailing samples on either side are ignored, not a panic.
        let obs = [1.0, 2.0, 3.0, 100.0];
        let pred = [1.0, 2.0, 3.0];
        assert!((r_squared(&obs, &pred) - 1.0).abs() < 1e-12);
        assert_eq!(rmse(&obs, &pred), 0.0);
        assert_eq!(mape(&obs, &pred), 0.0);
        assert!((pearson(&obs, &pred) - 1.0).abs() < 1e-12);
        // Empty pairings hit the documented sentinels.
        assert_eq!(pearson(&[], &[1.0]), 0.0);
        assert_eq!(r_squared(&[], &[]), 1.0);
        assert_eq!(mape(&[], &[]), 0.0);
        assert_eq!(rmse(&[1.0], &[]), 0.0);
    }

    #[test]
    fn r_squared_perfect_and_mean_predictor() {
        let obs = [1.0, 2.0, 3.0];
        assert!((r_squared(&obs, &obs) - 1.0).abs() < 1e-12);
        let mean_pred = [2.0, 2.0, 2.0];
        assert!(r_squared(&obs, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn mape_scale() {
        let obs = [100.0, 200.0];
        let pred = [110.0, 180.0];
        assert!((mape(&obs, &pred) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn rmse_simple() {
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.p50 > 49.0 && s.p50 < 52.0);
        assert!(s.p95 > 94.0 && s.p95 < 97.0);
    }

    #[test]
    fn summary_of_empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s, Summary::empty());
        assert_eq!(s.n, 0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 0.0);
    }
}
