//! Property-testing mini-framework with shrinking (offline `proptest`
//! substitute).
//!
//! A [`Gen`] produces random values plus *shrink candidates* — simpler
//! variants tried when a counterexample is found, so failures are reported
//! at (locally) minimal inputs. [`check`] runs a property over many random
//! cases and panics with the shrunk counterexample on failure.

use super::rng::Pcg64;

/// A generator of random `T` values with shrinking.
pub struct Gen<T> {
    generate: Box<dyn Fn(&mut Pcg64) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    /// Build a generator from generate + shrink functions.
    pub fn new(
        generate: impl Fn(&mut Pcg64) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Self { generate: Box::new(generate), shrink: Box::new(shrink) }
    }

    /// Generate one value.
    pub fn sample(&self, rng: &mut Pcg64) -> T {
        (self.generate)(rng)
    }

    /// Shrink candidates for a value (simpler-first).
    pub fn shrinks(&self, value: &T) -> Vec<T> {
        (self.shrink)(value)
    }

    /// Map the generated value through `f` (shrinking maps the *source*).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + Clone + 'static) -> Gen<U>
    where
        T: 'static,
    {
        // Keep a paired source value by regenerating: we wrap T generation and
        // shrink T, mapping each candidate. This requires f to be pure.
        let f2 = f.clone();
        let gen_t = std::rc::Rc::new(self);
        let gen_t2 = gen_t.clone();
        Gen::new(
            move |rng| {
                let t = gen_t.sample(rng);
                f(t)
            },
            move |_u| {
                // Without an inverse we cannot shrink through a map; produce a
                // fresh small sample ladder instead (degenerate but sound).
                let _ = &gen_t2;
                let _ = &f2;
                Vec::new()
            },
        )
    }
}

/// Uniform `usize` in `[lo, hi]` with halving-toward-`lo` shrinking.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(
        move |rng| lo + rng.below((hi - lo + 1) as u64) as usize,
        move |&v| {
            // Ladder toward `lo`: big jumps first (lo, v - span/2, v - span/4,
            // ..., v-1) so the shrink loop converges in O(log span) steps.
            let mut out = Vec::new();
            if v > lo {
                out.push(lo);
                let mut delta = (v - lo) / 2;
                while delta > 0 {
                    let candidate = v - delta;
                    if candidate != lo && out.last() != Some(&candidate) {
                        out.push(candidate);
                    }
                    delta /= 2;
                }
            }
            out
        },
    )
}

/// Uniform `f64` in `[lo, hi]`, shrinking toward `lo` and simple values.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    assert!(lo <= hi);
    Gen::new(
        move |rng| rng.uniform(lo, hi),
        move |&v| {
            let mut out = Vec::new();
            if v != lo {
                out.push(lo);
            }
            let mid = (lo + v) / 2.0;
            if mid != v && mid != lo {
                out.push(mid);
            }
            let rounded = v.round();
            if rounded != v && rounded >= lo && rounded <= hi {
                out.push(rounded);
            }
            out
        },
    )
}

/// Vector of values from `inner` with length in `[min_len, max_len]`;
/// shrinks by dropping elements, then shrinking elements.
pub fn vec_of<T: Clone + 'static>(
    inner: Gen<T>,
    min_len: usize,
    max_len: usize,
) -> Gen<Vec<T>> {
    assert!(min_len <= max_len);
    let inner = std::rc::Rc::new(inner);
    let inner2 = inner.clone();
    Gen::new(
        move |rng| {
            let len = min_len + rng.below((max_len - min_len + 1) as u64) as usize;
            (0..len).map(|_| inner.sample(rng)).collect()
        },
        move |v: &Vec<T>| {
            let mut out = Vec::new();
            // Drop halves, then single elements.
            if v.len() > min_len {
                let half = (v.len() / 2).max(min_len);
                out.push(v[..half].to_vec());
                if v.len() - 1 >= min_len {
                    out.push(v[..v.len() - 1].to_vec());
                    out.push(v[1..].to_vec());
                }
            }
            // Shrink one element at a time (first few positions).
            for i in 0..v.len().min(4) {
                for candidate in inner2.shrinks(&v[i]) {
                    let mut copy = v.clone();
                    copy[i] = candidate;
                    out.push(copy);
                }
            }
            out
        },
    )
}

/// Pair generator combining two independent generators.
pub fn pair<A: Clone + 'static, B: Clone + 'static>(ga: Gen<A>, gb: Gen<B>) -> Gen<(A, B)> {
    let ga = std::rc::Rc::new(ga);
    let gb = std::rc::Rc::new(gb);
    let (ga2, gb2) = (ga.clone(), gb.clone());
    Gen::new(
        move |rng| (ga.sample(rng), gb.sample(rng)),
        move |(a, b)| {
            let mut out: Vec<(A, B)> =
                ga2.shrinks(a).into_iter().map(|a2| (a2, b.clone())).collect();
            out.extend(gb2.shrinks(b).into_iter().map(|b2| (a.clone(), b2)));
            out
        },
    )
}

/// One of several fixed choices (no shrinking across choices).
pub fn one_of<T: Clone + 'static>(choices: Vec<T>) -> Gen<T> {
    assert!(!choices.is_empty());
    let shrink_to = choices[0].clone();
    Gen::new(
        move |rng| rng.choose(&choices).clone(),
        move |_| vec![shrink_to.clone()],
    )
}

/// Configuration for [`check`].
#[derive(Debug, Clone)]
pub struct Config {
    /// Random cases to run.
    pub cases: usize,
    /// Generator seed (fixed for reproducible failures).
    pub seed: u64,
    /// Cap on shrink attempts after a failure.
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0x9ADA_2022, max_shrink_steps: 500 }
    }
}

/// Run `property` on `config.cases` random inputs; on failure, shrink and
/// panic with the minimal counterexample found.
// Panicking is the harness's failure channel — it runs inside #[test]s.
#[allow(clippy::panic)]
pub fn check_with<T: Clone + std::fmt::Debug + 'static>(
    config: &Config,
    gen: &Gen<T>,
    property: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg64::new(config.seed);
    for case in 0..config.cases {
        let input = gen.sample(&mut rng);
        if !property(&input) {
            let minimal = shrink_loop(gen, input, &property, config.max_shrink_steps);
            panic!(
                "property failed (case {case}/{}) — minimal counterexample: {minimal:?}",
                config.cases
            );
        }
    }
}

/// [`check_with`] using the default configuration.
pub fn check<T: Clone + std::fmt::Debug + 'static>(gen: &Gen<T>, property: impl Fn(&T) -> bool) {
    check_with(&Config::default(), gen, property)
}

fn shrink_loop<T: Clone + 'static>(
    gen: &Gen<T>,
    mut current: T,
    property: &impl Fn(&T) -> bool,
    max_steps: usize,
) -> T {
    let mut steps = 0;
    'outer: while steps < max_steps {
        for candidate in gen.shrinks(&current) {
            steps += 1;
            if !property(&candidate) {
                current = candidate;
                continue 'outer;
            }
            if steps >= max_steps {
                break 'outer;
            }
        }
        break;
    }
    current
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&usize_in(0, 100), |&v| v <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics() {
        check(&usize_in(0, 1000), |&v| v < 500);
    }

    #[test]
    fn shrinking_reaches_boundary() {
        // Manually drive the shrink loop: property "v < 500" fails at the
        // minimum failing value 500.
        let gen = usize_in(0, 1000);
        let minimal = shrink_loop(&gen, 987, &|&v: &usize| v < 500, 1000);
        assert_eq!(minimal, 500);
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let gen = vec_of(usize_in(0, 9), 2, 5);
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            let v = gen.sample(&mut rng);
            assert!((2..=5).contains(&v.len()));
            assert!(v.iter().all(|&x| x <= 9));
        }
    }

    #[test]
    fn pair_shrinks_both_sides() {
        let gen = pair(usize_in(0, 10), usize_in(0, 10));
        let shrinks = gen.shrinks(&(5, 7));
        assert!(shrinks.iter().any(|&(a, b)| a < 5 && b == 7));
        assert!(shrinks.iter().any(|&(a, b)| a == 5 && b < 7));
    }

    #[test]
    fn one_of_only_yields_choices() {
        let gen = one_of(vec!["a", "b", "c"]);
        let mut rng = Pcg64::new(5);
        for _ in 0..50 {
            assert!(["a", "b", "c"].contains(&gen.sample(&mut rng)));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = usize_in(0, 1_000_000);
        let mut a = Pcg64::new(99);
        let mut b = Pcg64::new(99);
        for _ in 0..20 {
            assert_eq!(gen.sample(&mut a), gen.sample(&mut b));
        }
    }
}
