//! Offline-substitute utility substrates.
//!
//! The build environment has no crates.io access beyond the vendored `xla`
//! dependency closure, so the conventional ecosystem crates are replaced by
//! small, tested, from-scratch implementations (see DESIGN.md §1):
//!
//! * [`rng`]   — PCG64 pseudo-random generator + distributions (for `rand`)
//! * [`stats`] — descriptive statistics and summaries
//! * [`json`]  — JSON parser/writer (for `serde_json`)
//! * [`cli`]   — declarative command-line parser (for `clap`)
//! * [`prop`]  — property-testing mini-framework with shrinking (for `proptest`)
//! * [`table`] — aligned ASCII table and scatter-plot rendering
//! * [`log`]   — leveled stderr logger

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod prop;
pub mod table;
pub mod log;

/// Round `x` up to the next multiple of `m` (`m > 0`).
pub fn ceil_to(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Ceiling division for `usize`.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Linear interpolation between `a` and `b` at parameter `t ∈ [0, 1]`.
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Relative difference `|a - b| / max(|a|, |b|, eps)` — symmetric, safe at 0.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-30);
    (a - b).abs() / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_to_rounds_up() {
        assert_eq!(ceil_to(0, 4), 0);
        assert_eq!(ceil_to(1, 4), 4);
        assert_eq!(ceil_to(4, 4), 4);
        assert_eq!(ceil_to(5, 4), 8);
    }

    #[test]
    fn ceil_div_matches_manual() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(7, 3), 3);
    }

    #[test]
    fn lerp_endpoints() {
        assert_eq!(lerp(2.0, 10.0, 0.0), 2.0);
        assert_eq!(lerp(2.0, 10.0, 1.0), 10.0);
        assert_eq!(lerp(2.0, 10.0, 0.5), 6.0);
    }

    #[test]
    fn rel_diff_symmetric_and_zero_safe() {
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert!((rel_diff(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert_eq!(rel_diff(3.0, 4.0), rel_diff(4.0, 3.0));
    }
}
