//! Offline-substitute utility substrates.
//!
//! The build environment has no crates.io access beyond the vendored `xla`
//! dependency closure, so the conventional ecosystem crates are replaced by
//! small, tested, from-scratch implementations (see DESIGN.md §1):
//!
//! * [`rng`]   — PCG64 pseudo-random generator + distributions (for `rand`)
//! * [`stats`] — descriptive statistics and summaries
//! * [`json`]  — JSON parser/writer (for `serde_json`)
//! * [`cli`]   — declarative command-line parser (for `clap`)
//! * [`prop`]  — property-testing mini-framework with shrinking (for `proptest`)
//! * [`table`] — aligned ASCII table and scatter-plot rendering
//! * [`log`]   — leveled stderr logger
//! * [`text`]  — edit distance + "did you mean" suggestions

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod prop;
pub mod table;
pub mod log;
pub mod text;

/// Round `x` up to the next multiple of `m` (`m > 0`).
pub fn ceil_to(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Ceiling division for `usize`.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Linear interpolation between `a` and `b` at parameter `t ∈ [0, 1]`.
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Relative difference `|a - b| / max(|a|, |b|, eps)` — symmetric, safe at 0.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-30);
    (a - b).abs() / denom
}

/// Streaming 64-bit FNV-1a hasher. Unlike `std::hash`, the digest is
/// stable across platforms, compiler versions, and process runs, so it is
/// safe to persist (sweep fingerprints, point-cache keys).
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    /// Fresh hasher at the FNV offset basis.
    pub fn new() -> Self {
        Self(Self::OFFSET)
    }

    /// Absorb bytes.
    pub fn update(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
        self
    }

    /// Current digest.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot 64-bit FNV-1a hash of a byte string.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut hasher = Fnv64::new();
    hasher.update(bytes);
    hasher.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_to_rounds_up() {
        assert_eq!(ceil_to(0, 4), 0);
        assert_eq!(ceil_to(1, 4), 4);
        assert_eq!(ceil_to(4, 4), 4);
        assert_eq!(ceil_to(5, 4), 8);
    }

    #[test]
    fn ceil_div_matches_manual() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(7, 3), 3);
    }

    #[test]
    fn lerp_endpoints() {
        assert_eq!(lerp(2.0, 10.0, 0.0), 2.0);
        assert_eq!(lerp(2.0, 10.0, 1.0), 10.0);
        assert_eq!(lerp(2.0, 10.0, 0.5), 6.0);
    }

    #[test]
    fn rel_diff_symmetric_and_zero_safe() {
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert!((rel_diff(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert_eq!(rel_diff(3.0, 4.0), rel_diff(4.0, 3.0));
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv_streaming_equals_one_shot() {
        let mut hasher = Fnv64::new();
        hasher.update(b"qadam").update(b"::").update(b"persist");
        assert_eq!(hasher.finish(), fnv1a_64(b"qadam::persist"));
    }

    #[test]
    fn fnv_distinguishes_nearby_inputs() {
        assert_ne!(fnv1a_64(b"seed=7"), fnv1a_64(b"seed=8"));
    }
}
