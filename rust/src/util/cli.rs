//! Declarative command-line parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, defaults,
//! required options, and generated `--help` text. Used by the `qadam` binary
//! and the example drivers.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

/// Argument parsing error (also carries generated help output).
#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    required: bool,
    is_flag: bool,
}

/// A command (or subcommand) description.
#[derive(Debug, Clone)]
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    subs: Vec<Command>,
}

/// Parse result: matched subcommand path and option values.
#[derive(Debug, Clone)]
pub struct Matches {
    /// Subcommand chain, e.g. `["qadam", "dse"]`.
    pub path: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Options the user passed explicitly (vs. seeded defaults).
    explicit: BTreeSet<String>,
    /// Positional arguments left over after options.
    pub positional: Vec<String>,
}

impl Matches {
    /// The matched leaf subcommand name (empty for the root).
    pub fn subcommand(&self) -> &str {
        self.path.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// String value of an option (set or default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required string value; panics with a clear message if missing
    /// (parser guarantees presence for `required` options).
    // Panicking is this accessor's contract: a missing option is a
    // programmer error (undeclared flag), not a user input error.
    #[allow(clippy::panic)]
    pub fn get_str(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("option --{name} missing (declare a default?)"))
    }

    /// Parsed numeric value of an option.
    #[allow(clippy::panic)] // same contract as `get_str`
    pub fn get_f64(&self, name: &str) -> f64 {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("option --{name} is not a number"))
    }

    /// Parsed integer value of an option.
    #[allow(clippy::panic)] // same contract as `get_str`
    pub fn get_usize(&self, name: &str) -> usize {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("option --{name} is not an integer"))
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Whether the user explicitly passed `--name` on the command line
    /// (false when the value merely comes from the declared default) —
    /// lets subcommands reject contradictory flag combinations even for
    /// options that carry defaults.
    pub fn was_set(&self, name: &str) -> bool {
        self.explicit.contains(name)
    }
}

impl Command {
    /// New command with a one-line description.
    pub fn new(name: &str, about: &str) -> Self {
        Self { name: name.into(), about: about.into(), opts: Vec::new(), subs: Vec::new() }
    }

    /// Add an option taking a value, with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            required: false,
            is_flag: false,
        });
        self
    }

    /// Add a required option taking a value.
    pub fn opt_required(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            required: true,
            is_flag: false,
        });
        self
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            required: false,
            is_flag: true,
        });
        self
    }

    /// Add a subcommand.
    pub fn sub(mut self, sub: Command) -> Self {
        self.subs.push(sub);
        self
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} ", self.name, self.about, self.name);
        if !self.subs.is_empty() {
            out.push_str("<SUBCOMMAND> ");
        }
        out.push_str("[OPTIONS]\n");
        if !self.subs.is_empty() {
            out.push_str("\nSUBCOMMANDS:\n");
            for sub in &self.subs {
                out.push_str(&format!("  {:<14} {}\n", sub.name, sub.about));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for opt in &self.opts {
                let left = if opt.is_flag {
                    format!("--{}", opt.name)
                } else if let Some(d) = &opt.default {
                    format!("--{} <v={}>", opt.name, d)
                } else {
                    format!("--{} <v> (required)", opt.name)
                };
                out.push_str(&format!("  {left:<28} {}\n", opt.help));
            }
        }
        out
    }

    /// Parse an argument list (excluding `argv[0]`).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut matches = Matches {
            path: vec![self.name.clone()],
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            explicit: BTreeSet::new(),
            positional: Vec::new(),
        };
        self.parse_into(args, &mut matches)?;
        Ok(matches)
    }

    /// Parse `std::env::args()`, printing help/errors and exiting as needed.
    pub fn parse_or_exit(&self) -> Matches {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(m) => m,
            Err(e) => {
                if e.0 == "help" {
                    println!("{}", self.help());
                    std::process::exit(0);
                }
                eprintln!("error: {e}\n\n{}", self.help());
                std::process::exit(2);
            }
        }
    }

    fn parse_into(&self, args: &[String], matches: &mut Matches) -> Result<(), CliError> {
        // Seed defaults for this command level.
        for opt in &self.opts {
            if let Some(default) = &opt.default {
                matches.values.insert(opt.name.clone(), default.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError("help".into()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_value) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                matches.explicit.insert(name.to_string());
                if spec.is_flag {
                    matches.flags.insert(name.to_string(), true);
                } else {
                    let value = match inline_value {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    matches.values.insert(name.to_string(), value);
                }
            } else if let Some(sub) = self.subs.iter().find(|s| s.name == *arg) {
                matches.path.push(sub.name.clone());
                return sub.parse_into(&args[i + 1..], matches);
            } else {
                matches.positional.push(arg.clone());
            }
            i += 1;
        }
        for opt in &self.opts {
            if opt.required && !matches.values.contains_key(&opt.name) {
                return Err(CliError(format!("missing required option --{}", opt.name)));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("qadam", "test")
            .opt("seed", "42", "rng seed")
            .flag("verbose", "chatty")
            .sub(
                Command::new("dse", "run dse")
                    .opt("model", "resnet20", "dnn model")
                    .opt_required("dataset", "dataset name"),
            )
    }

    #[test]
    fn defaults_apply() {
        let m = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(m.get_str("seed"), "42");
        assert!(!m.flag("verbose"));
        assert_eq!(m.subcommand(), "qadam");
    }

    #[test]
    fn was_set_distinguishes_defaults_from_explicit() {
        let m = cmd().parse(&argv(&[])).unwrap();
        assert!(!m.was_set("seed"), "default must not count as explicitly set");
        let m = cmd().parse(&argv(&["--seed", "7", "--verbose"])).unwrap();
        assert!(m.was_set("seed"));
        assert!(m.was_set("verbose"));
        let m = cmd().parse(&argv(&["dse", "--dataset", "cifar10"])).unwrap();
        assert!(m.was_set("dataset"));
        assert!(!m.was_set("model"), "subcommand default must not count as set");
    }

    #[test]
    fn subcommand_and_values() {
        let m = cmd()
            .parse(&argv(&["dse", "--dataset", "cifar10", "--model=vgg16"]))
            .unwrap();
        assert_eq!(m.subcommand(), "dse");
        assert_eq!(m.get_str("dataset"), "cifar10");
        assert_eq!(m.get_str("model"), "vgg16");
    }

    #[test]
    fn required_enforced() {
        assert!(cmd().parse(&argv(&["dse"])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn flags_and_numbers() {
        let m = cmd().parse(&argv(&["--verbose", "--seed", "7"])).unwrap();
        assert!(m.flag("verbose"));
        assert_eq!(m.get_usize("seed"), 7);
        assert_eq!(m.get_f64("seed"), 7.0);
    }

    #[test]
    fn positional_collected() {
        let m = cmd().parse(&argv(&["extra1", "extra2"])).unwrap();
        assert_eq!(m.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn help_mentions_subcommands_and_options() {
        let help = cmd().help();
        assert!(help.contains("dse"));
        assert!(help.contains("--seed"));
    }
}
