//! Aligned ASCII tables and terminal scatter plots.
//!
//! Every figure-regeneration bench prints both a CSV block (machine
//! readable, diffable against the paper's series) and a terminal rendering
//! through this module.

/// Column-aligned ASCII table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of formatted f64 cells after a label.
    pub fn row_labeled(&mut self, label: &str, values: &[f64]) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format_sig(*v, 4)));
        self.row(&cells)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns and a separator under the header.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric content).
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format with `sig` significant digits (plain notation for sane ranges).
pub fn format_sig(value: f64, sig: usize) -> String {
    if value == 0.0 {
        return "0".to_string();
    }
    if !value.is_finite() {
        return format!("{value}");
    }
    let magnitude = value.abs().log10().floor() as i32;
    if !(-4..=9).contains(&magnitude) {
        return format!("{value:.*e}", sig.saturating_sub(1));
    }
    let decimals = (sig as i32 - 1 - magnitude).max(0) as usize;
    format!("{value:.decimals$}")
}

/// One named series of (x, y) points for a scatter plot.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// Single character plotted for this series.
    pub marker: char,
    /// The series' (x, y) points.
    pub points: Vec<(f64, f64)>,
}

/// Render a multi-series scatter plot on a character grid, with axis labels
/// and an optional log-log transform — enough to eyeball the paper's
/// figures in a terminal.
pub fn scatter(
    title: &str,
    xlabel: &str,
    ylabel: &str,
    series: &[Series],
    width: usize,
    height: usize,
    loglog: bool,
) -> String {
    let tf = |v: f64| if loglog { v.max(1e-12).log10() } else { v };
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|&(x, y)| (tf(x), tf(y))))
        .collect();
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 - x0 < 1e-12 {
        x1 = x0 + 1.0;
    }
    if y1 - y0 < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for &(px, py) in &s.points {
            let (px, py) = (tf(px), tf(py));
            let col = (((px - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let row = (((py - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - row.min(height - 1);
            grid[row][col.min(width - 1)] = s.marker;
        }
    }
    let mut out = format!("{title}\n");
    let legend: Vec<String> =
        series.iter().map(|s| format!("{}={}", s.marker, s.name)).collect();
    out.push_str(&format!("  [{}]{}\n", legend.join(" "), if loglog { " (log-log)" } else { "" }));
    for (i, row) in grid.iter().enumerate() {
        let ylab = if i == 0 {
            format_sig(if loglog { 10f64.powf(y1) } else { y1 }, 3)
        } else if i == height - 1 {
            format_sig(if loglog { 10f64.powf(y0) } else { y0 }, 3)
        } else {
            String::new()
        };
        out.push_str(&format!("{ylab:>9} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>9} +{}+\n{:>9}  {:<w$}{}\n",
        "",
        "-".repeat(width),
        "",
        format_sig(if loglog { 10f64.powf(x0) } else { x0 }, 3),
        format_sig(if loglog { 10f64.powf(x1) } else { x1 }, 3),
        w = width.saturating_sub(8),
    ));
    out.push_str(&format!("{:>9}  x: {xlabel}   y: {ylabel}\n", ""));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let text = t.render();
        assert!(text.contains("name"));
        assert!(text.lines().count() >= 4);
        // All rendered rows same width or less than header+sep line.
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[1].starts_with('-'));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_checks_arity() {
        Table::new(&["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new(&["x", "y"]);
        t.row_labeled("1.5", &[2.5]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("x,y\n"));
    }

    #[test]
    fn format_sig_ranges() {
        assert_eq!(format_sig(0.0, 3), "0");
        assert_eq!(format_sig(1234.0, 4), "1234");
        assert_eq!(format_sig(0.001234, 3), "0.00123");
        assert!(format_sig(1.0e12, 3).contains('e'));
    }

    #[test]
    fn scatter_contains_markers_and_legend() {
        let s = vec![
            Series { name: "a".into(), marker: '*', points: vec![(1.0, 1.0), (2.0, 4.0)] },
            Series { name: "b".into(), marker: 'o', points: vec![(3.0, 2.0)] },
        ];
        let plot = scatter("demo", "x", "y", &s, 40, 10, false);
        assert!(plot.contains('*'));
        assert!(plot.contains('o'));
        assert!(plot.contains("*=a"));
    }

    #[test]
    fn scatter_empty_is_graceful() {
        let plot = scatter("none", "x", "y", &[], 10, 5, true);
        assert!(plot.contains("no data"));
    }
}
