//! Small text utilities: edit distance and "did you mean" suggestions.
//!
//! Shared by every user-facing name boundary — the QSL resolver
//! ([`crate::spec`]), the CLI's dataset/model parsing, and any future
//! typo-tolerant lookup. Matching is case-insensitive and ignores `-`/`_`
//! so `CIFAR-10`, `cifar10`, and `Cifar_10` all land on the same
//! candidate.

/// Levenshtein edit distance between two strings (unit costs), computed
/// over `char`s with a single rolling row — O(|a|·|b|) time, O(|b|) space.
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let mut row: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut prev_diag = row[0];
        row[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let substitute = prev_diag + usize::from(ca != cb);
            prev_diag = row[j + 1];
            row[j + 1] = substitute.min(row[j] + 1).min(prev_diag + 1);
        }
    }
    row[b.len()]
}

/// Normalize a name for fuzzy comparison: lowercase, `-`/`_` stripped.
fn fold(name: &str) -> String {
    name.chars().filter(|c| *c != '-' && *c != '_').collect::<String>().to_lowercase()
}

/// The closest candidate to `input`, if any is close enough to be a
/// plausible typo (edit distance over folded names of at most
/// `max(1, len/3)`). Exact folded matches win outright; ties go to the
/// earlier candidate, so put canonical spellings first.
pub fn did_you_mean<'a, I>(input: &str, candidates: I) -> Option<&'a str>
where
    I: IntoIterator<Item = &'a str>,
{
    let folded_input = fold(input);
    let budget = (folded_input.chars().count() / 3).max(1);
    let mut best: Option<(usize, &'a str)> = None;
    for candidate in candidates {
        let d = edit_distance(&folded_input, &fold(candidate));
        if d == 0 {
            return Some(candidate);
        }
        if d <= budget && best.map_or(true, |(bd, _)| d < bd) {
            best = Some((d, candidate));
        }
    }
    best.map(|(_, c)| c)
}

/// Render a candidate list for an error message: `a, b, c`.
pub fn name_list<'a, I>(candidates: I) -> String
where
    I: IntoIterator<Item = &'a str>,
{
    candidates.into_iter().collect::<Vec<_>>().join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", ""), 3);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("flaw", "lawn"), 2);
        assert_eq!(edit_distance("same", "same"), 0);
    }

    #[test]
    fn suggestions_tolerate_case_and_separators() {
        let names = ["cifar10", "cifar100", "imagenet"];
        assert_eq!(did_you_mean("CIFAR-10", names), Some("cifar10"));
        assert_eq!(did_you_mean("imagnet", names), Some("imagenet"));
        assert_eq!(did_you_mean("cifar11", names), Some("cifar10"));
        assert_eq!(did_you_mean("mnist", names), None);
    }

    #[test]
    fn close_typos_beat_distant_candidates() {
        let names = ["pe_type", "array", "glb_kib", "spad", "dram_gbps", "clock_ghz"];
        assert_eq!(did_you_mean("pe_typ", names), Some("pe_type"));
        assert_eq!(did_you_mean("clocks_ghz", names), Some("clock_ghz"));
        assert_eq!(did_you_mean("zzz", names), None);
    }

    #[test]
    fn name_list_joins() {
        assert_eq!(name_list(["a", "b"]), "a, b");
    }
}
