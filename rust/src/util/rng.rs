//! PCG64 pseudo-random number generator and sampling distributions.
//!
//! Substitutes for the `rand` crate in this offline build. PCG-XSL-RR-128/64
//! (O'Neill 2014): 128-bit LCG state, 64-bit xorshift-rotate output. The
//! generator is deterministic from its seed, which the synthesis-noise model
//! and all tests rely on for reproducibility.

/// PCG-XSL-RR-128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xa02b_df91_5d05_d1a9)
    }

    /// Create a generator with an explicit stream selector; distinct streams
    /// are statistically independent even for equal seeds.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection method (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is undefined");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Standard normal sample (Box–Muller; one value per call, the pair's
    /// second member is discarded to keep the generator stateless-per-call).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal sample with mean `mu` and standard deviation `sigma`.
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal sample: `exp(N(mu, sigma))` — used for synthesis tool noise,
    /// which is multiplicative and right-skewed in practice.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Choose a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut rng = Pcg64::new(9);
        let mut counts = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(10) as usize] += 1;
        }
        let expect = n as f64 / 10.0;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "bin count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn range_inclusive_covers_bounds() {
        let mut rng = Pcg64::new(13);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            let v = rng.range_inclusive(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Pcg64::new(19);
        for _ in 0..1000 {
            assert!(rng.lognormal(0.0, 0.1) > 0.0);
        }
    }
}
