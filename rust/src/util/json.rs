//! Minimal JSON value model, parser, and writer.
//!
//! Substitutes for `serde_json` in this offline build. Supports the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null) plus pretty-printing. Used by the config system, the DSE result
//! dumps, and the report emitters.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// String value.
    Str(String),
    /// Array of values.
    Arr(Vec<Json>),
    /// Object; `BTreeMap` keeps keys sorted for canonical output.
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error with character position where applicable.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Character offset in the input where parsing failed.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Maximum container-nesting depth the parser accepts. Deeper documents
/// fail with a [`JsonError`] instead of overflowing the stack — the
/// recursive-descent parser recurses once per `[`/`{`, so adversarial
/// inputs like `[[[[...` would otherwise crash the process.
pub const MAX_DEPTH: usize = 128;

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    /// Containers nested deeper than [`MAX_DEPTH`] are rejected.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer value, if this is a number exactly representable as i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => Some(*x as i64),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Canonical rendering: compact, object keys in sorted order (the
    /// `BTreeMap` invariant), numbers in their shortest round-trip form.
    /// Two structurally equal values always render to identical bytes, so
    /// this form is safe to hash (sweep fingerprints, point-cache keys)
    /// and to diff across runs.
    pub fn to_string_canonical(&self) -> String {
        self.to_string_compact()
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructor for an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience constructor for a number.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

/// Convenience constructor for a string.
pub fn s(text: &str) -> Json {
    Json::Str(text.to_string())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

pub(crate) fn write_escaped(out: &mut String, text: &str) {
    out.push('"');
    for ch in text.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value(depth + 1)?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs: decode the low half if present.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            // The low half must actually be a low
                            // surrogate; anything else would make the
                            // combination arithmetic overflow (a panic in
                            // debug builds) before `from_u32` could say no.
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let combined = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let slice = &self.bytes[start..start + len];
                    let chunk =
                        std::str::from_utf8(slice).map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            cp = cp * 16
                + match d {
                    b'0'..=b'9' => (d - b'0') as u32,
                    b'a'..=b'f' => (d - b'a' + 10) as u32,
                    b'A'..=b'F' => (d - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        // The scanned range holds only ASCII sign/digit/exponent bytes.
        #[allow(clippy::unwrap_used)]
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("line\nquote\"slash\\tab\t".into());
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"caf\u{e9} \u{1F600}\"").unwrap();
        assert_eq!(v.as_str(), Some("café 😀"));
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = obj(vec![
            ("name", s("qadam")),
            ("dims", Json::Arr(vec![num(16.0), num(16.0)])),
            ("ok", Json::Bool(true)),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(num(5.0).to_string_compact(), "5");
        assert_eq!(num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn canonical_form_is_key_order_independent() {
        let a = Json::parse(r#"{"b": 1, "a": 2}"#).unwrap();
        let b = Json::parse(r#"{"a": 2, "b": 1}"#).unwrap();
        assert_eq!(a.to_string_canonical(), b.to_string_canonical());
        assert_eq!(a.to_string_canonical(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // Just inside the limit parses...
        let ok = format!("{}0{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        // ...one past it errors, and pathological depths stay errors.
        for depth in [MAX_DEPTH + 1, 10_000, 100_000] {
            let text = format!("{}0{}", "[".repeat(depth), "]".repeat(depth));
            let err = Json::parse(&text).unwrap_err();
            assert!(err.msg.contains("nesting"), "depth {depth}: {err}");
            let text = format!("{}{}", r#"{"k":"#.repeat(depth), "0");
            assert!(Json::parse(&text).is_err(), "unclosed objects at depth {depth}");
        }
    }

    #[test]
    fn surrogate_escapes_validate_both_halves() {
        // A valid pair decodes.
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        // A high surrogate followed by a non-low-surrogate escape is an
        // error (previously an arithmetic overflow in debug builds).
        for text in [r#""\ud800A""#, "\"\\ud800\u{0}\"", r#""\ud800\ud800""#, r#""\ud800""#, r#""\udc00""#]
        {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn control_characters_escape_and_round_trip() {
        let all_controls: String = (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        let original = Json::Str(all_controls);
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn canonical_floats_round_trip_exactly() {
        for x in [0.1, 1.0 / 3.0, 2.0f64.powi(-40), 9.87654321e8, -5.5] {
            let text = num(x).to_string_canonical();
            let parsed = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(parsed.to_bits(), x.to_bits(), "{text}");
        }
    }
}
