//! Leveled stderr logger with wall-clock offsets.
//!
//! Level is process-global and settable from the CLI (`--log-level`) or the
//! `QADAM_LOG` environment variable (error|warn|info|debug|trace).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Severity levels, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    /// Parse a level name (case-insensitive); `None` for unknown names.
    pub fn parse(text: &str) -> Option<Level> {
        match text.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-global maximum level.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from `QADAM_LOG` if set.
pub fn init_from_env() {
    if let Ok(text) = std::env::var("QADAM_LOG") {
        if let Some(level) = Level::parse(&text) {
            set_level(level);
        }
    }
}

/// Whether `level` is currently enabled.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

fn start_instant() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Log a preformatted message at `level` (prefer the macros).
pub fn log(level: Level, module: &str, message: &str) {
    if enabled(level) {
        let elapsed = start_instant().elapsed();
        eprintln!(
            "[{:>9.3}s {} {}] {}",
            elapsed.as_secs_f64(),
            level.tag(),
            module,
            message
        );
    }
}

/// Log at INFO.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

/// Log at WARN.
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

/// Log at DEBUG.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn ordering_is_sane() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }
}
