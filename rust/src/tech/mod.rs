//! 45 nm technology models — the FreePDK45 stand-in (DESIGN.md §1).
//!
//! The paper synthesizes designs with Synopsys Design Compiler against the
//! open-source FreePDK45 kit. Neither is available here, so this module
//! provides analytical standard-cell component models **anchored on
//! published 45 nm datapoints** (energy/area table in M. Horowitz,
//! "Computing's energy problem (and what we can do about it)", ISSCC 2014)
//! with textbook scaling laws between the anchors:
//!
//! * integer adder — energy/area ∝ bits, delay ∝ log(bits)
//! * integer multiplier — energy ∝ bits², area ∝ bits^1.8
//! * FP add/mul — interpolated between the fp16/fp32 anchors
//! * barrel shifter — area ∝ bits·log(bits) (mux tree)
//! * registers / register files — linear in bits
//! * SRAM macros — CACTI-style √capacity access energy (see [`sram`])
//!
//! All areas in µm², energies in pJ, delays in ns, leakage in mW.

pub mod sram;

pub use sram::SramMacro;

/// Operating point and global constants of the modeled node.
#[derive(Debug, Clone, Copy)]
pub struct TechNode {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Logic leakage power density (mW per µm²).
    pub logic_leakage_mw_per_um2: f64,
    /// DRAM access energy (pJ per byte transferred).
    pub dram_pj_per_byte: f64,
    /// Wire energy for on-chip NoC traversal (pJ per byte per mm).
    pub wire_pj_per_byte_mm: f64,
}

/// The default 45 nm node used throughout (FreePDK45-like, 0.9 V nominal).
pub const NODE_45NM: TechNode = TechNode {
    vdd: 0.9,
    logic_leakage_mw_per_um2: 1.0e-7, // 0.1 nW/µm²
    dram_pj_per_byte: 160.0,          // ~1.3 nJ / 64-bit access
    wire_pj_per_byte_mm: 0.5,
};

/// A synthesized datapath component: the unit of netlist composition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Component {
    /// Silicon area (µm²).
    pub area_um2: f64,
    /// Dynamic energy per operation (pJ).
    pub energy_pj: f64,
    /// Propagation delay (ns) — sets the critical path.
    pub delay_ns: f64,
}

impl Component {
    /// The zero component (identity for [`Component::plus`]).
    pub const ZERO: Component = Component { area_um2: 0.0, energy_pj: 0.0, delay_ns: 0.0 };

    /// Parallel composition: areas/energies add, delay is the max.
    pub fn plus(self, other: Component) -> Component {
        Component {
            area_um2: self.area_um2 + other.area_um2,
            energy_pj: self.energy_pj + other.energy_pj,
            delay_ns: self.delay_ns.max(other.delay_ns),
        }
    }

    /// Series composition: areas/energies add, delays add (cascade).
    pub fn then(self, other: Component) -> Component {
        Component {
            area_um2: self.area_um2 + other.area_um2,
            energy_pj: self.energy_pj + other.energy_pj,
            delay_ns: self.delay_ns + other.delay_ns,
        }
    }

    /// Replicate `n` copies operating in parallel.
    pub fn times(self, n: usize) -> Component {
        Component {
            area_um2: self.area_um2 * n as f64,
            energy_pj: self.energy_pj * n as f64,
            delay_ns: self.delay_ns,
        }
    }
}

fn log2(x: f64) -> f64 {
    x.max(1.0).log2()
}

/// Ripple-free (parallel-prefix) integer adder.
///
/// Anchors: 8-bit = 0.03 pJ / 36 µm²; 32-bit = 0.1 pJ / 137 µm².
pub fn int_adder(bits: u32) -> Component {
    let b = bits as f64;
    Component {
        area_um2: 4.3 * b,
        energy_pj: 0.00345 * b,
        delay_ns: 0.06 + 0.035 * log2(b),
    }
}

/// Array integer multiplier.
///
/// Anchors: 8-bit = 0.2 pJ / 282 µm²; 32-bit = 3.1 pJ / 3495 µm².
/// Energy fits ∝ b^1.98, area ∝ b^1.81 between the anchors.
pub fn int_multiplier(bits: u32) -> Component {
    let b = bits as f64;
    Component {
        area_um2: 6.54 * b.powf(1.81),
        energy_pj: 0.00324 * b.powf(1.98),
        delay_ns: 0.20 + 0.12 * log2(b),
    }
}

/// Asymmetric integer multiplier (`a_bits × w_bits`); modeled as the
/// geometric-mean square multiplier (standard DC synthesis behaviour for
/// rectangular Booth arrays).
pub fn int_multiplier_asym(a_bits: u32, w_bits: u32) -> Component {
    let eff = ((a_bits as f64) * (w_bits as f64)).sqrt();
    let b = eff;
    Component {
        area_um2: 6.54 * b.powf(1.81),
        energy_pj: 0.00324 * b.powf(1.98),
        delay_ns: 0.20 + 0.12 * log2(b),
    }
}

/// Floating-point adder. Anchors: fp16 = 0.4 pJ / 1360 µm²;
/// fp32 = 0.9 pJ / 4184 µm².
pub fn fp_adder(bits: u32) -> Component {
    let t = ((bits as f64) - 16.0) / 16.0; // 0 at fp16, 1 at fp32
    Component {
        area_um2: crate::util::lerp(1360.0, 4184.0, t),
        energy_pj: crate::util::lerp(0.4, 0.9, t),
        delay_ns: 0.55 + 0.25 * t,
    }
}

/// Floating-point multiplier. Anchors: fp16 = 1.1 pJ / 1640 µm²;
/// fp32 = 3.7 pJ / 7700 µm².
pub fn fp_multiplier(bits: u32) -> Component {
    let t = ((bits as f64) - 16.0) / 16.0;
    Component {
        area_um2: crate::util::lerp(1640.0, 7700.0, t),
        energy_pj: crate::util::lerp(1.1, 3.7, t),
        delay_ns: 0.70 + 0.35 * t,
    }
}

/// Barrel shifter over `data_bits` with `shift_bits` of control — the
/// LightPE "multiplier". Mux-tree: `data_bits × shift_bits` 2:1 muxes.
pub fn barrel_shifter(data_bits: u32, shift_bits: u32) -> Component {
    let muxes = (data_bits as f64) * (shift_bits as f64);
    Component {
        area_um2: 1.9 * muxes,         // ~1.9 µm² per 2:1 mux incl. wiring
        energy_pj: 0.0011 * muxes,     // switched-cap per mux level
        delay_ns: 0.03 + 0.022 * shift_bits as f64,
    }
}

/// Flip-flop register bank (`bits` wide): pipeline/output registers.
pub fn register(bits: u32) -> Component {
    let b = bits as f64;
    Component { area_um2: 4.5 * b, energy_pj: 0.0018 * b, delay_ns: 0.04 }
}

/// Two's-complement negate/conditional-invert stage (sign handling in
/// shift-add PEs): an XOR row plus carry-in.
pub fn sign_unit(bits: u32) -> Component {
    let b = bits as f64;
    Component { area_um2: 1.4 * b, energy_pj: 0.0006 * b, delay_ns: 0.05 }
}

/// Control/FSM overhead for a block with ~`states` states — decoders,
/// counters, handshake.
pub fn control_logic(states: u32) -> Component {
    let s = (states as f64).max(2.0);
    Component {
        area_um2: 60.0 + 22.0 * s * log2(s),
        energy_pj: 0.002 + 0.0008 * s,
        delay_ns: 0.12,
    }
}

/// Leakage power (mW) of `area_um2` of logic at the node.
pub fn logic_leakage_mw(node: &TechNode, area_um2: f64) -> f64 {
    node.logic_leakage_mw_per_um2 * area_um2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rel_diff;

    #[test]
    fn adder_hits_anchors() {
        let a8 = int_adder(8);
        let a32 = int_adder(32);
        assert!(rel_diff(a8.energy_pj, 0.03) < 0.15, "8b add energy {}", a8.energy_pj);
        assert!(rel_diff(a8.area_um2, 36.0) < 0.15, "8b add area {}", a8.area_um2);
        assert!(rel_diff(a32.energy_pj, 0.10) < 0.15);
        assert!(rel_diff(a32.area_um2, 137.0) < 0.15);
    }

    #[test]
    fn multiplier_hits_anchors() {
        let m8 = int_multiplier(8);
        let m32 = int_multiplier(32);
        assert!(rel_diff(m8.energy_pj, 0.2) < 0.15, "8b mult energy {}", m8.energy_pj);
        assert!(rel_diff(m8.area_um2, 282.0) < 0.15, "8b mult area {}", m8.area_um2);
        assert!(rel_diff(m32.energy_pj, 3.1) < 0.15, "32b mult energy {}", m32.energy_pj);
        assert!(rel_diff(m32.area_um2, 3495.0) < 0.15, "32b mult area {}", m32.area_um2);
    }

    #[test]
    fn fp_hits_anchors() {
        assert!(rel_diff(fp_adder(32).energy_pj, 0.9) < 0.01);
        assert!(rel_diff(fp_multiplier(32).area_um2, 7700.0) < 0.01);
        assert!(rel_diff(fp_adder(16).energy_pj, 0.4) < 0.01);
    }

    #[test]
    fn shifter_cheaper_than_multiplier() {
        let shift = barrel_shifter(16, 3);
        let mult = int_multiplier(16);
        assert!(shift.area_um2 < mult.area_um2 / 5.0);
        assert!(shift.energy_pj < mult.energy_pj / 5.0);
        assert!(shift.delay_ns < mult.delay_ns);
    }

    #[test]
    fn asym_multiplier_between_square_sizes() {
        let asym = int_multiplier_asym(16, 4);
        let m8 = int_multiplier(8);
        // geomean(16,4) = 8 → identical to the 8-bit square multiplier.
        assert!(rel_diff(asym.area_um2, m8.area_um2) < 1e-9);
    }

    #[test]
    fn composition_laws() {
        let a = int_adder(16);
        let b = register(16);
        let parallel = a.plus(b);
        assert!(rel_diff(parallel.area_um2, a.area_um2 + b.area_um2) < 1e-12);
        assert_eq!(parallel.delay_ns, a.delay_ns.max(b.delay_ns));
        let series = a.then(b);
        assert!(rel_diff(series.delay_ns, a.delay_ns + b.delay_ns) < 1e-12);
        let four = a.times(4);
        assert!(rel_diff(four.area_um2, 4.0 * a.area_um2) < 1e-12);
        assert_eq!(four.delay_ns, a.delay_ns);
    }

    #[test]
    fn scaling_monotone_in_bits() {
        for f in [int_adder as fn(u32) -> Component, int_multiplier, register] {
            let mut last = 0.0;
            for bits in [4, 8, 16, 32] {
                let c = f(bits);
                assert!(c.area_um2 > last);
                last = c.area_um2;
            }
        }
    }

    #[test]
    fn leakage_linear_in_area() {
        let l1 = logic_leakage_mw(&NODE_45NM, 1000.0);
        let l2 = logic_leakage_mw(&NODE_45NM, 2000.0);
        assert!(rel_diff(l2, 2.0 * l1) < 1e-12);
    }
}
