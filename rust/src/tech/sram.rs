//! CACTI-style SRAM / register-file macro model.
//!
//! Calibrated to the standard 45 nm datapoints (Horowitz ISSCC'14): a
//! 64-bit read from 8 KiB ≈ 10 pJ, 32 KiB ≈ 20 pJ, 1 MiB ≈ 100 pJ — i.e.
//! access energy ∝ word_bits × √capacity. Small arrays (≤ ~1 Kib) are
//! modeled as flip-flop register files instead, which is what synthesis
//! does with small scratchpads: lower access energy, higher per-bit area.

use super::{Component, TechNode};

/// Kind of storage macro the "synthesis tool" would infer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacroKind {
    /// 6T SRAM array with decoder/sense-amp periphery.
    Sram,
    /// Flip-flop based register file (small arrays).
    RegFile,
}

/// A synthesized storage macro.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramMacro {
    /// Macro implementation style.
    pub kind: MacroKind,
    /// Total capacity in bits.
    pub capacity_bits: usize,
    /// Read/write word width in bits.
    pub word_bits: usize,
    /// Area (µm²) including periphery.
    pub area_um2: f64,
    /// Energy per read access (pJ).
    pub read_pj: f64,
    /// Energy per write access (pJ).
    pub write_pj: f64,
    /// Access latency (ns).
    pub access_ns: f64,
    /// Leakage power (mW).
    pub leakage_mw: f64,
}

/// Register-file threshold: arrays at or below this size synthesize to FFs.
pub const REGFILE_THRESHOLD_BITS: usize = 1024;

const SRAM_CELL_UM2: f64 = 0.525; // 6T cell at 45 nm incl. array overhead
const SRAM_PERIPHERY_UM2_PER_SQRT_BIT: f64 = 14.0;
const SRAM_READ_PJ_PER_WORDBIT_SQRTBIT: f64 = 6.1e-4;
const SRAM_LEAKAGE_MW_PER_BIT: f64 = 2.4e-6; // ≈ 20 mW / MiB
const REGFILE_UM2_PER_BIT: f64 = 5.2;
const REGFILE_READ_PJ_PER_BIT: f64 = 0.0021;
const REGFILE_LEAKAGE_MW_PER_BIT: f64 = 4.0e-6;

/// Build the storage macro a synthesis run would produce for the given
/// capacity and word width.
pub fn build(capacity_bits: usize, word_bits: usize) -> SramMacro {
    assert!(capacity_bits > 0 && word_bits > 0);
    if capacity_bits <= REGFILE_THRESHOLD_BITS {
        build_regfile(capacity_bits, word_bits)
    } else {
        build_sram(capacity_bits, word_bits)
    }
}

/// Force a register-file macro regardless of capacity (Eyeriss-style PE
/// scratchpads are register files; synthesis maps them to FF arrays).
pub fn build_regfile(capacity_bits: usize, word_bits: usize) -> SramMacro {
    assert!(capacity_bits > 0 && word_bits > 0);
    let read_pj = REGFILE_READ_PJ_PER_BIT * word_bits as f64;
    SramMacro {
        kind: MacroKind::RegFile,
        capacity_bits,
        word_bits,
        area_um2: REGFILE_UM2_PER_BIT * capacity_bits as f64,
        read_pj,
        write_pj: read_pj * 1.1,
        access_ns: 0.15,
        leakage_mw: REGFILE_LEAKAGE_MW_PER_BIT * capacity_bits as f64,
    }
}

/// Force an SRAM macro regardless of capacity (global buffers).
pub fn build_sram(capacity_bits: usize, word_bits: usize) -> SramMacro {
    assert!(capacity_bits > 0 && word_bits > 0);
    let bits = capacity_bits as f64;
    let read_pj = SRAM_READ_PJ_PER_WORDBIT_SQRTBIT * word_bits as f64 * bits.sqrt();
    SramMacro {
        kind: MacroKind::Sram,
        capacity_bits,
        word_bits,
        area_um2: SRAM_CELL_UM2 * bits + SRAM_PERIPHERY_UM2_PER_SQRT_BIT * bits.sqrt(),
        read_pj,
        write_pj: read_pj * 1.2,
        access_ns: 0.25 + 0.05 * (bits / 65536.0).max(1.0).log2(),
        leakage_mw: SRAM_LEAKAGE_MW_PER_BIT * bits,
    }
}

impl SramMacro {
    /// As a [`Component`] for netlist composition (read path; energy is the
    /// read energy — the synthesis engine accounts writes separately).
    pub fn as_component(&self) -> Component {
        Component { area_um2: self.area_um2, energy_pj: self.read_pj, delay_ns: self.access_ns }
    }

    /// Total leakage at a node (macro model already holds the 45 nm value;
    /// `node` is accepted for future multi-node support).
    pub fn leakage_mw(&self, _node: &TechNode) -> f64 {
        self.leakage_mw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rel_diff;

    const KIB: usize = 8 * 1024;

    #[test]
    fn calibration_anchors() {
        // 8 KiB, 64-bit word ≈ 10 pJ/read.
        let m8k = build(8 * KIB, 64);
        assert!(rel_diff(m8k.read_pj, 10.0) < 0.10, "8KiB read {}", m8k.read_pj);
        // 32 KiB ≈ 20 pJ.
        let m32k = build(32 * KIB, 64);
        assert!(rel_diff(m32k.read_pj, 20.0) < 0.10, "32KiB read {}", m32k.read_pj);
        // 1 MiB ≈ 100 pJ (√ scaling gives ~113; within 15%).
        let m1m = build(1024 * KIB, 64);
        assert!(rel_diff(m1m.read_pj, 100.0) < 0.15, "1MiB read {}", m1m.read_pj);
    }

    #[test]
    fn small_arrays_are_regfiles() {
        assert_eq!(build(512, 16).kind, MacroKind::RegFile);
        assert_eq!(build(REGFILE_THRESHOLD_BITS, 16).kind, MacroKind::RegFile);
        assert_eq!(build(REGFILE_THRESHOLD_BITS + 1, 16).kind, MacroKind::Sram);
    }

    #[test]
    fn regfile_access_cheaper_but_area_denser_per_bit() {
        let rf = build(1024, 16);
        let sram = build(64 * KIB, 16);
        assert!(rf.read_pj < sram.read_pj);
        let rf_area_per_bit = rf.area_um2 / rf.capacity_bits as f64;
        let sram_area_per_bit = sram.area_um2 / sram.capacity_bits as f64;
        assert!(rf_area_per_bit > sram_area_per_bit);
    }

    #[test]
    fn energy_scales_with_sqrt_capacity() {
        let a = build(16 * KIB, 64);
        let b = build(64 * KIB, 64);
        assert!(rel_diff(b.read_pj / a.read_pj, 2.0) < 0.05);
    }

    #[test]
    fn energy_linear_in_word_width() {
        let narrow = build(64 * KIB, 32);
        let wide = build(64 * KIB, 128);
        assert!(rel_diff(wide.read_pj / narrow.read_pj, 4.0) < 0.05);
    }

    #[test]
    fn writes_cost_more_than_reads() {
        for m in [build(512, 16), build(64 * KIB, 64)] {
            assert!(m.write_pj > m.read_pj);
        }
    }

    #[test]
    fn leakage_proportional_to_capacity() {
        let a = build(8 * KIB, 64);
        let b = build(16 * KIB, 64);
        assert!(rel_diff(b.leakage_mw / a.leakage_mw, 2.0) < 0.05);
    }

    #[test]
    fn area_monotone_in_capacity() {
        let mut last = 0.0;
        for kib in [1, 2, 8, 64, 256, 1024] {
            let m = build(kib * KIB, 64);
            assert!(m.area_um2 > last);
            last = m.area_um2;
        }
    }
}
