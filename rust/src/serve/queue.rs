//! The batch queue: spec files → an ordered campaign set.
//!
//! [`BatchQueue::build`] expands every queued QSL file through
//! [`spec::expand`](crate::spec::expand) (include splicing, override
//! merging, matrix cross products) into a flat, ordered list of
//! [`QueueEntry`]s. Expansion *errors* abort the whole batch — a spec
//! that cannot be read is user input to fix, not a campaign to skip —
//! while per-campaign problems found later (lint denials, runtime
//! failures) only affect their campaign.
//!
//! Each entry keeps its composed AST and spliced source so the
//! scheduler can run the pre-flight lint gate with full-fidelity
//! diagnostics against the exact text the campaign came from.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::spec::ast::SpecFile;
use crate::spec::expand::{expand_path, Expansion};
use crate::spec::ResolvedCampaign;

/// One campaign awaiting execution.
#[derive(Debug, Clone)]
pub struct QueueEntry {
    /// The spec file this campaign expanded from.
    pub spec_path: PathBuf,
    /// Display name of that file (as given on the command line).
    pub filename: String,
    /// The spliced source all of this entry's spans refer to.
    pub source: String,
    /// Matrix label (`"seed=2,glb_kib=[128]"`; empty for plain specs).
    pub label: String,
    /// The composed per-campaign AST (for the lint gate).
    pub file: SpecFile,
    /// The resolved campaign.
    pub campaign: ResolvedCampaign,
    /// The campaign's QSL identity fingerprint — names its artifact
    /// directory and dedupes repeats within a batch.
    pub fingerprint: u64,
}

/// An ordered batch of campaigns, plus any expansion warnings rendered
/// for display.
#[derive(Debug, Clone, Default)]
pub struct BatchQueue {
    /// Campaigns in queue order (spec order, then matrix order).
    pub entries: Vec<QueueEntry>,
    /// Rendered warning batches, one per spec that produced any.
    pub warnings: Vec<String>,
}

impl BatchQueue {
    /// Expand `specs` (in order) into a batch queue. Any expansion
    /// error — unreadable file, include cycle, bad override/matrix,
    /// unresolvable campaign — fails the whole build with the rendered
    /// diagnostics.
    pub fn build(specs: &[PathBuf]) -> Result<Self> {
        if specs.is_empty() {
            return Err(Error::InvalidConfig(
                "qadam serve needs at least one spec file".into(),
            ));
        }
        let mut queue = BatchQueue::default();
        for path in specs {
            queue.push_spec(path)?;
        }
        Ok(queue)
    }

    /// Expand one spec file and append its campaigns.
    pub fn push_spec(&mut self, path: &Path) -> Result<()> {
        let Expansion { filename, source, campaigns, diags } = expand_path(path)?;
        if diags.has_errors() {
            return Err(diags.into_error(&source, &filename));
        }
        if !diags.is_empty() {
            self.warnings.push(diags.render(&source, &filename));
        }
        for expanded in campaigns {
            let fingerprint = expanded.campaign.fingerprint();
            self.entries.push(QueueEntry {
                spec_path: path.to_path_buf(),
                filename: filename.clone(),
                source: source.clone(),
                label: expanded.label,
                file: expanded.file,
                campaign: expanded.campaign,
                fingerprint,
            });
        }
        Ok(())
    }

    /// Number of queued campaigns.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the queue holds no campaigns.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}
