//! The machine-readable batch journal: `serve.status.json`.
//!
//! A [`BatchStatus`] tracks every campaign of a `qadam serve` batch
//! through its lifecycle (queued → linted → running → done / failed /
//! skipped) and streams each transition to disk as canonical JSON
//! (`{"kind": "qadam.serve.status", "schema": 1, ...}`), rewritten
//! atomically after every state change.
//!
//! Transitions carry a monotonic sequence number instead of wall-clock
//! timestamps, so the file is byte-deterministic for a deterministic
//! schedule and never perturbs resume behavior.
//!
//! **Recovery contract**: the scheduler only ever *writes* this file —
//! resuming a killed batch reconstructs everything from the per-campaign
//! checkpoint journals, so a torn or deleted `serve.status.json` loses
//! nothing (the fault suite truncates it at every byte offset to prove
//! that). [`BatchStatus::load`] exists for tooling and tests.

use std::path::Path;

use crate::error::{Error, Result};
use crate::explore::persist::{
    check_envelope_exact, envelope_at, field_arr, field_str, field_usize, write_atomic,
};
use crate::util::json::{num, obj, s, Json};

/// Schema version of the `qadam.serve.status` document.
pub const STATUS_SCHEMA: usize = 1;

/// Lifecycle state of one campaign in a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CampaignState {
    /// Accepted into the batch queue.
    Queued,
    /// Passed the pre-flight lint gate.
    Linted,
    /// Currently evaluating.
    Running,
    /// Completed; artifacts saved under the campaign's directory.
    Done,
    /// Execution failed (the batch continues without it).
    Failed,
    /// Not run: pre-flight lint denial or a duplicate fingerprint.
    Skipped,
}

impl CampaignState {
    /// The state's wire label.
    pub fn label(self) -> &'static str {
        match self {
            CampaignState::Queued => "queued",
            CampaignState::Linted => "linted",
            CampaignState::Running => "running",
            CampaignState::Done => "done",
            CampaignState::Failed => "failed",
            CampaignState::Skipped => "skipped",
        }
    }

    /// Parse a wire label back.
    pub fn parse(text: &str) -> Option<Self> {
        Some(match text {
            "queued" => CampaignState::Queued,
            "linted" => CampaignState::Linted,
            "running" => CampaignState::Running,
            "done" => CampaignState::Done,
            "failed" => CampaignState::Failed,
            "skipped" => CampaignState::Skipped,
            _ => return None,
        })
    }

    /// Whether the campaign's lifecycle is over.
    pub fn is_terminal(self) -> bool {
        matches!(self, CampaignState::Done | CampaignState::Failed | CampaignState::Skipped)
    }
}

/// Current status of one campaign in the batch.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignStatus {
    /// The campaign's QSL fingerprint (names its artifact directory).
    pub fingerprint: u64,
    /// The spec file it expanded from.
    pub spec: String,
    /// Its matrix label (empty for a plain spec).
    pub label: String,
    /// Current lifecycle state.
    pub state: CampaignState,
    /// Human-readable context for the latest transition.
    pub detail: String,
    /// Shared-cache hits attributed to this campaign (exact when the
    /// batch runs with `--max-concurrent 1`; see the scheduler docs).
    pub hits: u64,
    /// Shared-cache misses attributed to this campaign.
    pub misses: u64,
}

/// One recorded state transition.
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    /// Monotonic sequence number (0-based, batch-wide).
    pub seq: u64,
    /// Queue index of the campaign.
    pub index: usize,
    /// Fingerprint of the campaign (denormalized for grep-ability).
    pub fingerprint: u64,
    /// The state entered.
    pub state: CampaignState,
    /// Context for the transition.
    pub detail: String,
}

/// The batch journal: per-campaign current states plus the full ordered
/// transition log.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatchStatus {
    campaigns: Vec<CampaignStatus>,
    transitions: Vec<Transition>,
}

fn hex(value: u64) -> String {
    format!("{value:016x}")
}

fn field_u64_hex(json: &Json, key: &str) -> Result<u64> {
    let text = field_str(json, key)?;
    u64::from_str_radix(text, 16)
        .map_err(|_| Error::ParseError(format!("field '{key}' is not a hex u64: '{text}'")))
}

fn field_state(json: &Json, key: &str) -> Result<CampaignState> {
    let text = field_str(json, key)?;
    CampaignState::parse(text)
        .ok_or_else(|| Error::ParseError(format!("unknown campaign state '{text}'")))
}

impl BatchStatus {
    /// Empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit a campaign at the back of the queue (records its `queued`
    /// transition). Returns the campaign's queue index, the handle every
    /// later [`Self::transition`] uses — duplicate fingerprints may
    /// legally coexist in one batch (the scheduler skips the later one),
    /// so campaigns are addressed by index, not fingerprint.
    pub fn enqueue(&mut self, fingerprint: u64, spec: &str, label: &str) -> usize {
        let index = self.campaigns.len();
        self.campaigns.push(CampaignStatus {
            fingerprint,
            spec: spec.to_string(),
            label: label.to_string(),
            state: CampaignState::Queued,
            detail: String::new(),
            hits: 0,
            misses: 0,
        });
        self.record(index, CampaignState::Queued, String::new());
        index
    }

    /// Move campaign `index` to `state`, recording the transition.
    pub fn transition(&mut self, index: usize, state: CampaignState, detail: impl Into<String>) {
        let detail = detail.into();
        if let Some(campaign) = self.campaigns.get_mut(index) {
            campaign.state = state;
            campaign.detail.clone_from(&detail);
        }
        self.record(index, state, detail);
    }

    /// Attribute shared-cache hit/miss deltas to campaign `index`.
    pub fn set_counters(&mut self, index: usize, hits: u64, misses: u64) {
        if let Some(campaign) = self.campaigns.get_mut(index) {
            campaign.hits = hits;
            campaign.misses = misses;
        }
    }

    fn record(&mut self, index: usize, state: CampaignState, detail: String) {
        let fingerprint = self.campaigns.get(index).map_or(0, |c| c.fingerprint);
        let seq = self.transitions.len() as u64;
        self.transitions.push(Transition { seq, index, fingerprint, state, detail });
    }

    /// Per-campaign current states, in queue order.
    pub fn campaigns(&self) -> &[CampaignStatus] {
        &self.campaigns
    }

    /// The ordered transition log.
    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    /// Serialize as the schema-versioned canonical document.
    pub fn to_json(&self) -> Json {
        let campaigns: Vec<Json> = self
            .campaigns
            .iter()
            .map(|c| {
                obj(vec![
                    ("fingerprint", s(&hex(c.fingerprint))),
                    ("spec", s(&c.spec)),
                    ("label", s(&c.label)),
                    ("state", s(c.state.label())),
                    ("detail", s(&c.detail)),
                    ("hits", num(c.hits as f64)),
                    ("misses", num(c.misses as f64)),
                ])
            })
            .collect();
        let transitions: Vec<Json> = self
            .transitions
            .iter()
            .map(|t| {
                obj(vec![
                    ("seq", num(t.seq as f64)),
                    ("index", num(t.index as f64)),
                    ("fingerprint", s(&hex(t.fingerprint))),
                    ("state", s(t.state.label())),
                    ("detail", s(&t.detail)),
                ])
            })
            .collect();
        let mut fields = envelope_at("qadam.serve.status", STATUS_SCHEMA);
        fields.push(("campaigns", Json::Arr(campaigns)));
        fields.push(("transitions", Json::Arr(transitions)));
        obj(fields)
    }

    /// Deserialize from [`Self::to_json`] output. The status journal
    /// versions independently of the campaign artifact lineage, so its
    /// envelope is checked against [`STATUS_SCHEMA`] exactly — the
    /// ranged `check_envelope` would reject every schema-1 document.
    pub fn from_json(json: &Json) -> Result<Self> {
        check_envelope_exact(json, "qadam.serve.status", STATUS_SCHEMA)?;
        let mut status = Self::new();
        for entry in field_arr(json, "campaigns")? {
            status.campaigns.push(CampaignStatus {
                fingerprint: field_u64_hex(entry, "fingerprint")?,
                spec: field_str(entry, "spec")?.to_string(),
                label: field_str(entry, "label")?.to_string(),
                state: field_state(entry, "state")?,
                detail: field_str(entry, "detail")?.to_string(),
                hits: field_usize(entry, "hits")? as u64,
                misses: field_usize(entry, "misses")? as u64,
            });
        }
        for entry in field_arr(json, "transitions")? {
            status.transitions.push(Transition {
                seq: field_usize(entry, "seq")? as u64,
                index: field_usize(entry, "index")?,
                fingerprint: field_u64_hex(entry, "fingerprint")?,
                state: field_state(entry, "state")?,
                detail: field_str(entry, "detail")?.to_string(),
            });
        }
        Ok(status)
    }

    /// Atomically write the document (temp sibling + rename), pretty
    /// canonical JSON like every other artifact.
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_json().to_string_pretty())
    }

    /// Load a saved status document — tooling/test convenience; the
    /// scheduler itself never reads this file back.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)
            .map_err(|e| Error::ParseError(format!("{}: {e}", path.display())))?;
        Self::from_json(&json)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_roundtrips_and_streams_transitions() {
        let mut status = BatchStatus::new();
        let a = status.enqueue(0xabc, "a.qsl", "");
        let b = status.enqueue(0xdef, "b.qsl", "seed=2");
        status.transition(a, CampaignState::Linted, "0 finding(s)");
        status.transition(a, CampaignState::Running, "");
        status.set_counters(a, 4, 2);
        status.transition(a, CampaignState::Done, "6 points");
        status.transition(b, CampaignState::Skipped, "lint deny: Q012");
        assert_eq!(status.campaigns()[a].state, CampaignState::Done);
        assert_eq!(status.campaigns()[a].hits, 4);
        assert!(status.campaigns()[b].state.is_terminal());
        // seq is dense and monotonic: 2 enqueues + 4 transitions
        // (set_counters is an attribute update, not a transition).
        let seqs: Vec<u64> = status.transitions().iter().map(|t| t.seq).collect();
        assert_eq!(seqs, (0..6).collect::<Vec<u64>>());

        let json = status.to_json();
        let back = BatchStatus::from_json(&json).unwrap();
        assert_eq!(back, status);
        // Canonical: serialization is a fixed point.
        assert_eq!(back.to_json().to_string_pretty(), json.to_string_pretty());
    }

    #[test]
    fn unknown_state_is_a_parse_error() {
        let mut status = BatchStatus::new();
        status.enqueue(1, "x.qsl", "");
        let text = status.to_json().to_string_pretty().replace("queued", "teleported");
        let err = BatchStatus::from_json(&Json::parse(&text).unwrap()).unwrap_err();
        assert_eq!(err.kind(), "parse_error");
    }
}
