//! `qadam serve` — the multi-campaign batch scheduler.
//!
//! The serving layer between the single-campaign engine and a
//! multi-tenant service: it accepts a [`queue`] of QSL spec files (each
//! of which may expand into several campaigns via `include` /
//! `override` / `matrix` — see [`crate::spec::expand`]), runs them
//! through the [`sched`]uler over the existing
//! [`Explorer`](crate::explore::Explorer) machinery with one shared
//! content-addressed [`PointCache`](crate::explore::PointCache), and
//! streams per-campaign lifecycle transitions into the [`status`]
//! journal.
//!
//! Layout of a batch output directory:
//!
//! ```text
//! out/
//!   serve.status.json        batch journal (write-only; never read back)
//!   cache.json               shared dedupe cache (save-generation counted)
//!   <fingerprint>/           one directory per campaign
//!     run.journal            checkpoint journal (kill/resume source of truth)
//!     db.json                evaluation database
//!     frontier.json          streaming Pareto frontier
//! ```
//!
//! Recovery matrix (asserted byte-offset-by-byte-offset by the fault
//! suite, `tests/faults.rs`):
//!
//! | torn artifact        | recovery                                     |
//! |----------------------|----------------------------------------------|
//! | `run.journal` tail   | truncate to last complete line, resume       |
//! | `run.journal` header | journal set aside (`.torn`), fresh start     |
//! | `cache.json`         | cold cache — correct, just no dedupe         |
//! | `db.json`/`frontier` | rewritten whole on completion (atomic saves) |
//! | `serve.status.json`  | ignored — state lives in campaign journals   |

pub mod queue;
pub mod sched;
pub mod status;

pub use queue::{BatchQueue, QueueEntry};
pub use sched::{campaign_dir, serve, BatchOutcome, CampaignReport, ServeConfig};
pub use status::{BatchStatus, CampaignState, CampaignStatus, Transition, STATUS_SCHEMA};
