//! The batch scheduler: lint gate, concurrent execution, shared-cache
//! dedupe, and per-campaign artifact directories.
//!
//! [`serve`] drives a [`BatchQueue`] end to end:
//!
//! 1. every campaign is admitted to the [`BatchStatus`] journal
//!    (`serve.status.json`, rewritten atomically on every transition);
//! 2. duplicate fingerprints are skipped (the first occurrence wins);
//! 3. each campaign passes the Q001–Q012 pre-flight lint gate — deny
//!    findings skip *that campaign*, never the batch;
//! 4. surviving campaigns run over the existing [`Explorer`] pipeline,
//!    up to `max_concurrent` at a time, all sharing one
//!    `Arc<Mutex<PointCache>>` so overlapping evaluations across
//!    tenants dedupe to cache hits;
//! 5. each campaign persists its own checkpoint journal, database, and
//!    frontier under `<out>/<fingerprint>/`, so killing the batch at
//!    any point and re-running resumes every campaign from its journal,
//!    byte-identical to an uninterrupted run.
//!
//! The shared cache lives at `<out>/cache.json` and is saved (under the
//! cache mutex, bumping its save generation) after each campaign
//! completes. A torn or corrupt cache file on startup degrades to a
//! cold cache — results stay correct, only dedupe is lost. Per-campaign
//! hit/miss attributions come from counter snapshots around each run:
//! exact at `--max-concurrent 1` (the deterministic mode the tests
//! pin), approximate when runs overlap; batch-wide totals are always
//! exact.
//!
//! Campaign artifacts (journal, db, frontier) are byte-deterministic in
//! the campaign's identity alone — queue order, kill/resume timing, and
//! cache warmth change none of their bytes. `cache.json` is excluded
//! from that contract: its save generation counts completed saves.
//!
//! [`Explorer`]: crate::explore::Explorer

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use super::queue::{BatchQueue, QueueEntry};
use super::status::{BatchStatus, CampaignState};
use crate::error::Result;
use crate::explore::{lock_shared, PointCache};
use crate::spec::lint::{lint_campaign, Level, LintOptions};
use crate::spec::PersistPlan;

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Batch output directory: `serve.status.json`, `cache.json`, and
    /// one `<fingerprint>/` directory per completed campaign.
    pub out_dir: PathBuf,
    /// Campaigns in flight at once (minimum 1). At 1 the schedule — and
    /// the status journal — is fully deterministic.
    pub max_concurrent: usize,
    /// Per-campaign worker-thread override (0 = keep each campaign's
    /// own setting).
    pub workers: usize,
    /// Pre-flight lint configuration (deny findings skip the campaign).
    pub lint: LintOptions,
}

impl ServeConfig {
    /// Defaults: sequential, campaign-declared workers, default lint
    /// levels.
    pub fn new(out_dir: impl Into<PathBuf>) -> Self {
        Self {
            out_dir: out_dir.into(),
            max_concurrent: 1,
            workers: 0,
            lint: LintOptions::default(),
        }
    }
}

/// Final state of one campaign, for callers.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// The campaign's QSL fingerprint.
    pub fingerprint: u64,
    /// Spec file it came from.
    pub spec: String,
    /// Matrix label (empty for plain specs).
    pub label: String,
    /// Terminal state (`Done` / `Failed` / `Skipped`).
    pub state: CampaignState,
    /// Context for that state (lint codes, error text, point counts).
    pub detail: String,
    /// Shared-cache hits attributed to this campaign.
    pub hits: u64,
    /// Shared-cache misses attributed to this campaign.
    pub misses: u64,
    /// The campaign's artifact directory, when it completed.
    pub dir: Option<PathBuf>,
}

/// What a whole batch did.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Per-campaign reports in queue order.
    pub reports: Vec<CampaignReport>,
    /// Where the status journal lives.
    pub status_path: PathBuf,
    /// Where the shared cache was saved.
    pub cache_path: PathBuf,
    /// Design points in the shared cache after the batch.
    pub cache_entries: usize,
    /// Whether a torn/corrupt cache file was found on startup and the
    /// batch started cold instead (correct, just not deduped).
    pub cache_recovered: bool,
}

impl BatchOutcome {
    /// Number of campaigns that failed at runtime (skips don't count).
    pub fn failures(&self) -> usize {
        self.reports.iter().filter(|r| r.state == CampaignState::Failed).count()
    }
}

struct RunStats {
    points: usize,
    hits: u64,
    misses: u64,
}

enum Event {
    Started(usize),
    Finished(usize, std::result::Result<RunStats, String>),
}

/// Run a batch. See the module docs for the full contract. Errors out
/// only on batch-level failures (output directory, status-journal
/// writes); per-campaign failures land in the returned reports.
pub fn serve(queue: &BatchQueue, config: &ServeConfig) -> Result<BatchOutcome> {
    std::fs::create_dir_all(&config.out_dir)?;
    let status_path = config.out_dir.join("serve.status.json");
    let cache_path = config.out_dir.join("cache.json");

    let mut status = BatchStatus::new();
    for entry in &queue.entries {
        status.enqueue(entry.fingerprint, &entry.filename, &entry.label);
    }
    status.save(&status_path)?;

    // Warm the shared cache from a previous batch; torn or corrupt
    // files degrade to a cold (correct) start.
    let (loaded, cache_recovered) = if cache_path.exists() {
        match PointCache::load(&cache_path) {
            Ok(cache) => (cache, false),
            Err(_) => (PointCache::new(), true),
        }
    } else {
        (PointCache::new(), false)
    };
    let shared = Arc::new(Mutex::new(loaded));

    // Pre-flight: duplicate-fingerprint dedupe, then the lint gate.
    let mut runnable: Vec<usize> = Vec::new();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    for (index, entry) in queue.entries.iter().enumerate() {
        if !seen.insert(entry.fingerprint) {
            status.transition(
                index,
                CampaignState::Skipped,
                "duplicate campaign fingerprint in this batch",
            );
            status.save(&status_path)?;
            continue;
        }
        let findings = lint_campaign(&entry.source, &entry.file, &entry.campaign, &config.lint);
        let denials: Vec<&str> =
            findings.iter().filter(|f| f.level == Level::Deny).map(|f| f.code).collect();
        if denials.is_empty() {
            status.transition(
                index,
                CampaignState::Linted,
                format!("{} finding(s)", findings.len()),
            );
            runnable.push(index);
        } else {
            status.transition(
                index,
                CampaignState::Skipped,
                format!("lint deny: {}", denials.join(", ")),
            );
        }
        status.save(&status_path)?;
    }

    // Run phase: a pull-based worker pool over the runnable list. With
    // one worker the schedule is queue order exactly.
    let pool = config.max_concurrent.clamp(1, runnable.len().max(1));
    let next = Mutex::new(0usize);
    let (tx, rx) = mpsc::channel::<Event>();
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..pool {
            let tx = tx.clone();
            let shared = shared.clone();
            let (next, runnable) = (&next, &runnable);
            let entries = &queue.entries;
            let cache_path = &cache_path;
            scope.spawn(move || loop {
                let index = {
                    let mut cursor = lock_shared(next);
                    if *cursor >= runnable.len() {
                        break;
                    }
                    let index = runnable[*cursor];
                    *cursor += 1;
                    index
                };
                let _ = tx.send(Event::Started(index));
                let outcome = run_campaign(&entries[index], config, &shared, cache_path)
                    .map_err(|err| err.to_string());
                let _ = tx.send(Event::Finished(index, outcome));
            });
        }
        drop(tx);
        // The scheduler thread is the only status writer: workers
        // stream events, transitions land here in arrival order.
        for event in rx {
            match event {
                Event::Started(index) => {
                    status.transition(index, CampaignState::Running, "");
                    status.save(&status_path)?;
                }
                Event::Finished(index, Ok(stats)) => {
                    status.set_counters(index, stats.hits, stats.misses);
                    status.transition(
                        index,
                        CampaignState::Done,
                        format!(
                            "{} design points; {} cache hits / {} misses",
                            stats.points, stats.hits, stats.misses
                        ),
                    );
                    status.save(&status_path)?;
                }
                Event::Finished(index, Err(message)) => {
                    status.transition(index, CampaignState::Failed, message);
                    status.save(&status_path)?;
                }
            }
        }
        Ok(())
    })?;

    let cache_entries = lock_shared(&shared).len();
    let reports = status
        .campaigns()
        .iter()
        .map(|c| CampaignReport {
            fingerprint: c.fingerprint,
            spec: c.spec.clone(),
            label: c.label.clone(),
            state: c.state,
            detail: c.detail.clone(),
            hits: c.hits,
            misses: c.misses,
            dir: (c.state == CampaignState::Done)
                .then(|| campaign_dir(&config.out_dir, c.fingerprint)),
        })
        .collect();
    Ok(BatchOutcome { reports, status_path, cache_path, cache_entries, cache_recovered })
}

/// The artifact directory of a campaign within a batch output dir.
pub fn campaign_dir(out_dir: &Path, fingerprint: u64) -> PathBuf {
    out_dir.join(format!("{fingerprint:016x}"))
}

fn run_campaign(
    entry: &QueueEntry,
    config: &ServeConfig,
    shared: &Arc<Mutex<PointCache>>,
    cache_path: &Path,
) -> Result<RunStats> {
    let dir = campaign_dir(&config.out_dir, entry.fingerprint);
    std::fs::create_dir_all(&dir)?;
    // The scheduler owns artifact placement: any persist paths the spec
    // declares are superseded by the per-fingerprint directory (the
    // spec's `every` flush interval is honored). `plan.cache` stays
    // None — the shared cache is attached directly and saved below.
    let plan = PersistPlan {
        db: Some(dir.join("db.json")),
        cache: None,
        checkpoint: Some(dir.join("run.journal")),
        every: entry.campaign.persist.every,
        frontier: Some(dir.join("frontier.json")),
    };
    let mut campaign = entry.campaign.clone();
    if config.workers > 0 {
        campaign.workers = config.workers;
    }
    let (hits_before, misses_before) = {
        let cache = lock_shared(shared);
        (cache.hits(), cache.misses())
    };
    let outcome = campaign.execute_with(&plan, Some(shared.clone()))?;
    let (hits, misses) = {
        let mut cache = lock_shared(shared);
        cache.save(cache_path)?;
        (cache.hits() - hits_before, cache.misses() - misses_before)
    };
    Ok(RunStats { points: outcome.db.stats.design_points, hits, misses })
}
