//! The batch scheduler: lint gate, concurrent execution, shared-cache
//! dedupe, and per-campaign artifact directories.
//!
//! [`serve`] drives a [`BatchQueue`] end to end:
//!
//! 1. every campaign is admitted to the [`BatchStatus`] journal
//!    (`serve.status.json`, rewritten atomically on every transition);
//! 2. duplicate fingerprints are skipped (the first occurrence wins);
//! 3. each campaign passes the Q001–Q012 pre-flight lint gate — deny
//!    findings skip *that campaign*, never the batch;
//! 4. surviving campaigns run over the existing [`Explorer`] pipeline,
//!    up to `max_concurrent` at a time, all sharing one
//!    `Arc<Mutex<PointCache>>` so overlapping evaluations across
//!    tenants dedupe to cache hits;
//! 5. each campaign persists its own checkpoint journal, database, and
//!    frontier under `<out>/<fingerprint>/`, so killing the batch at
//!    any point and re-running resumes every campaign from its journal,
//!    byte-identical to an uninterrupted run.
//!
//! The shared cache lives at `<out>/cache.json` and is saved (under the
//! cache mutex, bumping its save generation) after each campaign
//! completes. A torn or corrupt cache file on startup degrades to a
//! cold cache — results stay correct, only dedupe is lost. Per-campaign
//! hit/miss attributions come from counter snapshots around each run:
//! exact at `--max-concurrent 1` (the deterministic mode the tests
//! pin), approximate when runs overlap; batch-wide totals are always
//! exact.
//!
//! Campaign artifacts (journal, db, frontier) are byte-deterministic in
//! the campaign's identity alone — queue order, kill/resume timing, and
//! cache warmth change none of their bytes. `cache.json` is excluded
//! from that contract: its save generation counts completed saves. A
//! per-campaign `trace.json` (when the spec sets `persist.trace`) is
//! *warmth-honest* like the cache — its hit/miss events reflect the
//! shared cache's actual state, so it too sits outside the kill/resume
//! byte contract within a batch (solo campaigns carry that guarantee).
//! The batch-level trace (`ServeConfig::trace`) records scheduler
//! events in arrival order: deterministic at `--max-concurrent 1`, a
//! faithful log otherwise.
//!
//! [`Explorer`]: crate::explore::Explorer

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use super::queue::{BatchQueue, QueueEntry};
use super::status::{BatchStatus, CampaignState};
use crate::error::Result;
use crate::explore::{lock_shared, PointCache};
use crate::obs::{self, TraceEvent, TraceRecorder, TraceSink};
use crate::spec::lint::{lint_campaign, Level, LintOptions};
use crate::spec::PersistPlan;

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Batch output directory: `serve.status.json`, `cache.json`, and
    /// one `<fingerprint>/` directory per completed campaign.
    pub out_dir: PathBuf,
    /// Campaigns in flight at once (minimum 1). At 1 the schedule — and
    /// the status journal — is fully deterministic.
    pub max_concurrent: usize,
    /// Per-campaign worker-thread override (0 = keep each campaign's
    /// own setting).
    pub workers: usize,
    /// Pre-flight lint configuration (deny findings skip the campaign).
    pub lint: LintOptions,
    /// Suppress the live per-campaign transition stream on stderr.
    /// Library embedders default to suppressed; `qadam serve` flips
    /// this to `false` unless `--quiet` is passed.
    pub quiet: bool,
    /// Record a batch-level `qadam.trace` (plus `.timing` sidecar) of
    /// every scheduler event to this path.
    pub trace: Option<PathBuf>,
}

impl ServeConfig {
    /// Defaults: sequential, campaign-declared workers, default lint
    /// levels, transition stream suppressed, no batch trace.
    pub fn new(out_dir: impl Into<PathBuf>) -> Self {
        Self {
            out_dir: out_dir.into(),
            max_concurrent: 1,
            workers: 0,
            lint: LintOptions::default(),
            quiet: true,
            trace: None,
        }
    }
}

/// Final state of one campaign, for callers.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// The campaign's QSL fingerprint.
    pub fingerprint: u64,
    /// Spec file it came from.
    pub spec: String,
    /// Matrix label (empty for plain specs).
    pub label: String,
    /// Terminal state (`Done` / `Failed` / `Skipped`).
    pub state: CampaignState,
    /// Context for that state (lint codes, error text, point counts).
    pub detail: String,
    /// Shared-cache hits attributed to this campaign.
    pub hits: u64,
    /// Shared-cache misses attributed to this campaign.
    pub misses: u64,
    /// The campaign's artifact directory, when it completed.
    pub dir: Option<PathBuf>,
}

/// What a whole batch did.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Per-campaign reports in queue order.
    pub reports: Vec<CampaignReport>,
    /// Where the status journal lives.
    pub status_path: PathBuf,
    /// Where the shared cache was saved.
    pub cache_path: PathBuf,
    /// Design points in the shared cache after the batch.
    pub cache_entries: usize,
    /// Whether a torn/corrupt cache file was found on startup and the
    /// batch started cold instead (correct, just not deduped).
    pub cache_recovered: bool,
    /// Where the batch-level trace was saved, when
    /// [`ServeConfig::trace`] was set (sidecar at `<path>.timing`).
    pub trace: Option<PathBuf>,
}

impl BatchOutcome {
    /// Number of campaigns that failed at runtime (skips don't count).
    pub fn failures(&self) -> usize {
        self.reports.iter().filter(|r| r.state == CampaignState::Failed).count()
    }
}

struct RunStats {
    points: usize,
    hits: u64,
    misses: u64,
    /// Shared-cache size when this campaign saved it.
    entries: usize,
    /// Shared-cache save generation after this campaign's save.
    generation: u64,
}

enum Event {
    Started(usize),
    Finished(usize, std::result::Result<RunStats, String>),
}

/// The scheduler's event fan-out: every state transition goes through
/// here once, feeding both the live stderr stream (satellite of
/// DESIGN.md §11: the stream *is* the trace, rendered) and the optional
/// batch-level recorder.
struct BatchTrace {
    recorder: Option<TraceRecorder>,
    quiet: bool,
}

impl BatchTrace {
    fn emit(&self, event: TraceEvent) {
        if !self.quiet {
            if let Some(line) = event.announce() {
                eprintln!("{line}");
            }
        }
        if let Some(recorder) = &self.recorder {
            recorder.record(event);
        }
    }

    fn transition(&self, index: usize, fingerprint: u64, state: CampaignState, detail: &str) {
        self.emit(TraceEvent::ServeTransition {
            index,
            fingerprint,
            state: state.label().to_string(),
            detail: detail.to_string(),
        });
    }
}

/// Run a batch. See the module docs for the full contract. Errors out
/// only on batch-level failures (output directory, status-journal
/// writes); per-campaign failures land in the returned reports.
pub fn serve(queue: &BatchQueue, config: &ServeConfig) -> Result<BatchOutcome> {
    std::fs::create_dir_all(&config.out_dir)?;
    let status_path = config.out_dir.join("serve.status.json");
    let cache_path = config.out_dir.join("cache.json");

    let batch_trace = BatchTrace {
        recorder: config.trace.as_ref().map(|_| TraceRecorder::new()),
        quiet: config.quiet,
    };
    batch_trace.emit(TraceEvent::ServeBegin { campaigns: queue.entries.len() });

    let mut status = BatchStatus::new();
    for entry in &queue.entries {
        status.enqueue(entry.fingerprint, &entry.filename, &entry.label);
    }
    status.save(&status_path)?;

    // Warm the shared cache from a previous batch; torn or corrupt
    // files degrade to a cold (correct) start.
    let (loaded, cache_recovered) = if cache_path.exists() {
        match PointCache::load(&cache_path) {
            Ok(cache) => (cache, false),
            Err(_) => (PointCache::new(), true),
        }
    } else {
        (PointCache::new(), false)
    };
    let shared = Arc::new(Mutex::new(loaded));

    // Pre-flight: duplicate-fingerprint dedupe, then the lint gate.
    let mut runnable: Vec<usize> = Vec::new();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    for (index, entry) in queue.entries.iter().enumerate() {
        if !seen.insert(entry.fingerprint) {
            let detail = "duplicate campaign fingerprint in this batch";
            status.transition(index, CampaignState::Skipped, detail);
            batch_trace.transition(index, entry.fingerprint, CampaignState::Skipped, detail);
            status.save(&status_path)?;
            continue;
        }
        let findings = lint_campaign(&entry.source, &entry.file, &entry.campaign, &config.lint);
        let denials: Vec<&str> =
            findings.iter().filter(|f| f.level == Level::Deny).map(|f| f.code).collect();
        if denials.is_empty() {
            let detail = format!("{} finding(s)", findings.len());
            status.transition(index, CampaignState::Linted, &detail);
            batch_trace.transition(index, entry.fingerprint, CampaignState::Linted, &detail);
            runnable.push(index);
        } else {
            let detail = format!("lint deny: {}", denials.join(", "));
            status.transition(index, CampaignState::Skipped, &detail);
            batch_trace.transition(index, entry.fingerprint, CampaignState::Skipped, &detail);
        }
        status.save(&status_path)?;
    }

    // Run phase: a pull-based worker pool over the runnable list. With
    // one worker the schedule is queue order exactly.
    let pool = config.max_concurrent.clamp(1, runnable.len().max(1));
    let next = Mutex::new(0usize);
    let (tx, rx) = mpsc::channel::<Event>();
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..pool {
            let tx = tx.clone();
            let shared = shared.clone();
            let (next, runnable) = (&next, &runnable);
            let entries = &queue.entries;
            let cache_path = &cache_path;
            scope.spawn(move || loop {
                let index = {
                    let mut cursor = lock_shared(next);
                    if *cursor >= runnable.len() {
                        break;
                    }
                    let index = runnable[*cursor];
                    *cursor += 1;
                    index
                };
                let _ = tx.send(Event::Started(index));
                let outcome = run_campaign(&entries[index], config, &shared, cache_path)
                    .map_err(|err| err.to_string());
                let _ = tx.send(Event::Finished(index, outcome));
            });
        }
        drop(tx);
        // The scheduler thread is the only status writer: workers
        // stream events, transitions land here in arrival order.
        for event in rx {
            match event {
                Event::Started(index) => {
                    status.transition(index, CampaignState::Running, "");
                    let fp = queue.entries[index].fingerprint;
                    batch_trace.transition(index, fp, CampaignState::Running, "");
                    status.save(&status_path)?;
                }
                Event::Finished(index, Ok(stats)) => {
                    status.set_counters(index, stats.hits, stats.misses);
                    let detail = format!(
                        "{} design points; {} cache hits / {} misses",
                        stats.points, stats.hits, stats.misses
                    );
                    status.transition(index, CampaignState::Done, &detail);
                    let fp = queue.entries[index].fingerprint;
                    batch_trace.transition(index, fp, CampaignState::Done, &detail);
                    batch_trace.emit(TraceEvent::ServeCacheSave {
                        index,
                        entries: stats.entries,
                        generation: stats.generation,
                    });
                    status.save(&status_path)?;
                }
                Event::Finished(index, Err(message)) => {
                    let fp = queue.entries[index].fingerprint;
                    batch_trace.transition(index, fp, CampaignState::Failed, &message);
                    status.transition(index, CampaignState::Failed, message);
                    status.save(&status_path)?;
                }
            }
        }
        Ok(())
    })?;

    let cache_entries = lock_shared(&shared).len();
    let tally = |state: CampaignState| {
        status.campaigns().iter().filter(|c| c.state == state).count()
    };
    batch_trace.emit(TraceEvent::ServeEnd {
        done: tally(CampaignState::Done),
        failed: tally(CampaignState::Failed),
        skipped: tally(CampaignState::Skipped),
    });
    let trace_path = match (&batch_trace.recorder, &config.trace) {
        (Some(recorder), Some(path)) => {
            let (trace, timing) = recorder.snapshot();
            trace.save(path)?;
            timing.save(&obs::sidecar_path(path))?;
            Some(path.clone())
        }
        _ => None,
    };
    let reports = status
        .campaigns()
        .iter()
        .map(|c| CampaignReport {
            fingerprint: c.fingerprint,
            spec: c.spec.clone(),
            label: c.label.clone(),
            state: c.state,
            detail: c.detail.clone(),
            hits: c.hits,
            misses: c.misses,
            dir: (c.state == CampaignState::Done)
                .then(|| campaign_dir(&config.out_dir, c.fingerprint)),
        })
        .collect();
    Ok(BatchOutcome {
        reports,
        status_path,
        cache_path,
        cache_entries,
        cache_recovered,
        trace: trace_path,
    })
}

/// The artifact directory of a campaign within a batch output dir.
pub fn campaign_dir(out_dir: &Path, fingerprint: u64) -> PathBuf {
    out_dir.join(format!("{fingerprint:016x}"))
}

fn run_campaign(
    entry: &QueueEntry,
    config: &ServeConfig,
    shared: &Arc<Mutex<PointCache>>,
    cache_path: &Path,
) -> Result<RunStats> {
    let dir = campaign_dir(&config.out_dir, entry.fingerprint);
    std::fs::create_dir_all(&dir)?;
    // The scheduler owns artifact placement: any persist paths the spec
    // declares are superseded by the per-fingerprint directory (the
    // spec's `every` flush interval is honored). `plan.cache` stays
    // None — the shared cache is attached directly and saved below.
    // `trace` is opt-in per spec: a per-campaign trace is warmth-honest
    // (its cache events see the shared cache), so it is only written
    // when the spec asked for one.
    let plan = PersistPlan {
        db: Some(dir.join("db.json")),
        cache: None,
        checkpoint: Some(dir.join("run.journal")),
        every: entry.campaign.persist.every,
        frontier: Some(dir.join("frontier.json")),
        trace: entry.campaign.persist.trace.as_ref().map(|_| dir.join("trace.json")),
    };
    let mut campaign = entry.campaign.clone();
    if config.workers > 0 {
        campaign.workers = config.workers;
    }
    let (hits_before, misses_before) = {
        let cache = lock_shared(shared);
        (cache.hits(), cache.misses())
    };
    let outcome = campaign.execute_with(&plan, Some(shared.clone()))?;
    let (hits, misses, entries, generation) = {
        let mut cache = lock_shared(shared);
        cache.save(cache_path)?;
        (
            cache.hits() - hits_before,
            cache.misses() - misses_before,
            cache.len(),
            cache.generation(),
        )
    };
    Ok(RunStats { points: outcome.db.stats.design_points, hits, misses, entries, generation })
}
