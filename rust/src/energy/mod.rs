//! Energy model: combine mapper traffic with synthesized per-access costs.
//!
//! `E_total = Σ_level accesses × E_access(level) + MACs × E_mac(pe)
//!          + P_leak × t_exec`  — the standard accelerator energy equation
//! the paper's framework evaluates per (config, DNN) pair (§III-C).

use crate::dataflow::{MappingTotals, ModelMapping};
use crate::synth::SynthReport;
use crate::tech::NODE_45NM;

/// Energy breakdown for one (config, model) evaluation, in µJ.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    /// MAC (datapath) switching energy.
    pub mac_uj: f64,
    /// Per-PE scratchpad access energy.
    pub spad_uj: f64,
    /// Global buffer access energy.
    pub glb_uj: f64,
    /// Off-chip DRAM access energy (reported separately from chip energy).
    pub dram_uj: f64,
    /// Leakage energy over the inference's runtime.
    pub leakage_uj: f64,
}

impl EnergyBreakdown {
    /// Total energy (µJ).
    pub fn total_uj(&self) -> f64 {
        self.mac_uj + self.spad_uj + self.glb_uj + self.dram_uj + self.leakage_uj
    }

    /// On-chip ("chip") energy: everything but DRAM (µJ). This is the
    /// paper's energy axis — synthesis-tool power × runtime covers the
    /// accelerator die only; DRAM energy is reported separately in the
    /// breakdown (DESIGN.md §1).
    pub fn chip_uj(&self) -> f64 {
        self.mac_uj + self.spad_uj + self.glb_uj + self.leakage_uj
    }

    /// On-chip fraction (everything but DRAM).
    pub fn onchip_fraction(&self) -> f64 {
        let total = self.total_uj();
        if total <= 0.0 {
            return 0.0;
        }
        (total - self.dram_uj) / total
    }
}

/// Evaluate the energy of one mapped model on one synthesized design.
pub fn energy_of(mapping: &ModelMapping, synth: &SynthReport) -> EnergyBreakdown {
    energy_of_totals(&mapping.totals(), synth)
}

/// [`energy_of`] over the label-free [`MappingTotals`] view — the DSE
/// hot-path entry point ([`crate::dataflow::map_model_stats`] →
/// `energy_of_totals` evaluates a point with zero heap allocation).
pub fn energy_of_totals(mapping: &MappingTotals, synth: &SynthReport) -> EnergyBreakdown {
    let pe = &synth.pe;
    const PJ_TO_UJ: f64 = 1e-6;

    // MAC datapath switching energy.
    let mac_uj = mapping.total_macs as f64 * pe.mac.energy_pj * PJ_TO_UJ;

    // Scratchpad traffic: reads at read cost, writes at write cost,
    // averaged over the three spads weighted by their natural traffic mix
    // (ifmap : filter : psum ≈ 1 : 1 : 2 under RS — psum is read+write).
    let spad_read_pj =
        (pe.ifmap_spad.read_pj + pe.filter_spad.read_pj + 2.0 * pe.psum_spad.read_pj) / 4.0;
    let spad_write_pj = (pe.psum_spad.write_pj
        + pe.ifmap_spad.write_pj
        + pe.filter_spad.write_pj)
        / 3.0;
    let spad_uj = (mapping.traffic.spad.reads as f64 * spad_read_pj
        + mapping.traffic.spad.writes as f64 * spad_write_pj)
        * PJ_TO_UJ;

    // Global buffer traffic. Access counts are in *elements*; the GLB macro
    // is costed per full-port access, so scale by the element width — a key
    // quantization effect: narrow activations pack more elements per port
    // word and spend proportionally less energy per element. Weight reads
    // scale with the *weight* width (4-bit LightPE-1 weights cost 4× less
    // per element than 16-bit ones).
    let act_fraction = synth.config.pe.act_bits() as f64 / synth.glb.word_bits as f64;
    let weight_fraction = synth.config.pe.weight_bits() as f64 / synth.glb.word_bits as f64;
    let act_reads =
        mapping.traffic.glb.reads.saturating_sub(mapping.traffic.glb_weight_reads) as f64;
    let weight_reads = mapping.traffic.glb_weight_reads as f64;
    let glb_uj = (act_reads * synth.glb.read_pj * act_fraction
        + weight_reads * synth.glb.read_pj * weight_fraction
        + mapping.traffic.glb.writes as f64 * synth.glb.write_pj * act_fraction)
        * PJ_TO_UJ;

    // DRAM traffic (precision-aware byte counts from the mapper).
    let dram_uj = mapping.traffic.dram_bytes as f64 * NODE_45NM.dram_pj_per_byte * PJ_TO_UJ;

    // Leakage over the execution interval at the achieved clock.
    let exec_s = mapping.total_cycles as f64 / (synth.achieved_clock_ghz * 1e9);
    let leakage_uj = synth.leakage_power_mw * exec_s * 1e3; // mW × s = mJ → ×1e3 = µJ

    EnergyBreakdown { mac_uj, spad_uj, glb_uj, dram_uj, leakage_uj }
}

/// Energy-delay product (µJ·s) — a secondary metric for DSE filtering.
pub fn edp(mapping: &ModelMapping, synth: &SynthReport) -> f64 {
    let energy = energy_of(mapping, synth).total_uj();
    energy * mapping.latency_s(synth.achieved_clock_ghz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::AcceleratorConfig;
    use crate::dataflow::{map_model, Dataflow};
    use crate::dnn::{model_for, Dataset, ModelKind};
    use crate::quant::PeType;
    use crate::synth::synthesize_clean;

    fn eval(pe: PeType) -> EnergyBreakdown {
        let config = AcceleratorConfig { pe, ..AcceleratorConfig::default() };
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let mapping = map_model(&model, &config, Dataflow::RowStationary);
        let synth = synthesize_clean(&config);
        energy_of(&mapping, &synth)
    }

    #[test]
    fn all_components_positive() {
        let e = eval(PeType::Int16);
        assert!(e.mac_uj > 0.0);
        assert!(e.spad_uj > 0.0);
        assert!(e.glb_uj > 0.0);
        assert!(e.dram_uj > 0.0);
        assert!(e.leakage_uj > 0.0);
        assert!((e.total_uj()
            - (e.mac_uj + e.spad_uj + e.glb_uj + e.dram_uj + e.leakage_uj))
            .abs()
            < 1e-12);
    }

    #[test]
    fn energy_ordering_matches_paper() {
        // Fig. 4: LightPE-1 < LightPE-2 < INT16 < FP32 in energy.
        let fp32 = eval(PeType::Fp32).total_uj();
        let int16 = eval(PeType::Int16).total_uj();
        let light2 = eval(PeType::LightPe2).total_uj();
        let light1 = eval(PeType::LightPe1).total_uj();
        assert!(fp32 > int16, "FP32 {fp32} vs INT16 {int16}");
        assert!(int16 > light2, "INT16 {int16} vs LightPE-2 {light2}");
        assert!(light2 >= light1, "LightPE-2 {light2} vs LightPE-1 {light1}");
    }

    #[test]
    fn lightpe_energy_gain_in_paper_band() {
        // Paper: LightPE-1 ≈ 4.7× less energy than best INT16 on average.
        // Same-config ratio should land in a compatible band (3–8×).
        let int16 = eval(PeType::Int16).total_uj();
        let light1 = eval(PeType::LightPe1).total_uj();
        let ratio = int16 / light1;
        assert!((2.0..10.0).contains(&ratio), "INT16/LightPE-1 energy ratio {ratio}");
    }

    #[test]
    fn onchip_fraction_bounded() {
        let e = eval(PeType::Int16);
        let f = e.onchip_fraction();
        assert!(f > 0.0 && f < 1.0);
    }

    #[test]
    fn totals_path_is_bit_identical_to_mapping_path() {
        let config = AcceleratorConfig::default();
        let model = model_for(ModelKind::ResNet56, Dataset::Cifar10);
        let mapping = map_model(&model, &config, Dataflow::RowStationary);
        let synth = synthesize_clean(&config);
        let via_mapping = energy_of(&mapping, &synth);
        let via_totals = energy_of_totals(&mapping.totals(), &synth);
        assert_eq!(via_mapping, via_totals);
    }

    #[test]
    fn edp_positive_and_consistent() {
        let config = AcceleratorConfig::default();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let mapping = map_model(&model, &config, Dataflow::RowStationary);
        let synth = synthesize_clean(&config);
        let product = edp(&mapping, &synth);
        let manual =
            energy_of(&mapping, &synth).total_uj() * mapping.latency_s(synth.achieved_clock_ghz);
        assert!((product - manual).abs() < 1e-12);
        assert!(product > 0.0);
    }
}
