//! Quantization numerics: PE types and their quantizers.
//!
//! The paper's design space has four processing-element types (§III-B):
//!
//! * **FP32** — IEEE-754 single-precision multiply-accumulate.
//! * **INT16** — 16-bit uniform affine (symmetric) weights and activations.
//! * **LightPE-1** — 8-bit activations, 4-bit power-of-two weights; the
//!   multiplier is replaced by **one shift** (LightNN-1 style, ref [6]).
//! * **LightPE-2** — 8-bit activations, 8-bit weights encoded as the sum of
//!   **two** powers of two; the multiplier is two shifts and an add
//!   (LightNN-2 style).
//!
//! These semantics are shared by the cycle-level simulator's golden model,
//! the synthesis engine (which sizes datapaths from the bit widths), and
//! mirrored exactly by the Pallas kernels in `python/compile/kernels/`.

pub mod quantizer;

pub use quantizer::{AffineQuantizer, Po2Quantizer, QuantizedTensor};

/// Processing element type — the paper's primary design-space axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PeType {
    /// IEEE-754 single-precision multiply-accumulate.
    Fp32,
    /// 16-bit uniform affine (symmetric) weights and activations.
    Int16,
    /// 8-bit activations, 4-bit power-of-two weights; one shift per MAC.
    LightPe1,
    /// 8-bit activations, 8-bit sum-of-two-powers weights; two shifts + add.
    LightPe2,
}

impl PeType {
    /// All PE types in the paper's presentation order.
    pub const ALL: [PeType; 4] = [PeType::Fp32, PeType::Int16, PeType::LightPe1, PeType::LightPe2];

    /// Display name matching the paper's figures.
    pub fn name(self) -> &'static str {
        match self {
            PeType::Fp32 => "FP32",
            PeType::Int16 => "INT16",
            PeType::LightPe1 => "LightPE-1",
            PeType::LightPe2 => "LightPE-2",
        }
    }

    /// Parse a user-facing name (case/dash insensitive).
    pub fn parse(text: &str) -> Option<PeType> {
        let key: String =
            text.chars().filter(|c| c.is_ascii_alphanumeric()).collect::<String>().to_lowercase();
        match key.as_str() {
            "fp32" | "float32" => Some(PeType::Fp32),
            "int16" => Some(PeType::Int16),
            "lightpe1" | "light1" | "lpe1" => Some(PeType::LightPe1),
            "lightpe2" | "light2" | "lpe2" => Some(PeType::LightPe2),
            _ => None,
        }
    }

    /// Activation datapath width in bits.
    pub fn act_bits(self) -> u32 {
        match self {
            PeType::Fp32 => 32,
            PeType::Int16 => 16,
            PeType::LightPe1 | PeType::LightPe2 => 8,
        }
    }

    /// Weight storage width in bits.
    pub fn weight_bits(self) -> u32 {
        match self {
            PeType::Fp32 => 32,
            PeType::Int16 => 16,
            PeType::LightPe1 => 4,
            PeType::LightPe2 => 8,
        }
    }

    /// Partial-sum accumulator width in bits (sized so accumulation over the
    /// largest supported reduction depth cannot overflow).
    pub fn psum_bits(self) -> u32 {
        match self {
            PeType::Fp32 => 32,
            PeType::Int16 => 48,
            PeType::LightPe1 => 24,
            PeType::LightPe2 => 24,
        }
    }

    /// Whether the multiplier is replaced by shift-add hardware.
    pub fn is_shift_add(self) -> bool {
        matches!(self, PeType::LightPe1 | PeType::LightPe2)
    }

    /// Whether the datapath is floating-point.
    pub fn is_float(self) -> bool {
        matches!(self, PeType::Fp32)
    }

    /// Number of shift units in the MAC (0 for multiplier-based PEs).
    pub fn shift_count(self) -> u32 {
        match self {
            PeType::LightPe1 => 1,
            PeType::LightPe2 => 2,
            _ => 0,
        }
    }
}

impl std::fmt::Display for PeType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Multiply `activation × weight` exactly as the PE hardware would, given
/// already-quantized integer codes. Used by the simulator golden model.
///
/// * `Int16`: plain integer product.
/// * `LightPe1`: weight code is (sign, exponent) — one arithmetic shift.
/// * `LightPe2`: weight code is (sign, e1, e2) — two shifts and an add.
///
/// # Panics
/// If the weight encoding does not match the PE type — the quantizer
/// only ever produces the matching encoding.
#[allow(clippy::panic)]
pub fn pe_multiply(pe: PeType, activation: i64, weight: QuantWeight) -> i64 {
    match (pe, weight) {
        (PeType::Int16, QuantWeight::Code(w)) => activation * w,
        (PeType::LightPe1, QuantWeight::Shift { sign, exp }) => {
            sign as i64 * (activation << exp)
        }
        (PeType::LightPe2, QuantWeight::TwoShift { sign, exp_hi, exp_lo }) => {
            let hi = activation << exp_hi;
            let lo = match exp_lo {
                Some(e) => activation << e,
                None => 0,
            };
            sign as i64 * (hi + lo)
        }
        (PeType::Fp32, QuantWeight::Code(w)) => activation * w, // exact path unused for fp
        _ => panic!("weight encoding does not match PE type {pe}"),
    }
}

/// Hardware weight encodings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantWeight {
    /// Plain two's-complement code (FP32 mantissa path / INT16).
    Code(i64),
    /// `sign * 2^exp` (LightPE-1).
    Shift { sign: i8, exp: u32 },
    /// `sign * (2^exp_hi + 2^exp_lo)` with optional second term (LightPE-2).
    TwoShift { sign: i8, exp_hi: u32, exp_lo: Option<u32> },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for pe in PeType::ALL {
            assert_eq!(PeType::parse(pe.name()), Some(pe));
        }
        assert_eq!(PeType::parse("lightpe-1"), Some(PeType::LightPe1));
        assert_eq!(PeType::parse("nope"), None);
    }

    #[test]
    fn bit_widths_match_paper() {
        assert_eq!(PeType::LightPe1.act_bits(), 8);
        assert_eq!(PeType::LightPe1.weight_bits(), 4);
        assert_eq!(PeType::LightPe2.act_bits(), 8);
        assert_eq!(PeType::LightPe2.weight_bits(), 8);
        assert_eq!(PeType::Int16.act_bits(), 16);
        assert_eq!(PeType::Fp32.weight_bits(), 32);
    }

    #[test]
    fn shift_multiply_matches_integer_multiply() {
        // LightPE-1: weight 8 = 2^3.
        let product = pe_multiply(PeType::LightPe1, 5, QuantWeight::Shift { sign: 1, exp: 3 });
        assert_eq!(product, 40);
        let negative =
            pe_multiply(PeType::LightPe1, 5, QuantWeight::Shift { sign: -1, exp: 1 });
        assert_eq!(negative, -10);
    }

    #[test]
    fn two_shift_multiply() {
        // LightPE-2: weight 12 = 2^3 + 2^2.
        let product = pe_multiply(
            PeType::LightPe2,
            7,
            QuantWeight::TwoShift { sign: 1, exp_hi: 3, exp_lo: Some(2) },
        );
        assert_eq!(product, 84);
        // Single-term encoding (exp_lo absent): weight 4.
        let single = pe_multiply(
            PeType::LightPe2,
            7,
            QuantWeight::TwoShift { sign: 1, exp_hi: 2, exp_lo: None },
        );
        assert_eq!(single, 28);
    }

    #[test]
    fn psum_width_covers_deep_reductions() {
        // Worst-case INT16 product is ~2^30; 2^18 accumulations need 48 bits.
        assert!(PeType::Int16.psum_bits() >= 16 + 16 + 16);
        assert!(PeType::LightPe1.psum_bits() >= 8 + 7 + 8);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn mismatched_encoding_panics() {
        pe_multiply(PeType::Int16, 1, QuantWeight::Shift { sign: 1, exp: 0 });
    }
}
