//! Quantizer implementations: uniform affine (symmetric) and power-of-two.
//!
//! The rust side quantizes with *round-to-nearest, ties-to-even* to match
//! `jnp.round` in the Pallas reference kernels bit-for-bit, so the simulator
//! golden model and the L1 kernel oracle agree.

use super::{PeType, QuantWeight};

/// Round half to even (banker's rounding) — matches `jnp.round`.
pub fn round_ties_even(x: f64) -> f64 {
    let floor = x.floor();
    let frac = x - floor;
    if frac > 0.5 {
        floor + 1.0
    } else if frac < 0.5 {
        floor
    } else if (floor as i64) % 2 == 0 {
        floor
    } else {
        floor + 1.0
    }
}

/// Symmetric uniform affine quantizer over `[-max_abs, max_abs]` with
/// `bits`-wide signed codes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AffineQuantizer {
    /// Signed code width in bits.
    pub bits: u32,
    /// Real value of one code step.
    pub scale: f64,
}

impl AffineQuantizer {
    /// Calibrate from the max-abs of the data (per-tensor symmetric).
    pub fn calibrate(bits: u32, data: &[f64]) -> Self {
        assert!(bits >= 2 && bits <= 32);
        let max_abs = data.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
        let qmax = ((1i64 << (bits - 1)) - 1) as f64;
        Self { bits, scale: max_abs / qmax }
    }

    /// Quantizer with an explicit scale.
    pub fn with_scale(bits: u32, scale: f64) -> Self {
        assert!(scale > 0.0);
        Self { bits, scale }
    }

    /// Largest positive code.
    pub fn qmax(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Quantize a real value to an integer code (saturating).
    pub fn quantize(&self, x: f64) -> i64 {
        let q = round_ties_even(x / self.scale) as i64;
        q.clamp(-self.qmax(), self.qmax())
    }

    /// Dequantize a code back to a real value.
    pub fn dequantize(&self, q: i64) -> f64 {
        q as f64 * self.scale
    }

    /// Fake-quantize (quantize then dequantize) — the QAT forward op.
    pub fn fake_quantize(&self, x: f64) -> f64 {
        self.dequantize(self.quantize(x))
    }
}

/// Power-of-two quantizer for LightPE weights.
///
/// * LightPE-1 (4-bit codes): values `±2^e`, `e ∈ [e_min, e_min+6]`, plus
///   exact zero — one barrel shift in hardware.
/// * LightPE-2 (8-bit codes): values `±(2^e1 + 2^e2)` or `±2^e1` — two
///   shifts and one add.
///
/// Exponents are *negative powers* for sub-unity weights: the hardware
/// folds the layer-wide `2^e_min` factor into the output scale, so shifts
/// are non-negative integers at the PE.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Po2Quantizer {
    /// Target PE type (fixes the exponent budget).
    pub pe: PeType,
    /// Smallest representable exponent (layer-calibrated).
    pub e_min: i32,
    /// Number of distinct exponents available.
    pub levels: u32,
}

impl Po2Quantizer {
    /// Calibrate exponent range from the weight distribution's max-abs.
    pub fn calibrate(pe: PeType, weights: &[f64]) -> Self {
        assert!(pe.is_shift_add(), "Po2Quantizer is for LightPE types");
        let max_abs = weights.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
        // Top exponent covers max_abs; levels span the code space.
        let e_max = max_abs.log2().ceil() as i32;
        let levels = match pe {
            PeType::LightPe1 => 7, // 4-bit: sign + 3-bit exponent code (one reserved for zero)
            PeType::LightPe2 => 7, // 8-bit: sign + two 3-bit exponent fields + zero flag
            _ => unreachable!(),
        };
        Self { pe, e_min: e_max - levels as i32 + 1, levels }
    }

    fn exponent_range(&self) -> (i32, i32) {
        (self.e_min, self.e_min + self.levels as i32 - 1)
    }

    /// Quantize a real weight to the nearest representable value, returning
    /// both the real value and the hardware encoding (shifts are relative to
    /// `e_min`, hence non-negative).
    pub fn quantize(&self, w: f64) -> (f64, QuantWeight) {
        let sign = if w < 0.0 { -1i8 } else { 1i8 };
        let mag = w.abs();
        let (e_lo, e_hi) = self.exponent_range();
        let zero_threshold = 2f64.powi(e_lo) / 2.0;
        if mag < zero_threshold {
            return (
                0.0,
                match self.pe {
                    PeType::LightPe1 => QuantWeight::Shift { sign: 0, exp: 0 },
                    _ => QuantWeight::TwoShift { sign: 0, exp_hi: 0, exp_lo: None },
                },
            );
        }
        match self.pe {
            PeType::LightPe1 => {
                // Nearest single power of two in value space.
                let mut best = (f64::INFINITY, e_lo);
                for e in e_lo..=e_hi {
                    let v = 2f64.powi(e);
                    let err = (v - mag).abs();
                    if err < best.0 {
                        best = (err, e);
                    }
                }
                let value = sign as f64 * 2f64.powi(best.1);
                let encoding =
                    QuantWeight::Shift { sign, exp: (best.1 - e_lo) as u32 };
                (value, encoding)
            }
            PeType::LightPe2 => {
                // Nearest single or two-term sum of powers of two.
                let mut best: (f64, f64, u32, Option<u32>) = (f64::INFINITY, 0.0, 0, None);
                for e1 in e_lo..=e_hi {
                    let v1 = 2f64.powi(e1);
                    let err1 = (v1 - mag).abs();
                    if err1 < best.0 {
                        best = (err1, v1, (e1 - e_lo) as u32, None);
                    }
                    for e2 in e_lo..e1 {
                        let v2 = v1 + 2f64.powi(e2);
                        let err2 = (v2 - mag).abs();
                        if err2 < best.0 {
                            best = (err2, v2, (e1 - e_lo) as u32, Some((e2 - e_lo) as u32));
                        }
                    }
                }
                let value = sign as f64 * best.1;
                (value, QuantWeight::TwoShift { sign, exp_hi: best.2, exp_lo: best.3 })
            }
            _ => unreachable!(),
        }
    }

    /// Fake-quantize a weight (value domain only).
    pub fn fake_quantize(&self, w: f64) -> f64 {
        self.quantize(w).0
    }

    /// The layer-wide output scale factor `2^e_min` the hardware folds out.
    pub fn output_scale(&self) -> f64 {
        2f64.powi(self.e_min)
    }
}

/// A quantized tensor: integer codes plus the shared scale.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedTensor {
    /// Integer codes, one per element.
    pub codes: Vec<i64>,
    /// Shared real value of one code step.
    pub scale: f64,
    /// Signed code width in bits.
    pub bits: u32,
}

impl QuantizedTensor {
    /// Quantize a real tensor with a calibrated symmetric affine quantizer.
    pub fn from_f64(bits: u32, data: &[f64]) -> Self {
        let q = AffineQuantizer::calibrate(bits, data);
        Self { codes: data.iter().map(|&x| q.quantize(x)).collect(), scale: q.scale, bits }
    }

    /// Dequantize back to real values.
    pub fn to_f64(&self) -> Vec<f64> {
        self.codes.iter().map(|&c| c as f64 * self.scale).collect()
    }

    /// Worst-case quantization error bound: half a step.
    pub fn error_bound(&self) -> f64 {
        self.scale / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_ties_even_matches_numpy() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), -0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(0.49), 0.0);
        assert_eq!(round_ties_even(0.51), 1.0);
    }

    #[test]
    fn affine_roundtrip_error_bounded() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64 - 50.0) / 17.0).collect();
        let q = AffineQuantizer::calibrate(8, &data);
        for &x in &data {
            let err = (q.fake_quantize(x) - x).abs();
            assert!(err <= q.scale / 2.0 + 1e-12, "err {err} scale {}", q.scale);
        }
    }

    #[test]
    fn affine_saturates() {
        let q = AffineQuantizer::with_scale(8, 0.1);
        assert_eq!(q.quantize(1e9), q.qmax());
        assert_eq!(q.quantize(-1e9), -q.qmax());
    }

    #[test]
    fn affine_higher_bits_lower_error() {
        let data: Vec<f64> = (0..1000).map(|i| ((i * 37) % 997) as f64 / 99.0 - 5.0).collect();
        let mut last_err = f64::INFINITY;
        for bits in [4, 8, 16] {
            let q = AffineQuantizer::calibrate(bits, &data);
            let err: f64 =
                data.iter().map(|&x| (q.fake_quantize(x) - x).abs()).sum::<f64>() / 1000.0;
            assert!(err < last_err, "bits={bits} err={err} last={last_err}");
            last_err = err;
        }
    }

    #[test]
    fn po2_exact_on_powers() {
        let q = Po2Quantizer { pe: PeType::LightPe1, e_min: -6, levels: 7 };
        for e in -6..=0 {
            let w = 2f64.powi(e);
            let (v, enc) = q.quantize(w);
            assert_eq!(v, w);
            match enc {
                QuantWeight::Shift { sign: 1, exp } => assert_eq!(exp as i32, e + 6),
                other => panic!("unexpected encoding {other:?}"),
            }
        }
    }

    #[test]
    fn po2_two_term_beats_one_term() {
        // 0.75 = 2^-1 + 2^-2 is exact for LightPE-2, inexact for LightPE-1.
        let q1 = Po2Quantizer { pe: PeType::LightPe1, e_min: -6, levels: 7 };
        let q2 = Po2Quantizer { pe: PeType::LightPe2, e_min: -6, levels: 7 };
        let err1 = (q1.fake_quantize(0.75) - 0.75).abs();
        let err2 = (q2.fake_quantize(0.75) - 0.75).abs();
        assert!(err2 < 1e-12, "LightPE-2 should be exact on 0.75, err {err2}");
        assert!(err1 > 1e-3, "LightPE-1 cannot represent 0.75 exactly");
    }

    #[test]
    fn po2_zero_below_threshold() {
        let q = Po2Quantizer { pe: PeType::LightPe1, e_min: -6, levels: 7 };
        let (v, enc) = q.quantize(1e-9);
        assert_eq!(v, 0.0);
        assert_eq!(enc, QuantWeight::Shift { sign: 0, exp: 0 });
    }

    #[test]
    fn po2_sign_preserved() {
        let q = Po2Quantizer { pe: PeType::LightPe2, e_min: -6, levels: 7 };
        let (v, _) = q.quantize(-0.5);
        assert!(v < 0.0);
        assert_eq!(v, -0.5);
    }

    #[test]
    fn po2_calibration_covers_max() {
        let weights: Vec<f64> = vec![0.9, -0.4, 0.02, 0.3];
        let q = Po2Quantizer::calibrate(PeType::LightPe1, &weights);
        // Max representable must reach at least max_abs.
        let top = 2f64.powi(q.e_min + q.levels as i32 - 1);
        assert!(top >= 0.9, "top representable {top}");
    }

    #[test]
    fn quantized_tensor_roundtrip() {
        let data = vec![0.1, -0.5, 0.33, 0.0, 0.49];
        let qt = QuantizedTensor::from_f64(8, &data);
        let back = qt.to_f64();
        for (orig, rec) in data.iter().zip(&back) {
            assert!((orig - rec).abs() <= qt.error_bound() + 1e-12);
        }
    }
}
