//! Pluggable search strategies: which design points a campaign actually
//! evaluates.
//!
//! A [`Strategy`] maps a (sharded) design space to a [`Selection`] of
//! shard positions *before* any evaluation happens, so the
//! [`Explorer`](crate::explore::Explorer) can walk a subspace instead of
//! the full cross-product. Selections are deterministic functions of the
//! strategy's own parameters, which is what lets checkpoint journals pin
//! a strategy [`descriptor`](Strategy::descriptor) and resume exactly
//! the campaign they were written for.
//!
//! Built-in strategies:
//!
//! * [`Exhaustive`] — every point (the default when no strategy is set).
//! * [`RandomSample`] — `n` distinct points drawn without replacement
//!   from a seeded PCG64; the classic QUIDAM-style subsampling baseline.
//! * [`SuccessiveHalving`] — ranks candidates with a cheap analytic
//!   perf/area proxy at increasing model fidelity, halving the pool each
//!   round, so the expensive synthesis + mapping pipeline only ever runs
//!   on the survivors.

use std::collections::BTreeSet;
use std::fmt;

use crate::arch::{AcceleratorConfig, DesignSpace, ModelVariant};
use crate::dnn::{lower_workload, Model};
use crate::error::{Error, Result};
use crate::util::rng::Pcg64;

/// Everything a strategy may consult when selecting points. Borrowed
/// from the explorer for the duration of the selection only.
#[derive(Debug, Clone, Copy)]
pub struct StrategyContext<'a> {
    /// The joint hardware × model design space being explored. A
    /// hardware-only campaign carries trivial model axes, so positions
    /// decode exactly as they always have.
    pub space: &'a DesignSpace,
    /// The *base* workload model set, in evaluation order (before any
    /// model-axes scaling — cheap proxies rank against the base shapes).
    pub models: &'a [Model],
    /// The campaign's synthesis seed (strategies needing randomness
    /// should carry their own seed so the descriptor pins it).
    pub seed: u64,
    /// Round-robin shard designator `(shard, num_shards)`.
    pub shard: (usize, usize),
    /// Number of shard positions available (the shard-aware point count);
    /// shard position `p` maps to joint cross-product index
    /// `shard + p * num_shards`.
    pub positions: usize,
}

impl StrategyContext<'_> {
    /// Decode the hardware configuration at shard position `pos`.
    ///
    /// # Panics
    /// If `pos >= self.positions`.
    #[allow(clippy::expect_used)] // the panic is this accessor's documented contract
    pub fn config_at(&self, pos: usize) -> AcceleratorConfig {
        let (shard, num_shards) = self.shard;
        self.space
            .get(shard + pos * num_shards)
            .expect("shard position within joint cross-product")
            .config
    }

    /// Decode the model variant at shard position `pos`.
    ///
    /// # Panics
    /// If `pos >= self.positions`.
    #[allow(clippy::expect_used)] // the panic is this accessor's documented contract
    pub fn variant_at(&self, pos: usize) -> ModelVariant {
        let (shard, num_shards) = self.shard;
        self.space
            .variant_of(shard + pos * num_shards)
            .expect("shard position within joint cross-product")
    }
}

/// The outcome of a strategy: which shard positions to evaluate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Selection {
    /// Every position in the (sharded) space, enumerated lazily — the
    /// exhaustive walk never materializes the space.
    All,
    /// An explicit subset of shard positions. Must be strictly ascending
    /// and within bounds; the explorer rejects malformed selections with
    /// [`Error::InvalidConfig`].
    Subset(Vec<usize>),
}

impl Selection {
    /// Number of positions selected, given the space holds `positions`.
    pub fn len(&self, positions: usize) -> usize {
        match self {
            Selection::All => positions,
            Selection::Subset(subset) => subset.len(),
        }
    }

    /// Validate a subset against the space: strictly ascending, in
    /// bounds, non-empty.
    pub fn validate(&self, positions: usize) -> Result<()> {
        let Selection::Subset(subset) = self else { return Ok(()) };
        let Some(&last) = subset.last() else {
            return Err(Error::InvalidConfig("strategy selected no design points".into()));
        };
        let ascending = subset.windows(2).all(|w| w[0] < w[1]);
        if !ascending || last >= positions {
            return Err(Error::InvalidConfig(
                "strategy selection must be strictly ascending shard positions \
                 within the design space"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// One pruning round of a multi-round strategy, as reported through
/// [`Strategy::select_observed`] — the rows of the `qadam trace show`
/// strategy funnel table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundReport {
    /// Round index, starting at 0.
    pub round: usize,
    /// Candidate positions entering the round.
    pub entered: usize,
    /// Positions surviving the round's cut.
    pub kept: usize,
}

/// A design-space search strategy. Implementations must be deterministic
/// in their own fields: the same strategy over the same space always
/// selects the same points (the checkpoint journal pins
/// [`Self::descriptor`] and replays against it).
pub trait Strategy: fmt::Debug + Send + Sync {
    /// Stable one-line identity (name + parameters), e.g.
    /// `random:1000:7`. Pinned in checkpoint-journal manifests; two
    /// strategies with equal descriptors must produce equal selections.
    fn descriptor(&self) -> String;

    /// Choose the shard positions to evaluate.
    fn select(&self, ctx: &StrategyContext<'_>) -> Result<Selection>;

    /// [`Self::select`], additionally reporting each pruning round to
    /// `observer` for tracing. The default forwards to `select` and
    /// reports nothing (single-round strategies have no funnel);
    /// multi-round strategies override it, and their `select` must stay
    /// behaviorally identical — the observer only watches.
    fn select_observed(
        &self,
        ctx: &StrategyContext<'_>,
        observer: &mut dyn FnMut(RoundReport),
    ) -> Result<Selection> {
        let _ = observer;
        self.select(ctx)
    }
}

/// Evaluate every design point — the default campaign behavior, made
/// explicit so `--strategy exhaustive` round-trips.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Exhaustive;

impl Strategy for Exhaustive {
    fn descriptor(&self) -> String {
        "exhaustive".into()
    }

    fn select(&self, _ctx: &StrategyContext<'_>) -> Result<Selection> {
        Ok(Selection::All)
    }
}

/// Evaluate `n` design points drawn uniformly without replacement.
///
/// Sampling uses Floyd's algorithm over a PCG64 stream seeded by
/// `seed` alone, so the selection depends only on `(n, seed, space
/// size)` — rerunning the same campaign touches the same points. When
/// `n` covers the whole space the selection degrades to
/// [`Selection::All`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RandomSample {
    /// Number of design points to evaluate.
    pub n: usize,
    /// Sampling seed (independent of the synthesis seed).
    pub seed: u64,
}

impl Strategy for RandomSample {
    fn descriptor(&self) -> String {
        format!("random:{}:{}", self.n, self.seed)
    }

    fn select(&self, ctx: &StrategyContext<'_>) -> Result<Selection> {
        if self.n == 0 {
            return Err(Error::InvalidConfig(
                "strategy 'random:0' selects an empty design space: the sample count must be \
                 at least 1"
                    .into(),
            ));
        }
        if self.n >= ctx.positions {
            return Ok(Selection::All);
        }
        // Floyd's sampling: n distinct values from [0, positions) with
        // exactly n RNG draws; BTreeSet keeps the result ascending.
        let mut rng = Pcg64::new(self.seed);
        let mut chosen: BTreeSet<usize> = BTreeSet::new();
        for j in (ctx.positions - self.n)..ctx.positions {
            let t = rng.below(j as u64 + 1) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        Ok(Selection::Subset(chosen.into_iter().collect()))
    }
}

/// Successive halving over a cheap analytic perf/area proxy.
///
/// All candidates start in the pool; each round re-scores the survivors
/// with [`proxy_perf_per_area`] at increasing model fidelity (the number
/// of workload layers the proxy considers doubles every round until the
/// full model set is in view) and keeps the better-scoring half, until
/// at most `keep` candidates remain. Only those survivors reach the real
/// synthesis + mapping pipeline, so the expensive work scales with
/// `keep`, not with the space.
///
/// The proxy is deliberately crude — datapath-width area estimates and a
/// row-stationary occupancy guess — but it is monotone enough to steer
/// the pool toward the high-perf/area region, and it is exact about
/// which points were selected: the selection is a deterministic function
/// of `(keep, rounds, space, model set)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SuccessiveHalving {
    /// Number of surviving candidates to fully evaluate.
    pub keep: usize,
    /// Halving rounds (the last round always scores at full fidelity).
    pub rounds: usize,
}

impl Strategy for SuccessiveHalving {
    fn descriptor(&self) -> String {
        format!("halving:{}:{}", self.keep, self.rounds)
    }

    fn select(&self, ctx: &StrategyContext<'_>) -> Result<Selection> {
        self.select_observed(ctx, &mut |_| {})
    }

    fn select_observed(
        &self,
        ctx: &StrategyContext<'_>,
        observer: &mut dyn FnMut(RoundReport),
    ) -> Result<Selection> {
        if self.keep == 0 || self.rounds == 0 {
            return Err(Error::InvalidConfig(
                "halving strategy needs keep >= 1 and rounds >= 1".into(),
            ));
        }
        if self.keep >= ctx.positions {
            return Ok(Selection::All);
        }
        // Joint campaigns: score each position against its variant's
        // *scaled* workload — the same `lower_workload` lowering the
        // explorer evaluates — otherwise every variant block of the
        // same hardware config would score identically and the position
        // tie-break would silently keep only the first variant.
        let (shard, num_shards) = ctx.shard;
        let variant_workloads = lower_workload(&ctx.space.model, ctx.models);
        let variant_of = |pos: usize| ctx.space.variant_index(shard + pos * num_shards);
        let max_layers = variant_workloads
            .iter()
            .flatten()
            .map(|m| m.compute_layers().count())
            .max()
            .unwrap_or(1)
            .max(1);
        let mut survivors: Vec<usize> = (0..ctx.positions).collect();
        for round in 0..self.rounds {
            if survivors.len() <= self.keep {
                break;
            }
            // Fidelity ladder: 1/2^(rounds-1-round) of the layers, so the
            // final round always scores the full workload.
            let shift = self.rounds - 1 - round;
            let layer_budget = (max_layers >> shift.min(63)).max(1);
            let mut scored: Vec<(f64, usize)> = survivors
                .iter()
                .map(|&pos| {
                    (
                        proxy_perf_per_area(
                            &ctx.config_at(pos),
                            &variant_workloads[variant_of(pos)],
                            layer_budget,
                        ),
                        pos,
                    )
                })
                .collect();
            // Best proxy score first; ties resolve to the lower position
            // so the ranking is total and deterministic.
            scored.sort_by(|a, b| {
                b.0.partial_cmp(&a.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.1.cmp(&b.1))
            });
            let target = if round + 1 == self.rounds {
                self.keep
            } else {
                (survivors.len() / 2).max(self.keep)
            };
            let entered = survivors.len();
            survivors = scored.into_iter().take(target).map(|(_, pos)| pos).collect();
            observer(RoundReport { round, entered, kept: survivors.len() });
        }
        survivors.truncate(self.keep);
        survivors.sort_unstable();
        Ok(Selection::Subset(survivors))
    }
}

/// Cheap analytic perf/area proxy (arbitrary units, higher is better):
/// no synthesis, no mapper — datapath bit-width area estimates and a
/// row-stationary occupancy guess over the first `layer_budget` compute
/// layers of each model. O(layers) per call.
pub fn proxy_perf_per_area(
    config: &AcceleratorConfig,
    models: &[Model],
    layer_budget: usize,
) -> f64 {
    let pe = config.pe;
    // Area proxy: a multiplier scales with act×weight bits, a shift-add
    // datapath with the shifter count; scratchpads and the GLB add their
    // storage bits at SRAM-ish density.
    let mac_units = if pe.is_shift_add() {
        pe.act_bits() as f64 * (4.0 + 4.0 * pe.shift_count() as f64)
    } else {
        pe.act_bits() as f64 * pe.weight_bits() as f64
    };
    let pe_units = mac_units + 0.25 * config.spad.total_bits(pe) as f64;
    let area = config.num_pes() as f64 * pe_units + 4.0 * config.glb_bytes() as f64;
    // Perf proxy: ideal MAC cycles inflated by a row-stationary occupancy
    // guess (kernel rows fill array rows, output rows fill columns).
    let mut cycles = 0.0f64;
    for model in models {
        for layer in model.compute_layers().take(layer_budget) {
            let rows_busy = (layer.kernel as f64 / config.rows as f64).min(1.0);
            let cols_busy = (layer.out_hw() as f64 / config.cols as f64).min(1.0);
            let occupancy = (rows_busy * cols_busy).max(1e-3);
            cycles += layer.macs() as f64 / (config.num_pes() as f64 * occupancy);
        }
    }
    if cycles <= 0.0 {
        return 0.0;
    }
    let inferences_per_s = config.clock_ghz * 1e9 / cycles;
    inferences_per_s / area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ModelAxes, SweepSpec};
    use crate::dnn::{models_for, Dataset};

    fn ctx<'a>(space: &'a DesignSpace, models: &'a [Model]) -> StrategyContext<'a> {
        StrategyContext { space, models, seed: 7, shard: (0, 1), positions: space.len() }
    }

    #[test]
    fn exhaustive_selects_all() {
        let space = DesignSpace::from(SweepSpec::tiny());
        let models = models_for(Dataset::Cifar10);
        assert_eq!(Exhaustive.select(&ctx(&space, &models)).unwrap(), Selection::All);
        assert_eq!(Exhaustive.descriptor(), "exhaustive");
    }

    #[test]
    fn random_sample_is_deterministic_and_in_bounds() {
        let space = DesignSpace::from(SweepSpec::default());
        let models = models_for(Dataset::Cifar10);
        let strategy = RandomSample { n: 17, seed: 42 };
        let a = strategy.select(&ctx(&space, &models)).unwrap();
        let b = strategy.select(&ctx(&space, &models)).unwrap();
        assert_eq!(a, b, "same seed must select the same points");
        let Selection::Subset(positions) = a else { panic!("expected a subset") };
        assert_eq!(positions.len(), 17);
        assert!(positions.windows(2).all(|w| w[0] < w[1]), "ascending & distinct");
        assert!(*positions.last().unwrap() < space.len());
        let c = RandomSample { n: 17, seed: 43 }.select(&ctx(&space, &models)).unwrap();
        assert_ne!(a, c, "different seeds should (overwhelmingly) differ");
    }

    #[test]
    fn random_sample_covering_space_is_all() {
        let space = DesignSpace::from(SweepSpec::tiny());
        let models = models_for(Dataset::Cifar10);
        let selection =
            RandomSample { n: space.len() + 5, seed: 1 }.select(&ctx(&space, &models)).unwrap();
        assert_eq!(selection, Selection::All);
    }

    #[test]
    fn random_sample_rejects_zero() {
        let space = DesignSpace::from(SweepSpec::tiny());
        let models = models_for(Dataset::Cifar10);
        let err = RandomSample { n: 0, seed: 1 }.select(&ctx(&space, &models)).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("random:0"), "{err}");
    }

    #[test]
    fn halving_keeps_exactly_keep_points() {
        let space = DesignSpace::from(SweepSpec::default());
        let models = models_for(Dataset::Cifar10);
        let strategy = SuccessiveHalving { keep: 9, rounds: 3 };
        let Selection::Subset(positions) = strategy.select(&ctx(&space, &models)).unwrap()
        else {
            panic!("expected a subset")
        };
        assert_eq!(positions.len(), 9);
        assert!(positions.windows(2).all(|w| w[0] < w[1]));
        // Deterministic: a second run selects the same survivors.
        let again = strategy.select(&ctx(&space, &models)).unwrap();
        assert_eq!(again, Selection::Subset(positions));
    }

    #[test]
    fn halving_prefers_high_proxy_scores() {
        let space = DesignSpace::from(SweepSpec::default());
        let models = models_for(Dataset::Cifar10);
        let context = ctx(&space, &models);
        let Selection::Subset(positions) =
            SuccessiveHalving { keep: 8, rounds: 2 }.select(&context).unwrap()
        else {
            panic!("expected a subset")
        };
        // Survivors should score at least as well (at full fidelity) as
        // the median of the space — the proxy actually steered.
        let full = space.len();
        let score =
            |pos: usize| proxy_perf_per_area(&context.config_at(pos), &models, usize::MAX);
        let mut all: Vec<f64> = (0..full).map(score).collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = all[full / 2];
        let surviving_best = positions.iter().map(|&p| score(p)).fold(f64::MIN, f64::max);
        assert!(surviving_best >= median, "halving survivors must not all be below median");
    }

    #[test]
    fn joint_halving_scores_each_variant_on_its_scaled_workload() {
        use crate::dnn::{model_for, ModelKind};
        // Base model first, slim variant second: the slim variant has
        // strictly fewer MACs on identical hardware, so its proxy
        // perf/area is strictly higher — every survivor must come from
        // the *second* variant block. (Under variant-blind scoring the
        // position tie-break would have kept the first block instead.)
        let space = DesignSpace::new(
            SweepSpec::tiny(),
            ModelAxes { width_mults: vec![1.0, 0.25], depth_mults: vec![1] },
        );
        let models = vec![model_for(ModelKind::ResNet20, Dataset::Cifar10)];
        let context = ctx(&space, &models);
        let Selection::Subset(positions) =
            SuccessiveHalving { keep: 3, rounds: 2 }.select(&context).unwrap()
        else {
            panic!("expected a subset")
        };
        let hw_len = space.hw.len();
        assert!(
            positions.iter().all(|&p| p >= hw_len),
            "survivors must come from the slim variant block: {positions:?}"
        );
    }

    #[test]
    fn joint_context_decodes_variants() {
        let space = DesignSpace::new(
            SweepSpec::tiny(),
            ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] },
        );
        let models = models_for(Dataset::Cifar10);
        let context = ctx(&space, &models);
        let hw_len = space.hw.len();
        assert_eq!(context.variant_at(0).width, 0.5);
        assert_eq!(context.variant_at(hw_len).width, 1.0);
        // Hardware configs repeat per variant block.
        assert_eq!(context.config_at(0), context.config_at(hw_len));
    }

    #[test]
    fn selection_validation_catches_malformed_subsets() {
        assert!(Selection::Subset(vec![]).validate(10).is_err());
        assert!(Selection::Subset(vec![3, 3]).validate(10).is_err());
        assert!(Selection::Subset(vec![5, 2]).validate(10).is_err());
        assert!(Selection::Subset(vec![2, 10]).validate(10).is_err());
        assert!(Selection::Subset(vec![0, 2, 9]).validate(10).is_ok());
        assert!(Selection::All.validate(0).is_ok());
    }
}
