//! Online multi-objective Pareto engine and pluggable search strategies.
//!
//! QADAM's headline result is a Pareto front over accuracy × perf/area ×
//! energy (Figs. 5–6). The seed reproduction computed those fronts
//! post-hoc over a fully materialized database; this module makes the
//! frontier an *online* object and the walk of the design space a
//! *strategy*, so million-point spaces become tractable:
//!
//! * [`front`] — [`ParetoFront`]`<const K>` maintains a dominance-pruned
//!   frontier incrementally, O(front) per insert, with deterministic
//!   tie-breaking so the streamed front is byte-identical to the batch
//!   computation ([`crate::dse::pareto_front`], now itself routed
//!   through this engine). Epsilon-dominance and budgeted (top-N
//!   contribution) archive variants bound memory when exactness is not
//!   required.
//! * [`strategy`] — the [`Strategy`] trait decides *which* design points
//!   a campaign evaluates: [`Exhaustive`], [`RandomSample`] (n points,
//!   seeded), or [`SuccessiveHalving`] over a cheap analytic PPA proxy.
//!   Attach with [`Explorer::strategy`](crate::explore::Explorer::strategy)
//!   or `qadam dse --strategy random:1000`.
//! * [`frontier`] — [`CampaignFrontier`] wires per-model fronts into the
//!   explorer's streaming delivery
//!   ([`Explorer::frontier`](crate::explore::Explorer::frontier)), so the
//!   front is available *live during* a campaign and persists through
//!   the canonical-JSON layer (`qadam dse --frontier front.json`).
//!
//! See `DESIGN.md` §5 for the data structures and the strategy contract.

pub mod front;
pub mod frontier;
pub mod strategy;

pub use front::{dominates, FrontCore, FrontEntry, InsertOutcome, Orientation, ParetoFront};
pub use frontier::{
    parallel_model_front, CampaignFrontier, FrontierBinding, FrontSample, ModelFrontier,
    OBJECTIVES,
};
pub use strategy::{
    proxy_perf_per_area, Exhaustive, RandomSample, RoundReport, Selection, Strategy,
    StrategyContext, SuccessiveHalving,
};
