//! The live campaign frontier: per-model streaming Pareto fronts over
//! the paper's two hardware axes, maintained while a campaign runs.
//!
//! Attach a shared handle with
//! [`Explorer::frontier`](crate::explore::Explorer::frontier) and the
//! explorer inserts every delivered evaluation into a per-model
//! [`ParetoFront`] over **(performance per area ↑, energy per inference
//! ↓)** — so the frontier is inspectable mid-campaign from another
//! thread, and a million-point sweep only ever retains O(front) of its
//! results. Fronts persist through the same schema-versioned
//! canonical-JSON layer as every other campaign artifact
//! (`qadam dse --frontier front.json`), so saved fronts diff cleanly.

use std::path::Path;
use std::sync::{Mutex, PoisonError};

use super::front::{InsertOutcome, Orientation, ParetoFront};
use crate::dse::Evaluation;
use crate::error::{Error, Result};
use crate::explore::EvalDatabase;
use crate::explore::persist::{
    check_envelope, envelope, field_arr, field_str, field_usize, write_atomic,
};
use crate::util::json::{num, obj, s, Json};

/// The frontier's fixed objectives: maximize performance per area,
/// minimize on-chip energy per inference (the paper's §III axes).
pub const OBJECTIVES: [Orientation; 2] = [Orientation::Maximize, Orientation::Minimize];

/// Identity of the campaign a frontier is bound to — the same fields the
/// checkpoint journal's manifest pins (minus the point count). Rebinding
/// a frontier to a campaign with any differing field is rejected, so
/// fronts from incomparable campaigns can never silently merge.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierBinding {
    /// [`DesignSpace::fingerprint`](crate::arch::DesignSpace::fingerprint)
    /// of the campaign's *joint* space — equal to the bare
    /// [`SweepSpec::fingerprint`](crate::arch::SweepSpec::fingerprint)
    /// for hardware-only campaigns, and covering the model axes for
    /// joint ones, so fronts built under different model axes can never
    /// silently merge.
    pub spec_fingerprint: u64,
    /// Synthesis-noise seed of the campaign.
    pub seed: u64,
    /// Round-robin shard designator `(shard, num_shards)`.
    pub shard: (usize, usize),
    /// Dataset label of the workload set.
    pub dataset: String,
    /// Search-strategy descriptor (`"exhaustive"` when none is set).
    pub strategy: String,
    /// Model names in evaluation order.
    pub models: Vec<String>,
}

impl FrontierBinding {
    fn ensure_matches(&self, other: &FrontierBinding) -> Result<()> {
        if self == other {
            return Ok(());
        }
        Err(Error::InvalidConfig(format!(
            "frontier was bound to a different campaign (bound: sweep {:016x}, seed {}, \
             shard {}/{}, {}, strategy '{}'; this campaign: sweep {:016x}, seed {}, shard \
             {}/{}, {}, strategy '{}')",
            self.spec_fingerprint,
            self.seed,
            self.shard.0,
            self.shard.1,
            self.dataset,
            self.strategy,
            other.spec_fingerprint,
            other.seed,
            other.shard.0,
            other.shard.1,
            other.dataset,
            other.strategy,
        )))
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("spec_fingerprint", s(&format!("{:016x}", self.spec_fingerprint))),
            ("seed", s(&format!("{:016x}", self.seed))),
            ("shard", num(self.shard.0 as f64)),
            ("num_shards", num(self.shard.1 as f64)),
            ("dataset", s(&self.dataset)),
            ("strategy", s(&self.strategy)),
            ("models", Json::Arr(self.models.iter().map(|m| s(m)).collect())),
        ])
    }

    fn from_json(json: &Json) -> Result<Self> {
        let hex_field = |key: &str| -> Result<u64> {
            let text = field_str(json, key)?;
            u64::from_str_radix(text, 16).map_err(|_| {
                Error::ParseError(format!("frontier binding field '{key}' is not a hex u64"))
            })
        };
        Ok(Self {
            spec_fingerprint: hex_field("spec_fingerprint")?,
            seed: hex_field("seed")?,
            shard: (field_usize(json, "shard")?, field_usize(json, "num_shards")?),
            dataset: field_str(json, "dataset")?.to_string(),
            strategy: field_str(json, "strategy")?.to_string(),
            models: field_arr(json, "models")?
                .iter()
                .map(|m| {
                    m.as_str().map(str::to_string).ok_or_else(|| {
                        Error::ParseError("frontier binding model names must be strings".into())
                    })
                })
                .collect::<Result<_>>()?,
        })
    }
}

/// One archived design point: where it sits in the sweep and its full
/// evaluation (so saved fronts can be re-plotted without the database).
#[derive(Debug, Clone)]
pub struct FrontSample {
    /// Cross-product index of the design point in its sweep.
    pub index: usize,
    /// The complete evaluation that put this point on the front.
    pub eval: Evaluation,
}

/// Fold a slice of evaluations into the campaign's two-objective front
/// (perf/area ↑, energy ↓) with sharded workers: each worker builds an
/// exact-mode sub-front over a contiguous chunk, offering every point under
/// its *global* slice index ([`ParetoFront::offer_seq`]), and a
/// deterministic tree-merge ([`ParetoFront::merge_all`]) reduces the shards.
/// The result is bit-identical — entries, plotting order, indices, and
/// `offered` — to folding the slice through one sequential
/// [`ParetoFront::insert`] loop, for any worker count.
///
/// Each archived [`FrontSample::index`] is the point's position in `evals`,
/// which for a whole-space exhaustive campaign database equals the sweep's
/// cross-product index.
pub fn parallel_model_front(evals: &[Evaluation], workers: usize) -> ParetoFront<2, FrontSample> {
    let workers = workers.clamp(1, evals.len().max(1));
    let chunk = evals.len().div_ceil(workers).max(1);
    let shards: Mutex<Vec<(usize, ParetoFront<2, FrontSample>)>> =
        Mutex::new(Vec::with_capacity(workers));
    std::thread::scope(|scope| {
        for (shard_idx, slice) in evals.chunks(chunk).enumerate() {
            let shards = &shards;
            scope.spawn(move || {
                let mut front = ParetoFront::new(OBJECTIVES);
                let base = shard_idx * chunk;
                for (off, eval) in slice.iter().enumerate() {
                    let index = base + off;
                    front.offer_seq(
                        index,
                        [eval.perf_per_area, eval.energy_uj],
                        FrontSample { index, eval: eval.clone() },
                    );
                }
                shards.lock().unwrap_or_else(PoisonError::into_inner).push((shard_idx, front));
            });
        }
    });
    let mut shards = shards.into_inner().unwrap_or_else(PoisonError::into_inner);
    // Merge in shard order so the reduction tree (and every internal
    // counter, not just the provably order-free entry set) is deterministic.
    shards.sort_by_key(|(idx, _)| *idx);
    ParetoFront::merge_all(shards.into_iter().map(|(_, front)| front).collect())
        .unwrap_or_else(|| ParetoFront::new(OBJECTIVES))
}

/// One model's streaming front.
#[derive(Debug, Clone)]
pub struct ModelFrontier {
    model_name: String,
    front: ParetoFront<2, FrontSample>,
}

impl ModelFrontier {
    /// The workload model this front belongs to.
    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    /// The underlying two-objective front.
    pub fn front(&self) -> &ParetoFront<2, FrontSample> {
        &self.front
    }
}

/// Per-model streaming Pareto fronts for one campaign (see the module
/// docs). Created empty; the explorer binds the model set at stream
/// start and feeds every delivered point.
///
/// Fronts are per *base* model family: in a joint hardware × model
/// campaign every delivered point carries one evaluation per base
/// model (scaled to that point's width/depth variant), so each base
/// model's front accumulates points from **all** of its variants — the
/// joint Pareto set of the family. Use each archived
/// [`FrontSample::index`] with
/// [`DesignSpace::variant_of`](crate::arch::DesignSpace::variant_of)
/// to recover which variant produced a front point.
#[derive(Debug, Clone, Default)]
pub struct CampaignFrontier {
    epsilon: Option<[f64; 2]>,
    capacity: Option<usize>,
    binding: Option<FrontierBinding>,
    /// Campaign-ordered observation cursor: how many delivery positions
    /// [`Self::observe_at`] has consumed. Checkpoint replay (and the
    /// re-delivery of journal-lost tail points) re-offers bit-identical
    /// evaluations of positions below this cursor, so they are skipped
    /// instead of archived twice.
    observed: usize,
    models: Vec<ModelFrontier>,
}

impl CampaignFrontier {
    /// Empty frontier in exact mode.
    pub fn new() -> Self {
        Self::default()
    }

    /// Use epsilon-dominance archives (see
    /// [`ParetoFront::with_epsilon`]); must be set before the first
    /// campaign binds the frontier.
    pub fn with_epsilon(mut self, epsilon: [f64; 2]) -> Self {
        self.epsilon = Some(epsilon);
        self
    }

    /// Bound each model's archive to `capacity` entries (see
    /// [`ParetoFront::with_capacity`]).
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = Some(capacity);
        self
    }

    fn make_front(&self) -> ParetoFront<2, FrontSample> {
        let mut front = ParetoFront::new(OBJECTIVES);
        if let Some(epsilon) = self.epsilon {
            front = front.with_epsilon(epsilon);
        }
        if let Some(capacity) = self.capacity {
            front = front.with_capacity(capacity);
        }
        front
    }

    /// Bind the frontier to a campaign (called by the explorer at stream
    /// start). A fresh frontier records the campaign identity and
    /// creates one empty front per model; a frontier that is already
    /// bound — e.g. reattached across a checkpoint resume, or reloaded
    /// from disk — must match the campaign *exactly* (sweep fingerprint,
    /// seed, shard, dataset, strategy, model set) or the campaign is
    /// rejected with [`Error::InvalidConfig`]: fronts from incomparable
    /// campaigns never merge.
    pub fn begin(&mut self, binding: &FrontierBinding) -> Result<()> {
        match &self.binding {
            None => {
                self.models = binding
                    .models
                    .iter()
                    .map(|name| ModelFrontier {
                        model_name: name.clone(),
                        front: self.make_front(),
                    })
                    .collect();
                self.binding = Some(binding.clone());
                Ok(())
            }
            Some(bound) => bound.ensure_matches(binding),
        }
    }

    /// The campaign this frontier is bound to, once [`Self::begin`] ran.
    pub fn binding(&self) -> Option<&FrontierBinding> {
        self.binding.as_ref()
    }

    /// Build a frontier post-hoc from a saved campaign database with
    /// [`parallel_model_front`] workers — the batch companion to streaming a
    /// campaign with a live frontier attached, for databases that were
    /// swept without one (`qadam pareto` over a million-point `.qdb`).
    ///
    /// The result is unbound (no campaign identity is stored in a
    /// database), exact-mode, and holds one front per database *space* —
    /// which for a joint hardware × model campaign means one front per
    /// scaled-model variant, a finer decomposition than the per-base-model
    /// fronts a live frontier maintains.
    pub fn from_database(db: &EvalDatabase, workers: usize) -> Self {
        let models = db
            .spaces
            .iter()
            .map(|space| ModelFrontier {
                model_name: space.model_name.clone(),
                front: parallel_model_front(&space.evals, workers),
            })
            .collect();
        CampaignFrontier {
            epsilon: None,
            capacity: None,
            binding: None,
            observed: db.stats.design_points,
            models,
        }
    }

    /// Delivery positions consumed by [`Self::observe_at`] so far.
    pub fn observed(&self) -> usize {
        self.observed
    }

    /// Low-level insertion: feed one design point's evaluations (in the
    /// campaign's model order) unconditionally, returning each model
    /// front's [`InsertOutcome`] (in the same order) for tracing. Does
    /// not advance the [`Self::observed`] cursor — campaign code goes
    /// through [`Self::observe_at`], which is what makes resumes
    /// idempotent.
    pub fn observe(&mut self, index: usize, evals: &[Evaluation]) -> Result<Vec<InsertOutcome>> {
        if evals.len() != self.models.len() {
            return Err(Error::InvalidConfig(format!(
                "frontier holds {} model fronts but the point carries {} evaluations",
                self.models.len(),
                evals.len()
            )));
        }
        let mut outcomes = Vec::with_capacity(self.models.len());
        for (model, eval) in self.models.iter_mut().zip(evals) {
            outcomes.push(model.front.insert(
                [eval.perf_per_area, eval.energy_uj],
                FrontSample { index, eval: eval.clone() },
            ));
        }
        Ok(outcomes)
    }

    /// Campaign-ordered observation of delivery position `pos` (the
    /// explorer calls this once per streamed point, in order). Positions
    /// below the [`Self::observed`] cursor are skipped: campaigns are
    /// deterministic, so a checkpoint replay — or the re-delivery of
    /// points whose journal lines were lost to a crash — re-offers
    /// bit-identical evaluations the frontier has already archived.
    /// A position *above* the cursor means the frontier is out of sync
    /// with the campaign and is rejected. Skipped (already-archived)
    /// positions return an empty outcome vector; freshly observed
    /// positions return one [`InsertOutcome`] per model front.
    pub fn observe_at(
        &mut self,
        pos: usize,
        index: usize,
        evals: &[Evaluation],
    ) -> Result<Vec<InsertOutcome>> {
        if pos < self.observed {
            return Ok(Vec::new());
        }
        if pos > self.observed {
            return Err(Error::InvalidConfig(format!(
                "frontier has observed {} points but the campaign delivered position {pos}; \
                 it was not produced by a prefix of this campaign",
                self.observed
            )));
        }
        self.observed += 1;
        self.observe(index, evals)
    }

    /// Per-model fronts, in the campaign's model order.
    pub fn models(&self) -> &[ModelFrontier] {
        &self.models
    }

    /// Whether no campaign has bound this frontier yet.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Total archived points across all model fronts.
    pub fn total_points(&self) -> usize {
        self.models.iter().map(|m| m.front.len()).sum()
    }

    /// Serialize to a schema-versioned canonical document. Points render
    /// in plotting order (ascending perf/area, insertion order on ties),
    /// so equal fronts always produce byte-identical, diffable files.
    pub fn to_json(&self) -> Json {
        let mut fields = envelope("qadam.frontier");
        fields.push((
            "epsilon",
            match self.epsilon {
                None => Json::Null,
                Some([a, b]) => Json::Arr(vec![num(a), num(b)]),
            },
        ));
        fields.push((
            "capacity",
            match self.capacity {
                None => Json::Null,
                Some(n) => num(n as f64),
            },
        ));
        fields.push((
            "campaign",
            match &self.binding {
                None => Json::Null,
                Some(binding) => binding.to_json(),
            },
        ));
        fields.push(("observed", num(self.observed as f64)));
        let models: Vec<Json> = self
            .models
            .iter()
            .map(|model| {
                let points: Vec<Json> = model
                    .front
                    .sorted()
                    .into_iter()
                    .map(|entry| {
                        obj(vec![
                            ("index", num(entry.payload.index as f64)),
                            ("eval", entry.payload.eval.to_json()),
                        ])
                    })
                    .collect();
                obj(vec![
                    ("model_name", s(&model.model_name)),
                    ("points", Json::Arr(points)),
                ])
            })
            .collect();
        fields.push(("models", Json::Arr(models)));
        obj(fields)
    }

    /// Deserialize from [`Self::to_json`] output. Entries are restored
    /// verbatim (no dominance re-check), so `save` → `load` → `save`
    /// is byte-identical in every archive mode.
    pub fn from_json(json: &Json) -> Result<Self> {
        check_envelope(json, "qadam.frontier")?;
        let epsilon = match json.get("epsilon") {
            None | Some(Json::Null) => None,
            Some(value) => {
                let pair = value.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                    Error::ParseError("frontier epsilon must be a two-element array".into())
                })?;
                let get = |j: &Json| {
                    j.as_f64().filter(|e| e.is_finite() && *e >= 0.0).ok_or_else(|| {
                        Error::ParseError(
                            "frontier epsilon entries must be finite numbers >= 0".into(),
                        )
                    })
                };
                Some([get(&pair[0])?, get(&pair[1])?])
            }
        };
        let capacity = match json.get("capacity") {
            None | Some(Json::Null) => None,
            // Validate here: a garbled value would otherwise trip the
            // `with_capacity` assert instead of the typed-error contract.
            Some(_) => match field_usize(json, "capacity")? {
                0 => {
                    return Err(Error::ParseError(
                        "frontier capacity must be at least 1".into(),
                    ))
                }
                n => Some(n),
            },
        };
        let binding = match json.get("campaign") {
            None | Some(Json::Null) => None,
            Some(value) => Some(FrontierBinding::from_json(value)?),
        };
        let observed = field_usize(json, "observed")?;
        let mut frontier =
            CampaignFrontier { epsilon, capacity, binding, observed, models: Vec::new() };
        for model_json in field_arr(json, "models")? {
            let mut model = ModelFrontier {
                model_name: field_str(model_json, "model_name")?.to_string(),
                front: frontier.make_front(),
            };
            for point in field_arr(model_json, "points")? {
                let index = field_usize(point, "index")?;
                let eval_json = point.get("eval").ok_or_else(|| {
                    Error::ParseError("frontier point missing field 'eval'".into())
                })?;
                let eval = Evaluation::from_json(eval_json)?;
                model
                    .front
                    .restore([eval.perf_per_area, eval.energy_uj], FrontSample { index, eval });
            }
            frontier.models.push(model);
        }
        Ok(frontier)
    }

    /// Write the frontier as pretty-printed canonical JSON (atomic:
    /// temp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_json().to_string_pretty())
    }

    /// Load a frontier written by [`Self::save`]. Missing files are
    /// [`Error::Io`]; garbled ones are [`Error::ParseError`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)
            .map_err(|e| Error::ParseError(format!("{}: {e}", path.display())))?;
        Self::from_json(&json)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::AcceleratorConfig;
    use crate::dnn::{model_for, Dataset, ModelKind};

    fn eval_with(rows: usize, seed: u64) -> Evaluation {
        let config = AcceleratorConfig { rows, ..Default::default() };
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        crate::dse::evaluate(&config, &model, seed)
    }

    fn binding_for(items: &[&str]) -> FrontierBinding {
        FrontierBinding {
            spec_fingerprint: 0xABCD,
            seed: 7,
            shard: (0, 1),
            dataset: "CIFAR-10".into(),
            strategy: "exhaustive".into(),
            models: items.iter().map(|s| s.to_string()).collect(),
        }
    }

    #[test]
    fn begin_binds_and_rebinds_only_matching_campaigns() {
        let mut frontier = CampaignFrontier::new();
        frontier.begin(&binding_for(&["A", "B"])).unwrap();
        assert_eq!(frontier.models().len(), 2);
        frontier.begin(&binding_for(&["A", "B"])).unwrap();
        let err = frontier.begin(&binding_for(&["A", "C"])).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        // Same models but a different campaign identity is rejected too.
        let mut other_seed = binding_for(&["A", "B"]);
        other_seed.seed = 8;
        assert_eq!(frontier.begin(&other_seed).unwrap_err().kind(), "invalid_config");
        let mut other_space = binding_for(&["A", "B"]);
        other_space.spec_fingerprint ^= 1;
        assert_eq!(frontier.begin(&other_space).unwrap_err().kind(), "invalid_config");
    }

    #[test]
    fn observe_requires_one_eval_per_model() {
        let mut frontier = CampaignFrontier::new();
        frontier.begin(&binding_for(&["A", "B"])).unwrap();
        let err = frontier.observe(0, &[eval_with(8, 1)]).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        frontier.observe(0, &[eval_with(8, 1), eval_with(16, 1)]).unwrap();
        assert_eq!(frontier.total_points(), 2);
    }

    #[test]
    fn observe_at_skips_replayed_positions_and_rejects_gaps() {
        let mut frontier = CampaignFrontier::new();
        frontier.begin(&binding_for(&["ResNet-20"])).unwrap();
        frontier.observe_at(0, 0, &[eval_with(8, 1)]).unwrap();
        frontier.observe_at(1, 1, &[eval_with(16, 1)]).unwrap();
        let points_before = frontier.total_points();
        // Replay of already-observed positions is a no-op…
        frontier.observe_at(0, 0, &[eval_with(8, 1)]).unwrap();
        frontier.observe_at(1, 1, &[eval_with(16, 1)]).unwrap();
        assert_eq!(frontier.total_points(), points_before);
        assert_eq!(frontier.observed(), 2);
        // …and a position gap means a desynchronized frontier.
        let err = frontier.observe_at(3, 3, &[eval_with(24, 1)]).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
    }

    #[test]
    fn frontier_round_trips_byte_for_byte() {
        let mut frontier = CampaignFrontier::new();
        frontier.begin(&binding_for(&["ResNet-20"])).unwrap();
        for (i, rows) in [8, 12, 16, 24, 32].iter().enumerate() {
            frontier.observe_at(i, i, &[eval_with(*rows, 7)]).unwrap();
        }
        let text = frontier.to_json().to_string_pretty();
        let reloaded = CampaignFrontier::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(reloaded.to_json().to_string_pretty(), text);
        assert_eq!(reloaded.total_points(), frontier.total_points());
        assert_eq!(reloaded.observed(), 5);
        assert_eq!(reloaded.binding(), frontier.binding());
    }

    #[test]
    fn bounded_frontier_round_trips_its_settings() {
        let mut frontier = CampaignFrontier::new().with_epsilon([0.1, 0.1]).with_capacity(3);
        frontier.begin(&binding_for(&["ResNet-20"])).unwrap();
        for (i, rows) in [8, 12, 16, 24, 32].iter().enumerate() {
            frontier.observe_at(i, i, &[eval_with(*rows, 7)]).unwrap();
        }
        assert!(frontier.total_points() <= 3);
        let text = frontier.to_json().to_string_pretty();
        let reloaded = CampaignFrontier::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(reloaded.to_json().to_string_pretty(), text);
    }

    #[test]
    fn wrong_kind_is_rejected() {
        let wrong = Json::parse(r#"{"kind": "qadam.evaldb", "schema": 3}"#).unwrap();
        assert_eq!(CampaignFrontier::from_json(&wrong).unwrap_err().kind(), "parse_error");
    }

    #[test]
    fn parallel_front_matches_sequential_for_any_worker_count() {
        // Real evaluations over a tie-heavy rows sweep (repeated rows give
        // duplicate metric points via the shared synthesis seed).
        let evals: Vec<Evaluation> =
            (0..40).map(|i| eval_with(8 + (i % 5) * 4, 7)).collect();
        let mut sequential = ParetoFront::new(OBJECTIVES);
        for (i, eval) in evals.iter().enumerate() {
            sequential.insert(
                [eval.perf_per_area, eval.energy_uj],
                FrontSample { index: i, eval: eval.clone() },
            );
        }
        for workers in [1, 2, 3, 8, 64] {
            let parallel = parallel_model_front(&evals, workers);
            assert_eq!(parallel.offered(), sequential.offered(), "workers={workers}");
            assert_eq!(parallel.indices(), sequential.indices(), "workers={workers}");
            for (a, b) in parallel.entries().iter().zip(sequential.entries()) {
                assert_eq!(a.seq, b.seq);
                assert_eq!(a.payload.index, b.payload.index);
                assert_eq!(a.point[0].to_bits(), b.point[0].to_bits());
                assert_eq!(a.point[1].to_bits(), b.point[1].to_bits());
            }
        }
    }

    #[test]
    fn parallel_front_matches_batch_reference() {
        let evals: Vec<Evaluation> = (0..30).map(|i| eval_with(4 + i, 3)).collect();
        let points: Vec<Vec<f64>> =
            evals.iter().map(|e| vec![e.perf_per_area, e.energy_uj]).collect();
        let reference = crate::dse::pareto_front_reference(&points, &OBJECTIVES);
        let parallel = parallel_model_front(&evals, 4);
        assert_eq!(parallel.indices(), reference);
    }

    #[test]
    fn parallel_front_of_empty_slice_is_empty() {
        let front = parallel_model_front(&[], 8);
        assert!(front.is_empty());
        assert_eq!(front.offered(), 0);
    }

    #[test]
    fn from_database_builds_per_space_fronts() {
        use crate::explore::{CampaignStats, ModelSpace};
        let db = EvalDatabase {
            dataset: Dataset::Cifar10,
            shard: (0, 1),
            strategy: "exhaustive".into(),
            spaces: vec![
                ModelSpace {
                    model_name: "A".into(),
                    dataset: Dataset::Cifar10,
                    evals: (0..12).map(|i| eval_with(8 + i, 7)).collect(),
                },
                ModelSpace {
                    model_name: "B".into(),
                    dataset: Dataset::Cifar10,
                    evals: (0..12).map(|i| eval_with(8 + i, 9)).collect(),
                },
            ],
            stats: CampaignStats {
                design_points: 12,
                evaluations: 24,
                wall_seconds: 0.0,
                workers: 0,
            },
        };
        let frontier = CampaignFrontier::from_database(&db, 3);
        assert_eq!(frontier.models().len(), 2);
        assert_eq!(frontier.observed(), 12);
        assert!(frontier.binding().is_none());
        for (model, space) in frontier.models().iter().zip(&db.spaces) {
            assert_eq!(model.model_name(), space.model_name);
            let mut sequential = ParetoFront::new(OBJECTIVES);
            for (i, eval) in space.evals.iter().enumerate() {
                sequential.insert(
                    [eval.perf_per_area, eval.energy_uj],
                    FrontSample { index: i, eval: eval.clone() },
                );
            }
            assert_eq!(model.front().indices(), sequential.indices());
        }
    }

    #[test]
    fn corrupt_settings_yield_typed_errors_not_panics() {
        for text in [
            r#"{"kind":"qadam.frontier","schema":3,"capacity":0,"epsilon":null,"models":[]}"#,
            r#"{"kind":"qadam.frontier","schema":3,"capacity":null,"epsilon":[-1.0,0.0],"models":[]}"#,
            r#"{"kind":"qadam.frontier","schema":3,"capacity":null,"epsilon":[1.0],"models":[]}"#,
        ] {
            let json = Json::parse(text).unwrap();
            let err = CampaignFrontier::from_json(&json).unwrap_err();
            assert_eq!(err.kind(), "parse_error", "{text}");
        }
    }
}
