//! The online Pareto front: incremental dominance pruning with exact,
//! epsilon, and budgeted archive modes.
//!
//! [`FrontCore`] is the runtime-dimension engine (axis count fixed at
//! construction); [`ParetoFront`] is the const-generic typed wrapper the
//! rest of the crate uses. In the default *exact* mode the maintained
//! front is provably identical — membership **and** extraction order —
//! to the post-hoc batch computation [`crate::dse::pareto_front`] runs
//! over the full point set, which is what lets the streaming figures
//! reproduce the paper's Fig. 5/6 fronts byte-for-byte (see the golden
//! and property suites).

use crate::util::stats;

/// Whether an objective is to be maximized or minimized.
///
/// This is the canonical home of the orientation type; `dse::pareto`
/// re-exports it for source compatibility.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Orientation {
    /// Larger values are better (e.g. performance per area, accuracy).
    Maximize,
    /// Smaller values are better (e.g. energy per inference, error).
    Minimize,
}

impl Orientation {
    /// Does value `a` dominate-or-tie `b` on this axis?
    pub fn at_least_as_good(self, a: f64, b: f64) -> bool {
        match self {
            Orientation::Maximize => a >= b,
            Orientation::Minimize => a <= b,
        }
    }

    /// Is value `a` strictly better than `b` on this axis?
    pub fn strictly_better(self, a: f64, b: f64) -> bool {
        match self {
            Orientation::Maximize => a > b,
            Orientation::Minimize => a < b,
        }
    }

    /// Map `v` into maximize-space (negate minimized axes) so generic
    /// geometry (gaps, hypervolume) can assume "larger is better".
    fn to_max_space(self, v: f64) -> f64 {
        match self {
            Orientation::Maximize => v,
            Orientation::Minimize => -v,
        }
    }
}

/// Does point `a` dominate point `b` under `orientations` (at least as
/// good on every axis, strictly better on at least one)?
///
/// # Panics
/// If the three slices disagree on length.
pub fn dominates(a: &[f64], b: &[f64], orientations: &[Orientation]) -> bool {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), orientations.len());
    let mut strictly = false;
    for ((&x, &y), &o) in a.iter().zip(b).zip(orientations) {
        if !o.at_least_as_good(x, y) {
            return false;
        }
        if o.strictly_better(x, y) {
            strictly = true;
        }
    }
    strictly
}

/// One surviving point of a front: its coordinates, the sequence number
/// of the offer that produced it, and the caller's payload.
#[derive(Debug, Clone)]
pub struct FrontEntry<P> {
    /// Objective coordinates, one per axis.
    pub point: Vec<f64>,
    /// Zero-based offer sequence number: the value of
    /// [`FrontCore::offered`] when this point was inserted. When every
    /// point of a set is offered exactly once, `seq` equals the point's
    /// index in that set.
    pub seq: usize,
    /// Caller-supplied payload (design-point index, evaluation, …).
    pub payload: P,
}

/// What happened to an offered point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// The point joined the front (possibly pruning dominated entries).
    Added,
    /// An existing entry (epsilon-)dominates the point; nothing changed.
    Dominated,
    /// The point joined a budgeted front but was immediately evicted as
    /// the lowest-contribution entry.
    Evicted,
    /// The point carried a NaN coordinate and was rejected. (The batch
    /// reference computation panics on NaN instead; the engine refuses
    /// the point so a single bad evaluation cannot poison a campaign.)
    Invalid,
}

impl InsertOutcome {
    /// Stable lowercase label, used by the `qadam.trace` wire format.
    pub fn label(self) -> &'static str {
        match self {
            InsertOutcome::Added => "added",
            InsertOutcome::Dominated => "dominated",
            InsertOutcome::Evicted => "evicted",
            InsertOutcome::Invalid => "invalid",
        }
    }

    /// Inverse of [`Self::label`]; `None` for unknown text.
    pub fn parse(text: &str) -> Option<Self> {
        match text {
            "added" => Some(InsertOutcome::Added),
            "dominated" => Some(InsertOutcome::Dominated),
            "evicted" => Some(InsertOutcome::Evicted),
            "invalid" => Some(InsertOutcome::Invalid),
            _ => None,
        }
    }
}

/// Runtime-dimension online Pareto front.
///
/// `insert` costs O(front) comparisons: a candidate dominated by any
/// entry is rejected, otherwise entries it dominates are pruned and the
/// candidate joins. Ties (exactly equal points) do not dominate each
/// other, so duplicates are all kept — matching the batch semantics.
///
/// Two optional relaxations, both off by default:
///
/// * **Epsilon-dominance** ([`Self::with_epsilon`]): a candidate is also
///   rejected when an existing entry is within `epsilon` of weakly
///   dominating it, bounding the archive's resolution (Laumanns-style
///   epsilon archive). The kept front is then an epsilon-approximation
///   of the exact one.
/// * **Budget** ([`Self::with_capacity`]): the front never exceeds N
///   entries; on overflow the entry with the smallest contribution is
///   evicted (exact exclusive 2-D hypervolume for two axes, crowding
///   distance otherwise; boundary entries are never evicted).
///
/// Only the default exact mode guarantees bit-identity with the batch
/// computation.
#[derive(Debug, Clone)]
pub struct FrontCore<P = ()> {
    orientations: Vec<Orientation>,
    epsilon: Option<Vec<f64>>,
    capacity: Option<usize>,
    entries: Vec<FrontEntry<P>>,
    offered: usize,
    pruned: usize,
    evicted: usize,
}

impl<P> FrontCore<P> {
    /// Empty front over the given axes.
    ///
    /// # Panics
    /// If `orientations` is empty.
    pub fn new(orientations: Vec<Orientation>) -> Self {
        assert!(!orientations.is_empty(), "a Pareto front needs at least one axis");
        Self {
            orientations,
            epsilon: None,
            capacity: None,
            entries: Vec::new(),
            offered: 0,
            pruned: 0,
            evicted: 0,
        }
    }

    /// Enable epsilon-dominance with a per-axis tolerance (finite,
    /// non-negative). With `epsilon = 0` this rejects exact duplicates
    /// (weak dominance), which already diverges from the exact mode.
    ///
    /// # Panics
    /// If the length disagrees with the axis count or any tolerance is
    /// negative or non-finite.
    pub fn with_epsilon(mut self, epsilon: Vec<f64>) -> Self {
        assert_eq!(epsilon.len(), self.orientations.len());
        assert!(epsilon.iter().all(|e| e.is_finite() && *e >= 0.0), "epsilon must be >= 0");
        self.epsilon = Some(epsilon);
        self
    }

    /// Bound the archive to at most `capacity` entries (budgeted mode).
    ///
    /// # Panics
    /// If `capacity` is zero.
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        assert!(capacity > 0, "a budgeted front needs capacity >= 1");
        self.capacity = Some(capacity);
        self
    }

    /// Number of entries currently on the front.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the front holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Axis orientations this front was built with.
    pub fn orientations(&self) -> &[Orientation] {
        &self.orientations
    }

    /// Total points offered to [`Self::insert`] so far.
    pub fn offered(&self) -> usize {
        self.offered
    }

    /// Entries pruned because a later point dominated them.
    pub fn pruned(&self) -> usize {
        self.pruned
    }

    /// Entries evicted by the capacity budget.
    pub fn evicted(&self) -> usize {
        self.evicted
    }

    /// Surviving entries in insertion order.
    pub fn entries(&self) -> &[FrontEntry<P>] {
        &self.entries
    }

    /// Surviving entries sorted ascending by the first axis, ties broken
    /// by sequence number — the plotting order, and exactly the order the
    /// batch computation's stable sort produces.
    pub fn sorted(&self) -> Vec<&FrontEntry<P>> {
        let mut out: Vec<&FrontEntry<P>> = self.entries.iter().collect();
        out.sort_by(|a, b| {
            a.point[0]
                .partial_cmp(&b.point[0])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.seq.cmp(&b.seq))
        });
        out
    }

    /// Sequence numbers of the surviving entries in [`Self::sorted`]
    /// order. When every point of a slice was offered exactly once, this
    /// is the same index list [`crate::dse::pareto_front`] returns.
    pub fn indices(&self) -> Vec<usize> {
        self.sorted().iter().map(|e| e.seq).collect()
    }

    /// Offer one point. See [`InsertOutcome`] for the possible fates and
    /// the type-level docs for the dominance rules. The sequence number
    /// consumed is the pre-call value of [`Self::offered`], which
    /// advances on every offer regardless of outcome.
    ///
    /// # Panics
    /// If `point` disagrees with the axis count.
    pub fn insert(&mut self, point: Vec<f64>, payload: P) -> InsertOutcome {
        let seq = self.offered;
        self.offered += 1;
        self.admit(seq, point, payload)
    }

    /// Offer one point under an explicit, caller-assigned sequence number —
    /// the sharded building block of [`Self::merge`]. A worker folding a
    /// contiguous slice of a larger point set offers each point with its
    /// *global* index so that tie-breaks (`sorted`, `indices`, budget
    /// eviction order) are decided exactly as the sequential fold would
    /// decide them. [`Self::offered`] still counts offers, so summing it
    /// across shards reproduces the sequential count.
    ///
    /// # Panics
    /// If `point` disagrees with the axis count.
    pub fn offer_seq(&mut self, seq: usize, point: Vec<f64>, payload: P) -> InsertOutcome {
        self.offered += 1;
        self.admit(seq, point, payload)
    }

    fn admit(&mut self, seq: usize, point: Vec<f64>, payload: P) -> InsertOutcome {
        assert_eq!(point.len(), self.orientations.len());
        if point.iter().any(|v| v.is_nan()) {
            return InsertOutcome::Invalid;
        }
        let rejected = match &self.epsilon {
            None => self
                .entries
                .iter()
                .any(|e| dominates(&e.point, &point, &self.orientations)),
            Some(eps) => self.entries.iter().any(|e| {
                e.point.iter().zip(&point).zip(&self.orientations).zip(eps).all(
                    |(((&have, &new), &o), &tol)| match o {
                        Orientation::Maximize => have + tol >= new,
                        Orientation::Minimize => have - tol <= new,
                    },
                )
            }),
        };
        if rejected {
            return InsertOutcome::Dominated;
        }
        let before = self.entries.len();
        let orientations = &self.orientations;
        self.entries.retain(|e| !dominates(&point, &e.point, orientations));
        self.pruned += before - self.entries.len();
        self.entries.push(FrontEntry { point, seq, payload });
        if let Some(capacity) = self.capacity {
            if self.entries.len() > capacity {
                let victim = self.lowest_contribution();
                let evicted_new = self.entries[victim].seq == seq;
                self.entries.remove(victim);
                self.evicted += 1;
                if evicted_new {
                    return InsertOutcome::Evicted;
                }
            }
        }
        InsertOutcome::Added
    }

    /// Crate-internal: re-append a persisted entry verbatim, skipping
    /// dominance/epsilon/budget checks, so reloading an archive never
    /// drops points the original insertion order kept.
    pub(crate) fn restore(&mut self, point: Vec<f64>, payload: P) {
        assert_eq!(point.len(), self.orientations.len());
        let seq = self.offered;
        self.offered += 1;
        self.entries.push(FrontEntry { point, seq, payload });
    }

    /// Merge two exact-mode sub-fronts built over disjoint shards of one
    /// point set (each point offered via [`Self::offer_seq`] with its global
    /// index). Dominance-front merge is associative: the result is the
    /// non-dominated subset of the union, with entries in ascending global
    /// sequence order — which in exact mode is **bit-identical** (entries,
    /// `sorted`, `indices`, and `offered`) to folding the whole set through
    /// one sequential [`Self::insert`] loop in ascending index order.
    ///
    /// `pruned` is the one counter that cannot be reproduced: the sequential
    /// count depends on how long a doomed point sat on the front before a
    /// dominator arrived, which sharding changes by construction. The merged
    /// count (shard prunes + cross-merge drops) still totals "offers that
    /// are not on the final front", but is not the sequential number.
    ///
    /// # Panics
    /// If the two fronts disagree on orientations, or either uses the
    /// epsilon or budget relaxation — epsilon acceptance and eviction are
    /// order-dependent, so only exact mode merges deterministically.
    pub fn merge(mut self, mut other: Self) -> Self {
        assert_eq!(
            self.orientations, other.orientations,
            "merged fronts must share axis orientations"
        );
        assert!(
            self.epsilon.is_none()
                && other.epsilon.is_none()
                && self.capacity.is_none()
                && other.capacity.is_none(),
            "only exact-mode fronts merge deterministically"
        );
        let orientations = &self.orientations;
        let survives = |entry: &FrontEntry<P>, against: &[FrontEntry<P>]| {
            !against.iter().any(|e| dominates(&e.point, &entry.point, orientations))
        };
        let before = self.entries.len() + other.entries.len();
        // Cross-prune each side against the other, then interleave by global
        // sequence number. Ties (exactly equal points) never dominate, so
        // duplicates survive the merge exactly as they survive insertion.
        let mut merged: Vec<FrontEntry<P>> = Vec::with_capacity(before);
        let keep_self: Vec<bool> =
            self.entries.iter().map(|e| survives(e, &other.entries)).collect();
        let keep_other: Vec<bool> =
            other.entries.iter().map(|e| survives(e, &self.entries)).collect();
        merged.extend(
            self.entries
                .drain(..)
                .zip(keep_self)
                .filter_map(|(e, keep)| keep.then_some(e)),
        );
        merged.extend(
            other
                .entries
                .drain(..)
                .zip(keep_other)
                .filter_map(|(e, keep)| keep.then_some(e)),
        );
        merged.sort_by_key(|e| e.seq);
        let cross_pruned = before - merged.len();
        Self {
            orientations: std::mem::take(&mut self.orientations),
            epsilon: None,
            capacity: None,
            entries: merged,
            offered: self.offered + other.offered,
            pruned: self.pruned + other.pruned + cross_pruned,
            evicted: 0,
        }
    }

    /// Reduce per-shard sub-fronts with a deterministic pairwise tree of
    /// [`Self::merge`] calls (adjacent pairs per round). Associativity makes
    /// the shape irrelevant to the result; the balanced tree keeps each
    /// round's fronts small. Returns `None` for an empty shard list.
    pub fn merge_all(shards: Vec<Self>) -> Option<Self> {
        let mut round = shards;
        while round.len() > 1 {
            let mut next = Vec::with_capacity(round.len() / 2 + 1);
            let mut iter = round.into_iter();
            while let Some(left) = iter.next() {
                match iter.next() {
                    Some(right) => next.push(left.merge(right)),
                    None => next.push(left),
                }
            }
            round = next;
        }
        round.pop()
    }

    /// 2-D hypervolume dominated by the front relative to `reference`
    /// (see [`crate::dse::hypervolume_2d`]); `None` unless the front has
    /// exactly two axes.
    pub fn hypervolume_2d(&self, reference: (f64, f64)) -> Option<f64> {
        if self.orientations.len() != 2 {
            return None;
        }
        let points: Vec<(f64, f64)> =
            self.entries.iter().map(|e| (e.point[0], e.point[1])).collect();
        Some(crate::dse::metrics::hypervolume_2d(
            &points,
            reference,
            (self.orientations[0], self.orientations[1]),
        ))
    }

    /// Index (into `entries`) of the budget-eviction victim: smallest
    /// contribution, ties broken toward the newest entry so established
    /// archive points are preferred.
    fn lowest_contribution(&self) -> usize {
        let contributions = if self.orientations.len() == 2 {
            self.exclusive_hypervolume_2d()
        } else {
            self.crowding_distances()
        };
        let mut victim = 0usize;
        for i in 1..self.entries.len() {
            let worse = contributions[i] < contributions[victim]
                || (contributions[i] == contributions[victim]
                    && self.entries[i].seq > self.entries[victim].seq);
            if worse {
                victim = i;
            }
        }
        victim
    }

    /// Exact exclusive 2-D hypervolume contribution per entry: in the
    /// staircase sorted by the first axis, an inner point's exclusive
    /// box is (gap to its left neighbor) × (gap to its right neighbor);
    /// boundary points contribute infinity (never evicted). Duplicate
    /// points contribute zero and are evicted first.
    fn exclusive_hypervolume_2d(&self) -> Vec<f64> {
        let n = self.entries.len();
        let mut order: Vec<usize> = (0..n).collect();
        let m0 = |i: usize| self.orientations[0].to_max_space(self.entries[i].point[0]);
        let m1 = |i: usize| self.orientations[1].to_max_space(self.entries[i].point[1]);
        order.sort_by(|&a, &b| {
            m0(a)
                .partial_cmp(&m0(b))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(self.entries[a].seq.cmp(&self.entries[b].seq))
        });
        let mut out = vec![f64::INFINITY; n];
        for (rank, &i) in order.iter().enumerate() {
            if rank == 0 || rank == n - 1 {
                continue; // boundary: protected
            }
            let left = order[rank - 1];
            let right = order[rank + 1];
            // Ascending first axis on a clean 2-D front means descending
            // second axis, so the right neighbor bounds this entry's
            // exclusive height and the left neighbor its width.
            out[i] = (m0(i) - m0(left)).max(0.0) * (m1(i) - m1(right)).max(0.0);
        }
        out
    }

    /// NSGA-II crowding distance per entry (the K≠2 budget heuristic):
    /// per axis, boundary points get infinity and inner points the
    /// normalized gap between their sorted neighbors.
    fn crowding_distances(&self) -> Vec<f64> {
        let n = self.entries.len();
        let mut out = vec![0.0f64; n];
        for axis in 0..self.orientations.len() {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                self.entries[a].point[axis]
                    .partial_cmp(&self.entries[b].point[axis])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(self.entries[a].seq.cmp(&self.entries[b].seq))
            });
            let values: Vec<f64> = order.iter().map(|&i| self.entries[i].point[axis]).collect();
            let span = stats::max(&values) - stats::min(&values);
            out[order[0]] = f64::INFINITY;
            out[order[n - 1]] = f64::INFINITY;
            if span <= 0.0 {
                continue;
            }
            for rank in 1..n - 1 {
                let gap = (values[rank + 1] - values[rank - 1]) / span;
                let i = order[rank];
                if out[i].is_finite() {
                    out[i] += gap;
                }
            }
        }
        out
    }
}

/// Typed online Pareto front over `K` objectives with payload `P` — the
/// engine behind the streaming Fig. 5/6 fronts and the live campaign
/// frontier (see [`crate::pareto`] for the module overview).
///
/// A thin wrapper over [`FrontCore`]: same semantics, but the axis count
/// is checked at compile time.
///
/// ```
/// use qadam::pareto::{Orientation, ParetoFront};
///
/// // Maximize the first axis, minimize the second (perf ↑, energy ↓).
/// let mut front = ParetoFront::<2>::new([Orientation::Maximize, Orientation::Minimize]);
/// front.insert([1.0, 1.0], ());
/// front.insert([2.0, 2.0], ()); // trade-off: kept
/// front.insert([1.5, 0.5], ()); // dominates (1.0, 1.0): prunes it
/// front.insert([0.5, 3.0], ()); // dominated: rejected
/// assert_eq!(front.len(), 2);
/// // Extraction order matches the batch computation: ascending first
/// // axis, and `seq` is the insertion index of each survivor.
/// assert_eq!(front.indices(), vec![2, 1]);
/// ```
#[derive(Debug, Clone)]
pub struct ParetoFront<const K: usize, P = ()> {
    core: FrontCore<P>,
}

impl<const K: usize, P> ParetoFront<K, P> {
    /// Empty front over `K` axes.
    ///
    /// # Panics
    /// If `K` is zero.
    pub fn new(orientations: [Orientation; K]) -> Self {
        Self { core: FrontCore::new(orientations.to_vec()) }
    }

    /// Enable epsilon-dominance — see [`FrontCore::with_epsilon`].
    pub fn with_epsilon(mut self, epsilon: [f64; K]) -> Self {
        self.core = self.core.with_epsilon(epsilon.to_vec());
        self
    }

    /// Bound the archive size — see [`FrontCore::with_capacity`].
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.core = self.core.with_capacity(capacity);
        self
    }

    /// Offer one point — see [`FrontCore::insert`].
    pub fn insert(&mut self, point: [f64; K], payload: P) -> InsertOutcome {
        self.core.insert(point.to_vec(), payload)
    }

    /// Offer one point under an explicit global sequence number — see
    /// [`FrontCore::offer_seq`].
    pub fn offer_seq(&mut self, seq: usize, point: [f64; K], payload: P) -> InsertOutcome {
        self.core.offer_seq(seq, point.to_vec(), payload)
    }

    /// Merge two exact-mode sub-fronts built over disjoint shards — see
    /// [`FrontCore::merge`] for the determinism contract.
    ///
    /// # Panics
    /// If either front uses the epsilon or budget relaxation.
    pub fn merge(self, other: Self) -> Self {
        Self { core: self.core.merge(other.core) }
    }

    /// Deterministic pairwise tree-reduce over per-shard sub-fronts — see
    /// [`FrontCore::merge_all`].
    pub fn merge_all(shards: Vec<Self>) -> Option<Self> {
        FrontCore::merge_all(shards.into_iter().map(|s| s.core).collect())
            .map(|core| Self { core })
    }

    /// Number of entries currently on the front.
    pub fn len(&self) -> usize {
        self.core.len()
    }

    /// Whether the front holds no entries.
    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    /// Total points offered so far.
    pub fn offered(&self) -> usize {
        self.core.offered()
    }

    /// Surviving entries in insertion order.
    pub fn entries(&self) -> &[FrontEntry<P>] {
        self.core.entries()
    }

    /// Entries sorted for plotting — see [`FrontCore::sorted`].
    pub fn sorted(&self) -> Vec<&FrontEntry<P>> {
        self.core.sorted()
    }

    /// Surviving sequence numbers in sorted order — see
    /// [`FrontCore::indices`].
    pub fn indices(&self) -> Vec<usize> {
        self.core.indices()
    }

    /// The underlying runtime-dimension engine.
    pub fn core(&self) -> &FrontCore<P> {
        &self.core
    }

    /// Crate-internal: re-append a persisted entry verbatim — see
    /// [`FrontCore::restore`].
    pub(crate) fn restore(&mut self, point: [f64; K], payload: P) {
        self.core.restore(point.to_vec(), payload);
    }
}

impl<P> ParetoFront<2, P> {
    /// 2-D hypervolume relative to `reference` — see
    /// [`FrontCore::hypervolume_2d`].
    // This impl is bound to exactly two axes, so the Option is always Some.
    #[allow(clippy::expect_used)]
    pub fn hypervolume(&self, reference: (f64, f64)) -> f64 {
        self.core.hypervolume_2d(reference).expect("two-axis front")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use Orientation::{Maximize, Minimize};

    fn exact2() -> FrontCore<()> {
        FrontCore::new(vec![Maximize, Minimize])
    }

    #[test]
    fn insert_prunes_and_rejects() {
        let mut front = exact2();
        assert_eq!(front.insert(vec![1.0, 1.0], ()), InsertOutcome::Added);
        assert_eq!(front.insert(vec![2.0, 2.0], ()), InsertOutcome::Added);
        assert_eq!(front.insert(vec![1.5, 0.5], ()), InsertOutcome::Added);
        assert_eq!(front.insert(vec![0.5, 3.0], ()), InsertOutcome::Dominated);
        assert_eq!(front.len(), 2);
        assert_eq!(front.pruned(), 1);
        assert_eq!(front.offered(), 4);
    }

    #[test]
    fn duplicates_are_all_kept() {
        let mut front = exact2();
        for _ in 0..3 {
            assert_eq!(front.insert(vec![1.0, 1.0], ()), InsertOutcome::Added);
        }
        assert_eq!(front.len(), 3);
        assert_eq!(front.indices(), vec![0, 1, 2]);
    }

    #[test]
    fn nan_is_rejected_not_archived() {
        let mut front = exact2();
        assert_eq!(front.insert(vec![f64::NAN, 1.0], ()), InsertOutcome::Invalid);
        assert!(front.is_empty());
        assert_eq!(front.offered(), 1, "invalid offers still consume a sequence number");
    }

    #[test]
    fn indices_match_batch_reference() {
        let points = vec![
            vec![1.0, 1.0],
            vec![2.0, 2.0],
            vec![3.0, 4.0],
            vec![2.0, 3.0],
            vec![1.5, 5.0],
        ];
        let mut front = exact2();
        for p in &points {
            front.insert(p.clone(), ());
        }
        let reference = crate::dse::pareto_front_reference(&points, &[Maximize, Minimize]);
        assert_eq!(front.indices(), reference);
    }

    #[test]
    fn epsilon_collapses_near_duplicates() {
        let mut front = FrontCore::new(vec![Maximize, Minimize]).with_epsilon(vec![0.5, 0.5]);
        assert_eq!(front.insert(vec![1.0, 1.0], ()), InsertOutcome::Added);
        // Within epsilon of the archived point on both axes: dropped.
        assert_eq!(front.insert(vec![1.3, 0.8], ()), InsertOutcome::Dominated);
        // Clearly better on the first axis: kept.
        assert_eq!(front.insert(vec![2.0, 1.2], ()), InsertOutcome::Added);
        assert_eq!(front.len(), 2);
    }

    #[test]
    fn budget_bounds_front_and_keeps_extremes() {
        let mut front = FrontCore::new(vec![Maximize, Minimize]).with_capacity(3);
        // A clean staircase of 6 mutually non-dominated points.
        for i in 0..6 {
            let x = i as f64;
            front.insert(vec![x, x * x / 10.0 + x], ());
        }
        assert_eq!(front.len(), 3);
        assert_eq!(front.evicted(), 3);
        let sorted = front.sorted();
        // Boundary points (best on each axis) are never evicted.
        assert_eq!(sorted[0].point[0], 0.0);
        assert_eq!(sorted[sorted.len() - 1].point[0], 5.0);
    }

    #[test]
    fn budget_evicts_duplicates_first() {
        let mut front = FrontCore::new(vec![Maximize, Minimize]).with_capacity(3);
        front.insert(vec![0.0, 0.0], ());
        front.insert(vec![5.0, 5.0], ());
        front.insert(vec![2.0, 1.0], ());
        // A duplicate of an inner point has zero contribution and is the
        // newest zero-contribution entry, so it is evicted immediately.
        assert_eq!(front.insert(vec![2.0, 1.0], ()), InsertOutcome::Evicted);
        assert_eq!(front.len(), 3);
    }

    #[test]
    fn crowding_path_used_for_three_axes() {
        // (x, x, x/2) under [max, min, max]: larger x is better on axes
        // 0 and 2 but worse on axis 1, so all points are non-dominated.
        let mut front =
            FrontCore::new(vec![Maximize, Minimize, Maximize]).with_capacity(4);
        for i in 0..8 {
            let x = i as f64;
            front.insert(vec![x, x, x * 0.5], ());
        }
        assert_eq!(front.len(), 4);
        assert_eq!(front.evicted(), 4);
    }

    #[test]
    fn typed_wrapper_delegates() {
        let mut front = ParetoFront::<2, u32>::new([Maximize, Minimize]);
        front.insert([1.0, 1.0], 7);
        front.insert([2.0, 0.5], 9);
        assert_eq!(front.len(), 1, "second point dominates the first");
        assert_eq!(front.entries()[0].payload, 9);
        assert!(front.hypervolume((0.0, 2.0)) > 0.0);
    }

    #[test]
    fn one_axis_front_keeps_all_tied_bests() {
        let mut front = FrontCore::new(vec![Maximize]);
        for v in [1.0, 3.0, 3.0, 2.0] {
            front.insert(vec![v], ());
        }
        let seqs: Vec<usize> = front.entries().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![1, 2], "both maxima survive, dominated values pruned");
    }

    /// Deterministic pseudo-random tie-heavy grid: small integer coordinates
    /// force duplicates, ties, and dominated points in every shard.
    fn tie_heavy_points(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut state = seed.max(1);
        (0..n)
            .map(|_| {
                let mut next = || {
                    state ^= state << 13;
                    state ^= state >> 7;
                    state ^= state << 17;
                    (state % 7) as f64
                };
                vec![next(), next()]
            })
            .collect()
    }

    fn sequential_fold(points: &[Vec<f64>]) -> FrontCore<usize> {
        let mut front = FrontCore::new(vec![Maximize, Minimize]);
        for (i, p) in points.iter().enumerate() {
            front.insert(p.clone(), i);
        }
        front
    }

    fn sharded_fold(points: &[Vec<f64>], shards: usize) -> FrontCore<usize> {
        let chunk = points.len().div_ceil(shards).max(1);
        let subs: Vec<FrontCore<usize>> = points
            .chunks(chunk)
            .enumerate()
            .map(|(s, slice)| {
                let mut front = FrontCore::new(vec![Maximize, Minimize]);
                for (off, p) in slice.iter().enumerate() {
                    front.offer_seq(s * chunk + off, p.clone(), s * chunk + off);
                }
                front
            })
            .collect();
        FrontCore::merge_all(subs).unwrap_or_else(|| FrontCore::new(vec![Maximize, Minimize]))
    }

    fn assert_bit_identical(a: &FrontCore<usize>, b: &FrontCore<usize>) {
        assert_eq!(a.offered(), b.offered());
        assert_eq!(a.len(), b.len());
        assert_eq!(a.indices(), b.indices());
        for (x, y) in a.entries().iter().zip(b.entries()) {
            assert_eq!(x.seq, y.seq);
            assert_eq!(x.payload, y.payload);
            let xb: Vec<u64> = x.point.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u64> = y.point.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb);
        }
    }

    #[test]
    fn merged_front_is_bit_identical_to_sequential_on_tie_heavy_grids() {
        for seed in [1, 7, 99] {
            let points = tie_heavy_points(500, seed);
            let sequential = sequential_fold(&points);
            for shards in [1, 2, 3, 8, 31] {
                let merged = sharded_fold(&points, shards);
                assert_bit_identical(&sequential, &merged);
            }
        }
    }

    #[test]
    fn merged_indices_match_batch_reference() {
        let points = tie_heavy_points(300, 42);
        let merged = sharded_fold(&points, 4);
        let reference = crate::dse::pareto_front_reference(&points, &[Maximize, Minimize]);
        assert_eq!(merged.indices(), reference);
    }

    #[test]
    fn merge_is_associative() {
        let points = tie_heavy_points(120, 5);
        let chunk = 40;
        let make = |range: std::ops::Range<usize>| {
            let mut front = FrontCore::new(vec![Maximize, Minimize]);
            for i in range {
                front.offer_seq(i, points[i].clone(), i);
            }
            front
        };
        let (a, b, c) = (make(0..chunk), make(chunk..2 * chunk), make(2 * chunk..points.len()));
        let left = a.clone().merge(b.clone()).merge(c.clone());
        let right = a.merge(b.merge(c));
        assert_bit_identical(&left, &right);
    }

    #[test]
    fn merge_keeps_cross_shard_duplicates() {
        let mut a = FrontCore::new(vec![Maximize, Minimize]);
        let mut b = FrontCore::new(vec![Maximize, Minimize]);
        a.offer_seq(0, vec![1.0, 1.0], ());
        b.offer_seq(1, vec![1.0, 1.0], ());
        let merged = a.merge(b);
        assert_eq!(merged.len(), 2, "exact ties never dominate, even across shards");
        assert_eq!(merged.indices(), vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "exact-mode")]
    fn merge_rejects_epsilon_fronts() {
        let a = FrontCore::<()>::new(vec![Maximize, Minimize]).with_epsilon(vec![0.1, 0.1]);
        let b = FrontCore::<()>::new(vec![Maximize, Minimize]);
        let _ = a.merge(b);
    }

    #[test]
    fn typed_wrapper_merges() {
        let mut a = ParetoFront::<2, usize>::new([Maximize, Minimize]);
        let mut b = ParetoFront::<2, usize>::new([Maximize, Minimize]);
        a.offer_seq(0, [1.0, 1.0], 0);
        a.offer_seq(1, [2.0, 2.0], 1);
        b.offer_seq(2, [1.5, 0.5], 2); // dominates (1.0, 1.0) across shards
        let merged = ParetoFront::merge_all(vec![a, b]).unwrap();
        assert_eq!(merged.indices(), vec![2, 1]);
        assert_eq!(merged.offered(), 3);
    }
}
