//! RTL generation — the paper's "automatically generated RTL code" output
//! (§II, §III-A).
//!
//! Emits synthesizable structural/behavioural Verilog-2001 for a complete
//! accelerator design point: the PE (per-type MAC + scratchpads), the 2-D
//! PE array with row/column broadcast buses, the global buffer wrapper,
//! the top-level with a simple load/compute FSM, and a self-checking
//! testbench. The generator is exercised by `examples/rtl_codegen.rs` and
//! validated structurally by the tests here (balanced begin/end, module
//! per instantiation, port-arity checks).

pub mod lint;
pub mod verilog;

pub use lint::{lint_bundle, LintIssue};
pub use verilog::{generate_design, RtlBundle};

use crate::arch::AcceleratorConfig;

/// A generated RTL file.
#[derive(Debug, Clone, PartialEq)]
pub struct RtlFile {
    /// File name (e.g. `pe.v`).
    pub name: String,
    /// Verilog source text.
    pub source: String,
}

impl RtlFile {
    /// Count occurrences of a word token (helper for structural tests).
    pub fn count_token(&self, token: &str) -> usize {
        self.source
            .split(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
            .filter(|w| *w == token)
            .count()
    }
}

/// Write a generated bundle to a directory; returns the file paths.
pub fn write_bundle(
    bundle: &RtlBundle,
    dir: &std::path::Path,
) -> std::io::Result<Vec<std::path::PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::new();
    for file in &bundle.files {
        let path = dir.join(&file.name);
        std::fs::write(&path, &file.source)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Generate the RTL bundle for a configuration (convenience wrapper).
pub fn generate(config: &AcceleratorConfig) -> RtlBundle {
    generate_design(config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::PeType;

    fn bundle(pe: PeType) -> RtlBundle {
        generate(&AcceleratorConfig { pe, ..AcceleratorConfig::default() })
    }

    #[test]
    fn bundle_has_all_files() {
        let b = bundle(PeType::Int16);
        let names: Vec<&str> = b.files.iter().map(|f| f.name.as_str()).collect();
        for expected in ["pe.v", "pe_array.v", "global_buffer.v", "accelerator_top.v", "tb_accelerator.v"]
        {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }

    #[test]
    fn modules_balanced() {
        for pe in PeType::ALL {
            for file in &bundle(pe).files {
                assert_eq!(
                    file.count_token("module"),
                    file.count_token("endmodule"),
                    "{}: unbalanced module/endmodule",
                    file.name
                );
                assert_eq!(
                    file.count_token("begin"),
                    file.count_token("end") ,
                    "{}: unbalanced begin/end",
                    file.name
                );
            }
        }
    }

    #[test]
    fn shift_add_pe_has_no_multiplier() {
        let light = bundle(PeType::LightPe1);
        let pe_file = light.files.iter().find(|f| f.name == "pe.v").unwrap();
        assert!(!pe_file.source.contains('*'), "LightPE RTL must not infer a multiplier");
        assert!(pe_file.source.contains("<<"), "LightPE RTL must shift");
        let int16 = bundle(PeType::Int16);
        let pe16 = int16.files.iter().find(|f| f.name == "pe.v").unwrap();
        assert!(pe16.source.contains('*'), "INT16 RTL must multiply");
    }

    #[test]
    fn array_instantiates_rows_times_cols() {
        let config = AcceleratorConfig { rows: 3, cols: 4, ..AcceleratorConfig::default() };
        let b = generate(&config);
        let array = b.files.iter().find(|f| f.name == "pe_array.v").unwrap();
        // One `pe u_pe_...` instantiation per grid position.
        assert_eq!(array.count_token("pe"), 12, "3×4 array must instantiate 12 PEs");
    }

    #[test]
    fn parameters_reflect_config() {
        let config = AcceleratorConfig { glb_kib: 256, ..AcceleratorConfig::default() };
        let b = generate(&config);
        let top = b.files.iter().find(|f| f.name == "accelerator_top.v").unwrap();
        assert!(top.source.contains("GLB_BYTES = 262144"), "GLB size must parameterize");
    }

    #[test]
    fn write_bundle_roundtrips() {
        let dir = std::env::temp_dir().join("qadam_rtl_test");
        let _ = std::fs::remove_dir_all(&dir);
        let b = bundle(PeType::LightPe2);
        let paths = write_bundle(&b, &dir).unwrap();
        assert_eq!(paths.len(), b.files.len());
        for path in &paths {
            assert!(path.exists());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
