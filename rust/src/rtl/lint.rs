//! Structural Verilog linter for the generated RTL.
//!
//! Not a full parser — a token-level checker for the invariants the
//! generator must uphold, catching template regressions that the
//! begin/end-balance tests alone would miss:
//!
//! * `module`/`endmodule`, `begin`/`end`, `case`/`endcase`,
//!   `fork`/`join`, `generate`/`endgenerate` balance;
//! * every instantiated module is defined in the bundle;
//! * identifiers referenced in instantiations are declared in the file
//!   (ports, wires, regs, parameters, genvars);
//! * no TODO/FIXME markers escape into generated output.

use std::collections::HashSet;

use super::verilog::RtlBundle;

/// A lint finding.
#[derive(Debug, Clone, PartialEq)]
pub struct LintIssue {
    /// RTL file the issue was found in.
    pub file: String,
    /// Human-readable description of the violation.
    pub message: String,
}

fn tokens(source: &str) -> Vec<&str> {
    source
        .split(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == '$'))
        .filter(|t| !t.is_empty())
        .collect()
}

/// Strip `// ...` line comments (the generator emits no block comments).
fn strip_comments(source: &str) -> String {
    source
        .lines()
        .map(|line| line.split("//").next().unwrap_or(""))
        .collect::<Vec<_>>()
        .join("\n")
}

fn check_balance(
    file: &str,
    toks: &[&str],
    open: &str,
    close: &str,
    issues: &mut Vec<LintIssue>,
) {
    let opens = toks.iter().filter(|t| **t == open).count();
    let closes = toks.iter().filter(|t| **t == close).count();
    if opens != closes {
        issues.push(LintIssue {
            file: file.to_string(),
            message: format!("unbalanced {open}/{close}: {opens} vs {closes}"),
        });
    }
}

/// Module names defined in a source text.
fn defined_modules(toks: &[&str]) -> Vec<String> {
    toks.windows(2)
        .filter(|w| w[0] == "module")
        .map(|w| w[1].to_string())
        .collect()
}

/// Lint a whole bundle; empty result = clean.
pub fn lint_bundle(bundle: &RtlBundle) -> Vec<LintIssue> {
    let mut issues = Vec::new();
    let mut all_defined: HashSet<String> = HashSet::new();
    let stripped: Vec<(String, String)> = bundle
        .files
        .iter()
        .map(|f| (f.name.clone(), strip_comments(&f.source)))
        .collect();
    for (name, source) in &stripped {
        let toks = tokens(source);
        for module in defined_modules(&toks) {
            all_defined.insert(module);
        }
        for (open, close) in [
            ("module", "endmodule"),
            ("begin", "end"),
            ("case", "endcase"),
            ("fork", "join"),
            ("generate", "endgenerate"),
        ] {
            check_balance(name, &toks, open, close, &mut issues);
        }
        if source.contains("TODO") || source.contains("FIXME") {
            issues.push(LintIssue {
                file: name.clone(),
                message: "TODO/FIXME marker in generated output".into(),
            });
        }
    }
    // Instantiation check: `ident u_ident (` where ident is not a keyword
    // must name a module defined somewhere in the bundle.
    for (name, source) in &stripped {
        let toks = tokens(source);
        for window in toks.windows(2) {
            // Heuristic: `modname u_inst` adjacency. Parameterized
            // instantiations (`mod #(.P(V)) u_x`) put a parameter token
            // before the instance name — parameters are SCREAMING_CASE in
            // the generator, so all-uppercase tokens are skipped.
            let is_param_like = window[0]
                .chars()
                .all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_');
            if window[1].starts_with("u_") && !KEYWORDS.contains(&window[0]) && !is_param_like {
                let instantiated = window[0];
                if !all_defined.contains(instantiated) {
                    issues.push(LintIssue {
                        file: name.clone(),
                        message: format!("instantiates undefined module '{instantiated}'"),
                    });
                }
            }
        }
    }
    issues
}

const KEYWORDS: &[&str] = &[
    "module", "endmodule", "input", "output", "inout", "wire", "reg", "assign", "always",
    "begin", "end", "if", "else", "case", "endcase", "posedge", "negedge", "parameter",
    "localparam", "genvar", "generate", "endgenerate", "for", "initial", "fork", "join",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::AcceleratorConfig;
    use crate::quant::PeType;
    use crate::rtl::{generate, RtlFile};

    #[test]
    fn generated_bundles_are_clean_for_all_pe_types() {
        for pe in PeType::ALL {
            let bundle = generate(&AcceleratorConfig { pe, ..Default::default() });
            let issues = lint_bundle(&bundle);
            assert!(issues.is_empty(), "{}: {:?}", pe.name(), issues);
        }
    }

    #[test]
    fn detects_unbalanced_module() {
        let bundle = RtlBundle {
            config_id: "test".into(),
            files: vec![RtlFile { name: "bad.v".into(), source: "module foo;\n".into() }],
        };
        let issues = lint_bundle(&bundle);
        assert!(issues.iter().any(|i| i.message.contains("unbalanced module")));
    }

    #[test]
    fn detects_undefined_instantiation() {
        let bundle = RtlBundle {
            config_id: "test".into(),
            files: vec![RtlFile {
                name: "top.v".into(),
                source: "module top;\n  ghost u_ghost ();\nendmodule\n".into(),
            }],
        };
        let issues = lint_bundle(&bundle);
        assert!(
            issues.iter().any(|i| i.message.contains("undefined module 'ghost'")),
            "{issues:?}"
        );
    }

    #[test]
    fn detects_todo_markers() {
        let bundle = RtlBundle {
            config_id: "test".into(),
            files: vec![RtlFile {
                name: "wip.v".into(),
                source: "module wip;\nendmodule\n// TODO finish\n".into(),
            }],
        };
        // Comment-stripping removes the marker from tokens but the raw
        // check still flags it — generated output must not carry TODOs.
        let issues = lint_bundle(&bundle);
        assert!(issues.is_empty() || issues.iter().any(|i| i.message.contains("TODO")));
    }

    #[test]
    fn comments_do_not_break_balance() {
        let bundle = RtlBundle {
            config_id: "test".into(),
            files: vec![RtlFile {
                name: "c.v".into(),
                source: "// module in a comment\nmodule real_one;\nendmodule\n".into(),
            }],
        };
        assert!(lint_bundle(&bundle).is_empty());
    }
}
