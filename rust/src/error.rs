//! Crate-wide typed error (`qadam::Error`) and result alias.
//!
//! Every fallible public API in the analytical core and the exploration
//! layer returns [`Error`] instead of `Result<_, String>` or panicking:
//! config validation ([`Error::InvalidConfig`]), input parsing
//! ([`Error::ParseError`]), the paper's INT16 normalization baseline
//! ([`Error::MissingBaseline`]), filesystem access ([`Error::Io`]), and
//! the PJRT runtime ([`Error::Runtime`] / [`Error::Unsupported`]).

use std::fmt;

use crate::util::json::JsonError;

/// Crate-wide result alias; the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// The unified QADAM error type.
#[derive(Debug)]
pub enum Error {
    /// A structurally invalid configuration: zero-sized PE arrays, empty
    /// sweep axes, out-of-range shard indices, unsupported datasets.
    InvalidConfig(String),
    /// Malformed input: JSON config files, CLI values, artifact manifests.
    ParseError(String),
    /// A design space has no INT16 evaluations to normalize against
    /// (Figs. 4-6 rescale "with respect to the INT16 hardware
    /// configuration with the highest performance per area").
    MissingBaseline(String),
    /// Filesystem failure (config files, RTL bundles, artifacts).
    Io(std::io::Error),
    /// PJRT runtime failure: artifact loading, compilation, execution,
    /// or tensor shape/dtype mismatches.
    Runtime(String),
    /// The requested capability is not compiled into this build (e.g. the
    /// `pjrt` feature for the XLA-backed runtime).
    Unsupported(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            Error::ParseError(msg) => write!(f, "parse error: {msg}"),
            Error::MissingBaseline(msg) => write!(f, "missing INT16 baseline: {msg}"),
            Error::Io(err) => write!(f, "io error: {err}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Unsupported(msg) => write!(f, "unsupported: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(err) => Some(err),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(err: std::io::Error) -> Self {
        Error::Io(err)
    }
}

impl From<JsonError> for Error {
    fn from(err: JsonError) -> Self {
        Error::ParseError(err.to_string())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(err: xla::Error) -> Self {
        Error::Runtime(err.to_string())
    }
}

impl Error {
    /// Short machine-readable kind tag (log filtering and test assertions).
    pub fn kind(&self) -> &'static str {
        match self {
            Error::InvalidConfig(_) => "invalid_config",
            Error::ParseError(_) => "parse_error",
            Error::MissingBaseline(_) => "missing_baseline",
            Error::Io(_) => "io",
            Error::Runtime(_) => "runtime",
            Error::Unsupported(_) => "unsupported",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_kind() {
        let err = Error::InvalidConfig("rows must be positive".into());
        assert!(err.to_string().contains("invalid configuration"));
        let err = Error::MissingBaseline("no INT16 points".into());
        assert!(err.to_string().contains("INT16"));
        assert_eq!(err.kind(), "missing_baseline");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err: Error = io.into();
        assert_eq!(err.kind(), "io");
        assert!(std::error::Error::source(&err).is_some());
    }

    #[test]
    fn json_errors_become_parse_errors() {
        let parse_failure = crate::util::json::Json::parse("{").unwrap_err();
        let err: Error = parse_failure.into();
        assert_eq!(err.kind(), "parse_error");
    }
}
