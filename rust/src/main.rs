//! `qadam` — the command-line launcher for the QADAM framework.
//!
//! Subcommands map one-to-one onto the paper's workflow (Fig. 1): feed
//! accelerator parameters + DNN configurations, get PPA results, DSE
//! scatter data, Pareto fronts, generated RTL, simulation traces, and the
//! QAT training driver. Every campaign runs through the unified
//! [`Explorer`] API; failures surface as typed [`qadam::Error`]s.

use std::path::Path;
use std::sync::{Arc, Mutex};

use qadam::arch::{AcceleratorConfig, SweepSpec};
use qadam::coordinator::default_workers;
use qadam::dataflow::{map_model, Dataflow};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::dse;
use qadam::energy::energy_of;
use qadam::explore::{EvalDatabase, Explorer, PointCache};
use qadam::pareto::{CampaignFrontier, RandomSample, SuccessiveHalving};
use qadam::ppa::PpaModel;
use qadam::quant::PeType;
use qadam::report;
use qadam::rtl;
use qadam::runtime::{QatDriver, Runtime};
use qadam::sim;
use qadam::synth;
use qadam::util::cli::Command;
use qadam::util::log::{self, Level};
use qadam::util::rng::Pcg64;
use qadam::util::table::{format_sig, Table};
use qadam::{Error, Result};

fn cli() -> Command {
    Command::new("qadam", "quantization-aware PPA modeling & DSE for DNN accelerators")
        .opt("log-level", "info", "error|warn|info|debug|trace")
        .opt("seed", "7", "rng / synthesis-noise seed")
        .opt("workers", "0", "worker threads (0 = cores-1)")
        .sub(
            Command::new("synth", "synthesize one design point (DC stand-in)")
                .opt("pe", "int16", "fp32|int16|lightpe1|lightpe2")
                .opt("rows", "16", "PE array rows")
                .opt("cols", "16", "PE array cols")
                .opt("glb-kib", "128", "global buffer KiB"),
        )
        .sub(
            Command::new("ppa", "evaluate PPA of one design on one model")
                .opt("pe", "int16", "PE type")
                .opt("model", "resnet20", "vgg16|resnet20|resnet34|resnet50|resnet56")
                .opt("dataset", "cifar10", "cifar10|cifar100|imagenet"),
        )
        .sub(
            Command::new("fit", "fit polynomial PPA surrogates (k-fold CV)")
                .opt("folds", "5", "cross-validation folds"),
        )
        .sub(
            Command::new("dse", "design-space exploration campaign")
                .opt("dataset", "cifar10", "cifar10|cifar100|imagenet")
                .opt("sweep", "", "JSON sweep-config file (empty = default space)")
                .opt("shard", "", "run only shard I of N (format: I/N)")
                .opt("strategy", "exhaustive", "exhaustive|random:N[:SEED]|halving:KEEP[:ROUNDS]")
                .opt("frontier", "", "write the streaming Pareto frontier to this JSON file")
                .opt("save", "", "write the evaluation database to this JSON file")
                .opt("load", "", "summarize a saved database instead of running")
                .opt("resume", "", "checkpoint journal path (resumes if present)")
                .opt("every", "16", "flush the checkpoint journal every N points")
                .opt("cache", "", "content-addressed point-cache file (reused & updated)"),
        )
        .sub(
            Command::new("cache", "inspect or clear a point-cache file")
                .opt("file", "qadam_cache.json", "cache file path")
                .flag("clear", "delete the cache file"),
        )
        .sub(
            Command::new("pareto", "Pareto-front analysis (Figs. 5/6)")
                .opt("dataset", "cifar10", "cifar10|cifar100")
                .opt("metric", "perf-per-area", "perf-per-area|energy"),
        )
        .sub(
            Command::new("rtl", "generate Verilog for a design point")
                .opt("pe", "lightpe1", "PE type")
                .opt("rows", "16", "PE array rows")
                .opt("cols", "16", "PE array cols")
                .opt("out", "rtl_out", "output directory"),
        )
        .sub(
            Command::new("sim", "cycle-level functional simulation (VCS stand-in)")
                .opt("pe", "int16", "PE type")
                .opt("hw", "8", "ifmap height/width")
                .opt("in-c", "3", "input channels")
                .opt("out-c", "8", "output channels"),
        )
        .sub(
            Command::new("train", "QAT training via the PJRT runtime")
                .opt("pe", "lightpe1", "PE type")
                .opt("steps", "100", "training steps")
                .opt("artifacts", "artifacts", "artifacts directory"),
        )
        .sub(
            Command::new("report", "regenerate a paper figure")
                .opt("fig", "4", "2|3|4|5|6")
                .opt("dataset", "cifar10", "dataset for figs 4-6")
                .opt("load", "", "render figs 4-6 from a saved database (no re-run)"),
        )
}

fn parse_pe(text: &str) -> Result<PeType> {
    PeType::parse(text).ok_or_else(|| Error::ParseError(format!("bad --pe '{text}'")))
}

fn parse_dataset(text: &str) -> Result<Dataset> {
    Dataset::parse(text).ok_or_else(|| Error::ParseError(format!("bad --dataset '{text}'")))
}

/// Parse an `I/N` shard designator ("2/8" = shard 2 of 8).
fn parse_shard(text: &str) -> Result<(usize, usize)> {
    let bad = || Error::ParseError(format!("bad --shard '{text}' (expected I/N, e.g. 0/4)"));
    let (i, n) = text.split_once('/').ok_or_else(bad)?;
    let shard: usize = i.trim().parse().map_err(|_| bad())?;
    let num_shards: usize = n.trim().parse().map_err(|_| bad())?;
    if num_shards == 0 || shard >= num_shards {
        return Err(bad());
    }
    Ok((shard, num_shards))
}

/// Parse a `--strategy` descriptor and attach it to the explorer:
/// `exhaustive`, `random:N[:SEED]` (SEED defaults to the campaign seed),
/// or `halving:KEEP[:ROUNDS]` (ROUNDS defaults to 3).
fn apply_strategy(explorer: Explorer, text: &str, campaign_seed: u64) -> Result<Explorer> {
    let bad = |detail: &str| {
        Error::ParseError(format!(
            "bad --strategy '{text}' ({detail}; expected exhaustive, random:N[:SEED], \
             or halving:KEEP[:ROUNDS])"
        ))
    };
    let mut parts = text.split(':');
    let kind = parts.next().unwrap_or("");
    let arg1 = parts.next();
    let arg2 = parts.next();
    if parts.next().is_some() {
        return Err(bad("too many parameters"));
    }
    let parse_num = |value: Option<&str>, name: &str| -> Result<Option<u64>> {
        match value {
            None => Ok(None),
            Some(v) => v
                .trim()
                .parse::<u64>()
                .map(Some)
                .map_err(|_| bad(&format!("{name} is not an integer"))),
        }
    };
    match kind {
        "exhaustive" => {
            if arg1.is_some() {
                return Err(bad("exhaustive takes no parameters"));
            }
            // No strategy attached: the explorer's default walk *is*
            // exhaustive, and leaving it unset keeps `run()`'s eval-vector
            // pre-sizing (the manifest descriptor is "exhaustive" either
            // way, so journals are interchangeable).
            Ok(explorer)
        }
        "random" => {
            let n = parse_num(arg1, "N")?.ok_or_else(|| bad("random needs N"))? as usize;
            let seed = parse_num(arg2, "SEED")?.unwrap_or(campaign_seed);
            Ok(explorer.strategy(RandomSample { n, seed }))
        }
        "halving" => {
            let keep = parse_num(arg1, "KEEP")?.ok_or_else(|| bad("halving needs KEEP"))? as usize;
            let rounds = parse_num(arg2, "ROUNDS")?.unwrap_or(3) as usize;
            Ok(explorer.strategy(SuccessiveHalving { keep, rounds }))
        }
        _ => Err(bad("unknown strategy")),
    }
}

/// Per-model best raw perf/area by PE type — the summary for databases
/// that cannot be normalized (partial coverage or no INT16 baseline).
fn print_raw_bests(db: &EvalDatabase) {
    for space in &db.spaces {
        print!("  {:<10} best perf/area:", space.model_name);
        for pe in PeType::ALL {
            if let Some(best) = dse::best_perf_per_area(&space.evals, pe) {
                print!(" {}={}", pe.name(), format_sig(best.perf_per_area, 3));
            }
        }
        println!();
    }
}

fn main() -> Result<()> {
    log::init_from_env();
    let matches = cli().parse_or_exit();
    if let Some(level) = Level::parse(matches.get_str("log-level")) {
        log::set_level(level);
    }
    let seed: u64 = matches.get_usize("seed") as u64;
    let workers = match matches.get_usize("workers") {
        0 => default_workers(),
        n => n,
    };

    match matches.subcommand() {
        "synth" => {
            let config = AcceleratorConfig {
                pe: parse_pe(matches.get_str("pe"))?,
                rows: matches.get_usize("rows"),
                cols: matches.get_usize("cols"),
                glb_kib: matches.get_usize("glb-kib"),
                ..Default::default()
            };
            config.validate()?;
            let report = synth::synthesize(&config, seed);
            let mut table = Table::new(&["metric", "value"]);
            table.row(&["design".into(), config.id()]);
            table.row(&["area_mm2".into(), format_sig(report.area.total_mm2(), 4)]);
            table.row(&["  pe_array_mm2".into(), format_sig(report.area.pe_array_um2 / 1e6, 4)]);
            table.row(&["  glb_mm2".into(), format_sig(report.area.glb_um2 / 1e6, 4)]);
            table.row(&["power_mw".into(), format_sig(report.total_power_mw(), 4)]);
            table.row(&["  leakage_mw".into(), format_sig(report.leakage_power_mw, 4)]);
            table.row(&["max_clock_ghz".into(), format_sig(report.max_clock_ghz, 4)]);
            table.row(&["peak_gmacs".into(), format_sig(report.peak_gmacs(), 4)]);
            print!("{}", table.render());
        }
        "ppa" => {
            let config = AcceleratorConfig {
                pe: parse_pe(matches.get_str("pe"))?,
                ..Default::default()
            };
            let dataset = parse_dataset(matches.get_str("dataset"))?;
            let kind = ModelKind::parse(matches.get_str("model")).ok_or_else(|| {
                Error::ParseError(format!("bad --model '{}'", matches.get_str("model")))
            })?;
            let model = model_for(kind, dataset);
            let synth_report = synth::synthesize(&config, seed);
            let mapping = map_model(&model, &config, Dataflow::RowStationary);
            let energy = energy_of(&mapping, &synth_report);
            let eval = dse::evaluate_with_synth(&synth_report, &model);
            let mut table = Table::new(&["metric", "value"]);
            table.row(&["model".into(), model.name.clone()]);
            table.row(&["total_macs".into(), mapping.total_macs.to_string()]);
            table.row(&["cycles".into(), mapping.total_cycles.to_string()]);
            table.row(&["utilization".into(), format_sig(mapping.avg_utilization, 3)]);
            table.row(&["latency_ms".into(), format_sig(eval.latency_ms, 4)]);
            table.row(&["inf_per_s".into(), format_sig(eval.inf_per_s, 4)]);
            table.row(&["perf_per_area".into(), format_sig(eval.perf_per_area, 4)]);
            table.row(&["chip_energy_uj".into(), format_sig(energy.chip_uj(), 4)]);
            table.row(&["dram_energy_uj".into(), format_sig(energy.dram_uj, 4)]);
            table.row(&["dram_bytes".into(), mapping.traffic.dram_bytes.to_string()]);
            table.row(&["glb_accesses".into(), mapping.traffic.glb.total().to_string()]);
            print!("{}", table.render());
        }
        "fit" => {
            let folds = matches.get_usize("folds");
            for pe in PeType::ALL {
                let dataset = synth::synthesize_sweep(&SweepSpec::default(), pe, seed);
                let model = PpaModel::fit(&dataset, folds, seed);
                for report in &model.reports {
                    println!(
                        "{:<10} {:<6} degree={} r={} R2={} MAPE={}%",
                        pe.name(),
                        report.metric,
                        report.degree,
                        format_sig(report.pearson, 4),
                        format_sig(report.r_squared, 4),
                        format_sig(report.mape, 3),
                    );
                }
            }
        }
        "dse" => {
            let load_path = matches.get_str("load").to_string();
            let shard_arg = matches.get_str("shard");
            let db = if !load_path.is_empty() {
                // --load summarizes an existing database; campaign-shaping
                // flags would be silently ignored, so reject them (also
                // the defaulted ones — `was_set` sees through defaults).
                let campaign_flags = [
                    "dataset", "sweep", "shard", "strategy", "frontier", "resume", "cache",
                    "every",
                ];
                for conflicting in campaign_flags {
                    if matches.was_set(conflicting) {
                        return Err(Error::InvalidConfig(format!(
                            "--load summarizes a saved database; --{conflicting} only applies \
                             to a live campaign"
                        )));
                    }
                }
                let db = EvalDatabase::load(Path::new(&load_path))?;
                println!(
                    "loaded {} design points x {} models from {load_path}",
                    db.stats.design_points,
                    db.spaces.len()
                );
                db
            } else {
                let dataset = parse_dataset(matches.get_str("dataset"))?;
                let sweep_path = matches.get_str("sweep");
                let spec = if sweep_path.is_empty() {
                    SweepSpec::default()
                } else {
                    SweepSpec::from_file(Path::new(sweep_path))?
                };
                let mut explorer =
                    Explorer::over(spec).dataset(dataset).workers(workers).seed(seed);
                if !shard_arg.is_empty() {
                    let (shard, num_shards) = parse_shard(shard_arg)?;
                    explorer = explorer.shard(shard, num_shards);
                }
                explorer = apply_strategy(explorer, matches.get_str("strategy"), seed)?;
                let frontier_path = matches.get_str("frontier").to_string();
                let frontier = if frontier_path.is_empty() {
                    None
                } else {
                    Some(Arc::new(Mutex::new(CampaignFrontier::new())))
                };
                if let Some(frontier) = &frontier {
                    explorer = explorer.frontier(frontier.clone());
                }
                let resume_path = matches.get_str("resume");
                if !resume_path.is_empty() {
                    explorer =
                        explorer.checkpoint(Path::new(resume_path), matches.get_usize("every"));
                }
                let cache_path = matches.get_str("cache").to_string();
                let cache = if cache_path.is_empty() {
                    None
                } else {
                    let path = Path::new(&cache_path);
                    let loaded =
                        if path.exists() { PointCache::load(path)? } else { PointCache::new() };
                    Some(Arc::new(Mutex::new(loaded)))
                };
                if let Some(cache) = &cache {
                    explorer = explorer.cache(cache.clone());
                }
                let db = explorer.run()?;
                println!(
                    "{} design points x {} models in {:.2}s ({:.0} evals/s, {} workers)",
                    db.stats.design_points,
                    db.spaces.len(),
                    db.stats.wall_seconds,
                    db.stats.evals_per_sec(),
                    db.stats.workers
                );
                if let Some(cache) = cache {
                    let cache = qadam::explore::lock_cache(&cache);
                    cache.save(Path::new(&cache_path))?;
                    println!(
                        "cache: {} design points ({} hits / {} misses this run), saved to \
                         {cache_path}",
                        cache.len(),
                        cache.hits(),
                        cache.misses()
                    );
                }
                if let Some(frontier) = frontier {
                    let frontier = qadam::explore::lock_shared(&frontier);
                    frontier.save(Path::new(&frontier_path))?;
                    print!("frontier: saved to {frontier_path} —");
                    for model in frontier.models() {
                        print!(" {}: {} points", model.model_name(), model.front().len());
                    }
                    println!();
                }
                db
            };
            // The database records its own coverage (shard + strategy), so
            // a loaded partial database is summarized exactly like a live
            // partial run.
            if !db.is_whole_space() {
                // A shard or a strategy-sampled subset sees only part of
                // the space, so its local best INT16 is not the campaign
                // baseline; normalized summaries would be silently wrong.
                // Report raw bests instead.
                if db.shard.1 > 1 {
                    println!("  (shard output: normalize after merging all shards)");
                } else {
                    println!(
                        "  (sampled by strategy '{}': raw bests only; rerun exhaustively to \
                         normalize)",
                        db.strategy
                    );
                }
                print_raw_bests(&db);
            } else {
                match db.headline_geomean() {
                    Ok(headline) => {
                        for (pe, ppa, energy) in headline {
                            println!(
                                "  {:<10} {}x perf/area, {}x less energy vs best INT16",
                                pe.name(),
                                format_sig(ppa, 3),
                                format_sig(energy, 3)
                            );
                        }
                        // Quantified Pareto quality per model: hypervolume of
                        // each PE type's normalized (perf/area ↑, energy ↓)
                        // cloud.
                        for space in &db.spaces {
                            let normalized = dse::normalize(&space.evals)?;
                            print!("  {:<10} hypervolume:", space.model_name);
                            for pe in PeType::ALL {
                                let points: Vec<(f64, f64)> = normalized
                                    .iter()
                                    .filter(|p| p.pe == pe)
                                    .map(|p| (p.norm_perf_per_area, p.norm_energy))
                                    .collect();
                                let hv = dse::hypervolume_2d(
                                    &points,
                                    (0.0, 10.0),
                                    (dse::Orientation::Maximize, dse::Orientation::Minimize),
                                );
                                print!(" {}={}", pe.name(), format_sig(hv, 3));
                            }
                            println!();
                        }
                    }
                    // A custom --sweep may legitimately contain no INT16
                    // points; report raw bests instead of failing the
                    // whole (already completed) campaign.
                    Err(Error::MissingBaseline(_)) => {
                        println!(
                            "  (explored space has no INT16 baseline: reporting raw bests)"
                        );
                        print_raw_bests(&db);
                    }
                    Err(err) => return Err(err),
                }
            }
            let save_path = matches.get_str("save");
            if !save_path.is_empty() {
                db.save(Path::new(save_path))?;
                println!("saved evaluation database to {save_path}");
            }
        }
        "cache" => {
            let file = matches.get_str("file");
            let path = Path::new(file);
            if matches.flag("clear") {
                if path.exists() {
                    std::fs::remove_file(path)?;
                    println!("removed {file}");
                } else {
                    println!("{file}: no cache file");
                }
            } else if !path.exists() {
                println!("{file}: no cache file");
            } else {
                let cache = PointCache::load(path)?;
                let bytes = std::fs::metadata(path)?.len();
                println!(
                    "{file}: {} cached design points, {} evaluations, {} bytes",
                    cache.len(),
                    cache.total_evaluations(),
                    bytes
                );
            }
        }
        "pareto" => {
            let dataset = parse_dataset(matches.get_str("dataset"))?;
            let figure = if matches.get_str("metric") == "energy" {
                report::fig6(dataset, workers, seed)?
            } else {
                report::fig5(dataset, workers, seed)?
            };
            print!("{}", figure.render());
        }
        "rtl" => {
            let config = AcceleratorConfig {
                pe: parse_pe(matches.get_str("pe"))?,
                rows: matches.get_usize("rows"),
                cols: matches.get_usize("cols"),
                ..Default::default()
            };
            config.validate()?;
            let bundle = rtl::generate(&config);
            let out = matches.get_str("out").to_string();
            let paths = rtl::write_bundle(&bundle, Path::new(&out))?;
            for path in paths {
                println!("wrote {}", path.display());
            }
        }
        "sim" => {
            let pe = parse_pe(matches.get_str("pe"))?;
            let config = AcceleratorConfig { pe, ..Default::default() };
            let layer = qadam::dnn::Layer::conv(
                "cli",
                matches.get_usize("hw"),
                matches.get_usize("in-c"),
                matches.get_usize("out-c"),
                3,
                1,
                1,
            );
            let mut rng = Pcg64::new(seed);
            let ifmap: Vec<f64> =
                (0..layer.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let weights: Vec<f64> =
                (0..layer.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let result = sim::simulate_layer(&layer, &config, &ifmap, &weights);
            println!(
                "cycles={} utilization={} verified={} max_quant_err={}",
                result.cycles,
                format_sig(result.utilization, 3),
                result.verified,
                format_sig(result.max_abs_error, 3)
            );
        }
        "train" => {
            let pe = parse_pe(matches.get_str("pe"))?;
            let steps = matches.get_usize("steps");
            let dir = matches.get_str("artifacts").to_string();
            let mut runtime = Runtime::new(Path::new(&dir))?;
            let outcome = QatDriver::train(&mut runtime, pe, steps, (steps / 10).max(1))?;
            for record in &outcome.loss_curve {
                println!("step {:>5}  loss {:.4}", record.step, record.loss);
            }
            println!(
                "{}: final accuracy {:.3} eval-loss {:.4} after {} steps",
                pe.name(),
                outcome.final_accuracy,
                outcome.final_eval_loss,
                outcome.steps
            );
        }
        "report" => {
            let load_path = matches.get_str("load");
            let figure = if load_path.is_empty() {
                let dataset = parse_dataset(matches.get_str("dataset"))?;
                match matches.get_str("fig") {
                    "2" => report::fig2(workers, seed)?,
                    "3" => report::fig3(seed)?,
                    "4" => report::fig4(dataset, workers, seed)?,
                    "5" => report::fig5(dataset, workers, seed)?,
                    "6" => report::fig6(dataset, workers, seed)?,
                    other => {
                        return Err(Error::ParseError(format!("unknown figure '{other}'")));
                    }
                }
            } else {
                // Figures 4-6 consume only the persisted evaluations, so a
                // saved database reproduces the live-run figure exactly.
                let db = EvalDatabase::load(Path::new(load_path))?;
                match matches.get_str("fig") {
                    "4" => report::fig4_from_db(&db)?,
                    "5" => report::fig5_from_db(&db)?,
                    "6" => report::fig6_from_db(&db)?,
                    other => {
                        return Err(Error::InvalidConfig(format!(
                            "--load renders figs 4-6 from a saved database; fig '{other}' \
                             requires a live run"
                        )));
                    }
                }
            };
            print!("{}", figure.render());
        }
        _ => {
            println!("{}", cli().help());
        }
    }
    Ok(())
}
