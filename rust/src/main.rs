//! `qadam` — the command-line launcher for the QADAM framework.
//!
//! Subcommands map one-to-one onto the paper's workflow (Fig. 1): feed
//! accelerator parameters + DNN configurations, get PPA results, DSE
//! scatter data, Pareto fronts, generated RTL, simulation traces, and the
//! QAT training driver. Campaigns — whether flag-driven (`dse`) or
//! spec-driven (`run`, QSL) — lower to one shared
//! [`ResolvedCampaign`] pipeline over the unified
//! [`Explorer`](qadam::explore::Explorer) API; failures surface as
//! typed [`qadam::Error`]s.

#![forbid(unsafe_code)]

use std::path::Path;

use qadam::arch::{AcceleratorConfig, SweepSpec};
use qadam::bench::BenchArtifact;
use qadam::coordinator::default_workers;
use qadam::dataflow::{map_model, Dataflow};
use qadam::dnn::{model_for, Dataset, ModelKind};
use qadam::dse;
use qadam::energy::energy_of;
use qadam::explore::{inspect_qdb, EvalDatabase, PointCache};
use qadam::obs::view::{render_diff, render_merge, render_show};
use qadam::obs::{sidecar_path, TimingSidecar, Trace};
use qadam::ppa::PpaModel;
use qadam::quant::PeType;
use qadam::report;
use qadam::rtl;
use qadam::runtime::{QatDriver, Runtime};
use qadam::serve::{BatchQueue, ServeConfig};
use qadam::sim;
use qadam::spec::lint::{self as spec_lint, LintOptions};
use qadam::spec::{
    self, CampaignOutcome, PersistPlan, ResolvedCampaign, StrategyChoice, WorkloadModel,
};
use qadam::synth;
use qadam::util::json::{num, obj, s, Json};
use qadam::util::cli::{Command, Matches};
use qadam::util::log::{self, Level};
use qadam::util::rng::Pcg64;
use qadam::util::table::{format_sig, Table};
use qadam::{Error, Result};

fn cli() -> Command {
    Command::new("qadam", "quantization-aware PPA modeling & DSE for DNN accelerators")
        .opt("log-level", "info", "error|warn|info|debug|trace")
        .opt("seed", "7", "rng / synthesis-noise seed")
        .opt("workers", "0", "worker threads (0 = cores-1)")
        .sub(
            Command::new("synth", "synthesize one design point (DC stand-in)")
                .opt("pe", "int16", "fp32|int16|lightpe1|lightpe2")
                .opt("rows", "16", "PE array rows")
                .opt("cols", "16", "PE array cols")
                .opt("glb-kib", "128", "global buffer KiB"),
        )
        .sub(
            Command::new("ppa", "evaluate PPA of one design on one model")
                .opt("pe", "int16", "PE type")
                .opt("model", "resnet20", "vgg16|resnet20|resnet34|resnet50|resnet56")
                .opt("dataset", "cifar10", "cifar10|cifar100|imagenet"),
        )
        .sub(
            Command::new("fit", "fit polynomial PPA surrogates (k-fold CV)")
                .opt("folds", "5", "cross-validation folds"),
        )
        .sub(
            Command::new("dse", "design-space exploration campaign")
                .opt("dataset", "cifar10", "cifar10|cifar100|imagenet")
                .opt("sweep", "", "JSON sweep-config file (empty = default space)")
                .opt("width-mults", "", "model width multipliers, e.g. 0.5,1.0 (joint co-exploration)")
                .opt("depth-mults", "", "model depth multipliers, e.g. 1,2 (joint co-exploration)")
                .opt("shard", "", "run only shard I of N (format: I/N)")
                .opt("strategy", "exhaustive", "exhaustive|random:N[:SEED]|halving:KEEP[:ROUNDS]")
                .opt("frontier", "", "write the streaming Pareto frontier to this JSON file")
                .opt("save", "", "write the evaluation database here (.qdb = columnar binary)")
                .opt("load", "", "summarize a saved database (JSON or .qdb) instead of running")
                .opt("resume", "", "checkpoint journal path (resumes if present)")
                .opt("every", "16", "flush the checkpoint journal every N points")
                .opt("cache", "", "content-addressed point-cache file (reused & updated)")
                .opt("trace", "", "write the deterministic event trace (+ .timing sidecar)"),
        )
        .sub(
            Command::new("run", "execute a QSL campaign spec (see 'qadam spec init')")
                .opt("save", "", "provide persist.db when the spec omits it")
                .opt("cache", "", "provide persist.cache when the spec omits it")
                .opt("resume", "", "provide persist.checkpoint when the spec omits it")
                .opt("every", "16", "provide persist.every when the spec omits it")
                .opt("frontier", "", "provide persist.frontier when the spec omits it")
                .opt("trace", "", "provide persist.trace when the spec omits it"),
        )
        .sub(
            Command::new(
                "serve",
                "run a batch of specs concurrently with a shared dedupe cache",
            )
            .opt("out", "serve-out", "batch output directory")
            .opt("max-concurrent", "1", "campaigns in flight at once")
            .opt("deny", "", "lint rules to escalate to errors (codes/names, or 'all')")
            .opt("allow", "", "lint rules to suppress (codes/names, or 'all')")
            .opt("trace", "", "record a batch-level scheduler trace to this file")
            .flag("quiet", "suppress the live per-campaign transition stream on stderr"),
        )
        .sub(
            Command::new(
                "validate",
                "parse + semantically check a QSL spec; print the resolved campaign",
            )
            .flag("lint", "also run the static-analysis pass (see 'qadam lint')")
            .opt("deny", "", "lint rules to escalate to errors (codes/names, or 'all')")
            .opt("allow", "", "lint rules to suppress (codes/names, or 'all')"),
        )
        .sub(
            Command::new("lint", "static analysis over QSL campaign specs (rules Q001...)")
                .opt("deny", "", "rules to escalate to errors (codes/names, or 'all')")
                .opt("allow", "", "rules to suppress (codes/names, or 'all')")
                .opt("format", "text", "text|json"),
        )
        .sub(
            Command::new("spec", "QSL spec-file utilities").sub(
                Command::new("init", "emit a commented starter spec")
                    .opt("out", "", "write to this file (default: stdout)"),
            ),
        )
        .sub(
            Command::new("db", "evaluation-database utilities (canonical JSON <-> qadam.qdb)")
                .sub(Command::new(
                    "convert",
                    "convert between formats: <in> <out> (a .qdb output extension selects \
                     the columnar binary)",
                ))
                .sub(Command::new(
                    "inspect",
                    "print a .qdb file's header, space shapes, and integrity fingerprint",
                )),
        )
        .sub(
            Command::new("bench", "bench-artifact utilities (see DESIGN.md §Bench artifacts)")
                .sub(
                    Command::new(
                        "merge",
                        "merge per-target artifacts (files or dirs) into one trajectory file",
                    )
                    .opt("out", "BENCH_PR10.json", "merged artifact output path"),
                )
                .sub(
                    Command::new("diff", "compare two artifacts: <old.json> <new.json>")
                        .opt("threshold", "10", "p50 regression/improvement threshold, percent")
                        .flag("strict", "exit nonzero when a regression exceeds the threshold"),
                )
                .sub(Command::new("show", "print one artifact's records as a table")),
        )
        .sub(
            Command::new("trace", "inspect saved qadam.trace event traces (DESIGN.md §11)")
                .sub(Command::new(
                    "show",
                    "render one trace: strategy funnel, cache stats, phase timings",
                ))
                .sub(
                    Command::new(
                        "merge",
                        "combine traces: per-tenant cache-dedupe effectiveness",
                    )
                    .opt("out", "", "also save the merged trace to this file"),
                )
                .sub(Command::new(
                    "diff",
                    "compare two traces: <left.json> <right.json>; exits nonzero on divergence",
                )),
        )
        .sub(
            Command::new("cache", "inspect or clear a point-cache file")
                .opt("file", "qadam_cache.json", "cache file path")
                .flag("clear", "delete the cache file"),
        )
        .sub(
            Command::new("pareto", "Pareto-front analysis (Figs. 5/6)")
                .opt("dataset", "cifar10", "cifar10|cifar100")
                .opt("metric", "perf-per-area", "perf-per-area|energy"),
        )
        .sub(
            Command::new("rtl", "generate Verilog for a design point")
                .opt("pe", "lightpe1", "PE type")
                .opt("rows", "16", "PE array rows")
                .opt("cols", "16", "PE array cols")
                .opt("out", "rtl_out", "output directory"),
        )
        .sub(
            Command::new("sim", "cycle-level functional simulation (VCS stand-in)")
                .opt("pe", "int16", "PE type")
                .opt("hw", "8", "ifmap height/width")
                .opt("in-c", "3", "input channels")
                .opt("out-c", "8", "output channels"),
        )
        .sub(
            Command::new("train", "QAT training via the PJRT runtime")
                .opt("pe", "lightpe1", "PE type")
                .opt("steps", "100", "training steps")
                .opt("artifacts", "artifacts", "artifacts directory"),
        )
        .sub(
            Command::new("report", "regenerate a paper figure")
                .opt("fig", "4", "2|3|4|5|6")
                .opt("dataset", "cifar10", "dataset for figs 4-6")
                .opt("load", "", "render figs 4-6 from a saved database (no re-run)")
                .opt(
                    "spec",
                    "",
                    "QSL spec whose accuracy{} declarations feed figs 5/6 (custom/scaled models)",
                ),
        )
}

fn parse_pe(text: &str) -> Result<PeType> {
    PeType::parse(text).ok_or_else(|| Error::ParseError(format!("bad --pe '{text}'")))
}

/// Parse a comma-separated width-multiplier list (`"0.5,1.0"`).
fn parse_width_mults(text: &str) -> Result<Vec<f64>> {
    let bad = |detail: &str| {
        Error::InvalidConfig(format!(
            "bad --width-mults '{text}' ({detail}; expected comma-separated positive numbers, \
             e.g. 0.5,1.0)"
        ))
    };
    let mut widths = Vec::new();
    for part in text.split(',') {
        let w: f64 = part.trim().parse().map_err(|_| bad("not a number"))?;
        if !w.is_finite() || w <= 0.0 {
            return Err(bad("multipliers must be positive"));
        }
        if widths.contains(&w) {
            return Err(bad("duplicate multiplier"));
        }
        widths.push(w);
    }
    Ok(widths)
}

/// Parse a comma-separated depth-multiplier list (`"1,2"`).
fn parse_depth_mults(text: &str) -> Result<Vec<usize>> {
    let bad = |detail: &str| {
        Error::InvalidConfig(format!(
            "bad --depth-mults '{text}' ({detail}; expected comma-separated integers >= 1, \
             e.g. 1,2)"
        ))
    };
    let mut depths = Vec::new();
    for part in text.split(',') {
        let d: usize = part.trim().parse().map_err(|_| bad("not an integer"))?;
        if d == 0 {
            return Err(bad("multipliers must be at least 1"));
        }
        if depths.contains(&d) {
            return Err(bad("duplicate multiplier"));
        }
        depths.push(d);
    }
    Ok(depths)
}

/// Parse an `I/N` shard designator ("2/8" = shard 2 of 8).
fn parse_shard(text: &str) -> Result<(usize, usize)> {
    let bad = || Error::ParseError(format!("bad --shard '{text}' (expected I/N, e.g. 0/4)"));
    let (i, n) = text.split_once('/').ok_or_else(bad)?;
    let shard: usize = i.trim().parse().map_err(|_| bad())?;
    let num_shards: usize = n.trim().parse().map_err(|_| bad())?;
    if num_shards == 0 || shard >= num_shards {
        return Err(bad());
    }
    Ok((shard, num_shards))
}

/// Print the `variant wWdD:` group header when a joint database's walk
/// crosses into the next scaled-model variant (no-op for hardware-only
/// databases, whose summaries are unchanged).
fn print_variant_header<'db>(
    db: &'db EvalDatabase,
    space: &'db qadam::explore::ModelSpace,
    last: &mut Option<Option<&'db str>>,
) {
    if !db.has_model_variants() {
        return;
    }
    let label = space.variant_label();
    if *last != Some(label) {
        *last = Some(label);
        println!("  variant {}:", label.unwrap_or("base (w1d1)"));
    }
}

/// Per-model best raw perf/area by PE type — the summary for databases
/// that cannot be normalized (partial coverage or no INT16 baseline).
/// Joint databases group the lines by scaled-model variant.
fn print_raw_bests(db: &EvalDatabase) {
    let mut last_variant = None;
    for space in &db.spaces {
        print_variant_header(db, space, &mut last_variant);
        print!("  {:<10} best perf/area:", space.model_name);
        for pe in PeType::ALL {
            if let Some(best) = dse::best_perf_per_area(&space.evals, pe) {
                print!(" {}={}", pe.name(), format_sig(best.perf_per_area, 3));
            }
        }
        println!();
    }
}

/// Summarize a database: normalized headline ratios + hypervolumes for
/// whole-space campaigns, raw bests otherwise. Shared by `dse` (live and
/// `--load`) and `run`.
fn summarize_db(db: &EvalDatabase) -> Result<()> {
    // The database records its own coverage (shard + strategy), so a
    // loaded partial database is summarized exactly like a live partial
    // run.
    if !db.is_whole_space() {
        // A shard or a strategy-sampled subset sees only part of the
        // space, so its local best INT16 is not the campaign baseline;
        // normalized summaries would be silently wrong. Report raw bests
        // instead.
        if db.shard.1 > 1 {
            println!("  (shard output: normalize after merging all shards)");
        } else {
            println!(
                "  (sampled by strategy '{}': raw bests only; rerun exhaustively to normalize)",
                db.strategy
            );
        }
        print_raw_bests(db);
        return Ok(());
    }
    match db.headline_geomean() {
        Ok(headline) => {
            if db.has_model_variants() {
                println!(
                    "  (joint campaign: geomeans span all {} scaled-model spaces)",
                    db.spaces.len()
                );
            }
            for (pe, ppa, energy) in headline {
                println!(
                    "  {:<10} {}x perf/area, {}x less energy vs best INT16",
                    pe.name(),
                    format_sig(ppa, 3),
                    format_sig(energy, 3)
                );
            }
            // Quantified Pareto quality per model: hypervolume of each PE
            // type's normalized (perf/area ↑, energy ↓) cloud, grouped by
            // scaled-model variant for joint campaigns.
            let mut last_variant = None;
            for space in &db.spaces {
                print_variant_header(db, space, &mut last_variant);
                let normalized = dse::normalize(&space.evals)?;
                print!("  {:<10} hypervolume:", space.model_name);
                for pe in PeType::ALL {
                    let points: Vec<(f64, f64)> = normalized
                        .iter()
                        .filter(|p| p.pe == pe)
                        .map(|p| (p.norm_perf_per_area, p.norm_energy))
                        .collect();
                    let hv = dse::hypervolume_2d(
                        &points,
                        (0.0, 10.0),
                        (dse::Orientation::Maximize, dse::Orientation::Minimize),
                    );
                    print!(" {}={}", pe.name(), format_sig(hv, 3));
                }
                println!();
            }
            Ok(())
        }
        // A custom sweep may legitimately contain no INT16 points; report
        // raw bests instead of failing the whole (already completed)
        // campaign.
        Err(Error::MissingBaseline(_)) => {
            println!("  (explored space has no INT16 baseline: reporting raw bests)");
            print_raw_bests(db);
            Ok(())
        }
        Err(err) => Err(err),
    }
}

/// `hits / (hits + misses)` as a percentage, `"-"` when nothing was
/// looked up.
fn hit_rate(hits: u64, misses: u64) -> String {
    let lookups = hits + misses;
    if lookups == 0 {
        "-".into()
    } else {
        format!("{:.1}%", 100.0 * hits as f64 / lookups as f64)
    }
}

/// Print an executed campaign the way `qadam dse` always has: stats
/// line, cache/frontier lines, database summary, save confirmation.
fn print_campaign_outcome(outcome: &CampaignOutcome) -> Result<()> {
    let db = &outcome.db;
    println!(
        "{} design points x {} models in {:.2}s ({:.0} evals/s, {} workers)",
        db.stats.design_points,
        db.spaces.len(),
        db.stats.wall_seconds,
        db.stats.evals_per_sec(),
        db.stats.workers
    );
    if let Some(cache) = &outcome.cache {
        println!(
            "cache: {} design points ({} hits / {} misses this run, {} hit rate), \
             generation {}, saved to {}",
            cache.entries,
            cache.hits,
            cache.misses,
            hit_rate(cache.hits, cache.misses),
            cache.generation,
            cache.path.display()
        );
    }
    if let Some(frontier) = &outcome.frontier {
        print!("frontier: saved to {} —", frontier.path.display());
        for (name, points) in &frontier.per_model {
            print!(" {name}: {points} points");
        }
        println!();
    }
    if let Some(trace) = &outcome.trace {
        println!(
            "trace: {} events -> {} (timing sidecar {})",
            trace.events,
            trace.path.display(),
            trace.timing.display()
        );
    }
    summarize_db(db)?;
    if let Some(path) = &outcome.saved_db {
        println!("saved evaluation database to {}", path.display());
    }
    Ok(())
}

/// Merge `qadam run` flags into a spec-built campaign. Flags may supply
/// fields the spec omits; a flag that contradicts a field the spec sets
/// explicitly is rejected with [`Error::InvalidConfig`] — the spec is
/// the source of truth for anything it pins.
fn merge_flag_overrides(campaign: &mut ResolvedCampaign, matches: &Matches) -> Result<()> {
    let conflict = |flag: &str, spec_key: &str| {
        Error::InvalidConfig(format!(
            "--{flag} conflicts with the spec's {spec_key}; drop the flag or edit the spec"
        ))
    };
    if matches.was_set("seed") {
        if campaign.sets("seed") {
            return Err(conflict("seed", "campaign.seed"));
        }
        campaign.seed = matches.get_usize("seed") as u64;
        // An unseeded random() pins the campaign seed (matching
        // `--strategy random:N`), so it follows the override.
        if let StrategyChoice::Random { n, .. } = campaign.strategy {
            if !campaign.sets("strategy.seed") {
                campaign.strategy = StrategyChoice::Random { n, seed: campaign.seed };
            }
        }
    }
    if matches.was_set("workers") {
        if campaign.sets("workers") {
            return Err(conflict("workers", "campaign.workers"));
        }
        campaign.workers = matches.get_usize("workers");
    }
    for (flag, key) in [
        ("save", "db"),
        ("cache", "cache"),
        ("resume", "checkpoint"),
        ("frontier", "frontier"),
        ("trace", "trace"),
    ] {
        if !matches.was_set(flag) {
            continue;
        }
        if campaign.sets(key) {
            return Err(conflict(flag, &format!("persist.{key}")));
        }
        let value = matches.get_str(flag).to_string();
        let path = (!value.is_empty()).then(|| Path::new(&value).to_path_buf());
        match key {
            "db" => campaign.persist.db = path,
            "cache" => campaign.persist.cache = path,
            "checkpoint" => campaign.persist.checkpoint = path,
            "trace" => campaign.persist.trace = path,
            _ => campaign.persist.frontier = path,
        }
    }
    if matches.was_set("every") {
        if campaign.sets("every") {
            return Err(conflict("every", "persist.every"));
        }
        campaign.persist.every = matches.get_usize("every");
    }
    Ok(())
}

/// Lint spec files and print findings (rendered text, or one JSON
/// document — a per-file object, batched when several files are given).
/// Fails on unresolvable specs and on surviving deny-level findings, so
/// `qadam lint --deny all` is a usable CI gate.
fn lint_files(files: &[String], opts: &LintOptions, json_mode: bool) -> Result<()> {
    let mut docs = Vec::new();
    let mut denials = 0usize;
    for file in files {
        let expansion = spec::expand_path(Path::new(file))?;
        let source = &expansion.source;
        if expansion.has_errors() {
            // Not lintable at all: surface the resolver's diagnostics.
            print!("{}", expansion.diags.render(source, file));
            return Err(Error::ParseError(format!(
                "{file}: {} error(s); fix the spec before linting",
                expansion.diags.error_count()
            )));
        }
        // Lint every expanded campaign, then dedupe: matrix combinations
        // share most of their composed AST, so identical findings (same
        // rule, same span, same message) would otherwise repeat per
        // combination.
        let mut findings: Vec<spec_lint::Finding> = Vec::new();
        for expanded in &expansion.campaigns {
            for finding in spec_lint::lint_campaign(source, &expanded.file, &expanded.campaign, opts)
            {
                let duplicate = findings.iter().any(|f| {
                    f.code == finding.code
                        && f.span.start == finding.span.start
                        && f.message == finding.message
                });
                if !duplicate {
                    findings.push(finding);
                }
            }
        }
        findings.sort_by(|a, b| (a.span.start, a.code).cmp(&(b.span.start, b.code)));
        denials += findings.iter().filter(|f| f.level == spec_lint::Level::Deny).count();
        if json_mode {
            docs.push(spec_lint::to_json(file, source, &findings));
        } else if findings.is_empty() {
            println!("{file}: clean ({} rules)", spec::RULES.len());
        } else {
            print!("{}", spec_lint::render(&findings, source, file));
        }
    }
    if json_mode {
        let doc = if docs.len() == 1 {
            docs.remove(0)
        } else {
            obj(vec![
                ("kind", s("qadam.lint-batch")),
                ("schema", num(1.0)),
                ("files", Json::Arr(docs)),
            ])
        };
        println!("{}", doc.to_string_pretty());
    }
    if denials > 0 {
        return Err(Error::InvalidConfig(format!("lint: {denials} deny-level finding(s)")));
    }
    Ok(())
}

/// Load bench artifacts from a mix of file and directory arguments; a
/// directory contributes every `*.json` inside it, in sorted order (the
/// `QADAM_BENCH_OUT` layout: one artifact per bench target).
fn load_bench_artifacts(args: &[String]) -> Result<Vec<BenchArtifact>> {
    let mut artifacts = Vec::new();
    for arg in args {
        let path = Path::new(arg);
        if path.is_dir() {
            let mut files: Vec<_> = std::fs::read_dir(path)?
                .filter_map(|entry| entry.ok().map(|e| e.path()))
                .filter(|p| p.extension().is_some_and(|ext| ext == "json"))
                .collect();
            files.sort();
            if files.is_empty() {
                return Err(Error::InvalidConfig(format!("{arg}: no *.json artifacts inside")));
            }
            for file in files {
                artifacts.push(BenchArtifact::load(&file)?);
            }
        } else {
            artifacts.push(BenchArtifact::load(path)?);
        }
    }
    Ok(artifacts)
}

/// The spec file named by the subcommand's positional argument.
fn spec_path(matches: &Matches, usage: &str) -> Result<String> {
    matches
        .positional
        .first()
        .cloned()
        .ok_or_else(|| Error::InvalidConfig(format!("usage: {usage}")))
}

fn main() -> Result<()> {
    log::init_from_env();
    let matches = cli().parse_or_exit();
    if let Some(level) = Level::parse(matches.get_str("log-level")) {
        log::set_level(level);
    }
    let seed: u64 = matches.get_usize("seed") as u64;
    let workers = match matches.get_usize("workers") {
        0 => default_workers(),
        n => n,
    };

    // `bench` and `trace` both own show/merge/diff leaves; the path's
    // first element says which parent a leaf belongs to.
    let parent = matches.path.first().map(String::as_str).unwrap_or("");

    match matches.subcommand() {
        "synth" => {
            let config = AcceleratorConfig {
                pe: parse_pe(matches.get_str("pe"))?,
                rows: matches.get_usize("rows"),
                cols: matches.get_usize("cols"),
                glb_kib: matches.get_usize("glb-kib"),
                ..Default::default()
            };
            config.validate()?;
            let report = synth::synthesize(&config, seed);
            let mut table = Table::new(&["metric", "value"]);
            table.row(&["design".into(), config.id()]);
            table.row(&["area_mm2".into(), format_sig(report.area.total_mm2(), 4)]);
            table.row(&["  pe_array_mm2".into(), format_sig(report.area.pe_array_um2 / 1e6, 4)]);
            table.row(&["  glb_mm2".into(), format_sig(report.area.glb_um2 / 1e6, 4)]);
            table.row(&["power_mw".into(), format_sig(report.total_power_mw(), 4)]);
            table.row(&["  leakage_mw".into(), format_sig(report.leakage_power_mw, 4)]);
            table.row(&["max_clock_ghz".into(), format_sig(report.max_clock_ghz, 4)]);
            table.row(&["peak_gmacs".into(), format_sig(report.peak_gmacs(), 4)]);
            print!("{}", table.render());
        }
        "ppa" => {
            let config = AcceleratorConfig {
                pe: parse_pe(matches.get_str("pe"))?,
                ..Default::default()
            };
            let dataset = Dataset::parse_strict(matches.get_str("dataset"))?;
            let kind = ModelKind::parse_strict(matches.get_str("model"))?;
            let model = model_for(kind, dataset);
            let synth_report = synth::synthesize(&config, seed);
            let mapping = map_model(&model, &config, Dataflow::RowStationary);
            let energy = energy_of(&mapping, &synth_report);
            let eval = dse::evaluate_with_synth(&synth_report, &model);
            let mut table = Table::new(&["metric", "value"]);
            table.row(&["model".into(), model.name.clone()]);
            table.row(&["total_macs".into(), mapping.total_macs.to_string()]);
            table.row(&["cycles".into(), mapping.total_cycles.to_string()]);
            table.row(&["utilization".into(), format_sig(mapping.avg_utilization, 3)]);
            table.row(&["latency_ms".into(), format_sig(eval.latency_ms, 4)]);
            table.row(&["inf_per_s".into(), format_sig(eval.inf_per_s, 4)]);
            table.row(&["perf_per_area".into(), format_sig(eval.perf_per_area, 4)]);
            table.row(&["chip_energy_uj".into(), format_sig(energy.chip_uj(), 4)]);
            table.row(&["dram_energy_uj".into(), format_sig(energy.dram_uj, 4)]);
            table.row(&["dram_bytes".into(), mapping.traffic.dram_bytes.to_string()]);
            table.row(&["glb_accesses".into(), mapping.traffic.glb.total().to_string()]);
            print!("{}", table.render());
        }
        "fit" => {
            let folds = matches.get_usize("folds");
            for pe in PeType::ALL {
                let dataset = synth::synthesize_sweep(&SweepSpec::default(), pe, seed);
                let model = PpaModel::fit(&dataset, folds, seed);
                for report in &model.reports {
                    println!(
                        "{:<10} {:<6} degree={} r={} R2={} MAPE={}%",
                        pe.name(),
                        report.metric,
                        report.degree,
                        format_sig(report.pearson, 4),
                        format_sig(report.r_squared, 4),
                        format_sig(report.mape, 3),
                    );
                }
            }
        }
        "dse" => {
            let load_path = matches.get_str("load").to_string();
            if !load_path.is_empty() {
                // --load summarizes an existing database; campaign-shaping
                // flags would be silently ignored, so reject them (also
                // the defaulted ones — `was_set` sees through defaults).
                let campaign_flags = [
                    "dataset", "sweep", "width-mults", "depth-mults", "shard", "strategy",
                    "frontier", "resume", "cache", "every", "trace",
                ];
                for conflicting in campaign_flags {
                    if matches.was_set(conflicting) {
                        return Err(Error::InvalidConfig(format!(
                            "--load summarizes a saved database; --{conflicting} only applies \
                             to a live campaign"
                        )));
                    }
                }
                let db = EvalDatabase::load_any(Path::new(&load_path))?;
                println!(
                    "loaded {} design points x {} models from {load_path}",
                    db.stats.design_points,
                    db.spaces.len()
                );
                summarize_db(&db)?;
                let save_path = matches.get_str("save");
                if !save_path.is_empty() {
                    db.save_auto(Path::new(save_path))?;
                    println!("saved evaluation database to {save_path}");
                }
            } else {
                // Build the same ResolvedCampaign a spec file would — the
                // flag path and `qadam run` share one execution pipeline,
                // so equivalent invocations are byte-identical.
                let dataset = Dataset::parse_strict(matches.get_str("dataset"))?;
                let sweep_path = matches.get_str("sweep");
                // A sweep file may carry a `model_axes` key (the
                // DesignSpace JSON form); honoring it here keeps file
                // and flag campaigns equivalent.
                let file_space = if sweep_path.is_empty() {
                    qadam::arch::DesignSpace::from(SweepSpec::default())
                } else {
                    qadam::arch::DesignSpace::from_file(Path::new(sweep_path))?
                };
                let sweep = file_space.hw;
                let file_axes = file_space.model;
                let shard_arg = matches.get_str("shard");
                let shard =
                    if shard_arg.is_empty() { (0, 1) } else { parse_shard(shard_arg)? };
                let strategy = StrategyChoice::parse_cli(matches.get_str("strategy"), seed)?;
                let path_of = |name: &str| {
                    let value = matches.get_str(name);
                    (!value.is_empty()).then(|| Path::new(value).to_path_buf())
                };
                let persist = PersistPlan {
                    db: path_of("save"),
                    cache: path_of("cache"),
                    checkpoint: path_of("resume"),
                    every: matches.get_usize("every"),
                    frontier: path_of("frontier"),
                    trace: path_of("trace"),
                };
                let workload =
                    dataset.paper_models().into_iter().map(WorkloadModel::Zoo).collect();
                let mut campaign = ResolvedCampaign::new(
                    sweep, dataset, workload, seed, workers, shard, strategy, persist,
                );
                // Joint co-exploration: model axes from the sweep file,
                // or from the flags — a file that pins them conflicts
                // with the flags (same rule as spec-set fields).
                let widths = matches.get_str("width-mults");
                let depths = matches.get_str("depth-mults");
                if !file_axes.is_trivial() && (!widths.is_empty() || !depths.is_empty()) {
                    return Err(Error::InvalidConfig(
                        "the sweep file pins model_axes; drop --width-mults/--depth-mults \
                         or edit the file"
                            .into(),
                    ));
                }
                campaign.model_axes = file_axes;
                if !widths.is_empty() {
                    campaign.model_axes.width_mults = parse_width_mults(widths)?;
                }
                if !depths.is_empty() {
                    campaign.model_axes.depth_mults = parse_depth_mults(depths)?;
                }
                print_campaign_outcome(&campaign.execute()?)?;
            }
        }
        "run" => {
            let file = spec_path(&matches, "qadam run <campaign.qsl> (see 'qadam spec init')")?;
            let expansion = spec::expand_path(Path::new(&file))?;
            if !expansion.diags.is_empty() {
                print!("{}", expansion.diags.render(&expansion.source, &file));
            }
            if expansion.has_errors() {
                return Err(Error::ParseError(format!(
                    "{file}: {} error(s)",
                    expansion.diags.error_count()
                )));
            }
            let mut campaigns = expansion.campaigns;
            if campaigns.len() != 1 {
                return Err(Error::InvalidConfig(format!(
                    "{file} expands to {} campaigns; run batches with 'qadam serve'",
                    campaigns.len()
                )));
            }
            let mut campaign = campaigns.remove(0).campaign;
            merge_flag_overrides(&mut campaign, &matches)?;
            println!(
                "campaign {}: {} design points x {} models [{}]",
                file,
                campaign.sweep.len() * campaign.model_axes.len(),
                campaign.workload.len(),
                campaign.strategy.descriptor()
            );
            print_campaign_outcome(&campaign.execute()?)?;
        }
        "serve" => {
            if matches.positional.is_empty() {
                return Err(Error::InvalidConfig(
                    "usage: qadam serve <campaign.qsl>... [--out DIR] [--max-concurrent K] \
                     [--deny CODES|all] [--allow CODES|all] [--trace FILE] [--quiet]"
                        .into(),
                ));
            }
            let specs: Vec<std::path::PathBuf> =
                matches.positional.iter().map(|p| Path::new(p).to_path_buf()).collect();
            let queue = BatchQueue::build(&specs)?;
            for warning in &queue.warnings {
                print!("{warning}");
            }
            let mut config = ServeConfig::new(matches.get_str("out"));
            config.max_concurrent = matches.get_usize("max-concurrent").max(1);
            if matches.was_set("workers") {
                config.workers = workers;
            }
            config.lint =
                LintOptions::parse(matches.get_str("deny"), matches.get_str("allow"))?;
            config.quiet = matches.flag("quiet");
            let trace_arg = matches.get_str("trace");
            config.trace =
                (!trace_arg.is_empty()).then(|| Path::new(trace_arg).to_path_buf());
            println!(
                "serving {} campaign(s) from {} spec file(s) -> {}",
                queue.len(),
                specs.len(),
                config.out_dir.display()
            );
            let outcome = qadam::serve::serve(&queue, &config)?;
            let mut table = Table::new(&["campaign", "label", "state", "hits", "misses", "detail"]);
            for report in &outcome.reports {
                table.row(&[
                    format!("{:016x}", report.fingerprint),
                    report.label.clone(),
                    report.state.label().into(),
                    report.hits.to_string(),
                    report.misses.to_string(),
                    report.detail.clone(),
                ]);
            }
            print!("{}", table.render());
            if outcome.cache_recovered {
                println!(
                    "warning: shared cache was torn or corrupt; started cold (results unaffected)"
                );
            }
            println!(
                "shared cache: {} design points -> {}",
                outcome.cache_entries,
                outcome.cache_path.display()
            );
            println!("status journal: {}", outcome.status_path.display());
            if let Some(path) = &outcome.trace {
                println!(
                    "batch trace: {} (timing sidecar {})",
                    path.display(),
                    sidecar_path(path).display()
                );
            }
            let failures = outcome.failures();
            if failures > 0 {
                return Err(Error::Runtime(format!("{failures} campaign(s) failed")));
            }
        }
        "validate" => {
            let file = spec_path(&matches, "qadam validate <campaign.qsl> [--lint]")?;
            let expansion = spec::expand_path(Path::new(&file))?;
            let source = &expansion.source;
            if !expansion.diags.is_empty() {
                print!("{}", expansion.diags.render(source, &file));
            }
            if expansion.has_errors() {
                return Err(Error::ParseError(format!(
                    "{file}: {} error(s)",
                    expansion.diags.error_count()
                )));
            }
            let lint_opts = matches
                .flag("lint")
                .then(|| LintOptions::parse(matches.get_str("deny"), matches.get_str("allow")))
                .transpose()?;
            let multi = expansion.campaigns.len() > 1;
            let mut denials = 0usize;
            for expanded in &expansion.campaigns {
                if multi {
                    println!("-- campaign [{}]", expanded.label);
                }
                if let Some(opts) = &lint_opts {
                    let findings =
                        spec_lint::lint_campaign(source, &expanded.file, &expanded.campaign, opts);
                    if !findings.is_empty() {
                        print!("{}", spec_lint::render(&findings, source, &file));
                    }
                    denials +=
                        findings.iter().filter(|f| f.level == spec_lint::Level::Deny).count();
                }
                print!("{}", expanded.campaign.summary());
            }
            if denials > 0 {
                return Err(Error::InvalidConfig(format!(
                    "{file}: {denials} deny-level lint finding(s)"
                )));
            }
            if multi {
                println!("{file}: ok ({} campaigns)", expansion.campaigns.len());
            } else {
                println!("{file}: ok");
            }
        }
        "lint" => {
            if matches.positional.is_empty() {
                return Err(Error::InvalidConfig(
                    "usage: qadam lint <campaign.qsl>... [--deny CODES|all] [--allow CODES|all] \
                     [--format text|json]"
                        .into(),
                ));
            }
            let opts = LintOptions::parse(matches.get_str("deny"), matches.get_str("allow"))?;
            let json_mode = match matches.get_str("format") {
                "json" => true,
                "text" => false,
                other => {
                    return Err(Error::InvalidConfig(format!(
                        "bad --format '{other}' (expected text or json)"
                    )));
                }
            };
            lint_files(&matches.positional, &opts, json_mode)?;
        }
        "init" => {
            let out = matches.get_str("out");
            if out.is_empty() || out == "-" {
                print!("{}", spec::STARTER_SPEC);
            } else {
                let path = Path::new(out);
                if path.exists() {
                    return Err(Error::InvalidConfig(format!(
                        "{out} already exists; remove it or pick another --out path"
                    )));
                }
                std::fs::write(path, spec::STARTER_SPEC)?;
                println!("wrote starter spec to {out}");
            }
        }
        "spec" => {
            println!("qadam spec init [--out FILE]  — emit a commented starter spec");
        }
        "db" => {
            println!(
                "qadam db convert <in> <out>  — JSON <-> qadam.qdb (format by output extension)"
            );
            println!("qadam db inspect <file.qdb>  — header, space shapes, integrity fingerprint");
        }
        "convert" if parent == "db" => {
            let [in_path, out_path] = matches.positional.as_slice() else {
                return Err(Error::InvalidConfig(
                    "usage: qadam db convert <in> <out> (a .qdb output extension selects the \
                     columnar binary; anything else writes canonical JSON)"
                        .into(),
                ));
            };
            let db = EvalDatabase::load_any(Path::new(in_path))?;
            db.save_auto(Path::new(out_path))?;
            let bytes = std::fs::metadata(Path::new(out_path))?.len();
            println!(
                "converted {in_path} -> {out_path}: {} design points x {} spaces, {bytes} bytes",
                db.stats.design_points,
                db.spaces.len()
            );
        }
        "inspect" if parent == "db" => {
            let file = spec_path(&matches, "qadam db inspect <file.qdb>")?;
            let info = inspect_qdb(Path::new(&file))?;
            println!(
                "{file}: qadam.qdb schema {}, fingerprint {:016x}, {} bytes",
                info.schema, info.fingerprint, info.bytes
            );
            println!(
                "  dataset {} — shard {}/{}, strategy '{}', {} design points, {} evaluations \
                 across {} space(s)",
                info.dataset.name(),
                info.shard.0,
                info.shard.1,
                info.strategy,
                info.design_points,
                info.evaluations,
                info.spaces.len()
            );
            let mut table = Table::new(&["space", "rows"]);
            for (name, rows) in &info.spaces {
                table.row(&[name.clone(), rows.to_string()]);
            }
            print!("{}", table.render());
        }
        "bench" => {
            println!("qadam bench merge <artifact|dir>... [--out FILE]  — build a trajectory file");
            println!("qadam bench diff <old.json> <new.json> [--threshold PCT] [--strict]");
            println!("qadam bench show <artifact.json>  — print one artifact's records");
        }
        "trace" => {
            println!("qadam trace show <trace.json>  — funnel, cache, and phase-timing tables");
            println!("qadam trace merge <trace.json>... [--out FILE]  — cross-tenant dedupe view");
            println!("qadam trace diff <left.json> <right.json>  — first divergence, if any");
        }
        "show" if parent == "trace" => {
            let file = spec_path(&matches, "qadam trace show <trace.json>")?;
            let trace = Trace::load(Path::new(&file))?;
            let sidecar = sidecar_path(Path::new(&file));
            let timing =
                sidecar.exists().then(|| TimingSidecar::load(&sidecar)).transpose()?;
            print!("{}", render_show(&trace, timing.as_ref()));
        }
        "merge" if parent == "trace" => {
            if matches.positional.is_empty() {
                return Err(Error::InvalidConfig(
                    "usage: qadam trace merge <trace.json>... [--out FILE]".into(),
                ));
            }
            let mut tenants = Vec::new();
            for file in &matches.positional {
                tenants.push((file.clone(), Trace::load(Path::new(file))?));
            }
            print!("{}", render_merge(&tenants));
            let out = matches.get_str("out");
            if !out.is_empty() {
                let merged = Trace::merge(tenants.iter().map(|(_, trace)| trace));
                merged.save(Path::new(out))?;
                println!("merged {} trace(s) ({} events) into {out}", tenants.len(), merged.len());
            }
        }
        "diff" if parent == "trace" => {
            let [left_path, right_path] = matches.positional.as_slice() else {
                return Err(Error::InvalidConfig(
                    "usage: qadam trace diff <left.json> <right.json>".into(),
                ));
            };
            let left = Trace::load(Path::new(left_path))?;
            let right = Trace::load(Path::new(right_path))?;
            let diff = left.diff(&right);
            print!("{}", render_diff(left_path, right_path, &left, &right));
            // Like `bench diff --strict`: a divergence is an exit-code
            // gate so CI can pin trace identity.
            if !diff.identical() {
                return Err(Error::Runtime(format!(
                    "traces diverge at seq {}",
                    diff.divergence.map(|seq| seq.to_string()).unwrap_or_default()
                )));
            }
        }
        "merge" => {
            if matches.positional.is_empty() {
                return Err(Error::InvalidConfig(
                    "usage: qadam bench merge <artifact.json|dir>... [--out FILE]".into(),
                ));
            }
            let parts = load_bench_artifacts(&matches.positional)?;
            let count = parts.len();
            let merged = BenchArtifact::merge(parts)?;
            let out = matches.get_str("out");
            merged.save(Path::new(out))?;
            println!(
                "merged {count} artifact(s) into {out} ({} benches, host '{}')",
                merged.benches.len(),
                merged.host.label
            );
        }
        "diff" => {
            let [old_path, new_path] = matches.positional.as_slice() else {
                return Err(Error::InvalidConfig(
                    "usage: qadam bench diff <old.json> <new.json> [--threshold PCT] [--strict]"
                        .into(),
                ));
            };
            let threshold: f64 = matches.get_str("threshold").parse().map_err(|_| {
                Error::ParseError(format!(
                    "bad --threshold '{}' (expected percent, e.g. 10)",
                    matches.get_str("threshold")
                ))
            })?;
            let old = BenchArtifact::load(Path::new(old_path))?;
            let new = BenchArtifact::load(Path::new(new_path))?;
            if old.host != new.host {
                println!(
                    "note: hosts differ ('{}' vs '{}'); timings are apples-to-oranges",
                    old.host.label, new.host.label
                );
            }
            let diff = old.diff(&new, threshold);
            print!("{}", diff.render());
            // Warn-only by default (the CI smoke job compares 1-iteration
            // noise against the committed baseline); --strict turns the
            // report into a gate.
            if matches.flag("strict") && diff.has_regressions() {
                return Err(Error::Runtime(format!(
                    "{} bench regression(s) beyond +{threshold}% p50: {}",
                    diff.regressions().len(),
                    diff.regressions().join(", ")
                )));
            }
        }
        "show" => {
            let file = spec_path(&matches, "qadam bench show <artifact.json>")?;
            let artifact = BenchArtifact::load(Path::new(&file))?;
            println!(
                "{file}: {} benches on '{}' ({}/{})",
                artifact.benches.len(),
                artifact.host.label,
                artifact.host.os,
                artifact.host.arch
            );
            let mut table = Table::new(&["bench", "p50_ms", "mean_ms", "p95_ms", "iters"]);
            for bench in &artifact.benches {
                table.row(&[
                    bench.name.clone(),
                    format_sig(bench.summary.p50 * 1e3, 4),
                    format_sig(bench.summary.mean * 1e3, 4),
                    format_sig(bench.summary.p95 * 1e3, 4),
                    bench.summary.n.to_string(),
                ]);
            }
            print!("{}", table.render());
        }
        "cache" => {
            let file = matches.get_str("file");
            let path = Path::new(file);
            if matches.flag("clear") {
                if path.exists() {
                    std::fs::remove_file(path)?;
                    println!("removed {file}");
                } else {
                    println!("{file}: no cache file");
                }
            } else if !path.exists() {
                println!("{file}: no cache file");
            } else {
                let cache = PointCache::load(path)?;
                let bytes = std::fs::metadata(path)?.len();
                println!(
                    "{file}: {} cached design points, {} evaluations, {} bytes",
                    cache.len(),
                    cache.total_evaluations(),
                    bytes
                );
                println!(
                    "  generation {} (completed saves), lifetime {} hits / {} misses ({} hit rate)",
                    cache.generation(),
                    cache.hits(),
                    cache.misses(),
                    hit_rate(cache.hits(), cache.misses())
                );
            }
        }
        "pareto" => {
            let dataset = Dataset::parse_strict(matches.get_str("dataset"))?;
            let figure = if matches.get_str("metric") == "energy" {
                report::fig6(dataset, workers, seed)?
            } else {
                report::fig5(dataset, workers, seed)?
            };
            print!("{}", figure.render());
        }
        "rtl" => {
            let config = AcceleratorConfig {
                pe: parse_pe(matches.get_str("pe"))?,
                rows: matches.get_usize("rows"),
                cols: matches.get_usize("cols"),
                ..Default::default()
            };
            config.validate()?;
            let bundle = rtl::generate(&config);
            let out = matches.get_str("out").to_string();
            let paths = rtl::write_bundle(&bundle, Path::new(&out))?;
            for path in paths {
                println!("wrote {}", path.display());
            }
        }
        "sim" => {
            let pe = parse_pe(matches.get_str("pe"))?;
            let config = AcceleratorConfig { pe, ..Default::default() };
            let layer = qadam::dnn::Layer::conv(
                "cli",
                matches.get_usize("hw"),
                matches.get_usize("in-c"),
                matches.get_usize("out-c"),
                3,
                1,
                1,
            );
            let mut rng = Pcg64::new(seed);
            let ifmap: Vec<f64> =
                (0..layer.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let weights: Vec<f64> =
                (0..layer.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let result = sim::simulate_layer(&layer, &config, &ifmap, &weights);
            println!(
                "cycles={} utilization={} verified={} max_quant_err={}",
                result.cycles,
                format_sig(result.utilization, 3),
                result.verified,
                format_sig(result.max_abs_error, 3)
            );
        }
        "train" => {
            let pe = parse_pe(matches.get_str("pe"))?;
            let steps = matches.get_usize("steps");
            let dir = matches.get_str("artifacts").to_string();
            let mut runtime = Runtime::new(Path::new(&dir))?;
            let outcome = QatDriver::train(&mut runtime, pe, steps, (steps / 10).max(1))?;
            for record in &outcome.loss_curve {
                println!("step {:>5}  loss {:.4}", record.step, record.loss);
            }
            println!(
                "{}: final accuracy {:.3} eval-loss {:.4} after {} steps",
                pe.name(),
                outcome.final_accuracy,
                outcome.final_eval_loss,
                outcome.steps
            );
        }
        "report" => {
            let load_path = matches.get_str("load");
            // `--spec campaign.qsl` supplies user-declared accuracies
            // (custom / scaled models) to the Fig. 5/6 accuracy fronts.
            // Other figures don't consume accuracy, so the flag would be
            // silently ignored there — reject it instead.
            if matches.was_set("spec") && !matches!(matches.get_str("fig"), "5" | "6") {
                return Err(Error::InvalidConfig(format!(
                    "--spec supplies accuracy declarations to figs 5/6 only; fig '{}' does \
                     not use it",
                    matches.get_str("fig")
                )));
            }
            let book = match matches.get_str("spec") {
                "" => qadam::accuracy::AccuracyBook::new(),
                spec_file => {
                    let source = std::fs::read_to_string(spec_file)?;
                    spec::compile(&source, spec_file)?.accuracy_book()
                }
            };
            let figure = if load_path.is_empty() {
                let dataset = Dataset::parse_strict(matches.get_str("dataset"))?;
                match matches.get_str("fig") {
                    "2" => report::fig2(workers, seed)?,
                    "3" => report::fig3(seed)?,
                    "4" => report::fig4(dataset, workers, seed)?,
                    "5" => report::fig5_with(dataset, workers, seed, &book)?,
                    "6" => report::fig6_with(dataset, workers, seed, &book)?,
                    other => {
                        return Err(Error::ParseError(format!("unknown figure '{other}'")));
                    }
                }
            } else {
                // Figures 4-6 consume only the persisted evaluations, so a
                // saved database reproduces the live-run figure exactly.
                let db = EvalDatabase::load_any(Path::new(load_path))?;
                match matches.get_str("fig") {
                    "4" => report::fig4_from_db(&db)?,
                    "5" => report::fig5_from_db_with(&db, &book)?,
                    "6" => report::fig6_from_db_with(&db, &book)?,
                    other => {
                        return Err(Error::InvalidConfig(format!(
                            "--load renders figs 4-6 from a saved database; fig '{other}' \
                             requires a live run"
                        )));
                    }
                }
            };
            print!("{}", figure.render());
        }
        _ => {
            println!("{}", cli().help());
        }
    }
    Ok(())
}
