//! Bench harness (offline `criterion` substitute) with comparable
//! artifacts.
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, timed iterations, and a statistics summary (mean/p50/p95),
//! printed in a criterion-like format plus CSV for EXPERIMENTS.md. Every
//! result is additionally recorded in-process; a target that calls
//! [`finish`] emits a [`artifact::BenchArtifact`] (`qadam.bench` canonical
//! JSON) when `QADAM_BENCH_OUT` names a directory — see `DESIGN.md`
//! "Bench artifacts & the perf-regression gate".
//!
//! Env protocol (all optional):
//! - `QADAM_BENCH_OUT=dir` — emit one `<dir>/<target>.json` artifact per
//!   bench target.
//! - `QADAM_BENCH_SMOKE=1` — override every config to 0 warmup / 1
//!   measured iteration (the CI smoke mode: exercises the full bench +
//!   artifact path in seconds; the numbers are not comparable).
//! - `QADAM_BENCH_HOST=label` — host label embedded in the artifact.

pub mod artifact;

pub use artifact::{BenchArtifact, BenchDiff, BenchRecord, DiffEntry, HostMeta};

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::stats::Summary;

/// Env var: artifact output directory for [`finish`].
pub const ENV_OUT: &str = "QADAM_BENCH_OUT";
/// Env var: force the 1-iteration smoke config.
pub const ENV_SMOKE: &str = "QADAM_BENCH_SMOKE";
/// Env var: host label recorded in emitted artifacts.
pub const ENV_HOST: &str = "QADAM_BENCH_HOST";

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BenchConfig {
    /// Untimed warmup iterations before measurement.
    pub warmup_iters: usize,
    /// Timed iterations aggregated into the summary. `0` is normalized to
    /// `1` by [`Self::normalized`] (a summary over zero samples would be
    /// meaningless).
    pub measure_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 2, measure_iters: 10 }
    }
}

impl BenchConfig {
    /// Fast config for expensive end-to-end benches.
    pub fn heavy() -> Self {
        Self { warmup_iters: 1, measure_iters: 3 }
    }

    /// CI smoke config: no warmup, a single measured iteration. Exercises
    /// the bench + artifact machinery; the numbers are not comparable.
    pub fn smoke() -> Self {
        Self { warmup_iters: 0, measure_iters: 1 }
    }

    /// The config actually run: `measure_iters` is clamped up to 1 so the
    /// timing summary is always over at least one sample. Applied once,
    /// up front, by [`bench_with`] — the result records the normalized
    /// values, not the requested ones.
    pub fn normalized(self) -> Self {
        Self { warmup_iters: self.warmup_iters, measure_iters: self.measure_iters.max(1) }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// The normalized config the measurements ran under.
    pub config: BenchConfig,
    /// Timing statistics over the measured iterations (seconds).
    pub summary: Summary,
}

impl BenchResult {
    /// criterion-style one-liner; the bracket labels the order statistics
    /// it prints (min / p50 / max).
    pub fn render(&self) -> String {
        format!(
            "{:<40} time: [min {} ms  p50 {} ms  max {} ms]  (mean ± σ: {} ± {} ms, n={})",
            self.name,
            fmt_ms(self.summary.min),
            fmt_ms(self.summary.p50),
            fmt_ms(self.summary.max),
            fmt_ms(self.summary.mean),
            fmt_ms(self.summary.stddev),
            self.summary.n,
        )
    }

    /// CSV row: name, mean_ms, p50_ms, p95_ms, n. The name field is
    /// escaped per RFC 4180 (quoted when it contains a comma, quote, or
    /// line break; embedded quotes doubled).
    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{:.3},{:.3},{:.3},{}",
            csv_field(&self.name),
            self.summary.mean * 1e3,
            self.summary.p50 * 1e3,
            self.summary.p95 * 1e3,
            self.summary.n
        )
    }

    /// The artifact record for this result.
    pub fn to_record(&self) -> BenchRecord {
        BenchRecord {
            name: self.name.clone(),
            warmup_iters: self.config.warmup_iters,
            measure_iters: self.config.measure_iters,
            summary: self.summary.clone(),
        }
    }
}

/// Quote/escape one CSV field per RFC 4180.
fn csv_field(text: &str) -> String {
    if text.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", text.replace('"', "\"\""))
    } else {
        text.to_string()
    }
}

fn fmt_ms(seconds: f64) -> String {
    format!("{:.3}", seconds * 1e3)
}

/// The in-process record sink drained by [`finish`] / [`take_records`].
fn recorder() -> &'static Mutex<Vec<BenchRecord>> {
    static RECORDER: OnceLock<Mutex<Vec<BenchRecord>>> = OnceLock::new();
    RECORDER.get_or_init(|| Mutex::new(Vec::new()))
}

fn with_recorder<R>(f: impl FnOnce(&mut Vec<BenchRecord>) -> R) -> R {
    // Recover from poisoning: a panicking bench iteration must not also
    // take down every later bench's recording (the Vec stays valid).
    let mut guard = match recorder().lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    };
    f(&mut guard)
}

/// Whether `QADAM_BENCH_SMOKE=1` is set (read per call — cheap, and keeps
/// the harness usable from tests that manipulate the environment).
pub fn smoke_enabled() -> bool {
    std::env::var(ENV_SMOKE).map(|v| v == "1").unwrap_or(false)
}

/// Time `f` under `config`, returning the timing summary (seconds).
///
/// The config is [`BenchConfig::normalized`] first (and replaced by
/// [`BenchConfig::smoke`] when `QADAM_BENCH_SMOKE=1`); the result is also
/// recorded in-process for [`finish`].
pub fn bench_with<R>(name: &str, config: BenchConfig, mut f: impl FnMut() -> R) -> BenchResult {
    let config = if smoke_enabled() { BenchConfig::smoke() } else { config }.normalized();
    for _ in 0..config.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(config.measure_iters);
    for _ in 0..config.measure_iters {
        let start = Instant::now();
        std::hint::black_box(f());
        samples.push(start.elapsed().as_secs_f64());
    }
    let result =
        BenchResult { name: name.to_string(), config, summary: Summary::of(&samples) };
    with_recorder(|records| records.push(result.to_record()));
    println!("{}", result.render());
    result
}

/// [`bench_with`] under the default config.
pub fn bench<R>(name: &str, f: impl FnMut() -> R) -> BenchResult {
    bench_with(name, BenchConfig::default(), f)
}

/// Print a bench-section header (groups output in `cargo bench` logs).
pub fn section(title: &str) {
    println!("\n──── {title} ────");
}

/// Drain every record collected since the last drain.
pub fn take_records() -> Vec<BenchRecord> {
    with_recorder(std::mem::take)
}

/// End-of-target hook: drain the recorded results and, when
/// `QADAM_BENCH_OUT` names a directory, write `<dir>/<target>.json` as a
/// canonical `qadam.bench` artifact. Host metadata is passed in by the
/// caller (conventionally [`HostMeta::from_env`]). Failures are reported
/// on stderr, never panicked — a bench run should survive a read-only
/// filesystem.
pub fn finish(target: &str, host: &HostMeta) {
    let records = take_records();
    let Some(dir) = std::env::var_os(ENV_OUT) else {
        return;
    };
    let dir = std::path::PathBuf::from(dir);
    if let Err(err) = std::fs::create_dir_all(&dir) {
        eprintln!("bench: cannot create {}: {err}", dir.display());
        return;
    }
    let path = dir.join(format!("{target}.json"));
    let artifact = BenchArtifact::new(host.clone(), records);
    match artifact.save(&path) {
        Ok(()) => println!("bench: artifact written to {}", path.display()),
        Err(err) => eprintln!("bench: failed to write {}: {err}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let result = bench_with(
            "noop",
            BenchConfig { warmup_iters: 1, measure_iters: 5 },
            || 1 + 1,
        );
        assert_eq!(result.summary.n, 5);
        assert!(result.summary.mean >= 0.0);
    }

    #[test]
    fn render_contains_name_and_units() {
        let result = bench_with(
            "render_test",
            BenchConfig { warmup_iters: 0, measure_iters: 2 },
            || (),
        );
        let line = result.render();
        assert!(line.contains("render_test"));
        assert!(line.contains("ms"));
        let csv = result.to_csv_row();
        assert_eq!(csv.split(',').count(), 5);
    }

    #[test]
    fn render_labels_its_order_statistics() {
        let result = bench_with(
            "label_test",
            BenchConfig { warmup_iters: 0, measure_iters: 2 },
            || (),
        );
        let line = result.render();
        for label in ["min", "p50", "max", "mean"] {
            assert!(line.contains(label), "missing '{label}' in: {line}");
        }
    }

    #[test]
    fn zero_measure_iters_normalizes_to_one() {
        assert_eq!(
            BenchConfig { warmup_iters: 0, measure_iters: 0 }.normalized().measure_iters,
            1
        );
        let result = bench_with(
            "zero_iters",
            BenchConfig { warmup_iters: 0, measure_iters: 0 },
            || (),
        );
        assert_eq!(result.summary.n, 1);
        assert_eq!(result.config.measure_iters, 1);
    }

    #[test]
    fn csv_escapes_per_rfc4180() {
        let mk = |name: &str| BenchResult {
            name: name.to_string(),
            config: BenchConfig::default(),
            summary: Summary::of(&[0.001]),
        };
        // A comma'd name stays one field (quoted), so the row still has
        // exactly 5 logical columns.
        let row = mk("joint, 4x4").to_csv_row();
        assert!(row.starts_with("\"joint, 4x4\","), "{row}");
        let row = mk("say \"hi\"").to_csv_row();
        assert!(row.starts_with("\"say \"\"hi\"\"\""), "{row}");
        // Plain names stay unquoted.
        assert!(mk("plain").to_csv_row().starts_with("plain,"));
    }

    #[test]
    fn results_are_recorded_for_artifacts() {
        let unique = "recorded_for_artifact_test";
        let result = bench_with(
            unique,
            BenchConfig { warmup_iters: 0, measure_iters: 2 },
            || (),
        );
        // Other lib tests share the process-wide recorder; look for our
        // record rather than asserting on the whole drain.
        let records = take_records();
        let mine = records.iter().find(|r| r.name == unique).expect("record present");
        assert_eq!(mine.measure_iters, 2);
        assert_eq!(&result.to_record(), mine);
    }

    #[test]
    fn timing_orders_workloads() {
        let cheap = bench_with(
            "cheap",
            BenchConfig { warmup_iters: 1, measure_iters: 3 },
            || (0..100u64).sum::<u64>(),
        );
        let costly = bench_with(
            "costly",
            BenchConfig { warmup_iters: 1, measure_iters: 3 },
            // fold with a multiply so LLVM cannot closed-form the loop
            || (0..2_000_000u64).fold(0u64, |acc, x| acc ^ x.wrapping_mul(0x9E3779B1)),
        );
        assert!(costly.summary.p50 >= cheap.summary.p50);
    }
}
