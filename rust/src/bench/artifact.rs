//! Comparable bench artifacts (`qadam.bench` canonical JSON, schema 1).
//!
//! Every `cargo bench` target records its [`super::BenchResult`]s and, when
//! `QADAM_BENCH_OUT` is set, emits one artifact file per target. Artifacts
//! are canonical JSON (sorted keys, shortest round-trip floats, compact),
//! so two runs of the same code on the same host produce byte-comparable
//! files and `qadam bench diff` can flag p50 regressions across commits.
//! The repo-root `BENCH_PR*.json` trajectory is built by merging the
//! per-target artifacts with `qadam bench merge`.
//!
//! Host metadata is *passed in* by the bench target (label via the
//! `QADAM_BENCH_HOST` env var, OS/arch from compile-time constants) —
//! never sampled from ambient wall-clock/entropy calls, so re-rendering an
//! artifact is deterministic.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{num, obj, s, Json};
use crate::util::stats::Summary;

/// Artifact `kind` tag (the canonical-JSON envelope convention shared
/// with `qadam.sweep` / `qadam.cache` / `qadam.checkpoint`).
pub const KIND: &str = "qadam.bench";
/// Artifact schema version.
pub const SCHEMA: i64 = 1;

/// Host metadata embedded in every artifact so diffs across machines are
/// recognizable as apples-to-oranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostMeta {
    /// Free-form host label (CI runner name, workstation tag, or
    /// `"unspecified"`). Conventionally supplied via `QADAM_BENCH_HOST`.
    pub label: String,
    /// Operating system (`std::env::consts::OS` — a compile-time constant).
    pub os: String,
    /// CPU architecture (`std::env::consts::ARCH`).
    pub arch: String,
}

impl HostMeta {
    /// Host metadata from compile-time constants plus an explicit label.
    pub fn with_label(label: &str) -> Self {
        Self {
            label: label.to_string(),
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
        }
    }

    /// Host metadata labeled from the `QADAM_BENCH_HOST` env var
    /// (`"unspecified"` when unset). The only ambient input is the env
    /// var — no clocks, no entropy.
    pub fn from_env() -> Self {
        let label = std::env::var(super::ENV_HOST).unwrap_or_else(|_| "unspecified".to_string());
        Self::with_label(&label)
    }

    /// JSON form.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("arch", s(&self.arch)),
            ("label", s(&self.label)),
            ("os", s(&self.os)),
        ])
    }

    /// Parse from [`Self::to_json`] output.
    pub fn from_json(json: &Json) -> Result<Self> {
        Ok(Self {
            label: get_str(json, "label")?,
            os: get_str(json, "os")?,
            arch: get_str(json, "arch")?,
        })
    }
}

/// One benchmark's record: name, the (normalized) config it ran under,
/// and the timing summary in seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Benchmark label (unique within a target).
    pub name: String,
    /// Untimed warmup iterations.
    pub warmup_iters: usize,
    /// Timed iterations aggregated into the summary.
    pub measure_iters: usize,
    /// Timing statistics over the measured iterations (seconds).
    pub summary: Summary,
}

impl BenchRecord {
    /// JSON form (envelope-free; embedded in a [`BenchArtifact`]).
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "config",
                obj(vec![
                    ("measure_iters", num(self.measure_iters as f64)),
                    ("warmup_iters", num(self.warmup_iters as f64)),
                ]),
            ),
            ("name", s(&self.name)),
            (
                "seconds",
                obj(vec![
                    ("max", num(self.summary.max)),
                    ("mean", num(self.summary.mean)),
                    ("min", num(self.summary.min)),
                    ("n", num(self.summary.n as f64)),
                    ("p50", num(self.summary.p50)),
                    ("p95", num(self.summary.p95)),
                    ("stddev", num(self.summary.stddev)),
                ]),
            ),
        ])
    }

    /// Parse from [`Self::to_json`] output.
    pub fn from_json(json: &Json) -> Result<Self> {
        let config = json
            .get("config")
            .ok_or_else(|| Error::ParseError("bench record missing 'config'".into()))?;
        let seconds = json
            .get("seconds")
            .ok_or_else(|| Error::ParseError("bench record missing 'seconds'".into()))?;
        Ok(Self {
            name: get_str(json, "name")?,
            warmup_iters: get_usize(config, "warmup_iters")?,
            measure_iters: get_usize(config, "measure_iters")?,
            summary: Summary {
                n: get_usize(seconds, "n")?,
                mean: get_num(seconds, "mean")?,
                stddev: get_num(seconds, "stddev")?,
                min: get_num(seconds, "min")?,
                p50: get_num(seconds, "p50")?,
                p95: get_num(seconds, "p95")?,
                max: get_num(seconds, "max")?,
            },
        })
    }
}

/// A comparable bench artifact: envelope + host + sorted bench records.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchArtifact {
    /// Where the numbers were measured.
    pub host: HostMeta,
    /// Bench records, kept sorted by name (the canonical order).
    pub benches: Vec<BenchRecord>,
}

impl BenchArtifact {
    /// Build an artifact; records are sorted by name and deduplicated
    /// (later records win), making the result canonical regardless of
    /// recording order.
    pub fn new(host: HostMeta, records: Vec<BenchRecord>) -> Self {
        let mut by_name: BTreeMap<String, BenchRecord> = BTreeMap::new();
        for record in records {
            by_name.insert(record.name.clone(), record);
        }
        Self { host, benches: by_name.into_values().collect() }
    }

    /// Merge several artifacts (e.g. one per `cargo bench` target) into a
    /// single trajectory artifact. On name collisions the record from the
    /// later artifact wins; the host is taken from the first.
    pub fn merge(artifacts: Vec<BenchArtifact>) -> Result<Self> {
        let mut iter = artifacts.into_iter();
        let first = iter
            .next()
            .ok_or_else(|| Error::InvalidConfig("merge needs at least one artifact".into()))?;
        let mut records = first.benches;
        for artifact in iter {
            records.extend(artifact.benches);
        }
        Ok(Self::new(first.host, records))
    }

    /// Look up a record by name.
    pub fn get(&self, name: &str) -> Option<&BenchRecord> {
        self.benches.iter().find(|b| b.name == name)
    }

    /// Canonical JSON form (`kind`/`schema` envelope first in key order).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("benches", Json::Arr(self.benches.iter().map(BenchRecord::to_json).collect())),
            ("host", self.host.to_json()),
            ("kind", s(KIND)),
            ("schema", num(SCHEMA as f64)),
        ])
    }

    /// Parse and envelope-check a `qadam.bench` document.
    pub fn from_json(json: &Json) -> Result<Self> {
        let kind = json.get("kind").and_then(Json::as_str).unwrap_or("");
        if kind != KIND {
            return Err(Error::ParseError(format!(
                "expected artifact kind '{KIND}', found '{kind}'"
            )));
        }
        let schema = json.get("schema").and_then(Json::as_i64).unwrap_or(-1);
        if schema != SCHEMA {
            return Err(Error::ParseError(format!(
                "unsupported {KIND} schema {schema} (this build reads schema {SCHEMA})"
            )));
        }
        let host = HostMeta::from_json(
            json.get("host")
                .ok_or_else(|| Error::ParseError("bench artifact missing 'host'".into()))?,
        )?;
        let benches = json
            .get("benches")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::ParseError("bench artifact missing 'benches'".into()))?
            .iter()
            .map(BenchRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self::new(host, benches))
    }

    /// Canonical text form: one line of canonical JSON plus a trailing
    /// newline. Structurally equal artifacts render to identical bytes.
    pub fn to_canonical_text(&self) -> String {
        let mut text = self.to_json().to_string_canonical();
        text.push('\n');
        text
    }

    /// Write atomically (temp file + rename) in canonical form.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::explore::persist::write_atomic(path, &self.to_canonical_text())
    }

    /// Load and envelope-check an artifact file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Compare `self` (old baseline) against `new`, flagging benches whose
    /// p50 moved by more than `threshold_pct` percent in *either*
    /// direction: growth is a regression, shrinkage an improvement (so
    /// wins land in the trajectory instead of silently passing).
    pub fn diff(&self, new: &BenchArtifact, threshold_pct: f64) -> BenchDiff {
        let mut entries = Vec::new();
        let mut added = Vec::new();
        for record in &new.benches {
            match self.get(&record.name) {
                None => added.push(record.name.clone()),
                Some(old) => {
                    let delta_pct = if old.summary.p50 > 0.0 {
                        100.0 * (record.summary.p50 - old.summary.p50) / old.summary.p50
                    } else {
                        0.0
                    };
                    entries.push(DiffEntry {
                        name: record.name.clone(),
                        old_p50: old.summary.p50,
                        new_p50: record.summary.p50,
                        delta_pct,
                        regression: delta_pct > threshold_pct,
                        improvement: delta_pct < -threshold_pct,
                    });
                }
            }
        }
        let removed = self
            .benches
            .iter()
            .filter(|b| new.get(&b.name).is_none())
            .map(|b| b.name.clone())
            .collect();
        BenchDiff { threshold_pct, entries, added, removed }
    }
}

/// One compared bench in a [`BenchDiff`].
#[derive(Debug, Clone, PartialEq)]
pub struct DiffEntry {
    /// Benchmark label.
    pub name: String,
    /// Baseline median (seconds).
    pub old_p50: f64,
    /// Candidate median (seconds).
    pub new_p50: f64,
    /// Relative p50 change in percent (positive = slower).
    pub delta_pct: f64,
    /// Whether the p50 grew beyond the diff threshold.
    pub regression: bool,
    /// Whether the p50 shrank beyond the diff threshold (a speedup worth
    /// recording in the trajectory).
    pub improvement: bool,
}

/// Result of diffing two bench artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchDiff {
    /// Regression threshold in percent applied to p50 growth.
    pub threshold_pct: f64,
    /// Benches present in both artifacts.
    pub entries: Vec<DiffEntry>,
    /// Benches only in the new artifact.
    pub added: Vec<String>,
    /// Benches only in the old artifact.
    pub removed: Vec<String>,
}

impl BenchDiff {
    /// Whether any compared bench regressed beyond the threshold.
    pub fn has_regressions(&self) -> bool {
        self.entries.iter().any(|e| e.regression)
    }

    /// Names of the regressed benches.
    pub fn regressions(&self) -> Vec<&str> {
        self.entries.iter().filter(|e| e.regression).map(|e| e.name.as_str()).collect()
    }

    /// Whether any compared bench sped up beyond the threshold.
    pub fn has_improvements(&self) -> bool {
        self.entries.iter().any(|e| e.improvement)
    }

    /// Names of the improved (sped-up) benches.
    pub fn improvements(&self) -> Vec<&str> {
        self.entries.iter().filter(|e| e.improvement).map(|e| e.name.as_str()).collect()
    }

    /// Human-readable report (one line per compared bench).
    pub fn render(&self) -> String {
        let mut out = format!(
            "bench diff: {} compared, threshold ±{:.1}% p50\n",
            self.entries.len(),
            self.threshold_pct
        );
        for e in &self.entries {
            let flag = if e.regression {
                "  REGRESSION"
            } else if e.improvement {
                "  IMPROVEMENT"
            } else {
                ""
            };
            out.push_str(&format!(
                "  {:<44} p50 {:>10.3} ms -> {:>10.3} ms  ({:+.1}%){}\n",
                e.name,
                e.old_p50 * 1e3,
                e.new_p50 * 1e3,
                e.delta_pct,
                flag,
            ));
        }
        if !self.added.is_empty() {
            out.push_str(&format!("  added: {}\n", self.added.join(", ")));
        }
        if !self.removed.is_empty() {
            out.push_str(&format!("  removed: {}\n", self.removed.join(", ")));
        }
        if self.has_regressions() {
            out.push_str(&format!(
                "  {} regression(s) beyond +{:.1}%\n",
                self.regressions().len(),
                self.threshold_pct
            ));
        } else {
            out.push_str("  no regressions beyond threshold\n");
        }
        if self.has_improvements() {
            out.push_str(&format!(
                "  {} improvement(s) beyond -{:.1}%\n",
                self.improvements().len(),
                self.threshold_pct
            ));
        }
        out
    }
}

fn get_str(json: &Json, key: &str) -> Result<String> {
    json.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| Error::ParseError(format!("missing string field '{key}'")))
}

fn get_num(json: &Json, key: &str) -> Result<f64> {
    json.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::ParseError(format!("missing numeric field '{key}'")))
}

fn get_usize(json: &Json, key: &str) -> Result<usize> {
    let v = json
        .get(key)
        .and_then(Json::as_i64)
        .ok_or_else(|| Error::ParseError(format!("missing integer field '{key}'")))?;
    usize::try_from(v)
        .map_err(|_| Error::ParseError(format!("field '{key}' must be non-negative")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record(name: &str, p50: f64) -> BenchRecord {
        BenchRecord {
            name: name.to_string(),
            warmup_iters: 1,
            measure_iters: 5,
            summary: Summary {
                n: 5,
                mean: p50 * 1.1,
                stddev: p50 * 0.05,
                min: p50 * 0.9,
                p50,
                p95: p50 * 1.3,
                max: p50 * 1.4,
            },
        }
    }

    fn sample_artifact() -> BenchArtifact {
        BenchArtifact::new(
            HostMeta::with_label("test-host"),
            vec![sample_record("zeta", 0.002), sample_record("alpha", 0.001)],
        )
    }

    #[test]
    fn artifact_round_trips_through_json() {
        let artifact = sample_artifact();
        let text = artifact.to_canonical_text();
        let parsed = BenchArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, artifact);
    }

    #[test]
    fn records_are_sorted_and_deduplicated() {
        let artifact = sample_artifact();
        assert_eq!(artifact.benches[0].name, "alpha");
        assert_eq!(artifact.benches[1].name, "zeta");
        let re = BenchArtifact::new(
            artifact.host.clone(),
            vec![sample_record("alpha", 0.001), sample_record("alpha", 0.009)],
        );
        assert_eq!(re.benches.len(), 1);
        assert_eq!(re.benches[0].summary.p50, 0.009);
    }

    #[test]
    fn canonical_text_is_deterministic_and_order_independent() {
        let a = BenchArtifact::new(
            HostMeta::with_label("h"),
            vec![sample_record("a", 0.001), sample_record("b", 0.002)],
        );
        let b = BenchArtifact::new(
            HostMeta::with_label("h"),
            vec![sample_record("b", 0.002), sample_record("a", 0.001)],
        );
        assert_eq!(a.to_canonical_text(), b.to_canonical_text());
        assert!(a.to_canonical_text().starts_with('{'));
        assert!(a.to_canonical_text().ends_with("}\n"));
    }

    #[test]
    fn envelope_is_checked() {
        let bad_kind = Json::parse(r#"{"kind":"qadam.sweep","schema":1}"#).unwrap();
        assert!(BenchArtifact::from_json(&bad_kind).is_err());
        let bad_schema =
            Json::parse(r#"{"benches":[],"host":{"arch":"x","label":"l","os":"o"},"kind":"qadam.bench","schema":99}"#)
                .unwrap();
        assert!(BenchArtifact::from_json(&bad_schema).is_err());
    }

    #[test]
    fn merge_combines_targets_first_host_wins() {
        let a = BenchArtifact::new(HostMeta::with_label("first"), vec![sample_record("a", 0.001)]);
        let b = BenchArtifact::new(HostMeta::with_label("second"), vec![sample_record("b", 0.002)]);
        let merged = BenchArtifact::merge(vec![a, b]).unwrap();
        assert_eq!(merged.host.label, "first");
        assert_eq!(merged.benches.len(), 2);
        assert!(BenchArtifact::merge(vec![]).is_err());
    }

    #[test]
    fn diff_flags_p50_regressions_beyond_threshold() {
        let old = sample_artifact();
        let mut slower = old.clone();
        slower.benches[0].summary.p50 *= 1.25; // alpha +25%
        let diff = old.diff(&slower, 10.0);
        assert!(diff.has_regressions());
        assert_eq!(diff.regressions(), vec!["alpha"]);
        assert!(diff.render().contains("REGRESSION"));
        assert!(!diff.has_improvements());
        // Within threshold: clean.
        let diff = old.diff(&old, 10.0);
        assert!(!diff.has_regressions());
        assert!(diff.render().contains("no regressions"));
    }

    #[test]
    fn diff_flags_p50_improvements_beyond_threshold() {
        let old = sample_artifact();
        let mut faster = old.clone();
        faster.benches[0].summary.p50 *= 0.5; // alpha -50%
        let diff = old.diff(&faster, 10.0);
        assert!(diff.has_improvements());
        assert_eq!(diff.improvements(), vec!["alpha"]);
        assert!(diff.render().contains("IMPROVEMENT"));
        // A speedup is not a regression: `--strict` semantics unaffected.
        assert!(!diff.has_regressions());
        assert!(diff.render().contains("no regressions"));
        // Within threshold: neither flag set.
        let diff = old.diff(&old, 10.0);
        assert!(!diff.has_improvements());
        assert!(!diff.render().contains("IMPROVEMENT"));
    }

    #[test]
    fn diff_tracks_added_and_removed() {
        let old = BenchArtifact::new(HostMeta::with_label("h"), vec![sample_record("gone", 0.001)]);
        let new =
            BenchArtifact::new(HostMeta::with_label("h"), vec![sample_record("fresh", 0.001)]);
        let diff = old.diff(&new, 10.0);
        assert_eq!(diff.added, vec!["fresh".to_string()]);
        assert_eq!(diff.removed, vec!["gone".to_string()]);
        assert!(!diff.has_regressions());
    }

    #[test]
    fn save_load_round_trips_on_disk() {
        let dir = std::env::temp_dir().join("qadam_bench_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.json");
        let artifact = sample_artifact();
        artifact.save(&path).unwrap();
        let loaded = BenchArtifact::load(&path).unwrap();
        assert_eq!(loaded, artifact);
        std::fs::remove_file(&path).ok();
    }
}
