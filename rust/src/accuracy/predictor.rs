//! SQNR-based accuracy-drop predictor.
//!
//! The paper's Figs. 5/6 need an accuracy value per (model, PE type); the
//! registry carries the reported numbers, and this module provides the
//! *model-based* alternative: estimate the signal-to-quantization-noise
//! ratio (SQNR) a PE type imposes on a network's weights/activations and
//! map it to an expected top-1 drop. This is the standard analytical
//! bridge (uniform b-bit quantization ⇒ SQNR ≈ 6.02·b − 9 dB for
//! unit-dynamic-range signals; power-of-two grids lose log-domain
//! resolution) and lets the framework extrapolate to PE types with no
//! registry entry — one of the paper's "future research" directions.

use crate::dnn::Model;
use crate::quant::PeType;

/// Effective uniform-equivalent bit budget of a PE type's weight grid.
///
/// * INT16/FP32 — the nominal width.
/// * LightPE-1 — 7 magnitude levels on a log grid ≈ a ~3-bit uniform grid
///   near the top of the range, worse below (we charge 3.0 bits).
/// * LightPE-2 — two-term sums ≈ 28 magnitude levels ≈ ~4.8 effective bits.
pub fn effective_weight_bits(pe: PeType) -> f64 {
    match pe {
        PeType::Fp32 => 23.0, // mantissa
        PeType::Int16 => 15.0,
        PeType::LightPe1 => 3.0,
        PeType::LightPe2 => 4.8,
    }
}

/// Weight-path SQNR in dB for a PE type (6.02·b − 9 rule with the
/// effective bits above; the −9 dB accounts for the ~3σ dynamic range of
/// weight distributions vs full-scale).
pub fn weight_sqnr_db(pe: PeType) -> f64 {
    6.02 * effective_weight_bits(pe) - 9.0
}

/// Activation-path SQNR in dB.
pub fn act_sqnr_db(pe: PeType) -> f64 {
    6.02 * (pe.act_bits().min(23) as f64 - 1.0) - 9.0
}

/// Combined network SQNR: noise powers add per layer and across the two
/// paths; deeper networks average noise across more layers which *damps*
/// the per-layer contribution (the §IV-C observation that the accuracy
/// gap shrinks with depth).
pub fn network_sqnr_db(model: &Model, pe: PeType) -> f64 {
    let layers = model.compute_layers().count().max(1) as f64;
    let weight_noise = 10f64.powf(-weight_sqnr_db(pe) / 10.0);
    let act_noise = 10f64.powf(-act_sqnr_db(pe) / 10.0);
    // Noise powers add across the two paths; over-parameterization buys
    // ~2.5·log10(L) dB of effective tolerance in deeper networks — the
    // mechanism behind §IV-C's shrinking accuracy gap.
    let combined = weight_noise + act_noise;
    -10.0 * combined.log10() + 2.5 * layers.log10()
}

/// Predicted top-1 accuracy drop (percentage points) vs the FP32 baseline.
///
/// Empirical exponential mapping calibrated on the registry's CIFAR
/// points: ≥35 dB effective SQNR ⇒ negligible drop; each ~8.3 dB below
/// that doubles it.
pub fn predicted_drop_pct(model: &Model, pe: PeType) -> f64 {
    if pe == PeType::Fp32 {
        return 0.0;
    }
    let sqnr = network_sqnr_db(model, pe);
    let deficit_db = (35.0 - sqnr).max(0.0);
    0.25 * (2f64.powf(deficit_db / 8.3) - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::registry;
    use crate::dnn::{model_for, Dataset, ModelKind};

    #[test]
    fn sqnr_ordering_tracks_precision() {
        assert!(weight_sqnr_db(PeType::Fp32) > weight_sqnr_db(PeType::Int16));
        assert!(weight_sqnr_db(PeType::Int16) > weight_sqnr_db(PeType::LightPe2));
        assert!(weight_sqnr_db(PeType::LightPe2) > weight_sqnr_db(PeType::LightPe1));
    }

    #[test]
    fn predicted_drop_ordering() {
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let drop = |pe| predicted_drop_pct(&model, pe);
        assert_eq!(drop(PeType::Fp32), 0.0);
        assert!(drop(PeType::Int16) < 0.2, "INT16 drop {}", drop(PeType::Int16));
        assert!(drop(PeType::LightPe2) < drop(PeType::LightPe1));
        assert!(drop(PeType::LightPe1) < 6.0, "drop must stay 'slight' (paper §III-B)");
    }

    #[test]
    fn predictions_track_registry_within_a_point() {
        // The analytical predictor must land within ~1.5 pt of the
        // registry's reported LightPE drops on CIFAR-10.
        for kind in [ModelKind::ResNet20, ModelKind::ResNet56, ModelKind::Vgg16] {
            let model = model_for(kind, Dataset::Cifar10);
            let fp32 = registry(kind, Dataset::Cifar10, PeType::Fp32).unwrap().top1;
            for pe in [PeType::LightPe1, PeType::LightPe2] {
                let reported_drop = fp32 - registry(kind, Dataset::Cifar10, pe).unwrap().top1;
                let predicted = predicted_drop_pct(&model, pe);
                assert!(
                    (predicted - reported_drop).abs() < 1.5,
                    "{kind:?}/{pe}: predicted {predicted:.2} vs reported {reported_drop:.2}"
                );
            }
        }
    }

    #[test]
    fn deeper_models_predicted_more_tolerant() {
        // §IV-C: the gap shrinks with capacity.
        let r20 = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let r56 = model_for(ModelKind::ResNet56, Dataset::Cifar10);
        assert!(
            predicted_drop_pct(&r56, PeType::LightPe1)
                < predicted_drop_pct(&r20, PeType::LightPe1)
        );
    }
}
