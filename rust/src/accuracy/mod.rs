//! Accuracy results for the Pareto analyses (Figs. 5/6).
//!
//! Two sources, combined per DESIGN.md §1:
//!
//! * [`registry`] — the paper's reported mean top-1 accuracies per
//!   (model, dataset, PE type), transcribed from Figs. 5/6 (5-trial means,
//!   200-epoch recipe of §IV-B). These drive the figure reproductions,
//!   since 200-epoch CIFAR training is out of scope for this box.
//! * Measured QAT outcomes from the PJRT runtime
//!   ([`crate::runtime::QatDriver`]) — the end-to-end proof that the
//!   quantized training pipeline works; `examples/qat_end_to_end.rs`
//!   records both side by side in EXPERIMENTS.md.

pub mod predictor;

pub use predictor::{network_sqnr_db, predicted_drop_pct};

use crate::dnn::{Dataset, ModelKind};
use crate::quant::PeType;

/// A (model, dataset, pe) → top-1 accuracy entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyEntry {
    /// Model architecture.
    pub model: ModelKind,
    /// Training/evaluation dataset.
    pub dataset: Dataset,
    /// PE type the model was quantization-aware trained for.
    pub pe: PeType,
    /// Mean top-1 accuracy in percent.
    pub top1: f64,
}

impl AccuracyEntry {
    /// Top-1 error in percent (Fig. 6 y-axis).
    pub fn top1_error(&self) -> f64 {
        100.0 - self.top1
    }
}

/// Paper-reported mean top-1 accuracies (percent), transcribed from
/// Figs. 5/6. FP32/INT16 track the published full-precision baselines
/// (He et al. / Simonyan-Zisserman CIFAR variants); LightPE degradations
/// follow the figures' visible gaps: LightPE-2 ≲ 0.5 pt, LightPE-1 ≲ 1.5 pt,
/// with the gap *shrinking* as model capacity grows (§IV-C's observation).
const REGISTRY: &[(ModelKind, Dataset, [f64; 4])] = &[
    // [FP32, INT16, LightPE-1, LightPE-2]
    (ModelKind::Vgg16, Dataset::Cifar10, [93.6, 93.5, 92.8, 93.2]),
    (ModelKind::ResNet20, Dataset::Cifar10, [91.7, 91.6, 90.3, 91.0]),
    (ModelKind::ResNet56, Dataset::Cifar10, [93.4, 93.3, 92.6, 93.0]),
    (ModelKind::Vgg16, Dataset::Cifar100, [73.1, 73.0, 71.6, 72.3]),
    (ModelKind::ResNet20, Dataset::Cifar100, [66.5, 66.4, 64.2, 65.3]),
    (ModelKind::ResNet56, Dataset::Cifar100, [70.9, 70.8, 69.4, 70.2]),
];

fn pe_index(pe: PeType) -> usize {
    match pe {
        PeType::Fp32 => 0,
        PeType::Int16 => 1,
        PeType::LightPe1 => 2,
        PeType::LightPe2 => 3,
    }
}

/// User-declared accuracies layered over the paper [`registry`] — the
/// lookup the Fig. 5/6-style accuracy fronts consult, so *custom* QSL
/// models and *scaled* model variants can appear on accuracy fronts.
///
/// Resolution order for a model name:
///
/// 1. a declaration for the exact name (e.g. `"tiny@w0.5d2"`),
/// 2. a declaration for the base family
///    ([`base_model_name`](crate::dnn::base_model_name) strips the
///    variant suffix) — a *user's* declared accuracy is assumed to hold
///    for every swept variant of their model unless a per-variant entry
///    overrides it,
/// 3. the paper registry — **unscaled** zoo names only. The paper never
///    measured width/depth-scaled variants, so a scaled zoo model
///    (`"ResNet-20@w0.25d1"`) resolves to `None` rather than silently
///    plotting the full model's published accuracy; declare variant
///    accuracies explicitly (e.g. via a `model slim20 like resnet20 {
///    accuracy { ... } }` block).
///
/// Declarations come from QSL `accuracy { int16 = 91.2, ... }` blocks
/// (see [`ResolvedCampaign::accuracy_book`](crate::spec::ResolvedCampaign::accuracy_book));
/// an empty book is exactly the registry.
#[derive(Debug, Clone, Default)]
pub struct AccuracyBook {
    declared: std::collections::BTreeMap<String, Vec<(PeType, f64)>>,
}

impl AccuracyBook {
    /// An empty book (registry-only lookups).
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare (or override) the top-1 accuracy of `model_name` under
    /// `pe`.
    pub fn declare(&mut self, model_name: &str, pe: PeType, top1: f64) {
        let entries = self.declared.entry(model_name.to_string()).or_default();
        match entries.iter_mut().find(|(p, _)| *p == pe) {
            Some(entry) => entry.1 = top1,
            None => entries.push((pe, top1)),
        }
    }

    /// Number of models with at least one declared entry.
    pub fn declared_models(&self) -> usize {
        self.declared.len()
    }

    /// Resolve the top-1 accuracy (percent) of `model_name` on
    /// `dataset` under `pe` — declared entries first (exact name, then
    /// base family), the paper registry last.
    pub fn lookup(&self, model_name: &str, dataset: Dataset, pe: PeType) -> Option<f64> {
        let find = |name: &str| {
            self.declared
                .get(name)
                .and_then(|entries| entries.iter().find(|(p, _)| *p == pe))
                .map(|&(_, top1)| top1)
        };
        if let Some(top1) = find(model_name) {
            return Some(top1);
        }
        let base = crate::dnn::base_model_name(model_name);
        if let Some(top1) = find(base) {
            return Some(top1);
        }
        // Registry entries describe the *unscaled* paper models only; a
        // variant suffix means the paper number does not apply.
        if base != model_name {
            return None;
        }
        let kind = ModelKind::parse(base)?;
        registry(kind, dataset, pe).map(|entry| entry.top1)
    }
}

/// Look up the paper-reported accuracy for a configuration.
pub fn registry(model: ModelKind, dataset: Dataset, pe: PeType) -> Option<AccuracyEntry> {
    REGISTRY
        .iter()
        .find(|(m, d, _)| *m == model && *d == dataset)
        .map(|(m, d, accs)| AccuracyEntry { model: *m, dataset: *d, pe, top1: accs[pe_index(pe)] })
}

/// All registry entries for a dataset (Fig. 5/6 input).
pub fn registry_for(dataset: Dataset) -> Vec<AccuracyEntry> {
    REGISTRY
        .iter()
        .filter(|(_, d, _)| *d == dataset)
        .flat_map(|(m, d, accs)| {
            PeType::ALL.iter().map(move |&pe| AccuracyEntry {
                model: *m,
                dataset: *d,
                pe,
                top1: accs[pe_index(pe)],
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_cifar_figures() {
        for dataset in [Dataset::Cifar10, Dataset::Cifar100] {
            for model in dataset.paper_models() {
                for pe in PeType::ALL {
                    assert!(
                        registry(model, dataset, pe).is_some(),
                        "missing {model} / {dataset} / {pe}"
                    );
                }
            }
        }
    }

    #[test]
    fn accuracy_ordering_fp32_first() {
        // FP32 ≥ INT16 ≥ LightPE-2 ≥ LightPE-1 (paper's visible ordering).
        for entry in REGISTRY {
            let [fp32, int16, light1, light2] = entry.2;
            assert!(fp32 >= int16);
            assert!(int16 >= light2);
            assert!(light2 >= light1);
        }
    }

    #[test]
    fn gap_shrinks_with_capacity() {
        // §IV-C: "as model complexity increases, the accuracy gap between
        // LightPEs and FP32 ... decreases" — ResNet-56 gap < ResNet-20 gap.
        for dataset in [Dataset::Cifar10, Dataset::Cifar100] {
            let gap = |model: ModelKind| {
                let fp32 = registry(model, dataset, PeType::Fp32).unwrap().top1;
                let light1 = registry(model, dataset, PeType::LightPe1).unwrap().top1;
                fp32 - light1
            };
            assert!(
                gap(ModelKind::ResNet56) < gap(ModelKind::ResNet20),
                "{dataset}: deeper model must close the gap"
            );
        }
    }

    #[test]
    fn top1_error_complementary() {
        let entry = registry(ModelKind::ResNet20, Dataset::Cifar10, PeType::Fp32).unwrap();
        assert!((entry.top1 + entry.top1_error() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn registry_for_dataset_complete() {
        let entries = registry_for(Dataset::Cifar10);
        assert_eq!(entries.len(), 3 * 4);
    }

    #[test]
    fn book_layers_declarations_over_registry() {
        let mut book = AccuracyBook::new();
        // Empty book == registry.
        assert_eq!(
            book.lookup("ResNet-20", Dataset::Cifar10, PeType::Int16),
            Some(91.6)
        );
        assert_eq!(book.lookup("tiny", Dataset::Cifar10, PeType::Int16), None);
        // Declarations cover custom models…
        book.declare("tiny", PeType::Int16, 88.5);
        assert_eq!(book.lookup("tiny", Dataset::Cifar10, PeType::Int16), Some(88.5));
        assert_eq!(book.lookup("tiny", Dataset::Cifar10, PeType::Fp32), None);
        // …and every scaled variant inherits the base declaration…
        assert_eq!(
            book.lookup("tiny@w0.5d2", Dataset::Cifar10, PeType::Int16),
            Some(88.5)
        );
        // …unless a per-variant entry overrides it.
        book.declare("tiny@w0.5d2", PeType::Int16, 85.0);
        assert_eq!(
            book.lookup("tiny@w0.5d2", Dataset::Cifar10, PeType::Int16),
            Some(85.0)
        );
        // Scaled *zoo* variants do NOT inherit the paper number — the
        // registry only describes the unscaled models — but an explicit
        // declaration covers them.
        assert_eq!(
            book.lookup("ResNet-20@w0.5d1", Dataset::Cifar10, PeType::Fp32),
            None
        );
        book.declare("ResNet-20", PeType::Fp32, 89.9);
        assert_eq!(
            book.lookup("ResNet-20@w0.5d1", Dataset::Cifar10, PeType::Fp32),
            Some(89.9)
        );
        // Re-declaring overrides in place.
        book.declare("tiny", PeType::Int16, 89.0);
        assert_eq!(book.lookup("tiny", Dataset::Cifar10, PeType::Int16), Some(89.0));
        assert_eq!(book.declared_models(), 3);
    }
}
