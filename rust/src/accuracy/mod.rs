//! Accuracy results for the Pareto analyses (Figs. 5/6).
//!
//! Two sources, combined per DESIGN.md §1:
//!
//! * [`registry`] — the paper's reported mean top-1 accuracies per
//!   (model, dataset, PE type), transcribed from Figs. 5/6 (5-trial means,
//!   200-epoch recipe of §IV-B). These drive the figure reproductions,
//!   since 200-epoch CIFAR training is out of scope for this box.
//! * Measured QAT outcomes from the PJRT runtime
//!   ([`crate::runtime::QatDriver`]) — the end-to-end proof that the
//!   quantized training pipeline works; `examples/qat_end_to_end.rs`
//!   records both side by side in EXPERIMENTS.md.

pub mod predictor;

pub use predictor::{network_sqnr_db, predicted_drop_pct};

use crate::dnn::{Dataset, ModelKind};
use crate::quant::PeType;

/// A (model, dataset, pe) → top-1 accuracy entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyEntry {
    /// Model architecture.
    pub model: ModelKind,
    /// Training/evaluation dataset.
    pub dataset: Dataset,
    /// PE type the model was quantization-aware trained for.
    pub pe: PeType,
    /// Mean top-1 accuracy in percent.
    pub top1: f64,
}

impl AccuracyEntry {
    /// Top-1 error in percent (Fig. 6 y-axis).
    pub fn top1_error(&self) -> f64 {
        100.0 - self.top1
    }
}

/// Paper-reported mean top-1 accuracies (percent), transcribed from
/// Figs. 5/6. FP32/INT16 track the published full-precision baselines
/// (He et al. / Simonyan-Zisserman CIFAR variants); LightPE degradations
/// follow the figures' visible gaps: LightPE-2 ≲ 0.5 pt, LightPE-1 ≲ 1.5 pt,
/// with the gap *shrinking* as model capacity grows (§IV-C's observation).
const REGISTRY: &[(ModelKind, Dataset, [f64; 4])] = &[
    // [FP32, INT16, LightPE-1, LightPE-2]
    (ModelKind::Vgg16, Dataset::Cifar10, [93.6, 93.5, 92.8, 93.2]),
    (ModelKind::ResNet20, Dataset::Cifar10, [91.7, 91.6, 90.3, 91.0]),
    (ModelKind::ResNet56, Dataset::Cifar10, [93.4, 93.3, 92.6, 93.0]),
    (ModelKind::Vgg16, Dataset::Cifar100, [73.1, 73.0, 71.6, 72.3]),
    (ModelKind::ResNet20, Dataset::Cifar100, [66.5, 66.4, 64.2, 65.3]),
    (ModelKind::ResNet56, Dataset::Cifar100, [70.9, 70.8, 69.4, 70.2]),
];

fn pe_index(pe: PeType) -> usize {
    match pe {
        PeType::Fp32 => 0,
        PeType::Int16 => 1,
        PeType::LightPe1 => 2,
        PeType::LightPe2 => 3,
    }
}

/// Look up the paper-reported accuracy for a configuration.
pub fn registry(model: ModelKind, dataset: Dataset, pe: PeType) -> Option<AccuracyEntry> {
    REGISTRY
        .iter()
        .find(|(m, d, _)| *m == model && *d == dataset)
        .map(|(m, d, accs)| AccuracyEntry { model: *m, dataset: *d, pe, top1: accs[pe_index(pe)] })
}

/// All registry entries for a dataset (Fig. 5/6 input).
pub fn registry_for(dataset: Dataset) -> Vec<AccuracyEntry> {
    REGISTRY
        .iter()
        .filter(|(_, d, _)| *d == dataset)
        .flat_map(|(m, d, accs)| {
            PeType::ALL.iter().map(move |&pe| AccuracyEntry {
                model: *m,
                dataset: *d,
                pe,
                top1: accs[pe_index(pe)],
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_cifar_figures() {
        for dataset in [Dataset::Cifar10, Dataset::Cifar100] {
            for model in dataset.paper_models() {
                for pe in PeType::ALL {
                    assert!(
                        registry(model, dataset, pe).is_some(),
                        "missing {model} / {dataset} / {pe}"
                    );
                }
            }
        }
    }

    #[test]
    fn accuracy_ordering_fp32_first() {
        // FP32 ≥ INT16 ≥ LightPE-2 ≥ LightPE-1 (paper's visible ordering).
        for entry in REGISTRY {
            let [fp32, int16, light1, light2] = entry.2;
            assert!(fp32 >= int16);
            assert!(int16 >= light2);
            assert!(light2 >= light1);
        }
    }

    #[test]
    fn gap_shrinks_with_capacity() {
        // §IV-C: "as model complexity increases, the accuracy gap between
        // LightPEs and FP32 ... decreases" — ResNet-56 gap < ResNet-20 gap.
        for dataset in [Dataset::Cifar10, Dataset::Cifar100] {
            let gap = |model: ModelKind| {
                let fp32 = registry(model, dataset, PeType::Fp32).unwrap().top1;
                let light1 = registry(model, dataset, PeType::LightPe1).unwrap().top1;
                fp32 - light1
            };
            assert!(
                gap(ModelKind::ResNet56) < gap(ModelKind::ResNet20),
                "{dataset}: deeper model must close the gap"
            );
        }
    }

    #[test]
    fn top1_error_complementary() {
        let entry = registry(ModelKind::ResNet20, Dataset::Cifar10, PeType::Fp32).unwrap();
        assert!((entry.top1 + entry.top1_error() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn registry_for_dataset_complete() {
        let entries = registry_for(Dataset::Cifar10);
        assert_eq!(entries.len(), 3 * 4);
    }
}
