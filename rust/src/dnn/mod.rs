//! DNN workload descriptions: layer shapes for the paper's model zoo.
//!
//! The evaluation (§IV) iterates VGG-16, ResNet-20, ResNet-34, ResNet-50
//! and ResNet-56 over CIFAR-10, CIFAR-100 and ImageNet. This module holds
//! the layer-wise configurations ([`Layer`]), the zoo constructors
//! ([`zoo`]), and the QUIDAM-style [`scale_model`] transform that lowers
//! width/depth-multiplier variants of a base model for joint
//! hardware × model co-exploration; the dataflow mapper consumes models
//! layer by layer.

pub mod zoo;

pub use zoo::{
    base_model_name, lower_workload, model_for, models_for, scale_model, variant_model_name,
    Dataset, ModelKind,
};

/// Layer kind; the mapper treats FC as a 1×1 conv over a 1×1 ifmap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution.
    Conv,
    /// Fully-connected (dense) layer.
    FullyConnected,
    /// Pooling moves data but does no MACs; it still costs memory traffic.
    Pool,
}

/// One layer's shape parameters (NCHW, square spatial dims).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// Layer label (unique within its model).
    pub name: String,
    /// Layer kind (conv / FC / pool).
    pub kind: LayerKind,
    /// Input feature map height = width.
    pub in_hw: usize,
    /// Input channels.
    pub in_c: usize,
    /// Output channels (filters).
    pub out_c: usize,
    /// Filter height = width.
    pub kernel: usize,
    /// Spatial stride.
    pub stride: usize,
    /// Zero padding on each border.
    pub padding: usize,
}

impl Layer {
    /// Convolution layer constructor.
    pub fn conv(
        name: &str,
        in_hw: usize,
        in_c: usize,
        out_c: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
    ) -> Self {
        Self { name: name.into(), kind: LayerKind::Conv, in_hw, in_c, out_c, kernel, stride, padding }
    }

    /// Fully-connected layer constructor (`in_features → out_features`).
    pub fn fc(name: &str, in_features: usize, out_features: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::FullyConnected,
            in_hw: 1,
            in_c: in_features,
            out_c: out_features,
            kernel: 1,
            stride: 1,
            padding: 0,
        }
    }

    /// Pooling layer constructor.
    pub fn pool(name: &str, in_hw: usize, in_c: usize, kernel: usize, stride: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Pool,
            in_hw,
            in_c,
            out_c: in_c,
            kernel,
            stride,
            padding: 0,
        }
    }

    /// Output feature-map height (= width).
    pub fn out_hw(&self) -> usize {
        (self.in_hw + 2 * self.padding - self.kernel) / self.stride + 1
    }

    /// Multiply-accumulates for one inference of this layer.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Pool => 0,
            _ => {
                let out = self.out_hw() as u64;
                out * out
                    * self.out_c as u64
                    * self.in_c as u64
                    * (self.kernel * self.kernel) as u64
            }
        }
    }

    /// Number of weights (no bias, matching the paper's MAC counting).
    pub fn weights(&self) -> u64 {
        match self.kind {
            LayerKind::Pool => 0,
            _ => self.out_c as u64 * self.in_c as u64 * (self.kernel * self.kernel) as u64,
        }
    }

    /// Input feature-map element count.
    pub fn ifmap_elems(&self) -> u64 {
        (self.in_hw * self.in_hw * self.in_c) as u64
    }

    /// Output feature-map element count.
    pub fn ofmap_elems(&self) -> u64 {
        let out = self.out_hw() as u64;
        out * out * self.out_c as u64
    }
}

/// A named model: ordered layers.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    /// Display name matching the paper's figures (e.g. `"ResNet-20"`).
    pub name: String,
    /// Dataset this model instance targets (fixes the input shape).
    pub dataset: Dataset,
    /// Ordered layer stack.
    pub layers: Vec<Layer>,
}

impl Model {
    /// Total MACs per inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Total weights.
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(Layer::weights).sum()
    }

    /// Only layers that do MACs (mapper input).
    pub fn compute_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.kind != LayerKind::Pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_shape() {
        // 32×32, 3×3, stride 1, pad 1 → 32×32.
        let layer = Layer::conv("c", 32, 3, 16, 3, 1, 1);
        assert_eq!(layer.out_hw(), 32);
        // 32×32, 3×3, stride 2, pad 1 → 16×16.
        let down = Layer::conv("d", 32, 16, 32, 3, 2, 1);
        assert_eq!(down.out_hw(), 16);
        // 224×224, 7×7, stride 2, pad 3 → 112×112 (ResNet stem).
        let stem = Layer::conv("stem", 224, 3, 64, 7, 2, 3);
        assert_eq!(stem.out_hw(), 112);
    }

    #[test]
    fn conv_macs_formula() {
        let layer = Layer::conv("c", 8, 4, 16, 3, 1, 1);
        // 8*8 output positions × 16 filters × 4 channels × 9 taps
        assert_eq!(layer.macs(), 8 * 8 * 16 * 4 * 9);
    }

    #[test]
    fn fc_as_matvec() {
        let fc = Layer::fc("fc", 512, 10);
        assert_eq!(fc.macs(), 5120);
        assert_eq!(fc.weights(), 5120);
        assert_eq!(fc.out_hw(), 1);
    }

    #[test]
    fn pool_is_mac_free() {
        let pool = Layer::pool("p", 32, 64, 2, 2);
        assert_eq!(pool.macs(), 0);
        assert_eq!(pool.out_hw(), 16);
        assert_eq!(pool.ofmap_elems(), 16 * 16 * 64);
    }

    #[test]
    fn fmap_sizes() {
        let layer = Layer::conv("c", 32, 3, 16, 3, 1, 1);
        assert_eq!(layer.ifmap_elems(), 32 * 32 * 3);
        assert_eq!(layer.ofmap_elems(), 32 * 32 * 16);
    }
}
