//! Model zoo: the paper's five networks at their evaluated resolutions.
//!
//! * CIFAR-10 / CIFAR-100 (32×32): VGG-16 (CIFAR variant), ResNet-20,
//!   ResNet-56 (He et al.'s CIFAR family, §IV-A).
//! * ImageNet (224×224): VGG-16, ResNet-34, ResNet-50.
//!
//! Layer tables follow the original papers; BN/ReLU are folded (no MACs),
//! biases omitted, matching the paper's MAC accounting.

use super::{Layer, Model};

/// Evaluation dataset (fixes input resolution and class count).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// CIFAR-10: 32×32 RGB, 10 classes.
    Cifar10,
    /// CIFAR-100: 32×32 RGB, 100 classes.
    Cifar100,
    /// ImageNet (ILSVRC): 224×224 RGB, 1000 classes.
    ImageNet,
}

impl Dataset {
    /// All datasets in the paper's order.
    pub const ALL: [Dataset; 3] = [Dataset::Cifar10, Dataset::Cifar100, Dataset::ImageNet];

    /// Canonical user-facing keys, in [`Self::ALL`] order — the single
    /// source for CLI "valid names" errors and QSL suggestions.
    pub const KEYS: [&'static str; 3] = ["cifar10", "cifar100", "imagenet"];

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Dataset::Cifar10 => "CIFAR-10",
            Dataset::Cifar100 => "CIFAR-100",
            Dataset::ImageNet => "ImageNet",
        }
    }

    /// Parse a user-facing name.
    pub fn parse(text: &str) -> Option<Dataset> {
        let key: String =
            text.chars().filter(|c| c.is_ascii_alphanumeric()).collect::<String>().to_lowercase();
        match key.as_str() {
            "cifar10" => Some(Dataset::Cifar10),
            "cifar100" => Some(Dataset::Cifar100),
            "imagenet" => Some(Dataset::ImageNet),
            _ => None,
        }
    }

    /// [`Self::parse`] for user-input boundaries (CLI flags, spec
    /// files): failures return
    /// [`Error::InvalidConfig`](crate::Error::InvalidConfig) listing the
    /// valid names and, when the input looks like a typo, the nearest
    /// match — instead of a bare generic message.
    pub fn parse_strict(text: &str) -> crate::error::Result<Dataset> {
        Self::parse(text).ok_or_else(|| {
            let hint = crate::util::text::did_you_mean(text, Self::KEYS)
                .map(|s| format!(" (did you mean '{s}'?)"))
                .unwrap_or_default();
            crate::error::Error::InvalidConfig(format!(
                "unknown dataset '{text}'; valid datasets: {}{hint}",
                crate::util::text::name_list(Self::KEYS)
            ))
        })
    }

    /// Input resolution (height = width).
    pub fn input_hw(self) -> usize {
        match self {
            Dataset::Cifar10 | Dataset::Cifar100 => 32,
            Dataset::ImageNet => 224,
        }
    }

    /// Number of classes.
    pub fn classes(self) -> usize {
        match self {
            Dataset::Cifar10 => 10,
            Dataset::Cifar100 => 100,
            Dataset::ImageNet => 1000,
        }
    }

    /// The models the paper evaluates on this dataset (Fig. 4 panels).
    pub fn paper_models(self) -> Vec<ModelKind> {
        match self {
            Dataset::Cifar10 | Dataset::Cifar100 => {
                vec![ModelKind::Vgg16, ModelKind::ResNet20, ModelKind::ResNet56]
            }
            Dataset::ImageNet => {
                vec![ModelKind::Vgg16, ModelKind::ResNet34, ModelKind::ResNet50]
            }
        }
    }
}

impl std::fmt::Display for Dataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Model family member.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// VGG-16.
    Vgg16,
    /// ResNet-20 (CIFAR-class).
    ResNet20,
    /// ResNet-34 (ImageNet-class).
    ResNet34,
    /// ResNet-50 (ImageNet-class).
    ResNet50,
    /// ResNet-56 (CIFAR-class).
    ResNet56,
}

impl ModelKind {
    /// Canonical user-facing keys (VGG first, ResNets by depth) — the
    /// single source for CLI "valid names" errors and QSL suggestions.
    pub const KEYS: [&'static str; 5] =
        ["vgg16", "resnet20", "resnet34", "resnet50", "resnet56"];

    /// Display name matching the paper.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Vgg16 => "VGG-16",
            ModelKind::ResNet20 => "ResNet-20",
            ModelKind::ResNet34 => "ResNet-34",
            ModelKind::ResNet50 => "ResNet-50",
            ModelKind::ResNet56 => "ResNet-56",
        }
    }

    /// Parse a user-facing name.
    pub fn parse(text: &str) -> Option<ModelKind> {
        let key: String =
            text.chars().filter(|c| c.is_ascii_alphanumeric()).collect::<String>().to_lowercase();
        match key.as_str() {
            "vgg16" => Some(ModelKind::Vgg16),
            "resnet20" => Some(ModelKind::ResNet20),
            "resnet34" => Some(ModelKind::ResNet34),
            "resnet50" => Some(ModelKind::ResNet50),
            "resnet56" => Some(ModelKind::ResNet56),
            _ => None,
        }
    }

    /// [`Self::parse`] for user-input boundaries (CLI flags, spec
    /// files): failures return
    /// [`Error::InvalidConfig`](crate::Error::InvalidConfig) listing the
    /// valid names and, when the input looks like a typo, the nearest
    /// match.
    pub fn parse_strict(text: &str) -> crate::error::Result<ModelKind> {
        Self::parse(text).ok_or_else(|| {
            let hint = crate::util::text::did_you_mean(text, Self::KEYS)
                .map(|s| format!(" (did you mean '{s}'?)"))
                .unwrap_or_default();
            crate::error::Error::InvalidConfig(format!(
                "unknown model '{text}'; valid models: {}{hint}",
                crate::util::text::name_list(Self::KEYS)
            ))
        })
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Build a model for a dataset.
pub fn model_for(kind: ModelKind, dataset: Dataset) -> Model {
    match kind {
        ModelKind::Vgg16 => vgg16(dataset),
        ModelKind::ResNet20 => resnet_cifar(20, dataset),
        ModelKind::ResNet56 => resnet_cifar(56, dataset),
        ModelKind::ResNet34 => resnet34(dataset),
        ModelKind::ResNet50 => resnet50(dataset),
    }
}

/// All (model, dataset) pairs the paper evaluates on a dataset.
pub fn models_for(dataset: Dataset) -> Vec<Model> {
    dataset.paper_models().into_iter().map(|k| model_for(k, dataset)).collect()
}

fn vgg16(dataset: Dataset) -> Model {
    let mut layers = Vec::new();
    let mut hw = dataset.input_hw();
    let mut in_c = 3;
    // (num convs, out channels) per VGG-16 stage.
    let stages = [(2usize, 64usize), (2, 128), (3, 256), (3, 512), (3, 512)];
    for (stage_idx, &(convs, out_c)) in stages.iter().enumerate() {
        for conv_idx in 0..convs {
            layers.push(Layer::conv(
                &format!("conv{}_{}", stage_idx + 1, conv_idx + 1),
                hw,
                in_c,
                out_c,
                3,
                1,
                1,
            ));
            in_c = out_c;
        }
        layers.push(Layer::pool(&format!("pool{}", stage_idx + 1), hw, in_c, 2, 2));
        hw /= 2;
    }
    // Classifier: ImageNet uses the original 4096-wide FCs over 7×7×512;
    // the CIFAR variant (Simonyan-style at 32×32) flattens 1×1×512.
    match dataset {
        Dataset::ImageNet => {
            layers.push(Layer::fc("fc6", hw * hw * in_c, 4096));
            layers.push(Layer::fc("fc7", 4096, 4096));
            layers.push(Layer::fc("fc8", 4096, dataset.classes()));
        }
        _ => {
            layers.push(Layer::fc("fc6", hw * hw * in_c, 512));
            layers.push(Layer::fc("fc7", 512, dataset.classes()));
        }
    }
    Model { name: "VGG-16".into(), dataset, layers }
}

/// He et al.'s CIFAR ResNet family: depth = 6n+2, stages of n basic blocks
/// at widths {16, 32, 64} over {32, 16, 8} spatial dims.
fn resnet_cifar(depth: usize, dataset: Dataset) -> Model {
    assert!(depth % 6 == 2, "CIFAR ResNet depth must be 6n+2");
    assert!(dataset != Dataset::ImageNet, "CIFAR ResNet is a 32×32 model");
    let n = (depth - 2) / 6;
    let mut layers = vec![Layer::conv("conv1", 32, 3, 16, 3, 1, 1)];
    let mut hw = 32;
    let mut in_c = 16;
    for (stage_idx, &width) in [16usize, 32, 64].iter().enumerate() {
        for block in 0..n {
            let stride = if stage_idx > 0 && block == 0 { 2 } else { 1 };
            let prefix = format!("s{}b{}", stage_idx + 1, block + 1);
            layers.push(Layer::conv(&format!("{prefix}_conv1"), hw, in_c, width, 3, stride, 1));
            let out_hw = layers.last().unwrap().out_hw();
            layers.push(Layer::conv(&format!("{prefix}_conv2"), out_hw, width, width, 3, 1, 1));
            if stride == 2 || in_c != width {
                // Projection shortcut (1×1, stride 2).
                layers.push(Layer::conv(&format!("{prefix}_proj"), hw, in_c, width, 1, stride, 0));
            }
            hw = out_hw;
            in_c = width;
        }
    }
    layers.push(Layer::pool("avgpool", hw, in_c, hw, hw));
    layers.push(Layer::fc("fc", in_c, dataset.classes()));
    Model { name: format!("ResNet-{depth}"), dataset, layers }
}

/// ImageNet ResNet-34: basic blocks [3, 4, 6, 3] at {64, 128, 256, 512}.
fn resnet34(dataset: Dataset) -> Model {
    let mut layers = vec![
        Layer::conv("conv1", dataset.input_hw(), 3, 64, 7, 2, 3),
        Layer::pool("maxpool", 112, 64, 3, 2),
    ];
    let mut hw = 56;
    let mut in_c = 64;
    let stages: [(usize, usize); 4] = [(3, 64), (4, 128), (6, 256), (3, 512)];
    for (stage_idx, &(blocks, width)) in stages.iter().enumerate() {
        for block in 0..blocks {
            let stride = if stage_idx > 0 && block == 0 { 2 } else { 1 };
            let prefix = format!("s{}b{}", stage_idx + 1, block + 1);
            layers.push(Layer::conv(&format!("{prefix}_conv1"), hw, in_c, width, 3, stride, 1));
            let out_hw = layers.last().unwrap().out_hw();
            layers.push(Layer::conv(&format!("{prefix}_conv2"), out_hw, width, width, 3, 1, 1));
            if stride == 2 || in_c != width {
                layers.push(Layer::conv(&format!("{prefix}_proj"), hw, in_c, width, 1, stride, 0));
            }
            hw = out_hw;
            in_c = width;
        }
    }
    layers.push(Layer::pool("avgpool", hw, in_c, hw, hw));
    layers.push(Layer::fc("fc", in_c, dataset.classes()));
    Model { name: "ResNet-34".into(), dataset, layers }
}

/// ImageNet ResNet-50: bottleneck blocks [3, 4, 6, 3], expansion 4.
fn resnet50(dataset: Dataset) -> Model {
    let mut layers = vec![
        Layer::conv("conv1", dataset.input_hw(), 3, 64, 7, 2, 3),
        Layer::pool("maxpool", 112, 64, 3, 2),
    ];
    let mut hw = 56;
    let mut in_c = 64;
    let stages: [(usize, usize); 4] = [(3, 64), (4, 128), (6, 256), (3, 512)];
    for (stage_idx, &(blocks, width)) in stages.iter().enumerate() {
        let out_c = width * 4;
        for block in 0..blocks {
            let stride = if stage_idx > 0 && block == 0 { 2 } else { 1 };
            let prefix = format!("s{}b{}", stage_idx + 1, block + 1);
            layers.push(Layer::conv(&format!("{prefix}_conv1"), hw, in_c, width, 1, 1, 0));
            layers.push(Layer::conv(&format!("{prefix}_conv2"), hw, width, width, 3, stride, 1));
            let out_hw = layers.last().unwrap().out_hw();
            layers.push(Layer::conv(&format!("{prefix}_conv3"), out_hw, width, out_c, 1, 1, 0));
            if stride == 2 || in_c != out_c {
                layers.push(Layer::conv(&format!("{prefix}_proj"), hw, in_c, out_c, 1, stride, 0));
            }
            hw = out_hw;
            in_c = out_c;
        }
    }
    layers.push(Layer::pool("avgpool", hw, in_c, hw, hw));
    layers.push(Layer::fc("fc", in_c, dataset.classes()));
    Model { name: "ResNet-50".into(), dataset, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_properties() {
        assert_eq!(Dataset::Cifar10.input_hw(), 32);
        assert_eq!(Dataset::Cifar100.classes(), 100);
        assert_eq!(Dataset::ImageNet.input_hw(), 224);
        assert_eq!(Dataset::parse("CIFAR-10"), Some(Dataset::Cifar10));
    }

    #[test]
    fn strict_parses_list_names_and_suggest() {
        assert_eq!(Dataset::parse_strict("imagenet").unwrap(), Dataset::ImageNet);
        let err = Dataset::parse_strict("cifar11").unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        let text = err.to_string();
        assert!(text.contains("cifar10, cifar100, imagenet"), "{text}");
        assert!(text.contains("did you mean 'cifar10'?"), "{text}");
        let err = Dataset::parse_strict("mnist").unwrap_err();
        assert!(!err.to_string().contains("did you mean"), "{err}");

        assert_eq!(ModelKind::parse_strict("ResNet-20").unwrap(), ModelKind::ResNet20);
        let err = ModelKind::parse_strict("resnet21").unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        let text = err.to_string();
        assert!(text.contains("vgg16, resnet20"), "{text}");
        assert!(text.contains("did you mean 'resnet20'?"), "{text}");
    }

    #[test]
    fn resnet20_has_correct_depth() {
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        // 20 weight layers on the main path: conv1 + 18 block convs + fc.
        let main_path = model
            .layers
            .iter()
            .filter(|l| l.kind != super::super::LayerKind::Pool && !l.name.contains("proj"))
            .count();
        assert_eq!(main_path, 20);
    }

    #[test]
    fn resnet56_has_correct_depth() {
        let model = model_for(ModelKind::ResNet56, Dataset::Cifar10);
        let main_path = model
            .layers
            .iter()
            .filter(|l| l.kind != super::super::LayerKind::Pool && !l.name.contains("proj"))
            .count();
        assert_eq!(main_path, 56);
    }

    #[test]
    fn resnet20_macs_near_published() {
        // ResNet-20/CIFAR-10 ≈ 40.8 M MACs (He et al. report ~0.27 GFLOPs
        // ≈ 41 M MACs incl. shortcuts).
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let macs = model.total_macs() as f64;
        assert!((3.5e7..5.0e7).contains(&macs), "ResNet-20 MACs {macs:.3e}");
    }

    #[test]
    fn vgg16_imagenet_macs_near_published() {
        // VGG-16/ImageNet ≈ 15.5 G MACs.
        let model = model_for(ModelKind::Vgg16, Dataset::ImageNet);
        let macs = model.total_macs() as f64;
        assert!((1.4e10..1.7e10).contains(&macs), "VGG-16 MACs {macs:.3e}");
    }

    #[test]
    fn resnet50_macs_near_published() {
        // ResNet-50/ImageNet ≈ 4.1 G MACs.
        let model = model_for(ModelKind::ResNet50, Dataset::ImageNet);
        let macs = model.total_macs() as f64;
        assert!((3.5e9..4.6e9).contains(&macs), "ResNet-50 MACs {macs:.3e}");
    }

    #[test]
    fn resnet34_macs_near_published() {
        // ResNet-34/ImageNet ≈ 3.6 G MACs.
        let model = model_for(ModelKind::ResNet34, Dataset::ImageNet);
        let macs = model.total_macs() as f64;
        assert!((3.2e9..4.1e9).contains(&macs), "ResNet-34 MACs {macs:.3e}");
    }

    #[test]
    fn vgg16_cifar_weights_dominated_by_conv() {
        let model = model_for(ModelKind::Vgg16, Dataset::Cifar10);
        let total = model.total_weights();
        assert!((1.4e7..1.6e7).contains(&(total as f64)), "VGG-16/CIFAR params {total}");
    }

    #[test]
    fn shapes_chain_correctly() {
        // Every layer's input must match the previous compute layer's output.
        for dataset in Dataset::ALL {
            for model in models_for(dataset) {
                let mut prev_hw: Option<usize> = None;
                for layer in &model.layers {
                    if let Some(_hw) = prev_hw {
                        // Projection layers branch from the block input, so only
                        // check monotonic non-increase of spatial dims.
                        assert!(
                            layer.in_hw <= model.layers[0].in_hw,
                            "{}: layer {} grows spatially",
                            model.name,
                            layer.name
                        );
                    }
                    prev_hw = Some(layer.out_hw());
                }
            }
        }
    }

    #[test]
    fn paper_models_per_dataset() {
        assert_eq!(Dataset::Cifar10.paper_models().len(), 3);
        assert!(Dataset::ImageNet.paper_models().contains(&ModelKind::ResNet50));
        assert!(!Dataset::ImageNet.paper_models().contains(&ModelKind::ResNet20));
    }

    #[test]
    fn fc_classes_match_dataset() {
        for dataset in Dataset::ALL {
            for model in models_for(dataset) {
                let fc = model.layers.last().unwrap();
                assert_eq!(fc.out_c, dataset.classes(), "{} on {}", model.name, dataset);
            }
        }
    }
}
