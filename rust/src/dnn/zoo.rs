//! Model zoo: the paper's five networks at their evaluated resolutions.
//!
//! * CIFAR-10 / CIFAR-100 (32×32): VGG-16 (CIFAR variant), ResNet-20,
//!   ResNet-56 (He et al.'s CIFAR family, §IV-A).
//! * ImageNet (224×224): VGG-16, ResNet-34, ResNet-50.
//!
//! Layer tables follow the original papers; BN/ReLU are folded (no MACs),
//! biases omitted, matching the paper's MAC accounting.

use super::{Layer, Model};

/// Evaluation dataset (fixes input resolution and class count).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// CIFAR-10: 32×32 RGB, 10 classes.
    Cifar10,
    /// CIFAR-100: 32×32 RGB, 100 classes.
    Cifar100,
    /// ImageNet (ILSVRC): 224×224 RGB, 1000 classes.
    ImageNet,
}

impl Dataset {
    /// All datasets in the paper's order.
    pub const ALL: [Dataset; 3] = [Dataset::Cifar10, Dataset::Cifar100, Dataset::ImageNet];

    /// Canonical user-facing keys, in [`Self::ALL`] order — the single
    /// source for CLI "valid names" errors and QSL suggestions.
    pub const KEYS: [&'static str; 3] = ["cifar10", "cifar100", "imagenet"];

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Dataset::Cifar10 => "CIFAR-10",
            Dataset::Cifar100 => "CIFAR-100",
            Dataset::ImageNet => "ImageNet",
        }
    }

    /// Parse a user-facing name.
    pub fn parse(text: &str) -> Option<Dataset> {
        let key: String =
            text.chars().filter(|c| c.is_ascii_alphanumeric()).collect::<String>().to_lowercase();
        match key.as_str() {
            "cifar10" => Some(Dataset::Cifar10),
            "cifar100" => Some(Dataset::Cifar100),
            "imagenet" => Some(Dataset::ImageNet),
            _ => None,
        }
    }

    /// [`Self::parse`] for user-input boundaries (CLI flags, spec
    /// files): failures return
    /// [`Error::InvalidConfig`](crate::Error::InvalidConfig) listing the
    /// valid names and, when the input looks like a typo, the nearest
    /// match — instead of a bare generic message.
    pub fn parse_strict(text: &str) -> crate::error::Result<Dataset> {
        Self::parse(text).ok_or_else(|| {
            let hint = crate::util::text::did_you_mean(text, Self::KEYS)
                .map(|s| format!(" (did you mean '{s}'?)"))
                .unwrap_or_default();
            crate::error::Error::InvalidConfig(format!(
                "unknown dataset '{text}'; valid datasets: {}{hint}",
                crate::util::text::name_list(Self::KEYS)
            ))
        })
    }

    /// Input resolution (height = width).
    pub fn input_hw(self) -> usize {
        match self {
            Dataset::Cifar10 | Dataset::Cifar100 => 32,
            Dataset::ImageNet => 224,
        }
    }

    /// Number of classes.
    pub fn classes(self) -> usize {
        match self {
            Dataset::Cifar10 => 10,
            Dataset::Cifar100 => 100,
            Dataset::ImageNet => 1000,
        }
    }

    /// The models the paper evaluates on this dataset (Fig. 4 panels).
    pub fn paper_models(self) -> Vec<ModelKind> {
        match self {
            Dataset::Cifar10 | Dataset::Cifar100 => {
                vec![ModelKind::Vgg16, ModelKind::ResNet20, ModelKind::ResNet56]
            }
            Dataset::ImageNet => {
                vec![ModelKind::Vgg16, ModelKind::ResNet34, ModelKind::ResNet50]
            }
        }
    }
}

impl std::fmt::Display for Dataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Model family member.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// VGG-16.
    Vgg16,
    /// ResNet-20 (CIFAR-class).
    ResNet20,
    /// ResNet-34 (ImageNet-class).
    ResNet34,
    /// ResNet-50 (ImageNet-class).
    ResNet50,
    /// ResNet-56 (CIFAR-class).
    ResNet56,
}

impl ModelKind {
    /// Canonical user-facing keys (VGG first, ResNets by depth) — the
    /// single source for CLI "valid names" errors and QSL suggestions.
    pub const KEYS: [&'static str; 5] =
        ["vgg16", "resnet20", "resnet34", "resnet50", "resnet56"];

    /// Display name matching the paper.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Vgg16 => "VGG-16",
            ModelKind::ResNet20 => "ResNet-20",
            ModelKind::ResNet34 => "ResNet-34",
            ModelKind::ResNet50 => "ResNet-50",
            ModelKind::ResNet56 => "ResNet-56",
        }
    }

    /// Parse a user-facing name.
    pub fn parse(text: &str) -> Option<ModelKind> {
        let key: String =
            text.chars().filter(|c| c.is_ascii_alphanumeric()).collect::<String>().to_lowercase();
        match key.as_str() {
            "vgg16" => Some(ModelKind::Vgg16),
            "resnet20" => Some(ModelKind::ResNet20),
            "resnet34" => Some(ModelKind::ResNet34),
            "resnet50" => Some(ModelKind::ResNet50),
            "resnet56" => Some(ModelKind::ResNet56),
            _ => None,
        }
    }

    /// [`Self::parse`] for user-input boundaries (CLI flags, spec
    /// files): failures return
    /// [`Error::InvalidConfig`](crate::Error::InvalidConfig) listing the
    /// valid names and, when the input looks like a typo, the nearest
    /// match.
    pub fn parse_strict(text: &str) -> crate::error::Result<ModelKind> {
        Self::parse(text).ok_or_else(|| {
            let hint = crate::util::text::did_you_mean(text, Self::KEYS)
                .map(|s| format!(" (did you mean '{s}'?)"))
                .unwrap_or_default();
            crate::error::Error::InvalidConfig(format!(
                "unknown model '{text}'; valid models: {}{hint}",
                crate::util::text::name_list(Self::KEYS)
            ))
        })
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Render the canonical name of a `(width, depth)`-scaled model
/// variant: the base name itself for the identity variant, otherwise
/// `"{base}@w{width}d{depth}"` (e.g. `"ResNet-20@w0.5d2"`). The `@`
/// separator cannot occur in zoo names or QSL identifiers, so
/// [`base_model_name`] can always recover the base family.
pub fn variant_model_name(base: &str, width: f64, depth: usize) -> String {
    if width == 1.0 && depth == 1 {
        base.to_string()
    } else {
        format!("{base}@w{width}d{depth}")
    }
}

/// Strip a variant suffix produced by [`variant_model_name`], returning
/// the base model family name (identity on unsuffixed names).
pub fn base_model_name(name: &str) -> &str {
    name.split('@').next().unwrap_or(name)
}

/// The QUIDAM-style model scaling transform: lower a `(width, depth)`
/// variant of a base model to a concrete [`Model`].
///
/// * **Width** multiplies every *internal* channel count by `width`
///   (rounded, minimum 1). The first layer's input channels (the image)
///   and the last layer's output channels (the class count) are
///   preserved, so variants stay valid classifiers for their dataset.
///   A fully-connected layer whose input equals its predecessor's
///   flattened output (`fc head { in = 4096 }` after a 16×16×16 pool —
///   the custom-model idiom) tracks the predecessor's *scaled*
///   flattened output exactly, so variants stay internally consistent
///   at every width.
/// * **Depth** appends `depth - 1` same-shape copies after every
///   stride-1, spatial-dim-preserving convolution (`in = out = the
///   conv's output`), named `{layer}__dK` — the layer-list analogue of
///   deepening each residual stage. Strided or shrinking convs, pools,
///   and the classifier are not repeated (their copies would be
///   geometrically inconsistent with their neighbors).
///
/// The identity variant (`width == 1.0 && depth == 1`) returns the base
/// model unchanged — same name, same layers — which is what keeps
/// hardware-only campaigns byte-identical to pre-joint builds. Scaled
/// variants get distinct names *and* distinct layer shapes, so the
/// content-addressed point cache can never alias two variants.
///
/// ```
/// use qadam::dnn::{model_for, scale_model, Dataset, ModelKind};
///
/// let base = model_for(ModelKind::ResNet20, Dataset::Cifar10);
/// let half = scale_model(&base, 0.5, 1);
/// assert_eq!(half.name, "ResNet-20@w0.5d1");
/// assert!(half.total_macs() < base.total_macs());
/// // The classifier still emits 10 classes.
/// assert_eq!(half.layers.last().unwrap().out_c, 10);
/// // The identity variant is the base model, name included.
/// assert_eq!(scale_model(&base, 1.0, 1), base);
/// ```
pub fn scale_model(base: &Model, width: f64, depth: usize) -> Model {
    assert!(width > 0.0 && width.is_finite(), "width multiplier must be positive");
    assert!(depth >= 1, "depth multiplier must be at least 1");
    if width == 1.0 && depth == 1 {
        return base.clone();
    }
    let last = base.layers.len().saturating_sub(1);
    let scale_c = |c: usize| ((c as f64 * width).round() as usize).max(1);
    let mut layers: Vec<Layer> = Vec::with_capacity(base.layers.len() * depth);
    // Flattened feature count (out_hw² × out_c) of the previous layer,
    // in the base model and in the scaled one: an FC whose base input
    // equals its predecessor's flattened output (the `fc head { in =
    // 4096 }` idiom) must track the *scaled* flattened output, not
    // `round(in × width)` — rounding the product and the factor
    // disagree for most widths, which would make the variant
    // geometrically impossible.
    let mut prev_flat: Option<(usize, usize)> = None;
    for (i, layer) in base.layers.iter().enumerate() {
        let mut scaled = layer.clone();
        if width != 1.0 {
            match scaled.kind {
                super::LayerKind::Pool => {
                    // Pools carry channels through; out_c mirrors in_c.
                    if i != 0 {
                        let c = scale_c(scaled.in_c);
                        scaled.in_c = c;
                        scaled.out_c = c;
                    }
                }
                super::LayerKind::FullyConnected => {
                    if i != 0 {
                        scaled.in_c = match prev_flat {
                            Some((base_flat, scaled_flat)) if base_flat == layer.in_c => {
                                scaled_flat
                            }
                            _ => scale_c(scaled.in_c),
                        };
                    }
                    if i != last {
                        scaled.out_c = scale_c(scaled.out_c);
                    }
                }
                super::LayerKind::Conv => {
                    if i != 0 {
                        scaled.in_c = scale_c(scaled.in_c);
                    }
                    if i != last {
                        scaled.out_c = scale_c(scaled.out_c);
                    }
                }
            }
        }
        let base_out = layer.out_hw();
        let scaled_out = scaled.out_hw();
        prev_flat = Some((base_out * base_out * layer.out_c, scaled_out * scaled_out * scaled.out_c));
        let out_hw = scaled.out_hw();
        let (copy_c, kernel, padding) = (scaled.out_c, scaled.kernel, scaled.padding);
        // Only spatial-dim-preserving convs gain copies: a copy of a
        // shrinking conv (e.g. 3x3 pad-0) would claim its predecessor's
        // *input* resolution and make consecutive copies geometrically
        // inconsistent.
        let repeatable = scaled.kind == super::LayerKind::Conv
            && scaled.stride == 1
            && i != last
            && out_hw == scaled.in_hw;
        let base_name = scaled.name.clone();
        layers.push(scaled);
        if repeatable {
            for k in 1..depth {
                layers.push(Layer {
                    name: format!("{base_name}__d{k}"),
                    kind: super::LayerKind::Conv,
                    in_hw: out_hw,
                    in_c: copy_c,
                    out_c: copy_c,
                    kernel,
                    stride: 1,
                    padding,
                });
            }
        }
    }
    Model { name: variant_model_name(&base.name, width, depth), dataset: base.dataset, layers }
}

/// Lower a base workload once per model-axes variant:
/// `result[v][m]` is base model `m` scaled by variant `v` of `axes`
/// (the base model itself for the identity variant). The single
/// lowering used by every joint-space consumer — the explorer's
/// evaluation walk and the halving strategy's proxy scoring — so all
/// of them score and evaluate *definitionally* identical workloads.
pub fn lower_workload(axes: &crate::arch::ModelAxes, models: &[Model]) -> Vec<Vec<Model>> {
    (0..axes.len())
        .filter_map(|v| axes.variant(v)) // v < len, so every index decodes
        .map(|variant| {
            models.iter().map(|m| scale_model(m, variant.width, variant.depth)).collect()
        })
        .collect()
}

/// Build a model for a dataset.
pub fn model_for(kind: ModelKind, dataset: Dataset) -> Model {
    match kind {
        ModelKind::Vgg16 => vgg16(dataset),
        ModelKind::ResNet20 => resnet_cifar(20, dataset),
        ModelKind::ResNet56 => resnet_cifar(56, dataset),
        ModelKind::ResNet34 => resnet34(dataset),
        ModelKind::ResNet50 => resnet50(dataset),
    }
}

/// All (model, dataset) pairs the paper evaluates on a dataset.
pub fn models_for(dataset: Dataset) -> Vec<Model> {
    dataset.paper_models().into_iter().map(|k| model_for(k, dataset)).collect()
}

fn vgg16(dataset: Dataset) -> Model {
    let mut layers = Vec::new();
    let mut hw = dataset.input_hw();
    let mut in_c = 3;
    // (num convs, out channels) per VGG-16 stage.
    let stages = [(2usize, 64usize), (2, 128), (3, 256), (3, 512), (3, 512)];
    for (stage_idx, &(convs, out_c)) in stages.iter().enumerate() {
        for conv_idx in 0..convs {
            layers.push(Layer::conv(
                &format!("conv{}_{}", stage_idx + 1, conv_idx + 1),
                hw,
                in_c,
                out_c,
                3,
                1,
                1,
            ));
            in_c = out_c;
        }
        layers.push(Layer::pool(&format!("pool{}", stage_idx + 1), hw, in_c, 2, 2));
        hw /= 2;
    }
    // Classifier: ImageNet uses the original 4096-wide FCs over 7×7×512;
    // the CIFAR variant (Simonyan-style at 32×32) flattens 1×1×512.
    match dataset {
        Dataset::ImageNet => {
            layers.push(Layer::fc("fc6", hw * hw * in_c, 4096));
            layers.push(Layer::fc("fc7", 4096, 4096));
            layers.push(Layer::fc("fc8", 4096, dataset.classes()));
        }
        _ => {
            layers.push(Layer::fc("fc6", hw * hw * in_c, 512));
            layers.push(Layer::fc("fc7", 512, dataset.classes()));
        }
    }
    Model { name: "VGG-16".into(), dataset, layers }
}

/// He et al.'s CIFAR ResNet family: depth = 6n+2, stages of n basic blocks
/// at widths {16, 32, 64} over {32, 16, 8} spatial dims.
fn resnet_cifar(depth: usize, dataset: Dataset) -> Model {
    assert!(depth % 6 == 2, "CIFAR ResNet depth must be 6n+2");
    assert!(dataset != Dataset::ImageNet, "CIFAR ResNet is a 32×32 model");
    let n = (depth - 2) / 6;
    let mut layers = vec![Layer::conv("conv1", 32, 3, 16, 3, 1, 1)];
    let mut hw = 32;
    let mut in_c = 16;
    for (stage_idx, &width) in [16usize, 32, 64].iter().enumerate() {
        for block in 0..n {
            let stride = if stage_idx > 0 && block == 0 { 2 } else { 1 };
            let prefix = format!("s{}b{}", stage_idx + 1, block + 1);
            let conv1 = Layer::conv(&format!("{prefix}_conv1"), hw, in_c, width, 3, stride, 1);
            let out_hw = conv1.out_hw();
            layers.push(conv1);
            layers.push(Layer::conv(&format!("{prefix}_conv2"), out_hw, width, width, 3, 1, 1));
            if stride == 2 || in_c != width {
                // Projection shortcut (1×1, stride 2).
                layers.push(Layer::conv(&format!("{prefix}_proj"), hw, in_c, width, 1, stride, 0));
            }
            hw = out_hw;
            in_c = width;
        }
    }
    layers.push(Layer::pool("avgpool", hw, in_c, hw, hw));
    layers.push(Layer::fc("fc", in_c, dataset.classes()));
    Model { name: format!("ResNet-{depth}"), dataset, layers }
}

/// ImageNet ResNet-34: basic blocks [3, 4, 6, 3] at {64, 128, 256, 512}.
fn resnet34(dataset: Dataset) -> Model {
    let mut layers = vec![
        Layer::conv("conv1", dataset.input_hw(), 3, 64, 7, 2, 3),
        Layer::pool("maxpool", 112, 64, 3, 2),
    ];
    let mut hw = 56;
    let mut in_c = 64;
    let stages: [(usize, usize); 4] = [(3, 64), (4, 128), (6, 256), (3, 512)];
    for (stage_idx, &(blocks, width)) in stages.iter().enumerate() {
        for block in 0..blocks {
            let stride = if stage_idx > 0 && block == 0 { 2 } else { 1 };
            let prefix = format!("s{}b{}", stage_idx + 1, block + 1);
            let conv1 = Layer::conv(&format!("{prefix}_conv1"), hw, in_c, width, 3, stride, 1);
            let out_hw = conv1.out_hw();
            layers.push(conv1);
            layers.push(Layer::conv(&format!("{prefix}_conv2"), out_hw, width, width, 3, 1, 1));
            if stride == 2 || in_c != width {
                layers.push(Layer::conv(&format!("{prefix}_proj"), hw, in_c, width, 1, stride, 0));
            }
            hw = out_hw;
            in_c = width;
        }
    }
    layers.push(Layer::pool("avgpool", hw, in_c, hw, hw));
    layers.push(Layer::fc("fc", in_c, dataset.classes()));
    Model { name: "ResNet-34".into(), dataset, layers }
}

/// ImageNet ResNet-50: bottleneck blocks [3, 4, 6, 3], expansion 4.
fn resnet50(dataset: Dataset) -> Model {
    let mut layers = vec![
        Layer::conv("conv1", dataset.input_hw(), 3, 64, 7, 2, 3),
        Layer::pool("maxpool", 112, 64, 3, 2),
    ];
    let mut hw = 56;
    let mut in_c = 64;
    let stages: [(usize, usize); 4] = [(3, 64), (4, 128), (6, 256), (3, 512)];
    for (stage_idx, &(blocks, width)) in stages.iter().enumerate() {
        let out_c = width * 4;
        for block in 0..blocks {
            let stride = if stage_idx > 0 && block == 0 { 2 } else { 1 };
            let prefix = format!("s{}b{}", stage_idx + 1, block + 1);
            layers.push(Layer::conv(&format!("{prefix}_conv1"), hw, in_c, width, 1, 1, 0));
            let conv2 = Layer::conv(&format!("{prefix}_conv2"), hw, width, width, 3, stride, 1);
            let out_hw = conv2.out_hw();
            layers.push(conv2);
            layers.push(Layer::conv(&format!("{prefix}_conv3"), out_hw, width, out_c, 1, 1, 0));
            if stride == 2 || in_c != out_c {
                layers.push(Layer::conv(&format!("{prefix}_proj"), hw, in_c, out_c, 1, stride, 0));
            }
            hw = out_hw;
            in_c = out_c;
        }
    }
    layers.push(Layer::pool("avgpool", hw, in_c, hw, hw));
    layers.push(Layer::fc("fc", in_c, dataset.classes()));
    Model { name: "ResNet-50".into(), dataset, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_properties() {
        assert_eq!(Dataset::Cifar10.input_hw(), 32);
        assert_eq!(Dataset::Cifar100.classes(), 100);
        assert_eq!(Dataset::ImageNet.input_hw(), 224);
        assert_eq!(Dataset::parse("CIFAR-10"), Some(Dataset::Cifar10));
    }

    #[test]
    fn strict_parses_list_names_and_suggest() {
        assert_eq!(Dataset::parse_strict("imagenet").unwrap(), Dataset::ImageNet);
        let err = Dataset::parse_strict("cifar11").unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        let text = err.to_string();
        assert!(text.contains("cifar10, cifar100, imagenet"), "{text}");
        assert!(text.contains("did you mean 'cifar10'?"), "{text}");
        let err = Dataset::parse_strict("mnist").unwrap_err();
        assert!(!err.to_string().contains("did you mean"), "{err}");

        assert_eq!(ModelKind::parse_strict("ResNet-20").unwrap(), ModelKind::ResNet20);
        let err = ModelKind::parse_strict("resnet21").unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        let text = err.to_string();
        assert!(text.contains("vgg16, resnet20"), "{text}");
        assert!(text.contains("did you mean 'resnet20'?"), "{text}");
    }

    #[test]
    fn resnet20_has_correct_depth() {
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        // 20 weight layers on the main path: conv1 + 18 block convs + fc.
        let main_path = model
            .layers
            .iter()
            .filter(|l| l.kind != super::super::LayerKind::Pool && !l.name.contains("proj"))
            .count();
        assert_eq!(main_path, 20);
    }

    #[test]
    fn resnet56_has_correct_depth() {
        let model = model_for(ModelKind::ResNet56, Dataset::Cifar10);
        let main_path = model
            .layers
            .iter()
            .filter(|l| l.kind != super::super::LayerKind::Pool && !l.name.contains("proj"))
            .count();
        assert_eq!(main_path, 56);
    }

    #[test]
    fn resnet20_macs_near_published() {
        // ResNet-20/CIFAR-10 ≈ 40.8 M MACs (He et al. report ~0.27 GFLOPs
        // ≈ 41 M MACs incl. shortcuts).
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let macs = model.total_macs() as f64;
        assert!((3.5e7..5.0e7).contains(&macs), "ResNet-20 MACs {macs:.3e}");
    }

    #[test]
    fn vgg16_imagenet_macs_near_published() {
        // VGG-16/ImageNet ≈ 15.5 G MACs.
        let model = model_for(ModelKind::Vgg16, Dataset::ImageNet);
        let macs = model.total_macs() as f64;
        assert!((1.4e10..1.7e10).contains(&macs), "VGG-16 MACs {macs:.3e}");
    }

    #[test]
    fn resnet50_macs_near_published() {
        // ResNet-50/ImageNet ≈ 4.1 G MACs.
        let model = model_for(ModelKind::ResNet50, Dataset::ImageNet);
        let macs = model.total_macs() as f64;
        assert!((3.5e9..4.6e9).contains(&macs), "ResNet-50 MACs {macs:.3e}");
    }

    #[test]
    fn resnet34_macs_near_published() {
        // ResNet-34/ImageNet ≈ 3.6 G MACs.
        let model = model_for(ModelKind::ResNet34, Dataset::ImageNet);
        let macs = model.total_macs() as f64;
        assert!((3.2e9..4.1e9).contains(&macs), "ResNet-34 MACs {macs:.3e}");
    }

    #[test]
    fn vgg16_cifar_weights_dominated_by_conv() {
        let model = model_for(ModelKind::Vgg16, Dataset::Cifar10);
        let total = model.total_weights();
        assert!((1.4e7..1.6e7).contains(&(total as f64)), "VGG-16/CIFAR params {total}");
    }

    #[test]
    fn shapes_chain_correctly() {
        // Every layer's input must match the previous compute layer's output.
        for dataset in Dataset::ALL {
            for model in models_for(dataset) {
                let mut prev_hw: Option<usize> = None;
                for layer in &model.layers {
                    if let Some(_hw) = prev_hw {
                        // Projection layers branch from the block input, so only
                        // check monotonic non-increase of spatial dims.
                        assert!(
                            layer.in_hw <= model.layers[0].in_hw,
                            "{}: layer {} grows spatially",
                            model.name,
                            layer.name
                        );
                    }
                    prev_hw = Some(layer.out_hw());
                }
            }
        }
    }

    #[test]
    fn paper_models_per_dataset() {
        assert_eq!(Dataset::Cifar10.paper_models().len(), 3);
        assert!(Dataset::ImageNet.paper_models().contains(&ModelKind::ResNet50));
        assert!(!Dataset::ImageNet.paper_models().contains(&ModelKind::ResNet20));
    }

    #[test]
    fn width_scaling_preserves_io_and_scales_interior() {
        let base = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let half = scale_model(&base, 0.5, 1);
        assert_eq!(half.layers.len(), base.layers.len());
        // Image channels and class count survive.
        assert_eq!(half.layers[0].in_c, base.layers[0].in_c);
        assert_eq!(half.layers.last().unwrap().out_c, Dataset::Cifar10.classes());
        // The stem narrows: 16 -> 8 output channels.
        assert_eq!(half.layers[0].out_c, 8);
        // MACs shrink roughly quadratically with width.
        let ratio = half.total_macs() as f64 / base.total_macs() as f64;
        assert!((0.15..0.5).contains(&ratio), "half-width MAC ratio {ratio}");
        // Widening never collapses a channel to zero.
        let tiny = scale_model(&base, 0.01, 1);
        assert!(tiny.layers.iter().all(|l| l.in_c >= 1 && l.out_c >= 1));
    }

    #[test]
    fn depth_scaling_repeats_stride1_convs_only() {
        let base = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let deep = scale_model(&base, 1.0, 2);
        assert!(deep.layers.len() > base.layers.len());
        assert!(deep.total_macs() > base.total_macs());
        for copy in deep.layers.iter().filter(|l| l.name.contains("__d")) {
            assert_eq!(copy.kind, super::super::LayerKind::Conv);
            assert_eq!(copy.stride, 1);
            assert_eq!(copy.in_c, copy.out_c, "{}", copy.name);
            // Copies keep spatial dims (same-padded stride-1 convs).
            assert_eq!(copy.out_hw(), copy.in_hw, "{}", copy.name);
        }
        // The classifier is never repeated.
        assert_eq!(deep.layers.last().unwrap().name, "fc");
        // Layer names stay unique.
        let mut names: Vec<&str> = deep.layers.iter().map(|l| l.name.as_str()).collect();
        let total = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), total);
    }

    #[test]
    fn width_scaling_keeps_flattened_fc_inputs_consistent() {
        // The custom-model idiom: fc input = predecessor's flattened
        // output. Scaling must keep the chain exact even when
        // round(in*w) and round(c*w)*spatial disagree (e.g. w = 0.9).
        let base = Model {
            name: "slim".into(),
            dataset: Dataset::Cifar10,
            layers: vec![
                Layer::conv("stem", 32, 3, 16, 3, 1, 1),
                Layer::pool("p1", 32, 16, 2, 2),
                Layer::fc("head", 16 * 16 * 16, 10),
            ],
        };
        for width in [0.25, 0.5, 0.9, 1.5] {
            let scaled = scale_model(&base, width, 1);
            let pool = &scaled.layers[1];
            let flat = pool.out_hw() * pool.out_hw() * pool.out_c;
            assert_eq!(
                scaled.layers[2].in_c, flat,
                "w{width}: fc input must equal the flattened pool output"
            );
        }
        // VGG/ImageNet exercises the idiom on a zoo model (fc6 takes
        // 7x7x512): every fc input matches its predecessor's flattened
        // output at w = 0.9 too.
        let vgg = scale_model(&model_for(ModelKind::Vgg16, Dataset::ImageNet), 0.9, 1);
        for pair in vgg.layers.windows(2) {
            if pair[1].kind == super::super::LayerKind::FullyConnected {
                let prev = &pair[0];
                assert_eq!(
                    pair[1].in_c,
                    prev.out_hw() * prev.out_hw() * prev.out_c,
                    "{} -> {}",
                    prev.name,
                    pair[1].name
                );
            }
        }
    }

    #[test]
    fn variant_names_round_trip_base_family() {
        assert_eq!(variant_model_name("ResNet-20", 1.0, 1), "ResNet-20");
        let scaled = variant_model_name("ResNet-20", 0.25, 3);
        assert_eq!(scaled, "ResNet-20@w0.25d3");
        assert_eq!(base_model_name(&scaled), "ResNet-20");
        assert_eq!(base_model_name("ResNet-20"), "ResNet-20");
    }

    #[test]
    fn scaled_variants_never_alias_in_shape() {
        // Two different variants of the same base must differ in name
        // and in layer shapes — the cache-key inputs.
        let base = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let a = scale_model(&base, 0.5, 1);
        let b = scale_model(&base, 0.5, 2);
        assert_ne!(a.name, b.name);
        assert_ne!(a.layers, b.layers);
    }

    #[test]
    fn fc_classes_match_dataset() {
        for dataset in Dataset::ALL {
            for model in models_for(dataset) {
                let fc = model.layers.last().unwrap();
                assert_eq!(fc.out_c, dataset.classes(), "{} on {}", model.name, dataset);
            }
        }
    }
}
