//! Dense linear algebra for the regression fits: symmetric positive
//! definite solves via Cholesky decomposition (ridge-regularized normal
//! equations are SPD by construction).

/// A dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Elements, row-major, `rows * cols` long.
    pub data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Element access.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    /// Element assignment.
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// Build from rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols), "ragged rows");
        Self { rows: rows.len(), cols, data: rows.concat() }
    }
}

/// Dot product over paired slices with four independent accumulators —
/// the inner kernel of [`cholesky`] and [`solve_spd`]. The independent
/// partial sums break the serial add dependency chain, so the loop keeps
/// the FPU pipeline full (and auto-vectorizes); the tail is summed
/// serially.
fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, w) in ca.by_ref().zip(cb.by_ref()) {
        acc[0] += x[0] * w[0];
        acc[1] += x[1] * w[1];
        acc[2] += x[2] * w[2];
        acc[3] += x[3] * w[3];
    }
    let tail: f64 =
        ca.remainder().iter().zip(cb.remainder()).map(|(x, w)| x * w).sum();
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Compute the Gram matrix `XᵀX` and moment vector `Xᵀy` in one pass.
///
/// The accumulation walks each design row once and updates the upper
/// triangle through contiguous row slices (no per-element index
/// arithmetic or bounds checks in the inner loop); the add order is
/// identical to the historical element-wise version, so results are
/// bit-for-bit unchanged.
pub fn normal_equations(x: &Matrix, y: &[f64]) -> (Matrix, Vec<f64>) {
    assert_eq!(x.rows, y.len());
    let p = x.cols;
    let mut gram = Matrix::zeros(p, p);
    let mut moment = vec![0.0; p];
    if p == 0 {
        return (gram, moment);
    }
    for (row, &yr) in x.data.chunks_exact(p).zip(y) {
        for i in 0..p {
            let xi = row[i];
            moment[i] += xi * yr;
            // Symmetric: fill the upper triangle, mirror after.
            let gram_row = &mut gram.data[i * p + i..(i + 1) * p];
            for (g, &xj) in gram_row.iter_mut().zip(&row[i..]) {
                *g += xi * xj;
            }
        }
    }
    for i in 0..p {
        for j in 0..i {
            gram.data[i * p + j] = gram.data[j * p + i];
        }
    }
    (gram, moment)
}

/// Cholesky decomposition `A = L·Lᵀ` of an SPD matrix. Returns `None` if
/// the matrix is not (numerically) positive definite.
///
/// Row-oriented formulation: the update for `L[i][j]` is a [`dot`] of the
/// finished prefixes of rows `i` and `j` — contiguous slices, obtained by
/// splitting the storage at row `i` so earlier rows stay readable while
/// row `i` is written.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        let (done, rest) = l.data.split_at_mut(i * n);
        let row_i = &mut rest[..n];
        let a_row = &a.data[i * n..(i + 1) * n];
        for j in 0..i {
            let row_j = &done[j * n..j * n + j];
            let sum = a_row[j] - dot(&row_i[..j], row_j);
            row_i[j] = sum / done[j * n + j];
        }
        let diag = a_row[i] - dot(&row_i[..i], &row_i[..i]);
        if diag <= 0.0 {
            return None;
        }
        row_i[i] = diag.sqrt();
    }
    Some(l)
}

/// Solve `A·w = b` for SPD `A` via Cholesky (forward + back substitution).
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Forward: L·z = b — row prefixes are contiguous, so each step is one
    // [`dot`] against the solved prefix.
    let mut z = vec![0.0; n];
    for i in 0..n {
        let row = &l.data[i * n..i * n + i];
        z[i] = (b[i] - dot(row, &z[..i])) / l.data[i * n + i];
    }
    // Back: Lᵀ·w = z — walks column `i` of `L` (stride `n`), accumulated
    // over the flat storage directly.
    let mut w = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = z[i];
        for k in i + 1..n {
            sum -= l.data[k * n + i] * w[k];
        }
        w[i] = sum / l.data[i * n + i];
    }
    Some(w)
}

/// Solve the ridge regression `(XᵀX + λI)·w = Xᵀy`.
pub fn ridge_fit(x: &Matrix, y: &[f64], lambda: f64) -> Option<Vec<f64>> {
    let (mut gram, moment) = normal_equations(x, y);
    for i in 0..gram.rows {
        let d = gram.data[i * gram.cols + i];
        gram.data[i * gram.cols + i] = d + lambda;
    }
    solve_spd(&gram, &moment)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_of_identity() {
        let mut a = Matrix::zeros(3, 3);
        for i in 0..3 {
            a.set(i, i, 1.0);
        }
        let l = cholesky(&a).unwrap();
        assert_eq!(l, a);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::zeros(2, 2);
        a.set(0, 0, 1.0);
        a.set(1, 1, -1.0);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_known_system() {
        // A = [[4, 2], [2, 3]], b = [10, 8] → w = [1.75, 1.5].
        let mut a = Matrix::zeros(2, 2);
        a.set(0, 0, 4.0);
        a.set(0, 1, 2.0);
        a.set(1, 0, 2.0);
        a.set(1, 1, 3.0);
        let w = solve_spd(&a, &[10.0, 8.0]).unwrap();
        assert!((w[0] - 1.75).abs() < 1e-12);
        assert!((w[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ridge_recovers_exact_linear_model() {
        // y = 3x₀ - 2x₁ + 1 (with intercept column).
        let rows: Vec<Vec<f64>> = (0..20)
            .map(|i| {
                let x0 = i as f64;
                let x1 = (i * 7 % 5) as f64;
                vec![1.0, x0, x1]
            })
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| 1.0 + 3.0 * r[1] - 2.0 * r[2]).collect();
        let x = Matrix::from_rows(&rows);
        let w = ridge_fit(&x, &y, 1e-9).unwrap();
        assert!((w[0] - 1.0).abs() < 1e-6);
        assert!((w[1] - 3.0).abs() < 1e-6);
        assert!((w[2] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn normal_equations_symmetric() {
        let x = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let (gram, _) = normal_equations(&x, &[1.0, 2.0, 3.0]);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(gram.get(i, j), gram.get(j, i));
            }
        }
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    /// Deterministic pseudo-random doubles in (0, 1) for kernel tests.
    fn lcg_seq(n: usize, mut state: u64) -> Vec<f64> {
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 11) as f64) / ((1u64 << 53) as f64)
            })
            .collect()
    }

    #[test]
    fn dot_matches_serial_reference_within_fp_reorder() {
        for n in [0, 1, 3, 4, 7, 8, 17, 64] {
            let a = lcg_seq(n, 1);
            let b = lcg_seq(n, 2);
            let serial: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let blocked = dot(&a, &b);
            assert!(
                (serial - blocked).abs() <= 1e-12 * serial.abs().max(1.0),
                "n={n}: serial {serial} vs blocked {blocked}"
            );
        }
    }

    #[test]
    fn normal_equations_bit_identical_to_elementwise_reference() {
        // The slice rewrite claims *identical* accumulation order; pin it
        // against the historical triple loop, exactly (f64 ==).
        let (rows, p) = (23, 5);
        let data = lcg_seq(rows * p, 3);
        let y = lcg_seq(rows, 4);
        let x = Matrix { rows, cols: p, data };
        let (gram, moment) = normal_equations(&x, &y);
        let mut ref_gram = Matrix::zeros(p, p);
        let mut ref_moment = vec![0.0; p];
        for r in 0..rows {
            let row = &x.data[r * p..(r + 1) * p];
            for i in 0..p {
                ref_moment[i] += row[i] * y[r];
                for j in i..p {
                    ref_gram.data[i * p + j] += row[i] * row[j];
                }
            }
        }
        for i in 0..p {
            for j in 0..i {
                ref_gram.data[i * p + j] = ref_gram.data[j * p + i];
            }
        }
        assert_eq!(gram, ref_gram);
        assert_eq!(moment, ref_moment);
    }

    #[test]
    fn cholesky_reconstructs_spd_input() {
        // A = XᵀX + I is SPD; L·Lᵀ must reproduce it to fp tolerance for
        // sizes exercising every dot-kernel tail length.
        for n in [1, 2, 3, 5, 8, 13] {
            let data = lcg_seq(3 * n * n, n as u64);
            let x = Matrix { rows: 3 * n, cols: n, data };
            let (mut a, _) = normal_equations(&x, &vec![0.0; 3 * n]);
            for i in 0..n {
                a.data[i * n + i] += 1.0;
            }
            let l = cholesky(&a).unwrap();
            for i in 0..n {
                for j in 0..n {
                    let recon: f64 = (0..n).map(|k| l.get(i, k) * l.get(j, k)).sum();
                    assert!(
                        (recon - a.get(i, j)).abs() < 1e-9,
                        "n={n} ({i},{j}): {recon} vs {}",
                        a.get(i, j)
                    );
                }
            }
            // And the solver inverts it: A·w = b round-trips.
            let b = lcg_seq(n, 99);
            let w = solve_spd(&a, &b).unwrap();
            for i in 0..n {
                let ax: f64 = (0..n).map(|k| a.get(i, k) * w[k]).sum();
                assert!((ax - b[i]).abs() < 1e-8, "n={n} row {i}: {ax} vs {}", b[i]);
            }
        }
    }
}
