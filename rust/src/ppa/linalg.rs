//! Dense linear algebra for the regression fits: symmetric positive
//! definite solves via Cholesky decomposition (ridge-regularized normal
//! equations are SPD by construction).

/// A dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Elements, row-major, `rows * cols` long.
    pub data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Element access.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    /// Element assignment.
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// Build from rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols), "ragged rows");
        Self { rows: rows.len(), cols, data: rows.concat() }
    }
}

/// Compute the Gram matrix `XᵀX` and moment vector `Xᵀy` in one pass.
pub fn normal_equations(x: &Matrix, y: &[f64]) -> (Matrix, Vec<f64>) {
    assert_eq!(x.rows, y.len());
    let p = x.cols;
    let mut gram = Matrix::zeros(p, p);
    let mut moment = vec![0.0; p];
    for r in 0..x.rows {
        let row = &x.data[r * p..(r + 1) * p];
        for i in 0..p {
            moment[i] += row[i] * y[r];
            // Symmetric: fill upper triangle, mirror after.
            for j in i..p {
                gram.data[i * p + j] += row[i] * row[j];
            }
        }
    }
    for i in 0..p {
        for j in 0..i {
            gram.data[i * p + j] = gram.data[j * p + i];
        }
    }
    (gram, moment)
}

/// Cholesky decomposition `A = L·Lᵀ` of an SPD matrix. Returns `None` if
/// the matrix is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Some(l)
}

/// Solve `A·w = b` for SPD `A` via Cholesky (forward + back substitution).
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Forward: L·z = b.
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l.get(i, k) * z[k];
        }
        z[i] = sum / l.get(i, i);
    }
    // Back: Lᵀ·w = z.
    let mut w = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = z[i];
        for k in i + 1..n {
            sum -= l.get(k, i) * w[k];
        }
        w[i] = sum / l.get(i, i);
    }
    Some(w)
}

/// Solve the ridge regression `(XᵀX + λI)·w = Xᵀy`.
pub fn ridge_fit(x: &Matrix, y: &[f64], lambda: f64) -> Option<Vec<f64>> {
    let (mut gram, moment) = normal_equations(x, y);
    for i in 0..gram.rows {
        let d = gram.data[i * gram.cols + i];
        gram.data[i * gram.cols + i] = d + lambda;
    }
    solve_spd(&gram, &moment)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_of_identity() {
        let mut a = Matrix::zeros(3, 3);
        for i in 0..3 {
            a.set(i, i, 1.0);
        }
        let l = cholesky(&a).unwrap();
        assert_eq!(l, a);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::zeros(2, 2);
        a.set(0, 0, 1.0);
        a.set(1, 1, -1.0);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_known_system() {
        // A = [[4, 2], [2, 3]], b = [10, 8] → w = [1.75, 1.5].
        let mut a = Matrix::zeros(2, 2);
        a.set(0, 0, 4.0);
        a.set(0, 1, 2.0);
        a.set(1, 0, 2.0);
        a.set(1, 1, 3.0);
        let w = solve_spd(&a, &[10.0, 8.0]).unwrap();
        assert!((w[0] - 1.75).abs() < 1e-12);
        assert!((w[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ridge_recovers_exact_linear_model() {
        // y = 3x₀ - 2x₁ + 1 (with intercept column).
        let rows: Vec<Vec<f64>> = (0..20)
            .map(|i| {
                let x0 = i as f64;
                let x1 = (i * 7 % 5) as f64;
                vec![1.0, x0, x1]
            })
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| 1.0 + 3.0 * r[1] - 2.0 * r[2]).collect();
        let x = Matrix::from_rows(&rows);
        let w = ridge_fit(&x, &y, 1e-9).unwrap();
        assert!((w[0] - 1.0).abs() < 1e-6);
        assert!((w[1] - 3.0).abs() < 1e-6);
        assert!((w[2] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn normal_equations_symmetric() {
        let x = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let (gram, _) = normal_equations(&x, &[1.0, 2.0, 3.0]);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(gram.get(i, j), gram.get(j, i));
            }
        }
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
