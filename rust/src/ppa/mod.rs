//! Polynomial PPA surrogate models (§III-C, Fig. 3).
//!
//! The paper fits polynomial regression models to the synthesis data and
//! selects the model with k-fold cross-validation (Mosteller–Tukey). This
//! module provides:
//!
//! * [`features`] — design-point feature extraction,
//! * [`linalg`] — dense linear algebra (Cholesky-solved ridge normal
//!   equations; no external crates),
//! * [`regression`] — polynomial expansion, fitting, k-fold CV model
//!   selection, and fit metrics (R², MAPE, Pearson correlation).
//!
//! A fitted [`PpaModel`] predicts area / power / max-clock for unseen
//! configurations ~10⁴× faster than re-running the synthesis engine, which
//! is what makes the large DSE sweeps of Fig. 4 cheap.

pub mod features;
pub mod linalg;
pub mod regression;

pub use features::design_features;
pub use regression::{kfold_select, FitReport, PolyModel};

use crate::arch::AcceleratorConfig;
use crate::quant::PeType;
use crate::synth::SynthDataset;

/// A per-PE-type trio of fitted surrogates: area (mm²), power (mW),
/// performance (max clock, GHz).
#[derive(Debug, Clone)]
pub struct PpaModel {
    /// PE type the surrogates were fitted for.
    pub pe: PeType,
    /// Area surrogate (mm²).
    pub area: PolyModel,
    /// Power surrogate (mW).
    pub power: PolyModel,
    /// Performance surrogate (max clock, GHz).
    pub perf: PolyModel,
    /// Held-out fit quality per metric (from k-fold CV).
    pub reports: Vec<FitReport>,
}

impl PpaModel {
    /// Fit all three metrics from a synthesis dataset with k-fold CV model
    /// selection over polynomial degrees 1..=3.
    pub fn fit(dataset: &SynthDataset, folds: usize, seed: u64) -> Self {
        let xs: Vec<Vec<f64>> =
            dataset.records.iter().map(|r| design_features(&r.config)).collect();
        let mut models = Vec::new();
        let mut reports = Vec::new();
        for metric in ["area", "power", "perf"] {
            let ys = dataset.targets(metric);
            let (model, report) = kfold_select(&xs, &ys, folds, seed, metric);
            models.push(model);
            reports.push(report);
        }
        let mut fitted = models.into_iter();
        let (Some(area), Some(power), Some(perf)) =
            (fitted.next(), fitted.next(), fitted.next())
        else {
            unreachable!("one model fitted per metric above")
        };
        Self { pe: dataset.pe, area, power, perf, reports }
    }

    /// Predict (area mm², power mW, max clock GHz) for a configuration.
    pub fn predict(&self, config: &AcceleratorConfig) -> (f64, f64, f64) {
        assert_eq!(config.pe, self.pe, "model fitted for a different PE type");
        let x = design_features(config);
        (self.area.predict(&x), self.power.predict(&x), self.perf.predict(&x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SweepSpec;
    use crate::synth::synthesize_sweep;

    #[test]
    fn fitted_model_correlates_with_synthesis() {
        let spec = SweepSpec::default();
        let dataset = synthesize_sweep(&spec, PeType::Int16, 3);
        let model = PpaModel::fit(&dataset, 5, 0);
        // In-sample correlation must be high for all three metrics —
        // the paper's "agrees closely with the actual values".
        for report in &model.reports {
            assert!(
                report.pearson > 0.95,
                "{}: r = {} (expected > 0.95)",
                report.metric,
                report.pearson
            );
        }
    }

    #[test]
    fn predictions_positive_and_sane() {
        let dataset = synthesize_sweep(&SweepSpec::default(), PeType::LightPe1, 3);
        let model = PpaModel::fit(&dataset, 5, 0);
        for record in &dataset.records {
            let (area, power, perf) = model.predict(&record.config);
            assert!(area > 0.0 && power > 0.0 && perf > 0.0);
            assert!(crate::util::rel_diff(area, record.area_mm2) < 0.5);
        }
    }

    #[test]
    #[should_panic(expected = "different PE type")]
    fn pe_type_mismatch_panics() {
        let dataset = synthesize_sweep(&SweepSpec::default(), PeType::Int16, 3);
        let model = PpaModel::fit(&dataset, 3, 0);
        let config = AcceleratorConfig { pe: PeType::Fp32, ..AcceleratorConfig::default() };
        model.predict(&config);
    }
}
