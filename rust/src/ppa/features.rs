//! Design-point feature extraction for the PPA surrogates.
//!
//! Features are chosen so degree-2 polynomials can express the synthesis
//! engine's dominant terms: PE count (area ∝ rows×cols), storage bits
//! (∝ spad entries × bit width), GLB capacity and its square root (the
//! CACTI access-energy term), and bandwidth.

use crate::arch::AcceleratorConfig;

/// Names of the features returned by [`design_features`] (for reports).
pub const FEATURE_NAMES: [&str; 8] = [
    "num_pes",
    "rows_plus_cols",
    "glb_kib",
    "sqrt_glb_kib",
    "ifmap_spad_bits",
    "filter_spad_bits",
    "psum_spad_bits",
    "dram_bw_gbps",
];

/// Extract the raw (degree-1) feature vector for a configuration.
///
/// PE type is *not* a feature: the paper fits a separate model per PE type
/// (Fig. 3 has one series per type), so all datapath-width effects are
/// absorbed into the per-type coefficients.
pub fn design_features(config: &AcceleratorConfig) -> Vec<f64> {
    let pe = config.pe;
    vec![
        config.num_pes() as f64,
        (config.rows + config.cols) as f64,
        config.glb_kib as f64,
        (config.glb_kib as f64).sqrt(),
        (config.spad.ifmap_entries * pe.act_bits() as usize) as f64,
        (config.spad.filter_entries * pe.weight_bits() as usize) as f64,
        (config.spad.psum_entries * pe.psum_bits() as usize) as f64,
        config.dram_bw_gbps,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::PeType;

    #[test]
    fn feature_count_matches_names() {
        let x = design_features(&AcceleratorConfig::default());
        assert_eq!(x.len(), FEATURE_NAMES.len());
    }

    #[test]
    fn features_respond_to_knobs() {
        let base = AcceleratorConfig::default();
        let x0 = design_features(&base);
        let bigger = AcceleratorConfig { rows: base.rows * 2, ..base.clone() };
        let x1 = design_features(&bigger);
        assert!(x1[0] > x0[0]); // num_pes
        assert!(x1[1] > x0[1]); // rows+cols
        assert_eq!(x1[2], x0[2]); // glb untouched
    }

    #[test]
    fn spad_bits_feature_sees_precision() {
        let int16 = design_features(&AcceleratorConfig {
            pe: PeType::Int16,
            ..AcceleratorConfig::default()
        });
        let light1 = design_features(&AcceleratorConfig {
            pe: PeType::LightPe1,
            ..AcceleratorConfig::default()
        });
        assert!(int16[5] > light1[5], "filter spad bits must shrink at 4-bit weights");
    }
}
