//! Polynomial regression with k-fold cross-validated model selection.
//!
//! Feature standardization → polynomial expansion (pure powers + pairwise
//! interactions at degree 2; cubes at degree 3) → ridge fit via the normal
//! equations. [`kfold_select`] picks the degree with the lowest held-out
//! RMSE, the paper's Mosteller–Tukey model-selection step.

use super::linalg::{ridge_fit, Matrix};
use crate::util::rng::Pcg64;
use crate::util::stats;

/// A fitted polynomial model over standardized raw features.
#[derive(Debug, Clone)]
pub struct PolyModel {
    /// Polynomial basis degree.
    pub degree: usize,
    /// Ridge regularization strength.
    pub lambda: f64,
    /// Per-raw-feature standardization: (mean, stddev).
    pub scaler: Vec<(f64, f64)>,
    /// Weights over the expanded basis (intercept first).
    pub weights: Vec<f64>,
}

/// Held-out fit quality (k-fold CV aggregate + in-sample correlation).
#[derive(Debug, Clone)]
pub struct FitReport {
    /// Metric label (`"area"`, `"power"`, `"perf"`).
    pub metric: String,
    /// Selected polynomial degree.
    pub degree: usize,
    /// Cross-validated RMSE (held-out).
    pub cv_rmse: f64,
    /// In-sample R².
    pub r_squared: f64,
    /// In-sample MAPE (%).
    pub mape: f64,
    /// In-sample Pearson correlation (the "agrees closely" of Fig. 3).
    pub pearson: f64,
    /// Candidate degrees and their CV RMSEs (the model-selection curve).
    pub selection_curve: Vec<(usize, f64)>,
}

/// Expand a standardized feature vector to the polynomial basis.
///
/// Degree 1: `[1, z₁..z_p]`. Degree 2 adds squares and pairwise products.
/// Degree 3 adds cubes (full cubic interactions would explode the basis
/// beyond what ~10² synthesis samples support).
pub fn expand(z: &[f64], degree: usize) -> Vec<f64> {
    let mut out = Vec::new();
    expand_into(z, degree, &mut out);
    out
}

/// [`expand`] into a caller-owned buffer (cleared first) — the reuse path
/// for repeated expansion against one basis: [`PolyModel::predict_with`]
/// and the fit loop thread one buffer through every row instead of
/// allocating a fresh `Vec` per sample.
pub fn expand_into(z: &[f64], degree: usize, out: &mut Vec<f64>) {
    let p = z.len();
    out.clear();
    out.reserve(1 + p * degree + if degree >= 2 { p * (p - 1) / 2 } else { 0 });
    out.push(1.0);
    out.extend_from_slice(z);
    if degree >= 2 {
        for i in 0..p {
            for j in i..p {
                out.push(z[i] * z[j]);
            }
        }
    }
    if degree >= 3 {
        for &v in z {
            out.push(v * v * v);
        }
    }
}

fn fit_scaler(xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
    let p = xs[0].len();
    (0..p)
        .map(|j| {
            let column: Vec<f64> = xs.iter().map(|x| x[j]).collect();
            let mean = stats::mean(&column);
            let sd = stats::stddev(&column).max(1e-12);
            (mean, sd)
        })
        .collect()
}

fn standardize_into(x: &[f64], scaler: &[(f64, f64)], out: &mut Vec<f64>) {
    out.clear();
    out.extend(x.iter().zip(scaler).map(|(v, (m, s))| (v - m) / s));
}

/// Reusable buffers for repeated prediction/expansion against one fitted
/// basis ([`PolyModel::predict_with`]). One scratch per caller thread
/// makes per-sample prediction allocation-free.
#[derive(Debug, Clone, Default)]
pub struct PredictScratch {
    z: Vec<f64>,
    basis: Vec<f64>,
}

impl PolyModel {
    /// Fit at a fixed degree with ridge regularization.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], degree: usize, lambda: f64) -> PolyModel {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let scaler = fit_scaler(xs);
        // Build the design matrix flat, reusing one expansion scratch per
        // row (the old path materialized a `Vec<Vec<f64>>` of every
        // expanded row before concatenating it again).
        let mut scratch = PredictScratch::default();
        let mut data = Vec::new();
        let mut cols = 0;
        for (r, x) in xs.iter().enumerate() {
            standardize_into(x, &scaler, &mut scratch.z);
            expand_into(&scratch.z, degree, &mut scratch.basis);
            if r == 0 {
                cols = scratch.basis.len();
                data.reserve(cols * xs.len());
            }
            data.extend_from_slice(&scratch.basis);
        }
        let design = Matrix { rows: xs.len(), cols, data };
        // The ridge system (XᵀX + λI) is SPD for any λ > 0, so the
        // Cholesky solve cannot fail on the lambdas this crate uses.
        #[allow(clippy::expect_used)]
        let weights = ridge_fit(&design, ys, lambda)
            .expect("ridge normal equations must be SPD with lambda > 0");
        PolyModel { degree, lambda, scaler, weights }
    }

    /// Predict the target for a raw feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        self.predict_with(x, &mut PredictScratch::default())
    }

    /// [`Self::predict`] with caller-owned scratch buffers — the
    /// fit-once-predict-many path: zero allocation per sample once the
    /// scratch has warmed to the basis size.
    pub fn predict_with(&self, x: &[f64], scratch: &mut PredictScratch) -> f64 {
        standardize_into(x, &self.scaler, &mut scratch.z);
        expand_into(&scratch.z, self.degree, &mut scratch.basis);
        scratch.basis.iter().zip(&self.weights).map(|(b, w)| b * w).sum()
    }

    /// Predictions over a raw feature matrix (one shared scratch).
    pub fn predict_all(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut scratch = PredictScratch::default();
        xs.iter().map(|x| self.predict_with(x, &mut scratch)).collect()
    }
}

/// K-fold cross-validated RMSE at a fixed degree.
pub fn cv_rmse(xs: &[Vec<f64>], ys: &[f64], degree: usize, folds: usize, seed: u64) -> f64 {
    assert!(folds >= 2 && xs.len() >= folds);
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    Pcg64::new(seed).shuffle(&mut order);
    let mut sq_err_sum = 0.0;
    for fold in 0..folds {
        let held: Vec<usize> =
            order.iter().cloned().skip(fold).step_by(folds).collect();
        let held_set: std::collections::HashSet<usize> = held.iter().cloned().collect();
        let train_x: Vec<Vec<f64>> = (0..n)
            .filter(|i| !held_set.contains(i))
            .map(|i| xs[i].clone())
            .collect();
        let train_y: Vec<f64> =
            (0..n).filter(|i| !held_set.contains(i)).map(|i| ys[i]).collect();
        let model = PolyModel::fit(&train_x, &train_y, degree, 1e-6);
        let mut scratch = PredictScratch::default();
        for &i in &held {
            sq_err_sum += (model.predict_with(&xs[i], &mut scratch) - ys[i]).powi(2);
        }
    }
    (sq_err_sum / n as f64).sqrt()
}

/// Select the polynomial degree (1..=3) by k-fold CV, refit on all data,
/// and report fit quality — the paper's model-selection procedure.
pub fn kfold_select(
    xs: &[Vec<f64>],
    ys: &[f64],
    folds: usize,
    seed: u64,
    metric: &str,
) -> (PolyModel, FitReport) {
    let mut selection_curve = Vec::new();
    for degree in 1..=3 {
        // Degree 3 needs enough samples per fold to stay overdetermined.
        let basis_size = expand(&vec![0.0; xs[0].len()], degree).len();
        if xs.len() * (folds - 1) / folds <= basis_size {
            break;
        }
        selection_curve.push((degree, cv_rmse(xs, ys, degree, folds, seed)));
    }
    assert!(!selection_curve.is_empty(), "not enough samples for any degree");
    // Non-empty by the assert above; NaN RMSEs order last under total_cmp.
    #[allow(clippy::unwrap_used)]
    let &(best_degree, best_rmse) =
        selection_curve.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
    let model = PolyModel::fit(xs, ys, best_degree, 1e-6);
    let predictions = model.predict_all(xs);
    let report = FitReport {
        metric: metric.to_string(),
        degree: best_degree,
        cv_rmse: best_rmse,
        r_squared: stats::r_squared(ys, &predictions),
        mape: stats::mape(ys, &predictions),
        pearson: stats::pearson(ys, &predictions),
        selection_curve,
    };
    (model, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_quadratic(n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::new(5);
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform(0.0, 10.0), rng.uniform(0.0, 5.0)]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 + 0.5 * x[0] + 1.5 * x[1] + 0.25 * x[0] * x[1] + 0.1 * x[0] * x[0])
            .collect();
        (xs, ys)
    }

    #[test]
    fn degree2_fits_quadratic_exactly() {
        let (xs, ys) = synthetic_quadratic(100);
        let model = PolyModel::fit(&xs, &ys, 2, 1e-9);
        let preds = model.predict_all(&xs);
        assert!(stats::r_squared(&ys, &preds) > 0.999999);
    }

    #[test]
    fn degree1_underfits_quadratic() {
        let (xs, ys) = synthetic_quadratic(100);
        let lin = PolyModel::fit(&xs, &ys, 1, 1e-9);
        let quad = PolyModel::fit(&xs, &ys, 2, 1e-9);
        let rmse = |m: &PolyModel| stats::rmse(&ys, &m.predict_all(&xs));
        assert!(rmse(&lin) > 10.0 * rmse(&quad));
    }

    #[test]
    fn kfold_selects_degree_2_for_quadratic_data() {
        let (xs, ys) = synthetic_quadratic(120);
        let (model, report) = kfold_select(&xs, &ys, 5, 0, "test");
        assert!(model.degree >= 2, "selected degree {}", model.degree);
        assert!(report.r_squared > 0.999);
        assert!(report.selection_curve.len() >= 2);
    }

    #[test]
    fn cv_rmse_positive_and_stable() {
        let (xs, ys) = synthetic_quadratic(80);
        let a = cv_rmse(&xs, &ys, 2, 4, 3);
        let b = cv_rmse(&xs, &ys, 2, 4, 3);
        assert_eq!(a, b, "same seed must give same folds");
        assert!(a >= 0.0);
    }

    #[test]
    fn expansion_sizes() {
        let z = vec![0.0; 4];
        assert_eq!(expand(&z, 1).len(), 1 + 4);
        assert_eq!(expand(&z, 2).len(), 1 + 4 + 10);
        assert_eq!(expand(&z, 3).len(), 1 + 4 + 10 + 4);
    }

    #[test]
    fn predict_with_reused_scratch_is_bit_identical() {
        let (xs, ys) = synthetic_quadratic(60);
        let model = PolyModel::fit(&xs, &ys, 2, 1e-9);
        let mut scratch = PredictScratch::default();
        for x in &xs {
            // Exact f64 equality: the scratch path computes the very same
            // operations as the allocating one.
            assert_eq!(model.predict_with(x, &mut scratch), model.predict(x));
        }
        // expand_into clears a dirty buffer before writing.
        let mut buf = vec![99.0; 7];
        expand_into(&[2.0, 3.0], 2, &mut buf);
        assert_eq!(buf, expand(&[2.0, 3.0], 2));
    }

    #[test]
    fn standardization_centers_features() {
        let xs = vec![vec![10.0], vec![20.0], vec![30.0]];
        let scaler = fit_scaler(&xs);
        let mut z = Vec::new();
        standardize_into(&[20.0], &scaler, &mut z);
        assert!(z[0].abs() < 1e-12);
    }

    #[test]
    fn noisy_fit_still_correlates() {
        let (xs, mut ys) = synthetic_quadratic(150);
        let mut rng = Pcg64::new(11);
        for y in &mut ys {
            *y *= rng.lognormal(0.0, 0.05);
        }
        let (_, report) = kfold_select(&xs, &ys, 5, 0, "noisy");
        assert!(report.pearson > 0.98, "pearson {}", report.pearson);
    }
}
