//! Golden reference for the simulator: direct convolution with the exact
//! hardware quantizer semantics from [`crate::quant`].

use crate::dnn::Layer;
use crate::quant::{
    pe_multiply, AffineQuantizer, PeType, Po2Quantizer, QuantWeight,
};

/// A layer's tensors quantized for a PE type, with hardware encodings.
#[derive(Debug, Clone)]
pub struct QuantizedLayer {
    /// PE type whose encodings this layer uses.
    pub pe: PeType,
    /// Activation codes (integer domain; fp32 passes raw bits through f64).
    pub act_codes: Vec<i64>,
    /// Raw activations (fp32 path).
    pub act_raw: Vec<f64>,
    /// Weight hardware encodings.
    pub weight_codes: Vec<QuantWeight>,
    /// Weight real values after fake-quantization.
    pub weight_values: Vec<f64>,
    /// Activation scale (code → value).
    pub act_scale: f64,
    /// Weight quantization step (affine scale or po2 output scale).
    pub weight_step: f64,
}

/// Quantize a layer's ifmap and weights for a PE type.
pub fn quantize_tensors(
    pe: PeType,
    _layer: &Layer,
    ifmap: &[f64],
    weights: &[f64],
) -> QuantizedLayer {
    match pe {
        PeType::Fp32 => QuantizedLayer {
            pe,
            act_codes: Vec::new(),
            act_raw: ifmap.to_vec(),
            weight_codes: Vec::new(),
            weight_values: weights.to_vec(),
            act_scale: 0.0,
            weight_step: 0.0,
        },
        PeType::Int16 => {
            let aq = AffineQuantizer::calibrate(16, ifmap);
            let wq = AffineQuantizer::calibrate(16, weights);
            QuantizedLayer {
                pe,
                act_codes: ifmap.iter().map(|&x| aq.quantize(x)).collect(),
                act_raw: ifmap.to_vec(),
                weight_codes: weights
                    .iter()
                    .map(|&w| QuantWeight::Code(wq.quantize(w)))
                    .collect(),
                weight_values: weights.iter().map(|&w| wq.fake_quantize(w)).collect(),
                act_scale: aq.scale,
                weight_step: wq.scale,
            }
        }
        PeType::LightPe1 | PeType::LightPe2 => {
            let aq = AffineQuantizer::calibrate(8, ifmap);
            let wq = Po2Quantizer::calibrate(pe, weights);
            let mut codes = Vec::with_capacity(weights.len());
            let mut values = Vec::with_capacity(weights.len());
            for &w in weights {
                let (value, code) = wq.quantize(w);
                codes.push(code);
                values.push(value);
            }
            QuantizedLayer {
                pe,
                act_codes: ifmap.iter().map(|&x| aq.quantize(x)).collect(),
                act_raw: ifmap.to_vec(),
                weight_codes: codes,
                weight_values: values,
                act_scale: aq.scale,
                weight_step: wq.output_scale(),
            }
        }
    }
}

impl QuantizedLayer {
    /// Hardware MAC over integer codes at a flat (act index, weight index);
    /// returns the integer-domain product (fp32 path multiplies reals and
    /// returns them via the value-domain accessor instead).
    pub fn multiply_codes(&self, act_idx: usize, weight_idx: usize) -> i64 {
        pe_multiply(self.pe, self.act_codes[act_idx], self.weight_codes[weight_idx])
    }

    /// Value-domain product for an (act, weight) pair — what the integer
    /// product dequantizes to. Shared by the simulator scoreboard.
    pub fn multiply_values(&self, act_idx: usize, weight_idx: usize) -> f64 {
        match self.pe {
            PeType::Fp32 => self.act_raw[act_idx] * self.weight_values[weight_idx],
            PeType::Int16 => {
                // code product × both scales.
                let q = self.multiply_codes(act_idx, weight_idx);
                q as f64 * self.act_scale * self.weight_step
            }
            PeType::LightPe1 | PeType::LightPe2 => {
                let q = self.multiply_codes(act_idx, weight_idx);
                q as f64 * self.act_scale * self.weight_step
            }
        }
    }

    /// Full dequantized convolution using the hardware multiply path.
    pub fn dequantized_conv(&self, layer: &Layer) -> Vec<f64> {
        conv_with(layer, |act_idx, weight_idx| self.multiply_values(act_idx, weight_idx))
    }
}

/// Index an NCHW ifmap element, `None` when (h, w) falls in padding.
pub fn ifmap_index(layer: &Layer, c: usize, h: i64, w: i64) -> Option<usize> {
    let hw = layer.in_hw as i64;
    if h < 0 || w < 0 || h >= hw || w >= hw {
        return None;
    }
    Some(c * layer.in_hw * layer.in_hw + h as usize * layer.in_hw + w as usize)
}

/// Index a weight element (m, c, kh, kw).
pub fn weight_index(layer: &Layer, m: usize, c: usize, kh: usize, kw: usize) -> usize {
    ((m * layer.in_c + c) * layer.kernel + kh) * layer.kernel + kw
}

/// Direct convolution parameterized by the multiply op (value domain).
fn conv_with(layer: &Layer, mul: impl Fn(usize, usize) -> f64) -> Vec<f64> {
    let out_hw = layer.out_hw();
    let mut output = vec![0.0f64; layer.ofmap_elems() as usize];
    for m in 0..layer.out_c {
        for oh in 0..out_hw {
            for ow in 0..out_hw {
                let mut acc = 0.0;
                for c in 0..layer.in_c {
                    for kh in 0..layer.kernel {
                        for kw in 0..layer.kernel {
                            let ih = (oh * layer.stride + kh) as i64 - layer.padding as i64;
                            let iw = (ow * layer.stride + kw) as i64 - layer.padding as i64;
                            if let Some(ai) = ifmap_index(layer, c, ih, iw) {
                                acc += mul(ai, weight_index(layer, m, c, kh, kw));
                            }
                        }
                    }
                }
                output[(m * out_hw + oh) * out_hw + ow] = acc;
            }
        }
    }
    output
}

/// Unquantized (f64) direct convolution — the numerical ground truth.
pub fn golden_conv(layer: &Layer, ifmap: &[f64], weights: &[f64]) -> Vec<f64> {
    assert_eq!(ifmap.len() as u64, layer.ifmap_elems());
    assert_eq!(weights.len() as u64, layer.weights());
    conv_with(layer, |ai, wi| ifmap[ai] * weights[wi])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn layer() -> Layer {
        Layer::conv("g", 5, 2, 3, 3, 1, 1)
    }

    fn inputs(seed: u64) -> (Vec<f64>, Vec<f64>) {
        let l = layer();
        let mut rng = Pcg64::new(seed);
        (
            (0..l.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect(),
            (0..l.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect(),
        )
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1×1 conv, single channel, weight 1.0 → output == input.
        let l = Layer::conv("id", 4, 1, 1, 1, 1, 0);
        let ifmap: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let out = golden_conv(&l, &ifmap, &[1.0]);
        assert_eq!(out, ifmap);
    }

    #[test]
    fn padding_zeroes_border_contributions() {
        // All-ones input & kernel: corner output sums only the in-bounds taps.
        let l = Layer::conv("pad", 3, 1, 1, 3, 1, 1);
        let out = golden_conv(&l, &vec![1.0; 9], &vec![1.0; 9]);
        assert_eq!(out[0], 4.0); // corner: 2×2 window in bounds
        assert_eq!(out[4], 9.0); // center: full 3×3
    }

    #[test]
    fn fp32_quantization_is_identity() {
        let (ifmap, weights) = inputs(1);
        let q = quantize_tensors(PeType::Fp32, &layer(), &ifmap, &weights);
        let exact = golden_conv(&layer(), &ifmap, &weights);
        let deq = q.dequantized_conv(&layer());
        for (a, b) in exact.iter().zip(&deq) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn int16_error_small() {
        let (ifmap, weights) = inputs(2);
        let q = quantize_tensors(PeType::Int16, &layer(), &ifmap, &weights);
        let exact = golden_conv(&layer(), &ifmap, &weights);
        let deq = q.dequantized_conv(&layer());
        let max_err =
            exact.iter().zip(&deq).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(max_err < 1e-3, "INT16 max err {max_err}");
    }

    #[test]
    fn lightpe1_coarser_than_lightpe2() {
        let (ifmap, weights) = inputs(3);
        let exact = golden_conv(&layer(), &ifmap, &weights);
        let err = |pe: PeType| {
            let q = quantize_tensors(pe, &layer(), &ifmap, &weights);
            let deq = q.dequantized_conv(&layer());
            exact.iter().zip(&deq).map(|(a, b)| (a - b).abs()).sum::<f64>()
        };
        assert!(err(PeType::LightPe1) > err(PeType::LightPe2));
    }

    #[test]
    fn integer_codes_match_value_domain_int16() {
        // The integer MAC path dequantizes to exactly the value-domain MAC.
        let (ifmap, weights) = inputs(4);
        let q = quantize_tensors(PeType::Int16, &layer(), &ifmap, &weights);
        for (ai, wi) in [(0usize, 0usize), (3, 7), (10, 17)] {
            let via_codes =
                q.multiply_codes(ai, wi) as f64 * q.act_scale * q.weight_step;
            let via_values = q.multiply_values(ai, wi);
            assert!((via_codes - via_values).abs() < 1e-12);
        }
    }

    #[test]
    fn weight_index_layout() {
        let l = layer();
        assert_eq!(weight_index(&l, 0, 0, 0, 0), 0);
        assert_eq!(weight_index(&l, 0, 0, 0, 1), 1);
        assert_eq!(weight_index(&l, 0, 1, 0, 0), 9);
        assert_eq!(weight_index(&l, 1, 0, 0, 0), 18);
    }
}
