//! Cycle-level row-stationary simulation engine.
//!
//! Walks the actual pass structure of the RS mapping: each pass assigns
//! (filter-row strips × output-row columns) to the physical array, then
//! advances cycle by cycle through the 1-D convolution primitives (F output
//! columns × S filter taps per PE). Every MAC goes through the hardware
//! multiply path from [`super::golden`], so the final feature map is
//! bit-identical to the quantized golden model — the "functional
//! verification" of §III-C.

use super::golden::{golden_conv, ifmap_index, quantize_tensors, weight_index};
use crate::arch::AcceleratorConfig;
use crate::dnn::{Layer, LayerKind};
use crate::util::ceil_div;

/// Simulation outcome for one layer.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Cycles consumed (compute passes; fills are pipelined).
    pub cycles: u64,
    /// Total MACs issued (must equal the layer's MAC count).
    pub mac_count: u64,
    /// Average array utilization.
    pub utilization: f64,
    /// Output feature map (value domain, dequantized).
    pub ofmap: Vec<f64>,
    /// Max |sim − quantized golden| (should be ≈ 0).
    pub max_divergence: f64,
    /// Max |sim − unquantized golden| (the quantization error).
    pub max_abs_error: f64,
    /// Whether the simulated output matched the quantized golden model.
    pub verified: bool,
}

/// Simulate one layer on one configuration with concrete tensors.
pub fn simulate_layer(
    layer: &Layer,
    config: &AcceleratorConfig,
    ifmap: &[f64],
    weights: &[f64],
) -> SimResult {
    assert_eq!(layer.kind, LayerKind::Conv, "simulator handles conv layers");
    assert_eq!(ifmap.len() as u64, layer.ifmap_elems());
    assert_eq!(weights.len() as u64, layer.weights());

    let q = quantize_tensors(config.pe, layer, ifmap, weights);
    let r = layer.kernel;
    let s = layer.kernel;
    let e = layer.out_hw();
    let f = layer.out_hw();

    // Spatial folding mirrors the analytical mapper.
    let strip_height = r.min(config.rows);
    let strips = (config.rows / strip_height).max(1);
    let e_spatial = e.min(config.cols);
    let r_folds = ceil_div(r, strip_height);

    let mut ofmap = vec![0.0f64; layer.ofmap_elems() as usize];
    let mut cycles: u64 = 0;

    // Enumerate (m, c) work units; strips take them in groups per pass.
    let mc_units: Vec<(usize, usize)> = (0..layer.out_c)
        .flat_map(|m| (0..layer.in_c).map(move |c| (m, c)))
        .collect();

    for mc_chunk in mc_units.chunks(strips) {
        for e_base in (0..e).step_by(e_spatial) {
            let e_count = e_spatial.min(e - e_base);
            for fold in 0..r_folds {
                // One pass: strips × e_count columns active. Each PE runs
                // the 1-D primitive: F output columns × S taps.
                let kh_base = fold * strip_height;
                let kh_count = strip_height.min(r - kh_base);
                for tap in 0..s {
                    for out_col in 0..f {
                        // One cycle: every active PE does one MAC.
                        cycles += 1;
                        for &(m, c) in mc_chunk {
                            for kh_off in 0..kh_count {
                                let kh = kh_base + kh_off;
                                for e_off in 0..e_count {
                                    let oh = e_base + e_off;
                                    let ih = (oh * layer.stride + kh) as i64
                                        - layer.padding as i64;
                                    let iw = (out_col * layer.stride + tap) as i64
                                        - layer.padding as i64;
                                    if let Some(ai) = ifmap_index(layer, c, ih, iw) {
                                        let wi = weight_index(layer, m, c, kh, tap);
                                        ofmap[(m * e + oh) * f + out_col] +=
                                            q.multiply_values(ai, wi);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    // Scoreboard: quantized golden (same multiply path) and fp golden.
    let golden_q = q.dequantized_conv(layer);
    let golden_fp = golden_conv(layer, ifmap, weights);
    let max_divergence = ofmap
        .iter()
        .zip(&golden_q)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let max_abs_error = ofmap
        .iter()
        .zip(&golden_fp)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);

    let utilization = layer.macs() as f64 / (cycles as f64 * config.num_pes() as f64);

    SimResult {
        cycles,
        mac_count: layer.macs(),
        utilization,
        ofmap,
        max_divergence,
        max_abs_error,
        verified: max_divergence < 1e-9,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::PeType;
    use crate::util::rng::Pcg64;

    fn run(pe: PeType, rows: usize, cols: usize, seed: u64) -> SimResult {
        let layer = Layer::conv("t", 6, 2, 3, 3, 1, 1);
        let mut rng = Pcg64::new(seed);
        let ifmap: Vec<f64> =
            (0..layer.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let weights: Vec<f64> =
            (0..layer.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect();
        let config = AcceleratorConfig { pe, rows, cols, ..Default::default() };
        simulate_layer(&layer, &config, &ifmap, &weights)
    }

    #[test]
    fn verified_for_every_pe_type() {
        for pe in PeType::ALL {
            let result = run(pe, 6, 6, 42);
            assert!(result.verified, "{}: divergence {}", pe.name(), result.max_divergence);
        }
    }

    #[test]
    fn fp32_exact_vs_unquantized() {
        let result = run(PeType::Fp32, 6, 6, 7);
        assert!(result.max_abs_error < 1e-12);
    }

    #[test]
    fn cycles_scale_down_with_array_size() {
        let small = run(PeType::Int16, 3, 3, 9);
        let large = run(PeType::Int16, 9, 6, 9);
        assert!(large.cycles < small.cycles);
        // Same functional output regardless of array shape.
        let max_diff = small
            .ofmap
            .iter()
            .zip(&large.ofmap)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_diff < 1e-9, "array shape must not change numerics");
    }

    #[test]
    fn utilization_drops_on_oversized_array() {
        let fitted = run(PeType::Int16, 6, 6, 11);
        let oversized = run(PeType::Int16, 32, 32, 11);
        assert!(oversized.utilization < fitted.utilization);
    }
}
