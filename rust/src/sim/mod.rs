//! Cycle-level functional simulator — the Synopsys VCS stand-in
//! (DESIGN.md §1, paper §III-C "functional verification and timing").
//!
//! Simulates the 2-D PE array executing one convolution layer under the
//! row-stationary dataflow at cycle granularity: strips of `R` PEs slide
//! filter rows over ifmap rows, psums accumulate down each strip, and the
//! result is checked against a golden direct-convolution reference that
//! uses the same quantizer semantics as the hardware ([`golden`]).
//!
//! The simulator serves two purposes the analytical mapper cannot:
//! functional verification of the PE numerics (including the LightPE
//! shift-add path), and an independent cycle count that cross-checks the
//! mapper's compute-cycle model on small layers.

pub mod golden;
pub mod engine;

pub use engine::{simulate_layer, SimResult};
pub use golden::{golden_conv, quantize_tensors, QuantizedLayer};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::AcceleratorConfig;
    use crate::dataflow::map_layer_rs;
    use crate::dnn::Layer;
    use crate::quant::PeType;
    use crate::util::rng::Pcg64;

    fn small_layer() -> Layer {
        Layer::conv("sim_test", 8, 3, 4, 3, 1, 1)
    }

    fn random_inputs(layer: &Layer, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let ifmap: Vec<f64> =
            (0..layer.ifmap_elems()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let weights: Vec<f64> =
            (0..layer.weights()).map(|_| rng.uniform(-0.5, 0.5)).collect();
        (ifmap, weights)
    }

    #[test]
    fn simulator_matches_golden_for_all_pe_types() {
        let layer = small_layer();
        let (ifmap, weights) = random_inputs(&layer, 1);
        for pe in PeType::ALL {
            let config = AcceleratorConfig { pe, rows: 6, cols: 8, ..Default::default() };
            let result = simulate_layer(&layer, &config, &ifmap, &weights);
            assert!(
                result.verified,
                "{}: simulator output diverges from golden (max err {})",
                pe.name(),
                result.max_abs_error
            );
        }
    }

    #[test]
    fn quantized_types_have_bounded_error_vs_fp() {
        // The quantized golden output must track the unquantized conv within
        // the accumulated quantization error bound.
        let layer = small_layer();
        let (ifmap, weights) = random_inputs(&layer, 2);
        let exact = golden_conv(&layer, &ifmap, &weights);
        for pe in [PeType::Int16, PeType::LightPe2] {
            let q = quantize_tensors(pe, &layer, &ifmap, &weights);
            let quantized = q.dequantized_conv(&layer);
            let max_err = exact
                .iter()
                .zip(&quantized)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            // Per-MAC error ≤ act_step·|w| + wgt_step·|a| summed over C·K².
            let reduction = (layer.in_c * layer.kernel * layer.kernel) as f64;
            let bound = reduction * (q.act_scale + q.weight_step) * 2.0;
            assert!(max_err < bound, "{}: err {} bound {}", pe.name(), max_err, bound);
        }
    }

    #[test]
    fn cycle_count_close_to_mapper_estimate() {
        // The mapper is analytical; the simulator walks real passes. They
        // must agree within 2× on compute cycles for a compute-bound layer.
        let layer = small_layer();
        let (ifmap, weights) = random_inputs(&layer, 3);
        let config = AcceleratorConfig { rows: 6, cols: 8, ..Default::default() };
        let sim = simulate_layer(&layer, &config, &ifmap, &weights);
        let mapped = map_layer_rs(&layer, &config);
        let ratio = sim.cycles as f64 / mapped.compute_cycles as f64;
        assert!(
            (0.5..2.0).contains(&ratio),
            "sim {} vs mapper {} (ratio {ratio})",
            sim.cycles,
            mapped.compute_cycles
        );
    }

    #[test]
    fn utilization_reported() {
        let layer = small_layer();
        let (ifmap, weights) = random_inputs(&layer, 4);
        let config = AcceleratorConfig { rows: 6, cols: 8, ..Default::default() };
        let sim = simulate_layer(&layer, &config, &ifmap, &weights);
        assert!(sim.utilization > 0.0 && sim.utilization <= 1.0);
        assert!(sim.mac_count == layer.macs());
    }
}
