//! Design-space exploration engine (§IV-A).
//!
//! Evaluates a design point of a sweep against a DNN workload —
//! synthesis (area/power/clock) × dataflow mapping (cycles/traffic) ×
//! energy — and produces the paper's two efficiency axes per point:
//! **performance per area** (inferences/s/mm²) and **energy per inference**
//! (on-chip µJ). [`normalize`] rescales a space against the best-INT16
//! baseline exactly as Figs. 4–6 do; [`pareto`] extracts Pareto fronts.
//!
//! Campaign orchestration lives in [`crate::explore::Explorer`]; this
//! module owns the per-point evaluation math and the normalization.

pub mod metrics;
pub mod pareto;

pub use metrics::{coverage, generational_distance, hypervolume_2d};
pub use pareto::{dominates, pareto_front, pareto_front_reference, Orientation};

use crate::arch::AcceleratorConfig;
use crate::dataflow::Dataflow;
use crate::dnn::Model;
use crate::energy::energy_of_totals;
use crate::error::{Error, Result};
use crate::quant::PeType;
use crate::synth::{synthesize, SynthReport};

/// One fully evaluated design point for one DNN workload.
///
/// `PartialEq` compares every metric bit-for-bit (f64 equality), which is
/// exactly what the persistence round-trip and cache-equivalence tests
/// need; see `explore::persist` for the JSON serialization.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    /// The hardware design point this evaluation measured.
    pub config: AcceleratorConfig,
    /// Total die area (mm²).
    pub area_mm2: f64,
    /// Achieved clock (GHz).
    pub clock_ghz: f64,
    /// End-to-end inference latency (ms).
    pub latency_ms: f64,
    /// Throughput (inferences/s).
    pub inf_per_s: f64,
    /// Performance per area (inferences/s per mm²) — Fig. 4/5 x-axis.
    pub perf_per_area: f64,
    /// On-chip energy per inference (µJ) — Fig. 4/6 energy axis.
    pub energy_uj: f64,
    /// DRAM energy per inference (µJ), reported separately (DESIGN.md §1).
    pub dram_energy_uj: f64,
    /// Average PE-array utilization.
    pub utilization: f64,
}

/// Evaluate one configuration on one model.
pub fn evaluate(config: &AcceleratorConfig, model: &Model, seed: u64) -> Evaluation {
    let synth = synthesize(config, seed);
    evaluate_with_synth(&synth, model)
}

/// Evaluate using an existing synthesis report (lets callers amortize
/// synthesis across the per-dataset model set).
pub fn evaluate_with_synth(synth: &SynthReport, model: &Model) -> Evaluation {
    let config = &synth.config;
    // Stats-only mapping: the hot path needs aggregates, not per-layer
    // records or even the model label — a `Copy` totals value, zero heap
    // allocation per point (§Perf optimization 1).
    let mapping = crate::dataflow::map_model_stats(model, config, Dataflow::RowStationary);
    let energy = energy_of_totals(&mapping, synth);
    let latency_s = mapping.latency_s(synth.achieved_clock_ghz);
    let inf_per_s = 1.0 / latency_s;
    Evaluation {
        config: config.clone(),
        area_mm2: synth.area.total_mm2(),
        clock_ghz: synth.achieved_clock_ghz,
        latency_ms: latency_s * 1e3,
        inf_per_s,
        perf_per_area: inf_per_s / synth.area.total_mm2(),
        energy_uj: energy.chip_uj(),
        dram_energy_uj: energy.dram_uj,
        utilization: mapping.avg_utilization,
    }
}

/// The best (highest perf/area) evaluation for a PE type, if any.
///
/// Routed through the online engine: a single-objective
/// [`ParetoFront`](crate::pareto::ParetoFront) keeps every tied maximum,
/// and the historical `max_by` tie-breaking (the *latest* of equal
/// bests) is preserved by picking the highest sequence number.
pub fn best_perf_per_area(evals: &[Evaluation], pe: PeType) -> Option<&Evaluation> {
    let mut front = crate::pareto::ParetoFront::<1, &Evaluation>::new([Orientation::Maximize]);
    for eval in evals.iter().filter(|e| e.config.pe == pe) {
        front.insert([eval.perf_per_area], eval);
    }
    front.entries().iter().max_by_key(|entry| entry.seq).map(|entry| entry.payload)
}

/// The best (lowest energy) evaluation for a PE type, if any.
///
/// Routed through the online engine like [`best_perf_per_area`]; the
/// historical `min_by` tie-breaking (the *earliest* of equal bests) is
/// preserved by picking the lowest sequence number.
pub fn best_energy(evals: &[Evaluation], pe: PeType) -> Option<&Evaluation> {
    let mut front = crate::pareto::ParetoFront::<1, &Evaluation>::new([Orientation::Minimize]);
    for eval in evals.iter().filter(|e| e.config.pe == pe) {
        front.insert([eval.energy_uj], eval);
    }
    front.entries().iter().min_by_key(|entry| entry.seq).map(|entry| entry.payload)
}

/// A design point normalized against the best-INT16 baseline (Fig. 4 axes:
/// higher `norm_perf_per_area` is better; lower `norm_energy` is better).
#[derive(Debug, Clone)]
pub struct NormalizedPoint {
    /// PE type of the underlying design point.
    pub pe: PeType,
    /// [`AcceleratorConfig::id`] of the underlying design point.
    pub config_id: String,
    /// Perf/area relative to the best-INT16 baseline (higher is better).
    pub norm_perf_per_area: f64,
    /// Energy relative to the best-INT16 baseline (lower is better).
    pub norm_energy: f64,
}

/// Normalize a whole space against the best-INT16-by-perf/area baseline
/// (the paper's normalization: "with respect to the INT16 hardware
/// configuration with the highest performance per area"). Returns
/// [`Error::MissingBaseline`] when the space has no INT16 evaluations.
pub fn normalize(evals: &[Evaluation]) -> Result<Vec<NormalizedPoint>> {
    let baseline = best_perf_per_area(evals, PeType::Int16).ok_or_else(|| {
        Error::MissingBaseline("normalize: design space has no INT16 evaluations".into())
    })?;
    let base_ppa = baseline.perf_per_area;
    let base_energy = baseline.energy_uj;
    Ok(evals
        .iter()
        .map(|e| NormalizedPoint {
            pe: e.config.pe,
            config_id: e.config.id(),
            norm_perf_per_area: e.perf_per_area / base_ppa,
            norm_energy: e.energy_uj / base_energy,
        })
        .collect())
}

/// Headline ratios for a design space (the Fig. 4 summary numbers):
/// per PE type, (best perf/area ÷ best INT16 perf/area,
///               best-INT16 energy ÷ best energy). Returns
/// [`Error::MissingBaseline`] when the space has no INT16 evaluations.
pub fn headline_ratios(evals: &[Evaluation]) -> Result<Vec<(PeType, f64, f64)>> {
    let base = best_perf_per_area(evals, PeType::Int16).ok_or_else(|| {
        Error::MissingBaseline("headline_ratios: design space has no INT16 evaluations".into())
    })?;
    let base_energy_best = best_energy(evals, PeType::Int16).ok_or_else(|| {
        Error::MissingBaseline("headline_ratios: design space has no INT16 evaluations".into())
    })?;
    Ok(PeType::ALL
        .iter()
        .filter_map(|&pe| {
            let best_ppa = best_perf_per_area(evals, pe)?;
            let best_e = best_energy(evals, pe)?;
            Some((
                pe,
                best_ppa.perf_per_area / base.perf_per_area,
                base_energy_best.energy_uj / best_e.energy_uj,
            ))
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SweepSpec;
    use crate::dnn::{model_for, Dataset, ModelKind};
    use crate::explore::Explorer;

    fn serial_space(spec: &SweepSpec, seed: u64) -> Vec<Evaluation> {
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        spec.iter().map(|config| evaluate(&config, &model, seed)).collect()
    }

    fn space() -> Vec<Evaluation> {
        serial_space(&SweepSpec::default(), 7)
    }

    #[test]
    fn serial_evaluation_covers_sweep() {
        let spec = SweepSpec::tiny();
        let evals = serial_space(&spec, 7);
        assert_eq!(evals.len(), spec.len());
        assert!(evals.iter().all(|e| e.perf_per_area > 0.0 && e.energy_uj > 0.0));
    }

    #[test]
    fn serial_iteration_matches_explorer() {
        // The serial reference path (`spec.iter()` + `evaluate`) is what
        // the parallel Explorer must reproduce bit-for-bit.
        let spec = SweepSpec::tiny();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let serial: Vec<Evaluation> =
            spec.iter().map(|c| evaluate(&c, &model, 7)).collect();
        let db = Explorer::over(spec).model(model).workers(2).seed(7).run().unwrap();
        assert_eq!(serial.len(), db.spaces[0].evals.len());
        for (a, b) in serial.iter().zip(&db.spaces[0].evals) {
            assert_eq!(a.config.id(), b.config.id());
            assert_eq!(a.perf_per_area, b.perf_per_area);
        }
    }

    #[test]
    fn lightpe_wins_both_axes() {
        // The paper's central result: LightPEs beat INT16 and FP32 on both
        // perf/area and energy at their respective best points.
        let evals = space();
        let ratios = headline_ratios(&evals).unwrap();
        let get = |pe: PeType| ratios.iter().find(|(p, _, _)| *p == pe).unwrap();
        let (_, l1_ppa, l1_energy) = get(PeType::LightPe1);
        let (_, l2_ppa, l2_energy) = get(PeType::LightPe2);
        let (_, fp32_ppa, fp32_energy) = get(PeType::Fp32);
        assert!(*l1_ppa > 1.5, "LightPE-1 perf/area ratio {l1_ppa}");
        assert!(*l2_ppa > 1.5, "LightPE-2 perf/area ratio {l2_ppa}");
        assert!(*l1_energy > 1.5, "LightPE-1 energy gain {l1_energy}");
        assert!(*l2_energy > 1.2, "LightPE-2 energy gain {l2_energy}");
        assert!(*fp32_ppa < 1.0, "FP32 must lose to INT16: {fp32_ppa}");
        assert!(*fp32_energy < 1.0, "FP32 energy must be worse: {fp32_energy}");
        // Ordering: LightPE-1 ≥ LightPE-2 on both.
        assert!(l1_ppa >= l2_ppa);
        assert!(l1_energy >= l2_energy);
    }

    #[test]
    fn normalization_baseline_is_unity() {
        let evals = space();
        let normalized = normalize(&evals).unwrap();
        let best = normalized
            .iter()
            .filter(|p| p.pe == PeType::Int16)
            .map(|p| p.norm_perf_per_area)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((best - 1.0).abs() < 1e-12, "best INT16 must normalize to 1.0, got {best}");
    }

    #[test]
    fn missing_int16_baseline_is_typed_error() {
        let spec = SweepSpec { pe_types: vec![PeType::Fp32], ..SweepSpec::tiny() };
        let evals = serial_space(&spec, 7);
        assert!(matches!(normalize(&evals), Err(Error::MissingBaseline(_))));
        assert!(matches!(headline_ratios(&evals), Err(Error::MissingBaseline(_))));
        // The empty space is also baseline-free, not a panic.
        assert!(matches!(normalize(&[]), Err(Error::MissingBaseline(_))));
    }

    #[test]
    fn best_selectors_agree_with_scan() {
        let evals = space();
        let best = best_perf_per_area(&evals, PeType::LightPe1).unwrap();
        for e in evals.iter().filter(|e| e.config.pe == PeType::LightPe1) {
            assert!(e.perf_per_area <= best.perf_per_area + 1e-12);
        }
        let beste = best_energy(&evals, PeType::Fp32).unwrap();
        for e in evals.iter().filter(|e| e.config.pe == PeType::Fp32) {
            assert!(e.energy_uj >= beste.energy_uj - 1e-12);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = serial_space(&SweepSpec::tiny(), 3);
        let b = serial_space(&SweepSpec::tiny(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.perf_per_area, y.perf_per_area);
        }
    }
}
