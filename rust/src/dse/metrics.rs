//! Pareto-front quality metrics: hypervolume, coverage, and front
//! distance. Used by the DSE campaign summaries to *quantify* "LightPEs
//! achieve a better Pareto-frontier" (§III-B) instead of eyeballing it.

use super::{dominates, Orientation};

/// 2-D hypervolume (area dominated by the front, bounded by a reference
/// point). Orientations fix which direction is "better" per axis; the
/// reference point must be dominated by every front point.
///
/// Points are internally mapped so both axes maximize, then the standard
/// staircase sweep computes the dominated area.
pub fn hypervolume_2d(
    points: &[(f64, f64)],
    reference: (f64, f64),
    orientations: (Orientation, Orientation),
) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    // Map to maximize-maximize space relative to the reference.
    let tf = |v: f64, r: f64, o: Orientation| match o {
        Orientation::Maximize => v - r,
        Orientation::Minimize => r - v,
    };
    let mut mapped: Vec<(f64, f64)> = points
        .iter()
        .map(|&(x, y)| (tf(x, reference.0, orientations.0), tf(y, reference.1, orientations.1)))
        .filter(|&(x, y)| x > 0.0 && y > 0.0)
        .collect();
    if mapped.is_empty() {
        return 0.0;
    }
    // Staircase sweep: descending x, track best y seen.
    mapped.sort_by(|a, b| b.0.total_cmp(&a.0));
    let mut volume = 0.0;
    let mut prev_x = mapped[0].0;
    let mut best_y = 0.0f64;
    for &(x, y) in &mapped {
        if x < prev_x {
            volume += (prev_x - x) * best_y;
            prev_x = x;
        }
        best_y = best_y.max(y);
    }
    volume += prev_x * best_y;
    volume
}

/// Coverage C(a, b): fraction of `b` dominated by at least one point of
/// `a` (Zitzler's binary coverage indicator). 1.0 = `a` completely covers
/// `b`; not symmetric.
pub fn coverage(
    a: &[Vec<f64>],
    b: &[Vec<f64>],
    orientations: &[Orientation],
) -> f64 {
    if b.is_empty() {
        return 0.0;
    }
    let covered = b
        .iter()
        .filter(|point| a.iter().any(|other| dominates(other, point, orientations)))
        .count();
    covered as f64 / b.len() as f64
}

/// Generational distance: mean Euclidean distance from each point of
/// `approx` to its nearest point of `reference_front` (lower = closer).
pub fn generational_distance(approx: &[Vec<f64>], reference_front: &[Vec<f64>]) -> f64 {
    if approx.is_empty() || reference_front.is_empty() {
        return f64::INFINITY;
    }
    let total: f64 = approx
        .iter()
        .map(|p| {
            reference_front
                .iter()
                .map(|q| {
                    p.iter().zip(q).map(|(a, b)| (a - b).powi(2)).sum::<f64>().sqrt()
                })
                .fold(f64::INFINITY, f64::min)
        })
        .sum();
    total / approx.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use Orientation::{Maximize, Minimize};

    #[test]
    fn hypervolume_single_point() {
        // Max-max: point (2, 3) vs reference (0, 0) dominates a 2×3 box.
        let hv = hypervolume_2d(&[(2.0, 3.0)], (0.0, 0.0), (Maximize, Maximize));
        assert!((hv - 6.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_staircase() {
        // Two non-dominating points: (3,1) and (1,3) vs ref (0,0):
        // area = 3*1 + (3-1)... staircase: 3×1 box ∪ 1×3 box = 3 + 2 = 5.
        let hv =
            hypervolume_2d(&[(3.0, 1.0), (1.0, 3.0)], (0.0, 0.0), (Maximize, Maximize));
        assert!((hv - 5.0).abs() < 1e-12, "hv {hv}");
    }

    #[test]
    fn hypervolume_dominated_point_adds_nothing() {
        let base = hypervolume_2d(&[(3.0, 3.0)], (0.0, 0.0), (Maximize, Maximize));
        let with_dominated =
            hypervolume_2d(&[(3.0, 3.0), (1.0, 1.0)], (0.0, 0.0), (Maximize, Maximize));
        assert!((base - with_dominated).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_minimize_axes() {
        // Min-min: point (1, 1) vs reference (4, 4) dominates a 3×3 box.
        let hv = hypervolume_2d(&[(1.0, 1.0)], (4.0, 4.0), (Minimize, Minimize));
        assert!((hv - 9.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_points_outside_reference_ignored() {
        let hv = hypervolume_2d(&[(-1.0, 5.0)], (0.0, 0.0), (Maximize, Maximize));
        assert_eq!(hv, 0.0);
    }

    #[test]
    fn coverage_basics() {
        let o = [Maximize, Minimize];
        let a = vec![vec![5.0, 1.0]];
        let b = vec![vec![4.0, 2.0], vec![6.0, 0.5]];
        // a dominates b[0] but not b[1].
        assert!((coverage(&a, &b, &o) - 0.5).abs() < 1e-12);
        assert_eq!(coverage(&b, &a, &o), 1.0); // b[1] dominates a[0]
    }

    #[test]
    fn generational_distance_zero_on_same_front() {
        let front = vec![vec![1.0, 2.0], vec![3.0, 0.5]];
        assert!(generational_distance(&front, &front) < 1e-12);
    }

    #[test]
    fn generational_distance_grows_with_gap() {
        let reference = vec![vec![0.0, 0.0]];
        let near = vec![vec![0.1, 0.0]];
        let far = vec![vec![5.0, 0.0]];
        assert!(
            generational_distance(&near, &reference)
                < generational_distance(&far, &reference)
        );
    }
}
