//! Pareto-front extraction (Figs. 4–6).
//!
//! Generic over the orientation of each axis so the same routine serves
//! "maximize perf/area vs maximize accuracy" (Fig. 5) and "minimize energy
//! vs minimize error" (Fig. 6).

/// Whether an objective is to be maximized or minimized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Orientation {
    Maximize,
    Minimize,
}

impl Orientation {
    /// Does value `a` dominate-or-tie `b` on this axis?
    fn at_least_as_good(self, a: f64, b: f64) -> bool {
        match self {
            Orientation::Maximize => a >= b,
            Orientation::Minimize => a <= b,
        }
    }

    /// Is value `a` strictly better than `b` on this axis?
    fn strictly_better(self, a: f64, b: f64) -> bool {
        match self {
            Orientation::Maximize => a > b,
            Orientation::Minimize => a < b,
        }
    }
}

/// Does point `a` dominate point `b` (at least as good on every axis,
/// strictly better on at least one)?
pub fn dominates(a: &[f64], b: &[f64], orientations: &[Orientation]) -> bool {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), orientations.len());
    let mut strictly = false;
    for ((&x, &y), &o) in a.iter().zip(b).zip(orientations) {
        if !o.at_least_as_good(x, y) {
            return false;
        }
        if o.strictly_better(x, y) {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the Pareto-optimal points in `points` under `orientations`.
/// Duplicated points are all kept (none dominates its copy). Output is
/// sorted ascending by the first axis for plotting.
pub fn pareto_front(points: &[Vec<f64>], orientations: &[Orientation]) -> Vec<usize> {
    let mut front: Vec<usize> = (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, other)| j != i && dominates(other, &points[i], orientations))
        })
        .collect();
    front.sort_by(|&a, &b| points[a][0].partial_cmp(&points[b][0]).unwrap());
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use Orientation::{Maximize, Minimize};

    #[test]
    fn dominance_basics() {
        let o = [Maximize, Minimize];
        assert!(dominates(&[2.0, 1.0], &[1.0, 2.0], &o));
        assert!(!dominates(&[1.0, 2.0], &[2.0, 1.0], &o));
        // Equal points do not dominate each other.
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0], &o));
        // Better on one axis, worse on the other: no dominance.
        assert!(!dominates(&[2.0, 3.0], &[1.0, 1.0], &o));
    }

    #[test]
    fn front_of_tradeoff_curve() {
        // Classic trade-off: (perf ↑, energy ↓); the knee points survive.
        let points = vec![
            vec![1.0, 1.0], // front (lowest energy)
            vec![2.0, 2.0], // front
            vec![3.0, 4.0], // front (highest perf)
            vec![2.0, 3.0], // dominated by (2,2)
            vec![1.5, 5.0], // dominated by (2,2)
        ];
        let front = pareto_front(&points, &[Maximize, Minimize]);
        assert_eq!(front, vec![0, 1, 2]);
    }

    #[test]
    fn single_point_is_front() {
        let front = pareto_front(&[vec![1.0, 1.0]], &[Maximize, Minimize]);
        assert_eq!(front, vec![0]);
    }

    #[test]
    fn all_equal_points_kept() {
        let points = vec![vec![1.0, 1.0]; 3];
        let front = pareto_front(&points, &[Maximize, Minimize]);
        assert_eq!(front.len(), 3);
    }

    #[test]
    fn orientation_flip_flips_front() {
        let points = vec![vec![1.0, 1.0], vec![2.0, 2.0]];
        let max_both = pareto_front(&points, &[Maximize, Maximize]);
        assert_eq!(max_both, vec![1]);
        let min_both = pareto_front(&points, &[Minimize, Minimize]);
        assert_eq!(min_both, vec![0]);
    }

    #[test]
    fn three_axis_dominance() {
        let o = [Maximize, Minimize, Maximize];
        assert!(dominates(&[2.0, 1.0, 5.0], &[2.0, 1.0, 4.0], &o));
        assert!(!dominates(&[2.0, 1.0, 4.0], &[2.0, 1.0, 5.0], &o));
    }

    #[test]
    fn front_sorted_by_first_axis() {
        let points = vec![vec![3.0, 4.0], vec![1.0, 1.0], vec![2.0, 2.0]];
        let front = pareto_front(&points, &[Maximize, Minimize]);
        let xs: Vec<f64> = front.iter().map(|&i| points[i][0]).collect();
        assert_eq!(xs, vec![1.0, 2.0, 3.0]);
    }
}
