//! Pareto-front extraction (Figs. 4–6).
//!
//! The dominance rules and [`Orientation`] now live in the online engine
//! ([`crate::pareto::front`]) and are re-exported here for source
//! compatibility. [`pareto_front`] — the batch entry point every figure
//! uses — is routed through that engine: it streams the points into a
//! [`FrontCore`](crate::pareto::FrontCore) and reads the survivors back,
//! so the post-hoc and streaming paths are one implementation. The
//! original quadratic scan survives as [`pareto_front_reference`], the
//! oracle the property suite compares the engine against.

pub use crate::pareto::front::{dominates, Orientation};

use crate::pareto::FrontCore;

/// Indices of the Pareto-optimal points in `points` under `orientations`.
/// Duplicated points are all kept (none dominates its copy). Output is
/// sorted ascending by the first axis (ties keep index order), the
/// figures' plotting order.
///
/// Routed through the online engine, so this is definitionally identical
/// to streaming the same points into a
/// [`ParetoFront`](crate::pareto::ParetoFront) — the golden and property
/// suites additionally pin it against [`pareto_front_reference`].
///
/// # Panics
/// If any point's axis count disagrees with `orientations`, or any
/// coordinate is NaN.
pub fn pareto_front(points: &[Vec<f64>], orientations: &[Orientation]) -> Vec<usize> {
    if points.is_empty() {
        return Vec::new();
    }
    let mut front = FrontCore::new(orientations.to_vec());
    for point in points {
        assert!(
            point.iter().all(|v| !v.is_nan()),
            "pareto_front requires NaN-free coordinates"
        );
        front.insert(point.clone(), ());
    }
    front.indices()
}

/// The original post-hoc O(n²) scan, kept verbatim as the differential
/// oracle: the engine-routed [`pareto_front`] must agree with it
/// bit-for-bit (membership and order) on every input.
pub fn pareto_front_reference(points: &[Vec<f64>], orientations: &[Orientation]) -> Vec<usize> {
    let mut front: Vec<usize> = (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, other)| j != i && dominates(other, &points[i], orientations))
        })
        .collect();
    front.sort_by(|&a, &b| points[a][0].total_cmp(&points[b][0]));
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use Orientation::{Maximize, Minimize};

    #[test]
    fn dominance_basics() {
        let o = [Maximize, Minimize];
        assert!(dominates(&[2.0, 1.0], &[1.0, 2.0], &o));
        assert!(!dominates(&[1.0, 2.0], &[2.0, 1.0], &o));
        // Equal points do not dominate each other.
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0], &o));
        // Better on one axis, worse on the other: no dominance.
        assert!(!dominates(&[2.0, 3.0], &[1.0, 1.0], &o));
    }

    #[test]
    fn front_of_tradeoff_curve() {
        // Classic trade-off: (perf ↑, energy ↓); the knee points survive.
        let points = vec![
            vec![1.0, 1.0], // front (lowest energy)
            vec![2.0, 2.0], // front
            vec![3.0, 4.0], // front (highest perf)
            vec![2.0, 3.0], // dominated by (2,2)
            vec![1.5, 5.0], // dominated by (2,2)
        ];
        let front = pareto_front(&points, &[Maximize, Minimize]);
        assert_eq!(front, vec![0, 1, 2]);
    }

    #[test]
    fn single_point_is_front() {
        let front = pareto_front(&[vec![1.0, 1.0]], &[Maximize, Minimize]);
        assert_eq!(front, vec![0]);
    }

    #[test]
    fn all_equal_points_kept() {
        let points = vec![vec![1.0, 1.0]; 3];
        let front = pareto_front(&points, &[Maximize, Minimize]);
        assert_eq!(front.len(), 3);
    }

    #[test]
    fn orientation_flip_flips_front() {
        let points = vec![vec![1.0, 1.0], vec![2.0, 2.0]];
        let max_both = pareto_front(&points, &[Maximize, Maximize]);
        assert_eq!(max_both, vec![1]);
        let min_both = pareto_front(&points, &[Minimize, Minimize]);
        assert_eq!(min_both, vec![0]);
    }

    #[test]
    fn three_axis_dominance() {
        let o = [Maximize, Minimize, Maximize];
        assert!(dominates(&[2.0, 1.0, 5.0], &[2.0, 1.0, 4.0], &o));
        assert!(!dominates(&[2.0, 1.0, 4.0], &[2.0, 1.0, 5.0], &o));
    }

    #[test]
    fn front_sorted_by_first_axis() {
        let points = vec![vec![3.0, 4.0], vec![1.0, 1.0], vec![2.0, 2.0]];
        let front = pareto_front(&points, &[Maximize, Minimize]);
        let xs: Vec<f64> = front.iter().map(|&i| points[i][0]).collect();
        assert_eq!(xs, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn engine_agrees_with_reference_on_tie_heavy_input() {
        // Duplicates, first-axis ties, and three axes — the cases where
        // ordering subtleties would show up first.
        let points = vec![
            vec![1.0, 2.0, 3.0],
            vec![1.0, 2.0, 3.0],
            vec![1.0, 1.0, 4.0],
            vec![2.0, 2.0, 2.0],
            vec![0.5, 0.5, 0.5],
        ];
        let o = [Maximize, Minimize, Maximize];
        assert_eq!(pareto_front(&points, &o), pareto_front_reference(&points, &o));
    }

    #[test]
    fn empty_input_is_empty_front() {
        assert!(pareto_front(&[], &[Maximize]).is_empty());
    }
}
