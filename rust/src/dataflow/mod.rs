//! Analytical dataflow mapper: maps DNN layers onto the PE array and counts
//! cycles, utilization, and per-level memory accesses (the paper's Fig. 1
//! outputs: "statistics on hardware utilization and memory accesses").
//!
//! The primary dataflow is **row stationary** (Eyeriss, §III-A): a strip of
//! `R` PEs computes one output row by sliding filter rows over ifmap rows;
//! strips replicate vertically across the array, output rows spread across
//! columns. The mapping is sensitive to every swept knob: array dims set
//! spatial parallelism, scratchpad sizes set temporal reuse (tile residency),
//! GLB size sets DRAM refetch, bit precision sets traffic bytes.
//!
//! [`alt`] provides weight-stationary and output-stationary mappers for the
//! paper's "RS optimizes data movement" ablation.

pub mod alt;
pub mod network;

pub use network::{map_model, map_model_stats, MappingTotals, ModelMapping};

use crate::arch::AcceleratorConfig;
use crate::dnn::{Layer, LayerKind};
use crate::util::ceil_div;

/// GLB service bandwidth in bytes/cycle: four 128-bit banked ports
/// (Eyeriss-class global buffers are multi-banked precisely so the array
/// does not starve).
pub const GLB_BYTES_PER_CYCLE: f64 = 64.0;

/// Which dataflow mapped a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Eyeriss-style row-stationary (the paper's dataflow).
    RowStationary,
    /// Weights pinned in the array, activations streamed.
    WeightStationary,
    /// Output partial sums pinned, inputs streamed.
    OutputStationary,
}

impl Dataflow {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Dataflow::RowStationary => "row-stationary",
            Dataflow::WeightStationary => "weight-stationary",
            Dataflow::OutputStationary => "output-stationary",
        }
    }
}

/// Access counts at one storage level (element granularity).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AccessCounts {
    /// Element reads at this level.
    pub reads: u64,
    /// Element writes at this level.
    pub writes: u64,
}

impl AccessCounts {
    /// Total accesses.
    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }
}

/// Per-level traffic statistics for one mapped layer.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TrafficStats {
    /// Per-PE scratchpad accesses (all three spads combined).
    pub spad: AccessCounts,
    /// Global buffer accesses.
    pub glb: AccessCounts,
    /// Of `glb.reads`, how many move *weights* (they cost `weight_bits`
    /// per element, not `act_bits` — the 4-bit LightPE-1 weights are 4×
    /// cheaper per element than INT16's).
    pub glb_weight_reads: u64,
    /// DRAM traffic in **bytes** (precision-dependent).
    pub dram_bytes: u64,
}

/// The mapper's result for one layer on one configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMapping {
    /// Name of the mapped layer.
    pub layer_name: String,
    /// Dataflow that produced this mapping.
    pub dataflow: Dataflow,
    /// MACs in the layer.
    pub macs: u64,
    /// Cycles to execute the layer (compute- or bandwidth-bound).
    pub cycles: u64,
    /// Compute-only cycles (no bandwidth stall).
    pub compute_cycles: u64,
    /// Average PE-array utilization in [0, 1]: MACs / (cycles × PEs).
    pub utilization: f64,
    /// Traffic statistics.
    pub traffic: TrafficStats,
    /// Tiling detail: (m_tiles, c_tiles, e_tiles) temporal tile counts.
    pub tiles: (usize, usize, usize),
}

impl LayerMapping {
    /// Latency in seconds at a clock (GHz).
    pub fn latency_s(&self, clock_ghz: f64) -> f64 {
        self.cycles as f64 / (clock_ghz * 1e9)
    }

    /// The label-free statistics view of this mapping.
    pub fn stats(&self) -> LayerStats {
        LayerStats {
            dataflow: self.dataflow,
            macs: self.macs,
            cycles: self.cycles,
            compute_cycles: self.compute_cycles,
            utilization: self.utilization,
            traffic: self.traffic,
            tiles: self.tiles,
        }
    }
}

/// Per-layer mapping statistics without the identifying label — a `Copy`
/// value, so the DSE hot loop ([`network::map_model_stats`]) aggregates
/// layer results with zero heap allocation. [`map_layer_rs`] wraps one
/// with the layer name for the reporting paths; the numbers are produced
/// by the exact same code either way.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerStats {
    /// Dataflow that produced this mapping.
    pub dataflow: Dataflow,
    /// MACs in the layer.
    pub macs: u64,
    /// Cycles to execute the layer (compute- or bandwidth-bound).
    pub cycles: u64,
    /// Compute-only cycles (no bandwidth stall).
    pub compute_cycles: u64,
    /// Average PE-array utilization in [0, 1]: MACs / (cycles × PEs).
    pub utilization: f64,
    /// Traffic statistics.
    pub traffic: TrafficStats,
    /// Tiling detail: (m_tiles, c_tiles, e_tiles) temporal tile counts.
    pub tiles: (usize, usize, usize),
}

impl LayerStats {
    /// Attach a layer name, producing the full [`LayerMapping`] record.
    pub fn named(self, layer_name: String) -> LayerMapping {
        LayerMapping {
            layer_name,
            dataflow: self.dataflow,
            macs: self.macs,
            cycles: self.cycles,
            compute_cycles: self.compute_cycles,
            utilization: self.utilization,
            traffic: self.traffic,
            tiles: self.tiles,
        }
    }
}

/// Map one layer with the row-stationary dataflow.
///
/// Pooling layers do no MACs but still move their feature maps through the
/// hierarchy; they are modeled as pure traffic.
pub fn map_layer_rs(layer: &Layer, config: &AcceleratorConfig) -> LayerMapping {
    map_layer_rs_stats(layer, config).named(layer.name.clone())
}

/// [`map_layer_rs`] without the name allocation — the hot-path entry.
pub fn map_layer_rs_stats(layer: &Layer, config: &AcceleratorConfig) -> LayerStats {
    if layer.kind == LayerKind::Pool {
        return map_pool_stats(layer, config);
    }
    let r = layer.kernel; // filter rows (= S columns; square)
    let s = layer.kernel;
    let e = layer.out_hw(); // output rows
    let f = layer.out_hw(); // output columns
    let c = layer.in_c;
    let m = layer.out_c;
    let macs = layer.macs();

    // --- Spatial mapping -------------------------------------------------
    // A strip of R PEs produces one output row for one (m, c) pair; strips
    // stack vertically, output rows spread across columns.
    let strip_height = r.min(config.rows);
    let r_folds = ceil_div(r, strip_height); // temporal fold if R > rows
    let strips = (config.rows / strip_height).max(1);
    let e_spatial = e.min(config.cols);

    // --- Temporal tiling (scratchpad residency) --------------------------
    // Filter spad holds `filter_entries` weights per PE: filter *rows* of S
    // weights, one row per resident (m, c) pair. Channels co-resident come
    // from the ifmap spad; the m-extent is what residency is left after
    // covering those channels.
    let rows_resident_per_pe = (config.spad.filter_entries / s.max(1)).max(1);
    let c_resident = (config.spad.ifmap_entries / s.max(1)).max(1).min(c.max(1));
    let c_tiles = ceil_div(c, c_resident);
    let mc_resident = strips * rows_resident_per_pe;
    let m_resident = (mc_resident / c_resident).max(1).min(m.max(1));
    let m_tiles = ceil_div(m, m_resident);
    // Psum spad bounds the output-row chunk a strip accumulates locally.
    let f_chunk = config.spad.psum_entries.min(f.max(1)).max(1);
    let f_spills = ceil_div(f, f_chunk); // chunks per output row
    let e_tiles = ceil_div(e, e_spatial);

    // --- Cycles -----------------------------------------------------------
    // Each pass: active strips × e_spatial PEs compute F×S MACs per
    // primitive; passes cover (m × c) pairs and output-row tiles.
    let mc_per_pass = strips;
    let passes = ceil_div(m * c, mc_per_pass) as u64 * e_tiles as u64 * r_folds as u64;
    let compute_cycles = passes * (f as u64) * (s as u64);
    // Boundary waste is captured by the ceil terms; utilization follows.

    // --- Traffic ----------------------------------------------------------
    // Scratchpad: ifmap read + filter read + psum read&write per MAC, plus
    // spad fill writes (one write per element entering the spad from GLB).
    let spad_reads = 3 * macs; // ifmap + filter + psum read
    let spad_writes = macs; // psum write
    // GLB→spad fills, with reuse: ifmap rows broadcast once per m-tile;
    // filters re-fetched once per output-row tile; psums spill when channel
    // accumulation is interrupted (c_tiles > 1) or rows chunk (f_spills).
    let ifmap_glb_reads = layer.ifmap_elems() * m_tiles as u64;
    let filter_glb_reads = layer.weights() * e_tiles as u64;
    let psum_spill_rounds = (c_tiles as u64 - 1) + (f_spills as u64 - 1);
    let psum_glb_writes = layer.ofmap_elems() * (psum_spill_rounds + 1);
    let psum_glb_reads = layer.ofmap_elems() * psum_spill_rounds;
    let glb = AccessCounts {
        reads: ifmap_glb_reads + filter_glb_reads + psum_glb_reads,
        writes: psum_glb_writes + ifmap_glb_reads + filter_glb_reads, // fills written into GLB once
    };
    let spad = AccessCounts {
        reads: spad_reads,
        writes: spad_writes + ifmap_glb_reads + filter_glb_reads,
    };

    // DRAM: ifmap + weights + ofmap move once if the GLB can cache the
    // ifmap alongside one filter tile across the m-tile passes; otherwise
    // the ifmap is re-fetched from DRAM for every filter tile.
    let act_bytes = |elems: u64| elems * config.pe.act_bits() as u64 / 8;
    let w_bytes = |elems: u64| (elems * config.pe.weight_bits() as u64).div_ceil(8);
    let cached_set_bytes = act_bytes(layer.ifmap_elems())
        + w_bytes(layer.weights() / m_tiles.max(1) as u64);
    let ifmap_refetch =
        if cached_set_bytes <= config.glb_bytes() as u64 { 1 } else { m_tiles as u64 };
    let dram_bytes = act_bytes(layer.ifmap_elems()) * ifmap_refetch
        + w_bytes(layer.weights())
        + act_bytes(layer.ofmap_elems());

    // --- Bandwidth bounds ---------------------------------------------------
    // DRAM: the configured off-chip bandwidth.
    let bw_bytes_per_cycle = config.dram_bw_gbps / config.clock_ghz; // GB/s ÷ Gcycle/s
    let dram_cycles = (dram_bytes as f64 / bw_bytes_per_cycle).ceil() as u64;
    // GLB: a banked buffer serves GLB_BYTES_PER_CYCLE across its ports;
    // designs with tiny scratchpads hammer the GLB and stall here — the
    // physical cost of trading spad area for traffic.
    let glb_bytes_moved =
        glb.total() as f64 * config.pe.act_bits() as f64 / 8.0;
    let glb_cycles = (glb_bytes_moved / GLB_BYTES_PER_CYCLE).ceil() as u64;
    let cycles = compute_cycles.max(dram_cycles).max(glb_cycles).max(1);
    let utilization = macs as f64 / (cycles as f64 * config.num_pes() as f64);

    LayerStats {
        dataflow: Dataflow::RowStationary,
        macs,
        cycles,
        compute_cycles,
        utilization,
        traffic: TrafficStats { spad, glb, glb_weight_reads: filter_glb_reads, dram_bytes },
        tiles: (m_tiles, c_tiles, e_tiles),
    }
}

/// Pooling: no MACs; feature map streams GLB↔DRAM and through the array.
fn map_pool_stats(layer: &Layer, config: &AcceleratorConfig) -> LayerStats {
    let act_bytes = |elems: u64| elems * config.pe.act_bits() as u64 / 8;
    let dram_bytes = act_bytes(layer.ifmap_elems()) + act_bytes(layer.ofmap_elems());
    let glb = AccessCounts { reads: layer.ifmap_elems(), writes: layer.ofmap_elems() };
    // Pool compares/averages at one element per PE per cycle.
    let compute_cycles =
        ceil_div(layer.ifmap_elems() as usize, config.num_pes()).max(1) as u64;
    let bw_bytes_per_cycle = config.dram_bw_gbps / config.clock_ghz;
    let dram_cycles = (dram_bytes as f64 / bw_bytes_per_cycle).ceil() as u64;
    let cycles = compute_cycles.max(dram_cycles).max(1);
    LayerStats {
        dataflow: Dataflow::RowStationary,
        macs: 0,
        cycles,
        compute_cycles,
        utilization: 0.0,
        traffic: TrafficStats { spad: AccessCounts::default(), glb, glb_weight_reads: 0, dram_bytes },
        tiles: (1, 1, 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ScratchpadCfg;
    use crate::quant::PeType;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    fn conv() -> Layer {
        Layer::conv("c", 32, 16, 32, 3, 1, 1)
    }

    #[test]
    fn utilization_in_unit_interval() {
        let mapping = map_layer_rs(&conv(), &cfg());
        assert!(mapping.utilization > 0.0 && mapping.utilization <= 1.0);
    }

    #[test]
    fn cycles_lower_bounded_by_ideal() {
        let mapping = map_layer_rs(&conv(), &cfg());
        let ideal = mapping.macs / cfg().num_pes() as u64;
        assert!(mapping.cycles >= ideal, "cycles {} < ideal {}", mapping.cycles, ideal);
    }

    #[test]
    fn bigger_array_fewer_cycles() {
        let small = map_layer_rs(&conv(), &AcceleratorConfig { rows: 8, cols: 8, ..cfg() });
        let big = map_layer_rs(&conv(), &AcceleratorConfig { rows: 32, cols: 32, ..cfg() });
        assert!(big.cycles < small.cycles);
    }

    #[test]
    fn bigger_filter_spad_fewer_ifmap_refetches() {
        let small_spad = AcceleratorConfig {
            spad: ScratchpadCfg { ifmap_entries: 12, filter_entries: 6, psum_entries: 24 },
            ..cfg()
        };
        let large_spad = AcceleratorConfig {
            spad: ScratchpadCfg { ifmap_entries: 12, filter_entries: 448, psum_entries: 24 },
            ..cfg()
        };
        let a = map_layer_rs(&conv(), &small_spad);
        let b = map_layer_rs(&conv(), &large_spad);
        assert!(
            b.traffic.glb.reads < a.traffic.glb.reads,
            "bigger filter spad must cut GLB traffic: {} vs {}",
            b.traffic.glb.reads,
            a.traffic.glb.reads
        );
    }

    #[test]
    fn lower_precision_less_dram_traffic() {
        let int16 = map_layer_rs(&conv(), &AcceleratorConfig { pe: PeType::Int16, ..cfg() });
        let light1 = map_layer_rs(&conv(), &AcceleratorConfig { pe: PeType::LightPe1, ..cfg() });
        assert!(light1.traffic.dram_bytes < int16.traffic.dram_bytes / 2 + 1);
    }

    #[test]
    fn small_glb_forces_refetch() {
        // Big ImageNet-ish layer with a tiny GLB must refetch the ifmap.
        let layer = Layer::conv("big", 56, 256, 256, 3, 1, 1);
        let tiny_glb = AcceleratorConfig { glb_kib: 16, ..cfg() };
        let big_glb = AcceleratorConfig { glb_kib: 4096, ..cfg() };
        let a = map_layer_rs(&layer, &tiny_glb);
        let b = map_layer_rs(&layer, &big_glb);
        assert!(a.traffic.dram_bytes > b.traffic.dram_bytes);
    }

    #[test]
    fn bandwidth_bound_when_starved() {
        let starved = AcceleratorConfig { dram_bw_gbps: 0.05, ..cfg() };
        let mapping = map_layer_rs(&conv(), &starved);
        assert!(mapping.cycles > mapping.compute_cycles);
        assert!(mapping.utilization < 0.5);
    }

    #[test]
    fn spad_traffic_scales_with_macs() {
        let mapping = map_layer_rs(&conv(), &cfg());
        assert!(mapping.traffic.spad.reads >= 3 * mapping.macs);
        assert!(mapping.traffic.spad.writes >= mapping.macs);
    }

    #[test]
    fn fc_layer_maps() {
        let fc = Layer::fc("fc", 512, 10);
        let mapping = map_layer_rs(&fc, &cfg());
        assert_eq!(mapping.macs, 5120);
        assert!(mapping.cycles > 0);
    }

    #[test]
    fn pool_layer_pure_traffic() {
        let pool = Layer::pool("p", 32, 64, 2, 2);
        let mapping = map_layer_rs(&pool, &cfg());
        assert_eq!(mapping.macs, 0);
        assert!(mapping.traffic.dram_bytes > 0);
        assert_eq!(mapping.utilization, 0.0);
    }

    #[test]
    fn stats_path_is_bit_identical_to_named_path() {
        for layer in [conv(), Layer::pool("p", 32, 64, 2, 2), Layer::fc("fc", 512, 10)] {
            let named = map_layer_rs(&layer, &cfg());
            let stats = map_layer_rs_stats(&layer, &cfg());
            assert_eq!(named.stats(), stats);
            assert_eq!(stats.named(layer.name.clone()), named);
        }
    }

    #[test]
    fn kernel_larger_than_array_folds() {
        // 7×7 stem on an 4-row array: R folds temporally, still completes.
        let stem = Layer::conv("stem", 224, 3, 64, 7, 2, 3);
        let narrow = AcceleratorConfig { rows: 4, cols: 16, ..cfg() };
        let mapping = map_layer_rs(&stem, &narrow);
        assert!(mapping.cycles > 0);
        assert!(mapping.utilization <= 1.0);
    }
}
