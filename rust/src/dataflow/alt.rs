//! Alternative dataflows for the ablation bench (§III-A claims RS optimizes
//! data movement; `benches/ablations.rs` quantifies that against these).
//!
//! Both mappers share the RS compute model (spatial parallelism is the
//! array, one MAC/PE/cycle) but differ in *which* operand stays resident,
//! which changes the per-level traffic exactly as in the Eyeriss taxonomy:
//!
//! * **Weight stationary (WS)**: weights pinned in PE registers; every psum
//!   streams through the array to the GLB (no local psum accumulation) and
//!   ifmaps are re-broadcast per filter pass.
//! * **Output stationary (OS)**: psums pinned; weights stream from GLB every
//!   cycle-group (no filter residency), ifmaps stream with modest reuse.

use super::{map_layer_rs_stats, AccessCounts, Dataflow, LayerMapping, LayerStats};
use crate::arch::AcceleratorConfig;
use crate::dnn::{Layer, LayerKind};
use crate::util::ceil_div;

/// Map one layer with the weight-stationary dataflow.
pub fn map_layer_ws(layer: &Layer, config: &AcceleratorConfig) -> LayerMapping {
    map_layer_ws_stats(layer, config).named(layer.name.clone())
}

/// [`map_layer_ws`] without the name allocation.
pub fn map_layer_ws_stats(layer: &Layer, config: &AcceleratorConfig) -> LayerStats {
    let mut mapping = base(layer, config, Dataflow::WeightStationary);
    if layer.kind == LayerKind::Pool {
        return mapping;
    }
    let s = layer.kernel;
    let taps = (s * s) as u64;
    // Weights resident: one tap per PE → weights load once per (m,c) group
    // rotation; total weight GLB reads = weights × 1.
    let weight_glb = layer.weights();
    // Ifmap: re-broadcast once per resident filter group.
    let m_resident = (config.num_pes() / taps.max(1) as usize).max(1).min(layer.out_c);
    let m_tiles = ceil_div(layer.out_c, m_resident) as u64;
    let ifmap_glb = layer.ifmap_elems() * m_tiles;
    // Psum: streams to GLB every tap — the WS tax: C×taps partial updates
    // per output element flow through the GLB hierarchy (accumulated in a
    // GLB-side adder tree every `taps` values → ofmap × C round trips).
    let psum_glb_writes = layer.ofmap_elems() * layer.in_c as u64;
    let psum_glb_reads = layer.ofmap_elems() * (layer.in_c as u64 - 1);
    mapping.traffic.glb = AccessCounts {
        reads: ifmap_glb + weight_glb + psum_glb_reads,
        writes: psum_glb_writes + ifmap_glb + weight_glb,
    };
    mapping.traffic.glb_weight_reads = weight_glb;
    // Spad traffic: no psum spad use; ifmap + weight register reads only.
    mapping.traffic.spad = AccessCounts { reads: 2 * mapping.macs, writes: ifmap_glb + weight_glb };
    mapping.tiles = (m_tiles as usize, 1, 1);
    finish(mapping, layer, config, ifmap_glb, weight_glb)
}

/// Map one layer with the output-stationary dataflow.
pub fn map_layer_os(layer: &Layer, config: &AcceleratorConfig) -> LayerMapping {
    map_layer_os_stats(layer, config).named(layer.name.clone())
}

/// [`map_layer_os`] without the name allocation.
pub fn map_layer_os_stats(layer: &Layer, config: &AcceleratorConfig) -> LayerStats {
    let mut mapping = base(layer, config, Dataflow::OutputStationary);
    if layer.kind == LayerKind::Pool {
        return mapping;
    }
    // Outputs pinned: each PE owns output pixels; psum never leaves.
    let psum_glb_writes = layer.ofmap_elems();
    // Weights stream every reuse-group: re-read once per output tile.
    let out_tiles = ceil_div(layer.ofmap_elems() as usize, config.num_pes()) as u64;
    let weight_glb = layer.weights() * out_tiles;
    // Ifmap: neighboring outputs share rows — reuse ≈ kernel height.
    let ifmap_glb = layer.ifmap_elems() * ceil_div(layer.kernel, 1) as u64;
    mapping.traffic.glb = AccessCounts {
        reads: ifmap_glb + weight_glb,
        writes: psum_glb_writes + ifmap_glb + weight_glb,
    };
    mapping.traffic.glb_weight_reads = weight_glb;
    mapping.traffic.spad =
        AccessCounts { reads: 3 * mapping.macs, writes: mapping.macs + ifmap_glb + weight_glb };
    mapping.tiles = (out_tiles as usize, 1, 1);
    finish(mapping, layer, config, ifmap_glb, weight_glb)
}

/// Dispatch by dataflow (RS delegates to the primary mapper).
pub fn map_layer(dataflow: Dataflow, layer: &Layer, config: &AcceleratorConfig) -> LayerMapping {
    map_layer_stats(dataflow, layer, config).named(layer.name.clone())
}

/// [`map_layer`] without the name allocation — the hot-path dispatch.
pub fn map_layer_stats(
    dataflow: Dataflow,
    layer: &Layer,
    config: &AcceleratorConfig,
) -> LayerStats {
    match dataflow {
        Dataflow::RowStationary => map_layer_rs_stats(layer, config),
        Dataflow::WeightStationary => map_layer_ws_stats(layer, config),
        Dataflow::OutputStationary => map_layer_os_stats(layer, config),
    }
}

/// Shared compute model: same cycles as RS (the dataflows differ in traffic,
/// not peak MACs/cycle), so traffic effects isolate cleanly in the ablation.
fn base(layer: &Layer, config: &AcceleratorConfig, dataflow: Dataflow) -> LayerStats {
    let mut mapping = map_layer_rs_stats(layer, config);
    mapping.dataflow = dataflow;
    mapping
}

/// Recompute DRAM traffic and the bandwidth bound after traffic edits.
fn finish(
    mut mapping: LayerStats,
    layer: &Layer,
    config: &AcceleratorConfig,
    ifmap_glb: u64,
    weight_glb: u64,
) -> LayerStats {
    let act_bytes = |elems: u64| elems * config.pe.act_bits() as u64 / 8;
    let w_bytes = |elems: u64| (elems * config.pe.weight_bits() as u64).div_ceil(8);
    // DRAM refetch mirrors GLB refetch when the working set spills.
    let working_set = act_bytes(layer.ifmap_elems()) + w_bytes(layer.weights());
    let spill = working_set > config.glb_bytes() as u64;
    let ifmap_factor = if spill { ifmap_glb.div_ceil(layer.ifmap_elems().max(1)) } else { 1 };
    let weight_factor = if spill { weight_glb.div_ceil(layer.weights().max(1)) } else { 1 };
    mapping.traffic.dram_bytes = act_bytes(layer.ifmap_elems()) * ifmap_factor
        + w_bytes(layer.weights()) * weight_factor
        + act_bytes(layer.ofmap_elems());
    let bw_bytes_per_cycle = config.dram_bw_gbps / config.clock_ghz;
    let dram_cycles = (mapping.traffic.dram_bytes as f64 / bw_bytes_per_cycle).ceil() as u64;
    mapping.cycles = mapping.compute_cycles.max(dram_cycles).max(1);
    mapping.utilization = mapping.macs as f64 / (mapping.cycles as f64 * config.num_pes() as f64);
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::PeType;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig { pe: PeType::Int16, ..AcceleratorConfig::default() }
    }

    fn conv() -> Layer {
        Layer::conv("c", 32, 32, 64, 3, 1, 1)
    }

    #[test]
    fn rs_moves_least_glb_data() {
        // The paper's §III-A claim, and Eyeriss's: RS minimizes overall
        // hierarchy traffic vs WS and OS for conv layers.
        let rs = map_layer(Dataflow::RowStationary, &conv(), &cfg());
        let ws = map_layer(Dataflow::WeightStationary, &conv(), &cfg());
        let os = map_layer(Dataflow::OutputStationary, &conv(), &cfg());
        assert!(
            rs.traffic.glb.total() < ws.traffic.glb.total(),
            "RS {} vs WS {}",
            rs.traffic.glb.total(),
            ws.traffic.glb.total()
        );
        assert!(
            rs.traffic.glb.total() < os.traffic.glb.total(),
            "RS {} vs OS {}",
            rs.traffic.glb.total(),
            os.traffic.glb.total()
        );
    }

    #[test]
    fn ws_psum_traffic_dominates() {
        let ws = map_layer(Dataflow::WeightStationary, &conv(), &cfg());
        // WS streams C partial updates per output element.
        let conv_layer = conv();
        assert!(ws.traffic.glb.writes >= conv_layer.ofmap_elems() * conv_layer.in_c as u64);
    }

    #[test]
    fn os_never_spills_psums() {
        let os = map_layer(Dataflow::OutputStationary, &conv(), &cfg());
        let rs = map_layer(Dataflow::RowStationary, &conv(), &cfg());
        // OS writes each output exactly once; RS may spill.
        let conv_layer = conv();
        let os_psum_writes = conv_layer.ofmap_elems();
        assert!(os.traffic.glb.writes >= os_psum_writes);
        let _ = rs;
    }

    #[test]
    fn all_dataflows_same_macs() {
        for df in [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary]
        {
            assert_eq!(map_layer(df, &conv(), &cfg()).macs, conv().macs());
        }
    }

    #[test]
    fn dataflow_tags_propagate() {
        assert_eq!(
            map_layer(Dataflow::WeightStationary, &conv(), &cfg()).dataflow,
            Dataflow::WeightStationary
        );
        assert_eq!(
            map_layer(Dataflow::OutputStationary, &conv(), &cfg()).dataflow,
            Dataflow::OutputStationary
        );
    }

    #[test]
    fn stats_dispatch_is_bit_identical_to_named_dispatch() {
        for df in [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary]
        {
            for layer in [conv(), Layer::pool("p", 32, 64, 2, 2)] {
                let named = map_layer(df, &layer, &cfg());
                let stats = map_layer_stats(df, &layer, &cfg());
                assert_eq!(named.stats(), stats, "{df:?} {}", layer.name);
            }
        }
    }

    #[test]
    fn pool_layers_identical_across_dataflows() {
        let pool = Layer::pool("p", 32, 64, 2, 2);
        let rs = map_layer(Dataflow::RowStationary, &pool, &cfg());
        let ws = map_layer(Dataflow::WeightStationary, &pool, &cfg());
        assert_eq!(rs.traffic.dram_bytes, ws.traffic.dram_bytes);
    }
}
