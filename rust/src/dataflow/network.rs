//! Whole-network mapping: run the layer mapper over a model and aggregate.

use super::{
    alt::{map_layer, map_layer_stats},
    Dataflow, LayerMapping, TrafficStats,
};
use crate::arch::AcceleratorConfig;
use crate::dnn::Model;

/// Aggregate mapping totals without the model label — a `Copy` value, so
/// the DSE hot path ([`map_model_stats`]) carries a whole model's mapping
/// result with zero heap allocation. [`ModelMapping`] is this plus
/// identity (and optionally per-layer records) for the reporting paths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MappingTotals {
    /// Dataflow that produced this mapping.
    pub dataflow: Dataflow,
    /// MACs per inference, summed over compute layers.
    pub total_macs: u64,
    /// End-to-end cycles per inference.
    pub total_cycles: u64,
    /// Aggregated memory traffic across all layers.
    pub traffic: TrafficStats,
    /// MAC-weighted average utilization.
    pub avg_utilization: f64,
}

impl MappingTotals {
    /// End-to-end inference latency (s) at a clock (GHz).
    pub fn latency_s(&self, clock_ghz: f64) -> f64 {
        self.total_cycles as f64 / (clock_ghz * 1e9)
    }

    /// Throughput in inferences/s at a clock (GHz).
    pub fn inferences_per_s(&self, clock_ghz: f64) -> f64 {
        1.0 / self.latency_s(clock_ghz)
    }

    /// Effective GMAC/s at a clock (GHz).
    pub fn effective_gmacs(&self, clock_ghz: f64) -> f64 {
        self.total_macs as f64 / self.latency_s(clock_ghz) / 1e9
    }

    /// Attach a model name, producing a totals-only [`ModelMapping`].
    pub fn named(self, model_name: String) -> ModelMapping {
        ModelMapping {
            model_name,
            dataflow: self.dataflow,
            layers: Vec::new(),
            total_macs: self.total_macs,
            total_cycles: self.total_cycles,
            traffic: self.traffic,
            avg_utilization: self.avg_utilization,
        }
    }
}

/// Aggregated mapping of a full model on one configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMapping {
    /// Name of the mapped model.
    pub model_name: String,
    /// Dataflow that produced this mapping.
    pub dataflow: Dataflow,
    /// Per-layer mappings (empty on the totals-only fast path).
    pub layers: Vec<LayerMapping>,
    /// MACs per inference, summed over compute layers.
    pub total_macs: u64,
    /// End-to-end cycles per inference.
    pub total_cycles: u64,
    /// Aggregated memory traffic across all layers.
    pub traffic: TrafficStats,
    /// MAC-weighted average utilization.
    pub avg_utilization: f64,
}

impl ModelMapping {
    /// End-to-end inference latency (s) at a clock (GHz).
    pub fn latency_s(&self, clock_ghz: f64) -> f64 {
        self.total_cycles as f64 / (clock_ghz * 1e9)
    }

    /// Throughput in inferences/s at a clock (GHz).
    pub fn inferences_per_s(&self, clock_ghz: f64) -> f64 {
        1.0 / self.latency_s(clock_ghz)
    }

    /// Effective GMAC/s at a clock (GHz).
    pub fn effective_gmacs(&self, clock_ghz: f64) -> f64 {
        self.total_macs as f64 / self.latency_s(clock_ghz) / 1e9
    }

    /// The label-free totals view of this mapping.
    pub fn totals(&self) -> MappingTotals {
        MappingTotals {
            dataflow: self.dataflow,
            total_macs: self.total_macs,
            total_cycles: self.total_cycles,
            traffic: self.traffic,
            avg_utilization: self.avg_utilization,
        }
    }
}

/// Map every layer of `model` and aggregate **totals only**, with zero
/// heap allocation — the DSE hot-path variant. No per-layer records or
/// name `String`s are materialized: each layer contributes a `Copy`
/// [`super::LayerStats`] (the earlier totals-only path still cloned one
/// layer-name `String` per layer; ≈35% of campaign time went to the full
/// per-layer records before that — EXPERIMENTS.md §Perf).
pub fn map_model_stats(
    model: &Model,
    config: &AcceleratorConfig,
    dataflow: Dataflow,
) -> MappingTotals {
    let mut total_macs = 0u64;
    let mut total_cycles = 0u64;
    let mut traffic = TrafficStats::default();
    for layer in &model.layers {
        let m = map_layer_stats(dataflow, layer, config);
        total_macs += m.macs;
        total_cycles += m.cycles;
        traffic.spad.reads += m.traffic.spad.reads;
        traffic.spad.writes += m.traffic.spad.writes;
        traffic.glb.reads += m.traffic.glb.reads;
        traffic.glb.writes += m.traffic.glb.writes;
        traffic.glb_weight_reads += m.traffic.glb_weight_reads;
        traffic.dram_bytes += m.traffic.dram_bytes;
    }
    let avg_utilization = if total_cycles == 0 {
        0.0
    } else {
        total_macs as f64 / (total_cycles as f64 * config.num_pes() as f64)
    };
    MappingTotals { dataflow, total_macs, total_cycles, traffic, avg_utilization }
}

/// Map every layer of `model` and aggregate **totals only** — the
/// historical totals entry point, now a thin wrapper over
/// [`map_model_stats`] that attaches the model name (`layers` stays
/// empty).
pub fn map_model_totals(
    model: &Model,
    config: &AcceleratorConfig,
    dataflow: Dataflow,
) -> ModelMapping {
    map_model_stats(model, config, dataflow).named(model.name.clone())
}

/// Map every layer of `model` and aggregate.
pub fn map_model(model: &Model, config: &AcceleratorConfig, dataflow: Dataflow) -> ModelMapping {
    let layers: Vec<LayerMapping> =
        model.layers.iter().map(|l| map_layer(dataflow, l, config)).collect();
    let total_macs = layers.iter().map(|m| m.macs).sum();
    let total_cycles = layers.iter().map(|m| m.cycles).sum();
    let traffic = layers.iter().fold(TrafficStats::default(), |mut acc, m| {
        acc.spad.reads += m.traffic.spad.reads;
        acc.spad.writes += m.traffic.spad.writes;
        acc.glb.reads += m.traffic.glb.reads;
        acc.glb.writes += m.traffic.glb.writes;
        acc.glb_weight_reads += m.traffic.glb_weight_reads;
        acc.dram_bytes += m.traffic.dram_bytes;
        acc
    });
    let avg_utilization = if total_cycles == 0 {
        0.0
    } else {
        total_macs as f64 / (total_cycles as f64 * config.num_pes() as f64)
    };
    ModelMapping {
        model_name: model.name.clone(),
        dataflow,
        layers,
        total_macs,
        total_cycles,
        traffic,
        avg_utilization,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{model_for, Dataset, ModelKind};

    #[test]
    fn aggregates_are_sums() {
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let config = AcceleratorConfig::default();
        let mapping = map_model(&model, &config, Dataflow::RowStationary);
        assert_eq!(mapping.total_macs, model.total_macs());
        assert_eq!(mapping.layers.len(), model.layers.len());
        let cycle_sum: u64 = mapping.layers.iter().map(|l| l.cycles).sum();
        assert_eq!(mapping.total_cycles, cycle_sum);
    }

    #[test]
    fn latency_and_throughput_consistent() {
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let mapping = map_model(&model, &AcceleratorConfig::default(), Dataflow::RowStationary);
        let latency = mapping.latency_s(1.0);
        assert!(latency > 0.0);
        let throughput = mapping.inferences_per_s(1.0);
        assert!((throughput * latency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_bounded() {
        for kind in [ModelKind::Vgg16, ModelKind::ResNet20, ModelKind::ResNet56] {
            let model = model_for(kind, Dataset::Cifar10);
            let mapping =
                map_model(&model, &AcceleratorConfig::default(), Dataflow::RowStationary);
            assert!(mapping.avg_utilization > 0.0 && mapping.avg_utilization <= 1.0);
        }
    }

    #[test]
    fn stats_path_matches_full_mapping_bit_for_bit() {
        let model = model_for(ModelKind::ResNet56, Dataset::Cifar10);
        let config = AcceleratorConfig::default();
        for df in
            [Dataflow::RowStationary, Dataflow::WeightStationary, Dataflow::OutputStationary]
        {
            let full = map_model(&model, &config, df);
            let stats = map_model_stats(&model, &config, df);
            assert_eq!(full.totals(), stats, "{df:?}");
            let totals = map_model_totals(&model, &config, df);
            assert_eq!(totals.totals(), stats, "{df:?}");
            assert_eq!(totals.model_name, model.name);
            assert!(totals.layers.is_empty());
        }
    }

    #[test]
    fn imagenet_models_map() {
        let model = model_for(ModelKind::ResNet50, Dataset::ImageNet);
        let mapping = map_model(&model, &AcceleratorConfig::default(), Dataflow::RowStationary);
        assert!(mapping.total_cycles > 1_000_000, "ResNet-50 should be millions of cycles");
        assert!(mapping.traffic.dram_bytes > model.total_weights());
    }
}
