//! # QADAM — Quantization-Aware DNN Accelerator Modeling for Pareto-Optimality
//!
//! A reproduction of the QADAM framework (Inci et al., cs.AR 2022): a highly
//! parameterized, quantization-aware power, performance, and area (PPA)
//! modeling and design-space-exploration framework for spatial-array DNN
//! accelerators.
//!
//! The crate is organized as substrates (technology models, a synthesis
//! engine, an RTL generator, a cycle-level simulator), the analytical core
//! (row-stationary dataflow mapper, energy model, polynomial PPA surrogates),
//! and the exploration layer (the unified [`explore::Explorer`] API, the
//! online [`pareto`] engine with pluggable search strategies, a
//! leader/worker coordinator, and a PJRT runtime that executes the
//! AOT-compiled JAX/Pallas quantization-aware training artifacts).
//!
//! Every DSE campaign — CLI, report generator, benches, examples — goes
//! through [`explore::Explorer`]; fallible APIs return the crate-wide
//! typed [`Error`]. Design spaces are *joint*: an
//! [`arch::DesignSpace`] crosses the hardware axes with
//! [`arch::ModelAxes`] (width/depth multipliers lowered per variant by
//! [`dnn::scale_model`]) for QUIDAM-style hardware × model
//! co-exploration. Pareto fronts are maintained incrementally by
//! [`pareto::ParetoFront`] as points stream out of a campaign, and
//! non-exhaustive [`pareto::Strategy`] walks make million-point spaces
//! tractable. Whole campaigns — space (model axes included), strategy,
//! workload (including user-defined models with declared accuracies),
//! persistence — are declarable as data in QSL spec files ([`spec`]):
//! `qadam run campaign.qsl`. Batches of campaigns — one spec expanding
//! into many via `include`/`override`/`matrix`, or many spec files —
//! run concurrently with cross-campaign cache dedupe through the
//! [`serve`] scheduler: `qadam serve a.qsl b.qsl --out batch/`.
//!
//! See `DESIGN.md` for the module inventory and the per-experiment index.

#![forbid(unsafe_code)]
#![warn(missing_docs)]
// The library-code panic wall (DESIGN.md "Static analysis & lint"):
// fallible paths return the typed `Error`; the few invariant-backed
// exceptions carry a scoped `#[allow]` with the invariant spelled out.
// Test code is exempt via clippy.toml's `allow-*-in-tests` keys.
#![warn(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

pub mod error;
pub mod util;
pub mod tech;
pub mod quant;
pub mod arch;
pub mod synth;
pub mod rtl;
pub mod dnn;
pub mod dataflow;
pub mod energy;
pub mod sim;
pub mod ppa;
pub mod dse;
pub mod pareto;
pub mod accuracy;
pub mod explore;
pub mod obs;
pub mod spec;
pub mod serve;
pub mod coordinator;
pub mod runtime;
pub mod report;
pub mod bench;

pub use error::{Error, Result};
pub use explore::Explorer;
pub use pareto::ParetoFront;
